"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV; a few minutes total on one CPU core.

  PYTHONPATH=src python -m benchmarks.run [table ...]

Tables map to the paper: overhead=Fig2, tts=Fig3, plan_rigor=Figs4-5,
backends=Fig6, radix=Fig7, dtypes=Fig8; kernels, lm_steps and serve are the
beyond-paper extensions (Pallas kernels, LM steps through the same runner,
the FFT serving layer under mixed-shape traffic).
Every table is a declarative :class:`repro.core.suite.SuiteSpec` executed by
the shared ``run_suite`` helper.
"""

from __future__ import annotations

import sys
import time

TABLES = ["overhead", "tts", "plan_rigor", "backends", "radix", "dtypes",
          "kernels", "lm_steps", "serve"]


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    flags = [a for a in argv if a.startswith("-")]
    want = [a for a in argv if not a.startswith("-")] or TABLES
    # validate up front: a typo'd table must not surface as a bare
    # ImportError halfway through a long run
    unknown = sorted(set(want) - set(TABLES))
    if unknown:
        print(f"unknown table(s): {', '.join(unknown)}\n"
              f"available: {', '.join(TABLES)}", file=sys.stderr)
        return 2
    if flags:
        print(f"warning: ignoring unrecognized flag(s): {' '.join(flags)}",
              file=sys.stderr)
    print("name,us_per_call,derived")
    for name in want:
        mod = __import__(f"benchmarks.table_{name}", fromlist=["run"])
        t0 = time.perf_counter()
        mod.run()
        print(f"# table_{name} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
