"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV; a few minutes total on one CPU core.

  PYTHONPATH=src python -m benchmarks.run [table ...]

Tables map to the paper: overhead=Fig2, tts=Fig3, plan_rigor=Figs4-5,
backends=Fig6, radix=Fig7, dtypes=Fig8; kernels + lm_steps are the
beyond-paper extensions (Pallas kernels, LM steps through the same runner).
"""

from __future__ import annotations

import sys
import time

TABLES = ["overhead", "tts", "plan_rigor", "backends", "radix", "dtypes",
          "kernels", "lm_steps"]


def main() -> None:
    want = [a for a in sys.argv[1:] if not a.startswith("-")] or TABLES
    print("name,us_per_call,derived")
    for name in want:
        mod = __import__(f"benchmarks.table_{name}", fromlist=["run"])
        t0 = time.perf_counter()
        mod.run()
        print(f"# table_{name} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
