"""Paper Fig. 3: time-to-solution for powerof2 3D single-precision R2C
out-of-place forward transforms, per backend."""

from __future__ import annotations

from repro.core.benchmark import Benchmark, BenchmarkConfig
from repro.core.client import Context
from repro.core.tree import build_tree
from repro.core.clients.jax_fft import FourStepClient, StockhamClient, XlaFFTClient
from .common import emit


def run(max_exp: int = 5, reps: int = 3) -> None:
    extents = [(2 ** e,) * 3 for e in range(3, max_exp + 1)]
    nodes = build_tree([XlaFFTClient, StockhamClient, FourStepClient], extents,
                       kinds=("Outplace_Real",), precisions=("float",))
    cfg = BenchmarkConfig(warmups=1, repetitions=reps, output="/dev/null")
    writer = Benchmark(Context(), cfg).run_nodes(nodes)
    for (lib, ext, prec, kind, rigor, op, mean, sd, n) in writer.aggregate(op="total"):
        emit(f"tts/{lib}/{ext}", mean * 1e3, f"sd={sd*1e3:.1f}us n={n}")
    for (lib, ext, prec, kind, rigor, op, mean, sd, n) in writer.aggregate(op="execute_forward"):
        emit(f"fft_only/{lib}/{ext}", mean * 1e3, f"sd={sd*1e3:.1f}us")
