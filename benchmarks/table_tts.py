"""Paper Fig. 3: time-to-solution for powerof2 3D single-precision R2C
out-of-place forward transforms, per backend."""

from __future__ import annotations

from dataclasses import replace

from repro.core.suite import SuiteSpec, SweepSpec
from .common import emit, run_suite

BASE = SuiteSpec(clients=("XlaFFT", "Stockham", "FourStep"),
                 kinds=("Outplace_Real",), precisions=("float",),
                 warmups=1, plan_cache=False, output=None)


def run(max_exp: int = 5, reps: int = 3) -> None:
    spec = replace(BASE, repetitions=reps,
                   sweeps=(SweepSpec("powerof2", rank=3,
                                     min_exp=3, max_exp=max_exp),))
    results = run_suite(spec)
    for a in results.aggregate_named(op="total"):
        emit(f"tts/{a.library}/{a.extents}", a.mean * 1e3,
             f"sd={a.sd*1e3:.1f}us n={a.n}")
    for a in results.aggregate_named(op="execute_forward"):
        emit(f"fft_only/{a.library}/{a.extents}", a.mean * 1e3,
             f"sd={a.sd*1e3:.1f}us")
