"""Paper Fig. 8: real vs complex transforms (8a) and single vs double
precision (8b). Expectation: R2C ~2x faster than C2C in the memory-bound
regime; f64 ~2x slower than f32."""

from __future__ import annotations

from dataclasses import replace

from repro.core.suite import SuiteSpec
from .common import emit, run_suite

EXTENTS = ("4096", "65536", "32x32x32")

SPECS = (
    # 8a: real vs complex, single precision
    SuiteSpec(clients=("XlaFFT",), extents=EXTENTS,
              kinds=("Outplace_Real", "Outplace_Complex"),
              precisions=("float",),
              warmups=1, plan_cache=False, output=None),
    # 8b: single vs double, real input
    SuiteSpec(clients=("XlaFFT",), extents=EXTENTS,
              kinds=("Outplace_Real",), precisions=("float", "double"),
              warmups=1, plan_cache=False, output=None),
)


def run(reps: int = 3) -> None:
    for spec in SPECS:
        results = run_suite(replace(spec, repetitions=reps))
        for a in results.aggregate_named(op="execute_forward"):
            emit(f"dtype/{a.kind}/{a.precision}/{a.extents}", a.mean * 1e3)
