"""Paper Fig. 8: real vs complex transforms (8a) and single vs double
precision (8b). Expectation: R2C ~2x faster than C2C in the memory-bound
regime; f64 ~2x slower than f32."""

from __future__ import annotations

from repro.core.benchmark import Benchmark, BenchmarkConfig
from repro.core.client import Context
from repro.core.tree import build_tree
from repro.core.clients.jax_fft import XlaFFTClient
from .common import emit


def run(reps: int = 3) -> None:
    extents = [(4096,), (65536,), (32, 32, 32)]
    # 8a: real vs complex, single precision
    nodes = build_tree([XlaFFTClient], extents,
                       kinds=("Outplace_Real", "Outplace_Complex"),
                       precisions=("float",))
    cfg = BenchmarkConfig(warmups=1, repetitions=reps, output="/dev/null")
    writer = Benchmark(Context(), cfg).run_nodes(nodes)
    for (lib, ext, prec, kind, rg, op, mean, sd, n) in \
            writer.aggregate(op="execute_forward"):
        emit(f"dtype/{kind}/{prec}/{ext}", mean * 1e3)
    # 8b: single vs double, real input
    nodes = build_tree([XlaFFTClient], extents, kinds=("Outplace_Real",),
                       precisions=("float", "double"))
    writer = Benchmark(Context(), cfg).run_nodes(nodes)
    for (lib, ext, prec, kind, rg, op, mean, sd, n) in \
            writer.aggregate(op="execute_forward"):
        emit(f"dtype/{kind}/{prec}/{ext}", mean * 1e3)
