"""Paper Fig. 6: FFT-only runtime per backend, 1D/2D/3D — the
CPU-vs-GPU-library comparison mapped onto our backend set (xla = vendor
library, fourstep = MXU formulation, stockham = butterfly baseline,
stockham_pallas = fused in-VMEM Stockham kernel, sixstep = composed
large-N kernel path, fft2_pallas = fused rank-2 kernel vs the separable
per-axis path; Pallas kernels run in interpret mode off-TPU)."""

from __future__ import annotations

from dataclasses import replace

from repro.core.suite import SuiteSpec
from .common import emit, run_suite

# plan_cache=False preserves the paper's per-run recompile measurement
SPECS = {
    "1d": SuiteSpec(clients=("XlaFFT", "Stockham", "FourStep", "Bluestein",
                             "StockhamPallas", "SixStep"),
                    extents=("256", "4096", "65536"),
                    kinds=("Outplace_Real",), precisions=("float",),
                    warmups=1, plan_cache=False, output=None),
    "2d": SuiteSpec(clients=("XlaFFT", "Stockham", "Fft2Pallas",
                             "StockhamPallas"),
                    extents=("64x64", "256x256"),
                    kinds=("Outplace_Real",), precisions=("float",),
                    warmups=1, plan_cache=False, output=None),
    "3d": SuiteSpec(clients=("XlaFFT", "Stockham", "FourStep", "Bluestein",
                             "StockhamPallas"),
                    extents=("16x16x16", "32x32x32"),
                    kinds=("Outplace_Real",), precisions=("float",),
                    warmups=1, plan_cache=False, output=None),
    # non-pow2 classes: mixed-radix kernel on radix357, fused chirp-Z on
    # oddshape, vs the vendor path and the staged jnp chirp baseline
    "nonpow2": SuiteSpec(clients=("XlaFFT", "StockhamPallas",
                                  "ChirpZPallas", "Bluestein"),
                         extents=("3072", str(19 ** 3)),
                         kinds=("Outplace_Real",), precisions=("float",),
                         warmups=1, plan_cache=False, output=None),
}


def run(reps: int = 3) -> None:
    for tag, spec in SPECS.items():
        results = run_suite(replace(spec, repetitions=reps))
        for a in results.aggregate_named(op="execute_forward"):
            emit(f"backend/{tag}/{a.library}/{a.extents}", a.mean * 1e3)
