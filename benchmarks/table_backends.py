"""Paper Fig. 6: FFT-only runtime per backend, 1D and 3D — the
CPU-vs-GPU-library comparison mapped onto our backend set (xla = vendor
library, fourstep = MXU formulation, stockham = butterfly baseline,
fourstep_pallas = fused kernel in interpret mode off-TPU)."""

from __future__ import annotations

from repro.core.benchmark import Benchmark, BenchmarkConfig
from repro.core.client import Context
from repro.core.tree import build_tree
from repro.core.clients.jax_fft import (BluesteinClient, FourStepClient,
                                        StockhamClient, XlaFFTClient)
from .common import emit


def run(reps: int = 3) -> None:
    clients = [XlaFFTClient, StockhamClient, FourStepClient, BluesteinClient]
    for tag, extents in (("1d", [(256,), (4096,), (65536,)]),
                         ("3d", [(16,) * 3, (32,) * 3])):
        nodes = build_tree(clients, extents, kinds=("Outplace_Real",),
                           precisions=("float",))
        cfg = BenchmarkConfig(warmups=1, repetitions=reps, output="/dev/null")
        writer = Benchmark(Context(), cfg).run_nodes(nodes)
        for (lib, ext, prec, kind, rg, op, mean, sd, n) in \
                writer.aggregate(op="execute_forward"):
            emit(f"backend/{tag}/{lib}/{ext}", mean * 1e3)
