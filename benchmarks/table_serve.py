"""Serving table (beyond-paper): tail latency and coalescing gain for the
FFT service under mixed-shape Zipf traffic.

Three sections:

* ``serve_replay/*`` — a seeded Zipf mix replayed open-loop; per-entry and
  aggregate p50/p95/p99 enqueue→complete latency.
* ``serve_burst/*`` — a same-shape closed-loop burst, coalesced vs. the
  serial FIFO baseline (window 0, max_batch 1); ``speedup`` is the
  throughput ratio the coalescer buys.
* ``serve_suite/*`` — the ServeFFT client through the ordinary Table-1
  timed path, proving the service benches with zero new driver code.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.client import Context
from repro.core.suite import Session, SuiteSpec
from repro.serve import FFTService, ServeConfig, TrafficSpec, replay
from .common import emit

REPLAY = TrafficSpec(extents=((1024,), (4096,), (256,), (64, 64)),
                     kinds=("Outplace_Complex", "Outplace_Real"),
                     precisions=("float",), requests=96, rate_hz=300.0,
                     zipf_s=1.1, seed=2017)


def _burst(config: ServeConfig, n_requests: int, payload: np.ndarray) -> dict:
    """Closed-loop same-shape burst; returns the service report."""
    with FFTService(config=config) as svc:
        # pay the bucket-ladder compiles outside the measured window
        svc.prewarm(payload.shape)
        t0 = time.perf_counter()
        reqs = svc.submit_many([payload] * n_requests)
        for r in reqs:
            r.result(timeout=600)
        wall = time.perf_counter() - t0
    rep = svc.report()
    rep["burst_wall_s"] = wall
    rep["burst_rps"] = n_requests / wall
    return rep


def run(requests: int = 96, burst: int = 128) -> None:
    # --- Zipf mixed-shape replay ------------------------------------------
    spec = REPLAY if requests == REPLAY.requests \
        else TrafficSpec(**{**REPLAY.to_dict(), "requests": requests})
    with FFTService(config=ServeConfig(coalesce_window_ms=2.0,
                                       max_batch=16)) as svc:
        for ext, kind, prec in spec.mix():
            svc.prewarm(ext, kind, prec)
        rep = replay(svc, spec)
    svc_rep = rep.service
    lat = svc_rep.get("latency_ms", {})
    emit("serve_replay/p50", lat.get("p50", 0.0) * 1e3,
         f"p95={lat.get('p95', 0.0):.1f}ms p99={lat.get('p99', 0.0):.1f}ms")
    emit("serve_replay/rps", svc_rep["rps"],
         f"coalesce_rate={svc_rep['coalesce_rate']:.2f} "
         f"batches={svc_rep['batches']}/{svc_rep['completed']}")
    for m in rep.per_mix:
        l = m.get("latency_ms", {})
        emit(f"serve_replay/{m['extents']}/{m['kind']}",
             l.get("p50", 0.0) * 1e3,
             f"n={m['requests']} p99={l.get('p99', 0.0):.1f}ms")

    # --- coalesced vs serial same-shape burst ------------------------------
    x = ((np.arange(4096) % 512) / 512.0).astype(np.complex64)
    serial = _burst(ServeConfig(coalesce_window_ms=0.0, max_batch=1,
                                inflight=1, backend="xla"), burst, x)
    coalesced = _burst(ServeConfig(coalesce_window_ms=5.0, max_batch=32,
                                   backend="xla"), burst, x)
    speedup = coalesced["burst_rps"] / serial["burst_rps"]
    emit("serve_burst/serial", serial["burst_wall_s"] * 1e6,
         f"rps={serial['burst_rps']:.0f}")
    emit("serve_burst/coalesced", coalesced["burst_wall_s"] * 1e6,
         f"rps={coalesced['burst_rps']:.0f} speedup={speedup:.1f}x "
         f"batches={coalesced['batches']}")

    # --- ServeFFT through the ordinary suite -------------------------------
    suite = SuiteSpec(clients=("ServeFFT",), extents=((1024,),),
                      kinds=("Outplace_Complex",), precisions=("float",),
                      warmups=1, repetitions=3, output=None)
    rs = Session(context=Context({"serve_burst": 8})).run(suite)
    for a in rs.aggregate_named(op="execute_forward", percentiles=True):
        emit(f"serve_suite/{a.library}/{a.extents}", a.mean * 1e3,
             f"p50={a.p50*1e3:.0f}us p99={a.p99*1e3:.0f}us n={a.n}")
