"""The framework integration table: LM train/serve steps measured through
the SAME gearshifft Runner/OpSchedule that drives the FFT clients
(DESIGN.md §3) — reduced configs on CPU; the full configs are exercised by
the dry-run.

Each (arch, mode) pair is a registered client whose Table-1 ops map onto the
LM workload: allocate = params/optimizer/cache init, upload = host batch to
device, init_forward = AOT compile of the step (prefill for decode),
execute_forward = one train/decode step, download = fetch the loss/logits.
The plan/executable cache memoizes the compiled step so warm repetitions
measure pure step dispatch, exactly like warm FFT repetitions.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np
import jax

from repro.configs.base import get_config
from repro.core.client import Context, Problem
from repro.core.plan import PlanCache, cached_build, executable_bytes
from repro.core.registry import register_client
from repro.core.schedule import OpSchedule, OpStep
from repro.core.suite import SuiteSpec
from repro.core.wisdom import Wisdom
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.model import Model
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.trainer import build_train_step
from .common import emit, run_suite

ARCHS = ["qwen3-1.7b", "granite-moe-1b-a400m", "xlstm-350m", "hymba-1.5b"]
SEQ_LEN = 64
BATCH = 4

#: LM steps have no inverse transform — their schedule says so, and the
#: shared Runner drives it with the same per-op timers.
LM_SCHEDULE = OpSchedule("lm_step", (
    OpStep("allocate", "allocate", bytes_method="get_alloc_size"),
    OpStep("upload", "upload", needs_input=True,
           bytes_method="get_transfer_size"),
    OpStep("init_forward", "init_forward", bytes_method="get_plan_size"),
    OpStep("execute_forward", "execute_forward"),
    OpStep("download", "download", captures_output=True),
    OpStep("destroy", "destroy"),
))


class LMStepClient:
    """Generic (non-FFT) client: one LM step behind the Table-1 protocol."""

    title = "LMStep"
    arch = "qwen3-1.7b"
    mode = "train"          # 'train' | 'decode'
    schedule = LM_SCHEDULE

    def __init__(self, problem: Problem, context: Context, rigor=None,
                 wisdom: Wisdom | None = None,
                 plan_cache: PlanCache | None = None):
        self.problem = problem
        self.context = context
        self.plan_cache = plan_cache
        self.cache_events: dict[str, str] = {}
        self.cfg = get_config(self.arch).reduced()
        self.model = Model(self.cfg, remat=False)
        self.params = None
        self.opt = None
        self.cache = None
        self.batch = None
        self._compiled = None
        self._out = None
        self._plan_bytes = 0
        # sizes are snapshotted when the state exists — the Runner queries
        # byte accessors after destroy() has dropped the live references
        self._alloc_bytes = 0
        self._transfer_bytes = 0

    # --- host input / validation hooks ------------------------------------
    @classmethod
    def make_host_input(cls, problem: Problem, seed: int) -> dict:
        cfg = get_config(cls.arch).reduced()
        data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=problem.extents[0],
                                          global_batch=problem.batch,
                                          n_codebooks=cfg.n_codebooks))
        return data.batch(seed % 1000)

    @classmethod
    def check(cls, problem, host_in, out, error_bound):
        ok = bool(np.all(np.isfinite(np.asarray(out))))
        return ok, "" if ok else "non-finite step output"

    # --- memory -----------------------------------------------------------
    def allocate(self) -> None:
        self.params = self.model.init_params(jax.random.PRNGKey(0))
        if self.mode == "train":
            self.opt = init_opt_state(self.params)
        else:
            self.cache = self.model.init_cache(self.problem.batch,
                                               self.problem.extents[0] + 32)
        jax.block_until_ready(self.params)
        self._alloc_bytes = int(sum(
            a.size * a.dtype.itemsize
            for a in jax.tree_util.tree_leaves(self.params)))

    def destroy(self) -> None:
        self.params = self.opt = self.cache = self.batch = None
        self._compiled = self._out = None

    def get_alloc_size(self) -> int:
        return self._alloc_bytes

    def get_transfer_size(self) -> int:
        return self._transfer_bytes

    def get_plan_size(self) -> int:
        return self._plan_bytes

    # --- transfer ---------------------------------------------------------
    def upload(self, host_batch: dict) -> None:
        self._transfer_bytes = int(sum(
            np.asarray(a).nbytes
            for a in jax.tree_util.tree_leaves(host_batch)))
        self.batch = jax.device_put(host_batch)
        jax.block_until_ready(self.batch)

    def download(self) -> np.ndarray:
        return np.asarray(self._out)

    # --- planning ---------------------------------------------------------
    def _aot(self, tag: str, fn, *args):
        """AOT lower+compile, memoized per (device, arch, mode) when a plan
        cache is attached — warm repetitions skip the recompile."""
        key = PlanCache.executable_key(
            getattr(self.context, "device_kind", "?"), self.problem,
            f"lm_{self.mode}[{self.arch}]", tag)
        return cached_build(self.plan_cache, self.cache_events,
                            "init_forward", key,
                            lambda: jax.jit(fn).lower(*args).compile())

    def init_forward(self) -> None:
        if self.mode == "train":
            step = build_train_step(self.model, OptConfig())
            self._compiled = self._aot("forward", step, self.params,
                                       self.opt, self.batch)
            self._plan_bytes = executable_bytes(self._compiled)
        else:
            # serve path setup: prefill the KV cache, then AOT the decode step
            _, self.cache = jax.jit(self.model.prefill)(
                self.params, self.batch["tokens"], self.cache)
            tok = self.batch["tokens"][:, :1]
            pos = jax.numpy.asarray(self.problem.extents[0])
            dec = lambda p, t, c, q: self.model.decode_step(p, t, c, q)[0]
            self._compiled = self._aot("forward", dec, self.params, tok,
                                       self.cache, pos)
            self._plan_bytes = executable_bytes(self._compiled)

    # --- execution --------------------------------------------------------
    def execute_forward(self) -> None:
        if self.mode == "train":
            _, _, metrics = self._compiled(self.params, self.opt, self.batch)
            self._out = metrics["loss"]
        else:
            tok = self.batch["tokens"][:, :1]
            pos = jax.numpy.asarray(self.problem.extents[0])
            self._out = self._compiled(self.params, tok, self.cache, pos)
        jax.block_until_ready(self._out)


def _registered(arch: str, mode: str) -> type:
    name = f"LM{'Train' if mode == 'train' else 'Decode'}-{arch}"
    cls = type(name.replace("-", "_").replace(".", "_"), (LMStepClient,),
               {"title": name, "arch": arch, "mode": mode})
    return register_client()(cls)


CLIENTS = {(a, m): _registered(a, m) for a in ARCHS for m in ("train", "decode")}

#: Declarative spec: clients by registered name, extents = the sequence
#: length, batch = the LM batch.  plan_cache=True memoizes the compiled step
#: so warm repetitions measure pure step dispatch.
SPEC = SuiteSpec(clients=tuple(CLIENTS[(a, m)].title
                               for a in ARCHS for m in ("train", "decode")),
                 extents=(str(SEQ_LEN),), kinds=("Outplace_Real",),
                 precisions=("float",), batch=BATCH,
                 warmups=1, plan_cache=True, output=None)


def run(reps: int = 3) -> None:
    results = run_suite(replace(SPEC, repetitions=reps))
    for a in results.aggregate_named(op="execute_forward"):
        lib = a.library
        mode, arch = ("train", lib[len("LMTrain-"):]) \
            if lib.startswith("LMTrain-") else ("decode", lib[len("LMDecode-"):])
        emit(f"lm/{mode}_step/{arch}", a.mean * 1e3,
             f"reduced b{BATCH}s{SEQ_LEN}")
