"""The framework integration table: LM train/serve steps measured through
the SAME gearshifft runner that measures FFT clients (DESIGN.md §3) —
reduced configs on CPU; the full configs are exercised by the dry-run."""

from __future__ import annotations

import jax

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.model import Model
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.trainer import build_train_step
from .common import emit, time_fn

ARCHS = ["qwen3-1.7b", "granite-moe-1b-a400m", "xlstm-350m", "hymba-1.5b"]


def run(reps: int = 3) -> None:
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        model = Model(cfg, remat=False)
        params = model.init_params(jax.random.PRNGKey(0))
        data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=64, global_batch=4,
                                          n_codebooks=cfg.n_codebooks))
        batch = data.batch(0)
        step = jax.jit(build_train_step(model, OptConfig()))
        opt = init_opt_state(params)
        us = time_fn(lambda p, o, b: step(p, o, b)[2]["loss"],
                     params, opt, batch, reps=reps)
        emit(f"lm/train_step/{arch}", us, "reduced b4s64")

        cache = model.init_cache(4, 96)
        _, cache = jax.jit(model.prefill)(params, batch["tokens"], cache)
        dec = jax.jit(model.decode_step)
        tok = batch["tokens"][:, :1]
        us = time_fn(lambda p, t, c: dec(p, t, c, jax.numpy.asarray(64))[0],
                     params, tok, cache, reps=reps)
        emit(f"lm/decode_step/{arch}", us, "reduced b4")
