"""Shared benchmark plumbing: every table declares a
:class:`repro.core.suite.SuiteSpec` and runs it through :func:`run_suite`;
results print as ``name,us_per_call,derived`` CSV rows (one per measured
configuration) to stdout."""

from __future__ import annotations

import statistics
import time

import numpy as np
import jax

from repro.core.suite import run_suite  # noqa: F401  (shared by every table)


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.2f},{derived}")


def time_fn(fn, *args, reps: int = 5, warmup: int = 2) -> float:
    """Median wall time in microseconds, device-synchronized."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(ts)


def seesaw(shape, dtype=np.float32):
    n = int(np.prod(shape))
    return ((np.arange(n) % 512) / 512.0).reshape(shape).astype(dtype)


def rand_complex(shape, dtype=np.complex64, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)).astype(dtype)
