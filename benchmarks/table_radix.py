"""Paper Fig. 7: powerof2 vs radix357 vs oddshape extent classes.
powerof2 should win; bluestein covers oddshape everywhere (cuFFT analogue),
the planner (PlannedClient) picks the best feasible backend per class."""

from __future__ import annotations

from repro.core.benchmark import Benchmark, BenchmarkConfig
from repro.core.client import Context
from repro.core.extents import classify
from repro.core.tree import build_tree
from repro.core.clients.jax_fft import PlannedClient, XlaFFTClient
from .common import emit


def run(reps: int = 3) -> None:
    extents = [(1024,), (960,), (19 * 19,),          # 1D per class
               (16, 16, 16), (12, 12, 12), (19, 19, 19)]
    nodes = build_tree([XlaFFTClient, PlannedClient], extents,
                       kinds=("Outplace_Real",), precisions=("float",))
    cfg = BenchmarkConfig(warmups=1, repetitions=reps, output="/dev/null")
    writer = Benchmark(Context(), cfg).run_nodes(nodes)
    for (lib, ext, prec, kind, rg, op, mean, sd, n) in \
            writer.aggregate(op="execute_forward"):
        cls = classify(tuple(int(v) for v in ext.split("x")))
        emit(f"radix/{cls}/{lib}/{ext}", mean * 1e3)
