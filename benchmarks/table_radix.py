"""Paper Fig. 7: powerof2 vs radix357 vs oddshape extent classes.
powerof2 should win; bluestein covers oddshape everywhere (cuFFT analogue),
the planner (PlannedClient) picks the best feasible backend per class."""

from __future__ import annotations

from dataclasses import replace

from repro.core.extents import classify
from repro.core.suite import SuiteSpec
from .common import emit, run_suite

SPEC = SuiteSpec(clients=("XlaFFT", "Planned", "ChirpZPallas"),
                 extents=("1024", "960", str(19 * 19),        # 1D per class
                          "16x16x16", "12x12x12", "19x19x19"),
                 kinds=("Outplace_Real",), precisions=("float",),
                 warmups=1, plan_cache=False, output=None)


def run(reps: int = 3) -> None:
    results = run_suite(replace(SPEC, repetitions=reps))
    for a in results.aggregate_named(op="execute_forward"):
        cls = classify(tuple(int(v) for v in a.extents.split("x")))
        emit(f"radix/{cls}/{a.library}/{a.extents}", a.mean * 1e3)
