"""Paper Fig. 2: framework measurement overhead.

Compares the gearshifft-framework-measured round-trip time against a
standalone single-timer loop over the same compiled executables
(standalone-tts) for two signal sizes. Paper claim: overhead < 2%,
shrinking with size.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.core.benchmark import Benchmark, BenchmarkConfig, make_input
from repro.core.client import Context, Problem
from repro.core.tree import build_tree
from repro.core.clients.jax_fft import XlaFFTClient, _forward_fn, _inverse_fn
from repro.core.plan import Candidate
from .common import emit


def _standalone_tts(problem: Problem, reps: int) -> float:
    """One timer around the whole round trip (paper's standalone-tts)."""
    cand = Candidate("xla")
    fwd = jax.jit(_forward_fn(problem, cand))
    inv = jax.jit(_inverse_fn(problem, cand))
    x = jax.device_put(make_input(problem, 0))
    jax.block_until_ready(inv(fwd(x)))  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        y = inv(fwd(jax.device_put(np.asarray(x))))
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / reps * 1e6


def run(reps: int = 5) -> None:
    for ext in [(32, 32, 32), (64, 64, 64)]:
        problem = Problem(ext, "Inplace_Real", "float")
        nodes = build_tree([XlaFFTClient], [ext], kinds=("Inplace_Real",),
                           precisions=("float",))
        cfg = BenchmarkConfig(warmups=2, repetitions=reps, output="/dev/null")
        writer = Benchmark(Context(), cfg).run_nodes(nodes)
        # framework view: sum of measured per-op times (upload..download)
        per_run = {}
        for r in writer.rows:
            if r.op in ("upload", "execute_forward", "execute_inverse",
                        "download"):
                per_run.setdefault(r.run, 0.0)
                per_run[r.run] += r.time_ms
        fw_us = 1e3 * np.mean(list(per_run.values()))
        sa_us = _standalone_tts(problem, reps)
        name = "x".join(map(str, ext))
        emit(f"overhead/framework/{name}", fw_us, "per-op timers")
        emit(f"overhead/standalone_tts/{name}", sa_us, "single timer")
        emit(f"overhead/ratio/{name}", fw_us / sa_us * 100, "percent")
