"""Paper Fig. 2: framework measurement overhead.

Compares the gearshifft-framework-measured round-trip time against a
standalone single-timer loop over the same compiled executables
(standalone-tts) for two signal sizes. Paper claim: overhead < 2%,
shrinking with size.
"""

from __future__ import annotations

import time
from dataclasses import replace

import numpy as np
import jax

from repro.core.benchmark import make_input
from repro.core.client import Problem
from repro.core.clients.jax_fft import _forward_fn, _inverse_fn
from repro.core.plan import Candidate
from repro.core.suite import SuiteSpec
from .common import emit, run_suite

SPEC = SuiteSpec(clients=("XlaFFT",), kinds=("Inplace_Real",),
                 precisions=("float",), warmups=2, plan_cache=False,
                 output=None)


def _standalone_tts(problem: Problem, reps: int) -> float:
    """One timer around the whole round trip (paper's standalone-tts)."""
    cand = Candidate("xla")
    fwd = jax.jit(_forward_fn(problem, cand))
    inv = jax.jit(_inverse_fn(problem, cand))
    x = jax.device_put(make_input(problem, 0))
    jax.block_until_ready(inv(fwd(x)))  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        y = inv(fwd(jax.device_put(np.asarray(x))))
    jax.block_until_ready(y)
    return (time.perf_counter() - t0) / reps * 1e6


def run(reps: int = 5) -> None:
    for ext in [(32, 32, 32), (64, 64, 64)]:
        name = "x".join(map(str, ext))
        results = run_suite(replace(SPEC, extents=(name,), repetitions=reps))
        # framework view: sum of measured per-op times (upload..download)
        per_run: dict[int, float] = {}
        for op in ("upload", "execute_forward", "execute_inverse", "download"):
            for r in results.query(op=op):
                per_run[r.run] = per_run.get(r.run, 0.0) + r.time_ms
        fw_us = 1e3 * np.mean(list(per_run.values()))
        sa_us = _standalone_tts(Problem(ext, "Inplace_Real", "float"), reps)
        emit(f"overhead/framework/{name}", fw_us, "per-op timers")
        emit(f"overhead/standalone_tts/{name}", sa_us, "single timer")
        emit(f"overhead/ratio/{name}", fw_us / sa_us * 100, "percent")
