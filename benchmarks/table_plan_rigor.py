"""Paper Figs. 4-5: plan-rigor trade-offs — planning time vs transform time
for ESTIMATE (hand-written *and* fitted cost model) / MEASURE / WISDOM_ONLY
(wisdom pre-generated like fftwf-wisdom, via the planner's ``near=False``
sweep — the same path ``tools/pregen_wisdom.py`` drives offline)."""

from __future__ import annotations

import os
import tempfile
from dataclasses import replace

from repro.core.client import Problem
from repro.core.extents import parse_extents
from repro.core.plan import PlanRigor, make_plan
from repro.core.suite import SuiteSpec
from repro.core.wisdom import Wisdom
from .common import emit, run_suite

EXTENTS = ("256", "2048", "16x16x16", "32x32x32")

#: The committed fitted coefficient table (CI CPU device kind).  When it
#: exists, the table gains an ``estimate_fitted`` column: the same instant
#: ESTIMATE heuristic, ranked by regressed per-device coefficients instead
#: of the hand-written defaults — the Fig. 4-5 story with a calibrated
#: model in the loop.
FITTED_TABLE = os.path.join(os.path.dirname(__file__), "baselines",
                            "costmodel_cpu.json")

# plan_cache=False: every repetition re-plans, the honest Figs. 4-5 cost
SPEC = SuiteSpec(clients=("Planned",), extents=EXTENTS,
                 kinds=("Inplace_Real",), precisions=("float",),
                 warmups=1, plan_cache=False, output=None)


def _pregenerate(exts, path: str) -> None:
    """MEASURE-sweep every extent into a wisdom pack (``near=False``: a
    pregeneration run must not inherit a neighbor's pick)."""
    import jax

    from repro.core.clients.jax_fft import build_forward

    wisdom = Wisdom(path, device_kind=jax.devices()[0].device_kind)
    for ext in exts:
        problem = Problem(tuple(ext), "Inplace_Real", "float")
        make_plan(problem, PlanRigor.MEASURE,
                  build=lambda c, p=problem: build_forward(p, c),
                  wisdom=wisdom, near=False)
    wisdom.save()


def _emit_rigor(label: str, results) -> None:
    for a in results.aggregate_named(op="init_forward"):
        emit(f"plan_time/{label}/{a.extents}", a.mean * 1e3)
    for a in results.aggregate_named(op="execute_forward"):
        emit(f"fft_time/{label}/{a.extents}", a.mean * 1e3)


def run(reps: int = 3) -> None:
    exts = [parse_extents(e) for e in EXTENTS]
    with tempfile.TemporaryDirectory() as td:
        wpath = os.path.join(td, "wisdom.json")
        _pregenerate(exts, wpath)
        for rigor in (PlanRigor.ESTIMATE, PlanRigor.MEASURE,
                      PlanRigor.WISDOM_ONLY):
            # wisdom only for the WISDOM_ONLY column: MEASURE with wisdom
            # attached would short-circuit the sweep (fftw semantics) and
            # report wisdom-lookup time instead of the honest Fig. 4-5 cost
            spec = replace(SPEC, repetitions=reps, rigor=rigor.value,
                           wisdom=wpath if rigor is PlanRigor.WISDOM_ONLY
                           else None)
            _emit_rigor(rigor.value, run_suite(spec))
    if os.path.exists(FITTED_TABLE):
        spec = replace(SPEC, repetitions=reps,
                       rigor=PlanRigor.ESTIMATE.value,
                       costmodel=FITTED_TABLE)
        _emit_rigor("estimate_fitted", run_suite(spec))
