"""Paper Figs. 4-5: plan-rigor trade-offs — planning time vs transform time
for ESTIMATE / MEASURE / WISDOM_ONLY (wisdom pre-generated like
fftwf-wisdom)."""

from __future__ import annotations

import os
import tempfile

from repro.core.benchmark import Benchmark, BenchmarkConfig
from repro.core.client import Context
from repro.core.plan import PlanRigor
from repro.core.tree import build_tree
from repro.core.wisdom import generate
from repro.core.clients.jax_fft import PlannedClient
from .common import emit


def run(reps: int = 3) -> None:
    extents = [(256,), (2048,), (16, 16, 16), (32, 32, 32)]
    with tempfile.TemporaryDirectory() as td:
        wpath = os.path.join(td, "wisdom.json")
        wisdom = generate(extents, wpath, rigor=PlanRigor.MEASURE,
                          kinds=("Inplace_Real",))
        for rigor in (PlanRigor.ESTIMATE, PlanRigor.MEASURE,
                      PlanRigor.WISDOM_ONLY):
            nodes = build_tree([PlannedClient], extents,
                               kinds=("Inplace_Real",), precisions=("float",))
            cfg = BenchmarkConfig(warmups=1, repetitions=reps, rigor=rigor,
                                  output="/dev/null")
            writer = Benchmark(Context(), cfg).run_nodes(nodes, wisdom=wisdom)
            for (lib, ext, prec, kind, rg, op, mean, sd, n) in \
                    writer.aggregate(op="init_forward"):
                emit(f"plan_time/{rigor.value}/{ext}", mean * 1e3)
            for (lib, ext, prec, kind, rg, op, mean, sd, n) in \
                    writer.aggregate(op="execute_forward"):
                emit(f"fft_time/{rigor.value}/{ext}", mean * 1e3)
