"""Paper Figs. 4-5: plan-rigor trade-offs — planning time vs transform time
for ESTIMATE / MEASURE / WISDOM_ONLY (wisdom pre-generated like
fftwf-wisdom)."""

from __future__ import annotations

import os
import tempfile
from dataclasses import replace

from repro.core.extents import parse_extents
from repro.core.plan import PlanRigor
from repro.core.suite import SuiteSpec
from repro.core.wisdom import generate
from .common import emit, run_suite

EXTENTS = ("256", "2048", "16x16x16", "32x32x32")

# plan_cache=False: every repetition re-plans, the honest Figs. 4-5 cost
SPEC = SuiteSpec(clients=("Planned",), extents=EXTENTS,
                 kinds=("Inplace_Real",), precisions=("float",),
                 warmups=1, plan_cache=False, output=None)


def run(reps: int = 3) -> None:
    exts = [parse_extents(e) for e in EXTENTS]
    with tempfile.TemporaryDirectory() as td:
        wpath = os.path.join(td, "wisdom.json")
        generate(exts, wpath, rigor=PlanRigor.MEASURE, kinds=("Inplace_Real",))
        for rigor in (PlanRigor.ESTIMATE, PlanRigor.MEASURE,
                      PlanRigor.WISDOM_ONLY):
            # wisdom only for the WISDOM_ONLY column: MEASURE with wisdom
            # attached would short-circuit the sweep (fftw semantics) and
            # report wisdom-lookup time instead of the honest Fig. 4-5 cost
            spec = replace(SPEC, repetitions=reps, rigor=rigor.value,
                           wisdom=wpath if rigor is PlanRigor.WISDOM_ONLY
                           else None)
            results = run_suite(spec)
            for a in results.aggregate_named(op="init_forward"):
                emit(f"plan_time/{rigor.value}/{a.extents}", a.mean * 1e3)
            for a in results.aggregate_named(op="execute_forward"):
                emit(f"fft_time/{rigor.value}/{a.extents}", a.mean * 1e3)
