"""Beyond-paper: Pallas kernel micro-benchmarks (interpret mode off-TPU —
numbers are correctness-path timings; the roofline table speaks for TPU) and
the fused-fftconv vs unfused comparison that motivates the kernel."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.fft import fftconv as fftconv_mod
from repro.kernels.fftconv import ops as conv_ops
from repro.kernels.fft4step import ops as fs_ops
from .common import emit, time_fn, rand_complex


def run(reps: int = 3) -> None:
    x = jnp.asarray(rand_complex((8, 4096)))
    emit("kernel/fft4step_interp/4096x8",
         time_fn(lambda v: fs_ops.fft(v, interpret=True), x, reps=reps))
    emit("kernel/fourstep_jnp/4096x8",
         time_fn(lambda v: __import__("repro.fft.fourstep", fromlist=["fft"]).fft(v),
                 x, reps=reps))

    c, b, L, K = 4, 4, 2048, 64
    xs = jnp.asarray(np.random.default_rng(0).standard_normal((c, b, L)),
                     jnp.float32)
    h = jnp.asarray(np.random.default_rng(1).standard_normal((c, K)),
                    jnp.float32)
    emit("kernel/fftconv_fused_interp/2048",
         time_fn(lambda a, f: conv_ops.fftconv(a, f, interpret=True), xs, h,
                 reps=reps))
    # unfused jnp path on the same workload (x as (B, L, D) layout)
    xt = jnp.moveaxis(xs.reshape(c * b, L)[None], -1, 1).reshape(1, L, c * b)
    ht = jnp.repeat(h, b, axis=0).T
    emit("kernel/fftconv_unfused_xla/2048",
         time_fn(lambda a, f: fftconv_mod.fftconv(a, f, backend="xla"), xt, ht,
                 reps=reps))
