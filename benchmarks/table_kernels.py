"""Beyond-paper: Pallas kernel micro-benchmarks (interpret mode off-TPU —
numbers are correctness-path timings; the roofline table speaks for TPU) and
the fused-fftconv vs unfused comparison that motivates the kernel.

Each kernel variant is a registered client behind a minimal op schedule
(allocate → upload → execute_forward → download → destroy), so the table is
a declarative spec through the shared engine like every other table.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.client import Context, Problem
from repro.core.registry import register_client
from repro.core.schedule import OpSchedule, OpStep
from repro.core.suite import SuiteSpec
from .common import emit, rand_complex, run_suite

#: Direct-call micro-benchmarks: no separate planning/inverse ops.
KERNEL_SCHEDULE = OpSchedule("kernel", (
    OpStep("allocate", "allocate"),
    OpStep("upload", "upload", needs_input=True,
           bytes_method="get_transfer_size"),
    OpStep("execute_forward", "execute_forward"),
    OpStep("download", "download", captures_output=True),
    OpStep("destroy", "destroy"),
))


class KernelClient:
    """One kernel variant behind the minimal schedule; subclasses implement
    ``make_host_input`` and ``_call``."""

    title = "kernel"
    schedule = KERNEL_SCHEDULE

    def __init__(self, problem: Problem, context: Context, rigor=None,
                 wisdom=None, plan_cache=None):
        self.problem = problem
        self.context = context
        self.cache_events: dict[str, str] = {}
        self._args = None
        self._out = None
        self._nbytes = 0

    @classmethod
    def check(cls, problem, host_in, out, error_bound):
        ok = bool(np.all(np.isfinite(np.asarray(out))))
        return ok, "" if ok else "non-finite kernel output"

    def allocate(self) -> None:
        pass

    def destroy(self) -> None:
        self._args = self._out = None

    def get_transfer_size(self) -> int:
        return self._nbytes

    def upload(self, host_args) -> None:
        self._nbytes = sum(np.asarray(a).nbytes for a in host_args)
        self._args = tuple(jax.device_put(a) for a in host_args)
        jax.block_until_ready(self._args)

    def execute_forward(self) -> None:
        self._out = self._call(*self._args)
        jax.block_until_ready(self._out)

    def download(self) -> np.ndarray:
        return np.asarray(self._out)

    def _call(self, *args):
        raise NotImplementedError


@register_client()
class Fft4StepInterpKernel(KernelClient):
    title = "KernelFft4StepInterp"

    @classmethod
    def make_host_input(cls, problem: Problem, seed: int):
        return (rand_complex((problem.batch, problem.extents[0]), seed=seed),)

    def _call(self, x):
        from repro.kernels.fft4step import ops as fs_ops
        return fs_ops.fft(x, interpret=True)


@register_client()
class FourStepJnpKernel(KernelClient):
    title = "KernelFourStepJnp"

    @classmethod
    def make_host_input(cls, problem: Problem, seed: int):
        return (rand_complex((problem.batch, problem.extents[0]), seed=seed),)

    def _call(self, x):
        from repro.fft import fourstep
        return fourstep.fft(x)


@register_client()
class StockhamPallasKernel(KernelClient):
    title = "KernelStockhamPallasInterp"

    @classmethod
    def make_host_input(cls, problem: Problem, seed: int):
        return (rand_complex((problem.batch, problem.extents[0]), seed=seed),)

    def _call(self, x):
        from repro.kernels.stockham_pallas import ops as sp_ops
        return sp_ops.fft(x, interpret=True)


@register_client()
class StockhamJnpKernel(KernelClient):
    title = "KernelStockhamJnp"

    @classmethod
    def make_host_input(cls, problem: Problem, seed: int):
        return (rand_complex((problem.batch, problem.extents[0]), seed=seed),)

    def _call(self, x):
        from repro.fft import stockham
        return stockham.fft(x)


@register_client()
class Fft2PallasKernel(KernelClient):
    """Fused rank-2 kernel: whole n1 x n2 tile in VMEM, one HBM touch."""
    title = "KernelFft2PallasInterp"

    @classmethod
    def make_host_input(cls, problem: Problem, seed: int):
        return (rand_complex((problem.batch, *problem.extents), seed=seed),)

    def _call(self, x):
        from repro.kernels.fft2_pallas import ops as f2_ops
        return f2_ops.fft2(x, interpret=True)


@register_client()
class Fft2SeparableKernel(KernelClient):
    """The same 2D transform as two fused 1-D kernel passes + swapaxes —
    what the planner's separable path pays when fft2_pallas is off."""
    title = "KernelFft2Separable"

    @classmethod
    def make_host_input(cls, problem: Problem, seed: int):
        return (rand_complex((problem.batch, *problem.extents), seed=seed),)

    def _call(self, x):
        from repro.fft import nd
        from repro.kernels.stockham_pallas import ops as sp_ops
        return nd.fftn(
            x, lambda v, inverse=False: sp_ops.fft(v, inverse=inverse,
                                                   interpret=True),
            axes=(-2, -1))


# fused-vs-unfused fftconv workload: c channels, b batch, length L, taps K
C, B, K = 4, 4, 64


@register_client()
class FftconvFusedKernel(KernelClient):
    title = "KernelFftconvFused"

    @classmethod
    def make_host_input(cls, problem: Problem, seed: int):
        L = problem.extents[0]
        xs = np.random.default_rng(0).standard_normal((C, B, L)).astype(np.float32)
        h = np.random.default_rng(1).standard_normal((C, K)).astype(np.float32)
        return (xs, h)

    def _call(self, xs, h):
        from repro.kernels.fftconv import ops as conv_ops
        return conv_ops.fftconv(xs, h, interpret=True)


@register_client()
class FftconvUnfusedKernel(KernelClient):
    title = "KernelFftconvUnfused"

    @classmethod
    def make_host_input(cls, problem: Problem, seed: int):
        L = problem.extents[0]
        xs = np.random.default_rng(0).standard_normal((C, B, L)).astype(np.float32)
        h = np.random.default_rng(1).standard_normal((C, K)).astype(np.float32)
        # same workload in the unfused path's (B, L, D) layout
        xt = np.moveaxis(xs.reshape(C * B, L)[None], -1, 1).reshape(1, L, C * B)
        ht = np.repeat(h, B, axis=0).T
        return (np.ascontiguousarray(xt), np.ascontiguousarray(ht))

    def _call(self, xt, ht):
        from repro.fft import fftconv as fftconv_mod
        return fftconv_mod.fftconv(jnp.asarray(xt), jnp.asarray(ht),
                                   backend="xla")


SPECS = (
    SuiteSpec(clients=("KernelFft4StepInterp", "KernelFourStepJnp",
                       "KernelStockhamPallasInterp", "KernelStockhamJnp"),
              extents=("4096",), batch=8,
              kinds=("Outplace_Complex",), precisions=("float",),
              warmups=2, plan_cache=False, output=None),
    SuiteSpec(clients=("KernelFftconvFused", "KernelFftconvUnfused"),
              extents=("2048",), batch=1,
              kinds=("Outplace_Real",), precisions=("float",),
              warmups=2, plan_cache=False, output=None),
    SuiteSpec(clients=("KernelFft2PallasInterp", "KernelFft2Separable"),
              extents=("64x64",), batch=4,
              kinds=("Outplace_Complex",), precisions=("float",),
              warmups=2, plan_cache=False, output=None),
)

#: client title -> the table row name (kept from the pre-spec version)
NAMES = {
    "KernelFft4StepInterp": "kernel/fft4step_interp/4096x8",
    "KernelFourStepJnp": "kernel/fourstep_jnp/4096x8",
    "KernelStockhamPallasInterp": "kernel/stockham_pallas_interp/4096x8",
    "KernelStockhamJnp": "kernel/stockham_jnp/4096x8",
    "KernelFftconvFused": "kernel/fftconv_fused_interp/2048",
    "KernelFftconvUnfused": "kernel/fftconv_unfused_xla/2048",
    "KernelFft2PallasInterp": "kernel/fft2_pallas_interp/64x64x4",
    "KernelFft2Separable": "kernel/fft2_separable_interp/64x64x4",
}


def run(reps: int = 3) -> None:
    for spec in SPECS:
        results = run_suite(replace(spec, repetitions=reps))
        for a in results.aggregate_named(op="execute_forward"):
            emit(NAMES.get(a.library, f"kernel/{a.library}/{a.extents}"),
                 a.mean * 1e3)
