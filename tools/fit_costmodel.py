#!/usr/bin/env python
"""Fit per-device cost-model coefficients from measured trajectory data.

The planner's ESTIMATE rigor ranks candidates with the hand-written
bytes-moved table in ``repro.core.costmodel``.  This tool regresses that
table against reality: it pools every measured (problem, backend, time)
observation it can find — grid rows of ``BENCH_*.json`` trajectory
documents plus ``measured_ms`` provenance from schema-v3 wisdom packs —
and calibrates one multiplicative scale per backend and device kind
(median measured-time / modeled-bytes ratio on the training half,
normalized to the vendor ``xla`` path so coefficients stay in
HBM-pass units).  Scaling whole backends rather than individual
coefficients preserves each backend's internal structure (chirp padding
ratios, per-stage growth) while fixing what the hand-written table gets
wrong on a given device — e.g. interpret-mode Pallas kernels on the CI
CPU costing far more than one fused HBM pass.

Quality is reported as Spearman rank correlation between modeled cost and
measured time on a deterministic held-out half (alternating split over
the sorted observation keys), per device kind and per extent class, for
both the hand-written and the fitted table — rank correlation is the
right target because ESTIMATE only ever *orders* candidates.

    PYTHONPATH=src python tools/fit_costmodel.py \\
        benchmarks/baselines/BENCH_smoke.json BENCH_PR*.json \\
        --wisdom benchmarks/baselines/wisdom_cpu.json \\
        --out benchmarks/baselines/costmodel_cpu.json \\
        --assert-min-rho 0.6 --assert-improves --assert-kind cpu

The output table is the versioned ``costmodel`` schema that
``repro.core.costmodel.load_tables`` / ``model_for_device`` consume and a
``SuiteSpec.costmodel`` path installs for a run.  Stdlib-only on purpose:
the CI fit-smoke step runs it in a bare container.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import statistics
import sys
from collections import defaultdict
from dataclasses import dataclass

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.candidates import Candidate  # noqa: E402
from repro.core.client import Problem  # noqa: E402
from repro.core.compare import BenchFormatError, load_bench  # noqa: E402
from repro.core.costmodel import (BACKEND_COEFFS, DEFAULT_MODEL,  # noqa: E402
                                  CostModel, save_tables, spearman)
from repro.core.extents import classify, parse_extents  # noqa: E402
from repro.core.wisdom import Wisdom  # noqa: E402


@dataclass(frozen=True)
class Obs:
    """One measured observation the fitter can learn from."""

    device_kind: str
    extent_class: str
    backend: str
    problem: Problem
    cand: Candidate
    time_ms: float
    origin: str       # file the measurement came from, for the report

    def key(self) -> tuple:
        return (self.device_kind, self.extent_class, self.backend,
                self.problem.signature(), self.origin)


# ---------------------------------------------------------------------------
# observation collection
# ---------------------------------------------------------------------------
def bench_observations(paths: list[str]) -> tuple[list[Obs], dict]:
    """Grid rows of BENCH documents as observations.

    Serve/chaos rows (no fixed problem), multi-device rows (dist cost is
    per-device and link-dominated — not what the per-backend scales
    calibrate), failed rows, and backends without fittable coefficients
    are skipped; the skip census is returned for the report so dropped
    coverage is visible rather than silent.
    """
    obs: list[Obs] = []
    skipped: dict[str, int] = defaultdict(int)
    for path in paths:
        doc = load_bench(path)
        kind = str(doc.meta.get("device_kind", "") or "unknown")
        meta_batch = int(doc.meta.get("batch", 1) or 1)
        for row in doc.rows:
            if row.get("mode") != "grid":
                skipped["non-grid row (serve/chaos)"] += 1
                continue
            if not row.get("ok"):
                skipped["failed row"] += 1
                continue
            if int(row.get("devices", 1)) != 1:
                skipped["multi-device row"] += 1
                continue
            t = row.get("time_ms")
            if not isinstance(t, (int, float)) or not math.isfinite(t) \
                    or t <= 0:
                skipped["bad time_ms"] += 1
                continue
            backend = str(row.get("backend", ""))
            if backend not in BACKEND_COEFFS:
                skipped[f"backend without coefficients ({backend})"] += 1
                continue
            try:
                problem = Problem(parse_extents(str(row["extent"])),
                                  row["kind"], row["precision"],
                                  batch=int(row.get("batch", meta_batch)))
            except (KeyError, ValueError):
                skipped["unparseable problem"] += 1
                continue
            obs.append(Obs(kind, classify(problem.extents), backend,
                           problem, Candidate(backend), float(t),
                           doc.label))
    return obs, dict(skipped)


def wisdom_observations(paths: list[str]) -> tuple[list[Obs], dict]:
    """Schema-v3 ``measured_ms`` provenance from wisdom packs.

    A pack's keys embed the device kind they were measured on, so the
    kinds are sniffed from the raw file and a reader is opened per kind.
    Mixed/mesh candidates are skipped — their cost isn't attributable to
    a single backend's coefficients.
    """
    obs: list[Obs] = []
    skipped: dict[str, int] = defaultdict(int)
    for path in paths:
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, json.JSONDecodeError):
            skipped[f"unreadable wisdom file ({os.path.basename(path)})"] += 1
            continue
        kinds = sorted({k.split("|", 1)[0] for k in raw
                        if isinstance(k, str) and "|" in k
                        and not k.startswith("__")})
        for kind in kinds:
            store = Wisdom(path, device_kind=kind)
            for problem, cand, ms in store.measurements():
                if not math.isfinite(ms) or ms <= 0:
                    skipped["bad measured_ms"] += 1
                    continue
                if cand.backend not in BACKEND_COEFFS or cand.mesh:
                    skipped[f"unfittable candidate ({cand.backend})"] += 1
                    continue
                obs.append(Obs(kind, classify(problem.extents),
                               cand.backend, problem, cand, float(ms),
                               os.path.basename(path)))
    return obs, dict(skipped)


def predictable(obs: list[Obs]) -> tuple[list[Obs], int]:
    """Drop observations the model calls infeasible (feasibility is
    structural — coefficient-independent — so a row infeasible under the
    defaults is infeasible under any fit)."""
    kept, dropped = [], 0
    for o in obs:
        if math.isfinite(DEFAULT_MODEL.estimate_bytes_moved(o.problem,
                                                            o.cand)):
            kept.append(o)
        else:
            dropped += 1
    return kept, dropped


# ---------------------------------------------------------------------------
# fitting + evaluation
# ---------------------------------------------------------------------------
def split_train_test(obs: list[Obs]) -> tuple[list[Obs], list[Obs]]:
    """Deterministic alternating held-out split over sorted keys — stable
    across runs, and every (backend, class) stratum lands in both halves
    once it has two observations."""
    ordered = sorted(obs, key=Obs.key)
    return ordered[0::2], ordered[1::2]


def fit_scales(train: list[Obs]) -> dict[str, float]:
    """Per-backend multiplicative scale for one device kind.

    median(time / modeled_bytes) per backend puts every backend's cost in
    the same measured-milliseconds unit; dividing by the reference
    backend's ratio (``xla`` when present — the vendor path the
    hand-written table is anchored to) keeps the fitted coefficients in
    interpretable HBM-pass units.
    """
    ratios: dict[str, list[float]] = defaultdict(list)
    for o in train:
        pred = DEFAULT_MODEL.estimate_bytes_moved(o.problem, o.cand)
        ratios[o.backend].append(o.time_ms / pred)
    scales = {b: statistics.median(r) for b, r in sorted(ratios.items())}
    if not scales:
        return {}
    ref = scales.get("xla") or statistics.median(scales.values())
    return {b: s / ref for b, s in scales.items()}


def rho_report(test: list[Obs], model: CostModel) -> dict:
    """Held-out Spearman between modeled cost and measured time, pooled
    per device kind and broken out per extent class."""
    by_kind: dict[str, list[Obs]] = defaultdict(list)
    for o in test:
        by_kind[o.device_kind].append(o)
    out: dict[str, dict] = {}
    for kind, rows in sorted(by_kind.items()):
        preds = [model.estimate_bytes_moved(o.problem, o.cand)
                 for o in rows]
        times = [o.time_ms for o in rows]
        entry = {"rho": spearman(preds, times), "n": len(rows),
                 "classes": {}}
        by_cls: dict[str, list[Obs]] = defaultdict(list)
        for o in rows:
            by_cls[o.extent_class].append(o)
        for cls, crows in sorted(by_cls.items()):
            entry["classes"][cls] = {
                "rho": spearman(
                    [model.estimate_bytes_moved(o.problem, o.cand)
                     for o in crows],
                    [o.time_ms for o in crows]),
                "n": len(crows)}
        out[kind] = entry
    return out


def _fmt_rho(v: float) -> str:
    return "nan" if math.isnan(v) else f"{v:+.3f}"


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="fit cost-model coefficients from BENCH + wisdom data")
    ap.add_argument("bench", nargs="+", help="BENCH_*.json documents")
    ap.add_argument("--wisdom", action="append", default=[],
                    help="schema-v3 wisdom pack(s) with measured_ms rows")
    ap.add_argument("--out", help="write the fitted coefficient table here")
    ap.add_argument("--assert-min-rho", type=float, default=None,
                    metavar="RHO",
                    help="exit 1 unless fitted held-out rho >= RHO")
    ap.add_argument("--assert-improves", action="store_true",
                    help="exit 1 unless fitted rho strictly beats the "
                         "hand-written table's")
    ap.add_argument("--assert-kind", default=None, metavar="KIND",
                    help="device kind the assertions apply to "
                         "(default: every fitted kind)")
    args = ap.parse_args(argv)

    try:
        bench_obs, bench_skips = bench_observations(args.bench)
    except BenchFormatError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    wis_obs, wis_skips = wisdom_observations(args.wisdom)
    obs, infeasible = predictable(bench_obs + wis_obs)

    print(f"observations: {len(bench_obs)} bench + {len(wis_obs)} wisdom, "
          f"{infeasible} infeasible-under-model dropped")
    for why, n in sorted({**bench_skips, **wis_skips}.items()):
        print(f"  skipped {n:4d}  {why}")
    if not obs:
        print("error: no usable observations", file=sys.stderr)
        return 2

    train, test = split_train_test(obs)
    by_kind_train: dict[str, list[Obs]] = defaultdict(list)
    for o in train:
        by_kind_train[o.device_kind].append(o)

    models: dict[str, CostModel] = {}
    all_scales: dict[str, dict[str, float]] = {}
    for kind, rows in sorted(by_kind_train.items()):
        scales = fit_scales(rows)
        all_scales[kind] = scales
        models[kind] = DEFAULT_MODEL.scaled(
            scales, device_kind=kind, source="tools/fit_costmodel.py")

    default_rho = rho_report(test, DEFAULT_MODEL)
    fitted_rho = {kind: rho_report([o for o in test
                                    if o.device_kind == kind],
                                   model).get(kind, {})
                  for kind, model in models.items()}

    print(f"\nheld-out split: {len(train)} train / {len(test)} test")
    for kind in sorted(models):
        d = default_rho.get(kind, {})
        f = fitted_rho.get(kind, {})
        print(f"\ndevice kind {kind!r}  "
              f"(n={f.get('n', 0)} held-out)")
        print(f"  pooled rho   hand-written {_fmt_rho(d.get('rho', float('nan')))}"
              f"   fitted {_fmt_rho(f.get('rho', float('nan')))}")
        classes = sorted(set(d.get("classes", {})) | set(f.get("classes", {})))
        for cls in classes:
            dc = d.get("classes", {}).get(cls, {})
            fc = f.get("classes", {}).get(cls, {})
            print(f"  {cls:<10} rho  hand-written "
                  f"{_fmt_rho(dc.get('rho', float('nan')))}   fitted "
                  f"{_fmt_rho(fc.get('rho', float('nan')))}   "
                  f"(n={fc.get('n', 0)})")
        print("  backend scales: "
              + ", ".join(f"{b}={s:.3g}"
                          for b, s in all_scales[kind].items()))

    if args.out:
        meta = {
            "generated_by": "tools/fit_costmodel.py",
            "inputs": sorted(os.path.basename(p)
                             for p in args.bench + args.wisdom),
            "observations": len(obs),
            "backend_scales": all_scales,
            "held_out_rho": {
                kind: {"hand_written": default_rho.get(kind, {}).get("rho"),
                       "fitted": fitted_rho.get(kind, {}).get("rho"),
                       "n": fitted_rho.get(kind, {}).get("n")}
                for kind in sorted(models)},
        }
        save_tables(args.out, models, meta=meta)
        print(f"\nwrote {args.out} ({len(models)} device kind(s))")

    failures = []
    kinds = [args.assert_kind] if args.assert_kind else sorted(models)
    for kind in kinds:
        f_rho = fitted_rho.get(kind, {}).get("rho", float("nan"))
        d_rho = default_rho.get(kind, {}).get("rho", float("nan"))
        if args.assert_min_rho is not None and \
                not (f_rho >= args.assert_min_rho):
            failures.append(
                f"{kind}: fitted rho {_fmt_rho(f_rho)} < "
                f"required {args.assert_min_rho}")
        if args.assert_improves and not (f_rho > d_rho):
            failures.append(
                f"{kind}: fitted rho {_fmt_rho(f_rho)} does not strictly "
                f"improve on hand-written {_fmt_rho(d_rho)}")
    for msg in failures:
        print(f"ASSERTION FAILED: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
