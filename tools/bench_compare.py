"""Per-backend FFT throughput over a fixed extent grid — the PR-over-PR
perf trajectory record.

Times the *forward transform only* (the hot path the tentpole kernels
optimize), via the same ``build_forward`` the planner's MEASURE sweep uses,
and writes one JSON document:

    PYTHONPATH=src python tools/bench_compare.py --out BENCH_PR4.json
    PYTHONPATH=src python tools/bench_compare.py --smoke --out /tmp/b.json

``--smoke`` shrinks the grid/reps to seconds for the CI interpret-mode run.
The grid spans 1D, 2D, and 3D extents (``--extents 4096 64x64 16x16x16``
syntax) so the ND planning work — fused rank-2 kernel vs separable per-axis
application with its swapaxes traffic — shows up in the trajectory, and all
three paper extent classes (powerof2, radix357 rows like 3072, oddshape
rows like 6859 = 19^3) so the mixed-radix kernel and the fused chirp-Z
path are measured against the xla / jnp-bluestein fallbacks they replace.
Throughput is complex-signal GiB/s moved at the *algorithmic minimum* of
one HBM read + one write — so a fused one-pass kernel scores its real
bandwidth while a log-N staged backend is penalized for its extra passes,
which is exactly the trajectory worth recording (paper Fig. 8).

With ``--devices 1 2 4 8`` the tool becomes the scaling driver for the
mesh-parallel backends: one subprocess per device count (a process's XLA
device count is fixed at first jax init, so the axis NEEDS processes) with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``, benching the
distributed decompositions (dist1d / slab / pencil, TRANSPOSED layout)
against the single-device ``xla`` reference over one extent per paper
class, merged into one document whose records carry a ``devices`` field:

    PYTHONPATH=src python tools/bench_compare.py --devices 1 2 4 8 \\
        --out BENCH_PR6.json

Documents carry the schema-2 provenance header (``repro.core.compare``:
schema version, git sha, device kind, jax version, reps) and every grid
row records ``mean_ms``/``sd_ms``/``n`` alongside the min — the spread
columns ``tools/bench_diff.py``'s pooled-noise regression gate consumes —
plus the bytes-based FFT roofline: ``model_flops`` (5·N·log2 N),
``model_bytes`` (the planner's ``estimate_bytes_moved``), and
``roofline_frac``, the achieved fraction of whichever device wall binds.
``--report fig7.md`` renders the gearshifft-style Fig. 7 table (backend ×
extent class × achieved fraction) from the written document.

With ``--serve`` the tool benches the FFT serving layer instead: a seeded
Zipf mixed-shape replay per backend (p50/p95/p99 enqueue→complete latency,
sustained GiB/s, coalesce + plan-cache counters) plus the coalesced-vs-
serial same-shape burst whose ``speedup`` field is the coalescer's
dispatch-amortization win:

    PYTHONPATH=src python tools/bench_compare.py --serve --out BENCH_PR7.json
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import statistics
import subprocess
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.compare import fig7_report, load_bench, make_meta  # noqa: E402

DEFAULT_EXTENTS = ("1024", "4096", "16384", "65536",        # 1D powerof2
                   "3072", "18432",                         # 1D radix357
                   "6859",                                  # 1D oddshape 19^3
                   "64x64", "256x256",                      # 2D (fft2 range)
                   "32x32x32")                              # 3D
SMOKE_EXTENTS = ("256", "1024", "12", "19", "16x16", "8x8x8")

DEFAULT_BACKENDS = ("xla", "stockham", "fourstep", "fourstep_pallas",
                    "stockham_pallas", "sixstep", "fft2_pallas",
                    "chirpz_pallas", "bluestein")

#: One extent per paper class for the --devices scaling grid (all shardable
#: over 8 devices): 1D/3D powerof2, 3D radix357, 1D oddshape
#: (438976 = 2^6 * 19^3 factors as 152 x 2888, both divisible by 8).
SCALING_EXTENTS = ("4096", "64x64x64", "48x48x48", "438976")
SMOKE_SCALING_EXTENTS = ("1024", "8x8x8", "12x12x12", "304")

DIST_BACKENDS = ("dist1d", "slab", "pencil")


def _record_times(rec: dict, times: list[float]) -> float:
    """min/mean/sd/n columns from per-rep wall times (seconds); returns the
    best time.  The sd/n columns are what bench_diff's pooled-noise gate
    reads — a 1-rep smoke run records sd=0, n=1 (no spread information)."""
    best = min(times)
    rec["time_ms"] = best * 1e3
    rec["mean_ms"] = statistics.fmean(times) * 1e3
    rec["sd_ms"] = statistics.stdev(times) * 1e3 if len(times) > 1 else 0.0
    rec["n"] = len(times)
    return best


#: Rows whose roofline had to fall back to the algorithmic-minimum bytes
#: because the cost model judged the (problem, candidate) infeasible —
#: reported after the grid so a model/feasibility drift is visible in the
#: run log instead of silently flattering roofline_frac.
ROOFLINE_FALLBACKS: list[tuple[str, str]] = []


def _annotate_roofline(rec: dict, problem, cand, best_s: float) -> None:
    """Attach the bytes-based FFT roofline: modeled 5·N·log2(N) flops,
    modeled HBM bytes from the *active* cost model (so a fitted per-device
    table flows into roofline_frac too), and the achieved fraction of
    whichever wall binds (always finite for an ok row — an
    :class:`~repro.core.costmodel.Infeasible` verdict degrades to the
    one-read+one-write algorithmic minimum, and the row is tagged and
    logged: a row that actually ran but models as infeasible means the
    model's feasibility rules have drifted from the kernels')."""
    import jax
    from repro.core.costmodel import get_active_model
    from repro.roofline.analysis import fft_model_flops, fft_roofline_frac

    flops = fft_model_flops(problem.extents, problem.batch)
    verdict = get_active_model().estimate(problem, cand)
    bytes_ = float(verdict)
    if not (0.0 < bytes_ < float("inf")):
        bytes_ = 2.0 * problem.signal_bytes
        reason = getattr(verdict, "reason", "") or "non-finite model bytes"
        rec["roofline_fallback"] = reason
        ROOFLINE_FALLBACKS.append(
            (f"{cand.key()} @ {problem.signature()}", reason))
    rec["model_flops"] = flops
    rec["model_bytes"] = bytes_
    rec["roofline_frac"] = fft_roofline_frac(
        best_s * 1e3, flops, bytes_, jax.devices()[0].device_kind)


def bench_backend(backend: str, extents: tuple[int, ...], batch: int,
                  reps: int, warmups: int) -> dict:
    import jax
    from repro.core.client import Problem
    from repro.core.extents import classify
    from repro.core.plan import Candidate, backend_supports
    from repro.core.clients.jax_fft import build_forward

    problem = Problem(extents, "Outplace_Complex", "float", batch=batch)
    rec = {"backend": backend, "extent": "x".join(map(str, extents)),
           "rank": len(extents), "batch": batch,
           "kind": problem.kind, "precision": problem.precision,
           "class": classify(extents)}
    if not backend_supports(backend, problem):
        rec.update(ok=False, error="unsupported extents/rank")
        return rec
    try:
        cand = Candidate(backend)
        fn = build_forward(problem, cand)
        rng = np.random.default_rng(0)
        shape = (batch, *extents)
        x = (rng.standard_normal(shape) +
             1j * rng.standard_normal(shape)).astype(np.complex64)
        xd = jax.device_put(x)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(xd))
        rec["compile_ms"] = (time.perf_counter() - t0) * 1e3
        for _ in range(warmups):
            jax.block_until_ready(fn(xd))
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(xd))
            times.append(time.perf_counter() - t0)
        best = _record_times(rec, times)
        moved = 2 * x.nbytes          # one read + one write of the signal
        rec["gib_per_s"] = moved / best / 2**30
        _annotate_roofline(rec, problem, cand, best)
        rec["ok"] = True
    except Exception as e:  # infeasible extent for this backend: record it
        rec.update(ok=False, error=f"{type(e).__name__}: {e}")
    return rec


def bench_dist_backend(backend: str, extents: tuple[int, ...], batch: int,
                       reps: int, warmups: int) -> dict:
    """Time one mesh-parallel decomposition over every visible device, in
    the production TRANSPOSED-output layout (no reordering pass) with the
    planner's default local engines."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.client import Problem
    from repro.core.extents import classify
    from repro.core.plan import Candidate, _pencil_mesh_shapes
    from repro.core.clients.dist_fft import dist_engines
    from repro.fft import distributed as dist
    from repro.launch.mesh import flat_mesh, reshaped_mesh

    p_dev = jax.device_count()
    b = 1 if backend == "dist1d" else batch    # dist1d consumes the whole axis
    problem = Problem(extents, "Outplace_Complex", "float", batch=b)
    rec = {"backend": backend, "extent": "x".join(map(str, extents)),
           "rank": len(extents), "batch": b,
           "kind": problem.kind, "precision": problem.precision,
           "class": classify(extents), "devices": p_dev}
    if backend == "pencil":
        shapes = _pencil_mesh_shapes(p_dev)
        if not shapes and p_dev == 1:
            shapes = [(1, 1)]   # degenerate 1-device baseline point
        mesh_shape = shapes[0] if shapes else None
    else:
        mesh_shape = (p_dev,)
    rank = len(extents)
    feasible = mesh_shape is not None and (
        (backend == "dist1d" and rank == 1
         and dist.can_shard_1d(extents[0], p_dev))
        or (backend == "slab" and rank in (2, 3)
            and dist.slab_divisible(extents, p_dev))
        or (backend == "pencil" and rank == 3
            and dist.pencil_divisible(extents, *mesh_shape)))
    if not feasible:
        rec.update(ok=False, error="unsupported extents/rank/device count")
        return rec
    rec["mesh"] = "x".join(map(str, mesh_shape))
    try:
        base = flat_mesh()
        cand = Candidate(backend, mesh=mesh_shape)
        engines = dist_engines(problem, cand)
        if backend == "dist1d":
            mesh = reshaped_mesh(base, mesh_shape, names=("data",))
            fn, _ = dist.make_fft1d(mesh, "data", extents[0],
                                    engines=engines)
            sharding = NamedSharding(mesh, P("data"))
            shape = (extents[0],)
        else:
            mesh = reshaped_mesh(base, mesh_shape)
            if backend == "slab":
                fn, in_spec, _ = dist.make_slab_fftnd(
                    mesh, "d0", extents, engines=engines)
            else:
                fn, in_spec, _ = dist.make_pencil_fftnd(
                    mesh, "d0", "d1", extents, engines=engines)
            sharding = NamedSharding(mesh, in_spec)
            shape = (b, *extents)
        rng = np.random.default_rng(0)
        x = (rng.standard_normal(shape) +
             1j * rng.standard_normal(shape)).astype(np.complex64)
        xd = jax.device_put(x, sharding)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(xd))
        rec["compile_ms"] = (time.perf_counter() - t0) * 1e3
        for _ in range(warmups):
            jax.block_until_ready(fn(xd))
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(xd))
            times.append(time.perf_counter() - t0)
        best = _record_times(rec, times)
        moved = 2 * x.nbytes          # one read + one write of the signal
        rec["gib_per_s"] = moved / best / 2**30
        _annotate_roofline(rec, problem, cand, best)
        _annotate_hlo_collectives(rec, fn, xd)
        rec["ok"] = True
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}")
    return rec


def _annotate_hlo_collectives(rec: dict, fn, xd) -> None:
    """Loop-aware collective traffic from the compiled HLO (per-device
    SPMD module) on the distributed rows — the measured-side cross-check of
    the planner's interconnect term in ``estimate_bytes_moved``.  Best
    effort: not every callable exposes its compiled module."""
    try:
        from repro.roofline.hlo_parse import analyze
        hlo = analyze(fn.lower(xd).compile().as_text())
        rec["hlo_collective_bytes"] = hlo["collective_total"]
        rec["hlo_collective_counts"] = hlo["collective_counts"]
    except Exception:
        pass


#: Backends the serving replay is pinned to, plus the planner default
#: (backend None → per-request plan selection through the shared cache).
SERVE_BACKENDS = (None, "xla", "stockham_pallas")


def bench_serve_replay(backend, requests: int, smoke: bool) -> dict:
    """One seeded Zipf mixed-shape replay against a fresh service pinned to
    ``backend`` (None = planner-selected); records tail latency, sustained
    GiB/s, and the coalescing/cache counters."""
    from repro.serve import FFTService, ServeConfig, TrafficSpec, replay

    spec = TrafficSpec(
        extents=(("256", "1024", "16x16") if smoke
                 else ("1024", "4096", "256", "64x64")),
        kinds=("Outplace_Complex",) if smoke
        else ("Outplace_Complex", "Outplace_Real"),
        precisions=("float",), requests=requests, rate_hz=0.0,
        zipf_s=1.1, seed=2017)
    rec = {"mode": "serve_replay", "backend": backend or "planned",
           "traffic": spec.to_dict()}
    try:
        cfg = ServeConfig(coalesce_window_ms=2.0, max_batch=16,
                          backend=backend)
        with FFTService(config=cfg) as svc:
            for ext, kind, prec in spec.mix():   # steady state, not compiles
                svc.prewarm(ext, kind, prec)
            rep = replay(svc, spec)
        s = rep.service
        lat = s.get("latency_ms", {})
        rec.update(ok=True, requests=s["requests"], completed=s["completed"],
                   errors=s["errors"], timeouts=s["timeouts"],
                   batches=s["batches"],
                   batched_requests=s["batched_requests"],
                   coalesce_rate=s["coalesce_rate"], rps=s["rps"],
                   gib_per_s=s["gib_per_s"], wall_s=rep.wall_s,
                   mean_ms=lat.get("mean"), p50_ms=lat.get("p50"),
                   p95_ms=lat.get("p95"), p99_ms=lat.get("p99"),
                   plan_cache=s.get("plan_cache"))
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}")
    return rec


def bench_serve_burst(n_requests: int, ext: int = 4096) -> dict:
    """Coalesced vs serial-FIFO throughput on a same-shape closed-loop
    burst — the acceptance number for the coalescer (>= 2x on CPU).

    Serial means what it says: one request per launch, one launch at a
    time (window 0, max_batch 1, inflight 1).  Both sides use the batch
    intake (``submit_many``) and a prewarmed executable ladder, so the
    ratio isolates dispatch coalescing, not producer overhead or compiles.
    """
    from repro.serve import FFTService, ServeConfig

    x = ((np.arange(ext) % 512) / 512.0).astype(np.complex64)

    def run(cfg):
        with FFTService(config=cfg) as svc:
            svc.prewarm((ext,))                 # compiles outside the timing
            t0 = time.perf_counter()
            reqs = svc.submit_many([x] * n_requests)
            for r in reqs:
                r.result(timeout=600)
            wall = time.perf_counter() - t0
        rep = svc.report()
        return n_requests / wall, rep["batches"]

    rec = {"mode": "serve_burst", "extent": str(ext), "requests": n_requests}
    try:
        serial_rps, _ = run(ServeConfig(coalesce_window_ms=0.0, max_batch=1,
                                        inflight=1, backend="xla"))
        coalesced_rps, batches = run(ServeConfig(coalesce_window_ms=5.0,
                                                 max_batch=32,
                                                 backend="xla"))
        rec.update(ok=True, serial_rps=serial_rps,
                   coalesced_rps=coalesced_rps, coalesced_batches=batches,
                   speedup=coalesced_rps / serial_rps)
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}")
    return rec


def bench_chaos_fallback(requests: int) -> dict:
    """Chaos scenario 1: the top-ranked backend for the hot shape hard-fails
    at compile, plus one transient execute fault.  Serial FIFO (window 0,
    max_batch 1) so the recovery path is deterministic: every request must
    still be delivered — via the fallback chain (the compile fault) or a
    backoff retry (the transient) — with the demotion recorded."""
    from repro.core.client import Problem
    from repro.core.plan import fallback_chain
    from repro.serve import FFTService, ServeConfig, TrafficSpec, chaos_replay

    hot = Problem((256,), "Outplace_Complex", "float")
    top = fallback_chain(hot)[0].backend
    spec = TrafficSpec(extents=("256", "64"), kinds=("Outplace_Complex",),
                       precisions=("float",), requests=requests, rate_hz=0.0,
                       zipf_s=1.1, seed=2017,
                       faults=({"fault": "compile_error", "backend": top},
                               {"fault": "execute_error", "times": 1}))
    rec = {"mode": "chaos_fallback", "top_backend": top,
           "traffic": spec.to_dict()}
    try:
        cfg = ServeConfig(coalesce_window_ms=0.0, max_batch=1,
                          breaker_threshold=1, max_retries=2)
        with FFTService(config=cfg) as svc:
            rep = chaos_replay(svc, spec)
        s = rep.replay.service
        rec.update(ok=rep.ok and s["demotions"] >= 1
                   and s["retry_successes"] >= 1,
                   clean_success_rate=rep.clean_success_rate,
                   poisoned=rep.poisoned, violations=rep.violations,
                   demotions=s["demotions"], retries=s["retries"],
                   retry_successes=s["retry_successes"],
                   faults_injected=s["faults_injected"],
                   quarantined=[k for k, v in s["quarantine"].items()
                                if v["state"] != "closed"],
                   wedged=s["wedged"], completed=s["completed"])
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}")
    return rec


def bench_chaos_kill(requests: int) -> dict:
    """Chaos scenario 2: a worker thread is killed mid-dispatch.  The
    watchdog must fail the in-flight request cleanly (its future completes
    with an error, not a hang), restart the worker, and the service must
    finish the rest of the tape — no wedge, at most the one orphaned
    request lost."""
    from repro.serve import FFTService, ServeConfig, TrafficSpec, chaos_replay

    spec = TrafficSpec(extents=("256",), kinds=("Outplace_Complex",),
                       precisions=("float",), requests=requests, rate_hz=0.0,
                       seed=2017,
                       faults=({"fault": "kill_worker", "after": 2,
                                "times": 1},))
    rec = {"mode": "chaos_kill", "traffic": spec.to_dict()}
    try:
        cfg = ServeConfig(coalesce_window_ms=0.0, max_batch=1,
                          watchdog_interval_s=0.05)
        with FFTService(config=cfg) as svc:
            # orphaned in-flight requests are failed by design: the dying
            # worker can hold its current batch plus up to `inflight`
            # pending batches, so the gate tolerates that much loss
            lost = 1 + cfg.inflight
            rep = chaos_replay(svc, spec,
                               min_clean_success=1.0 - (lost + 1) / requests)
        s = rep.replay.service
        rec.update(ok=rep.ok and s["worker_restarts"] >= 1
                   and s["wedged"] == 0,
                   clean_success_rate=rep.clean_success_rate,
                   violations=rep.violations, completed=s["completed"],
                   failed_in_flight=s["errors"],
                   worker_restarts=s["worker_restarts"], wedged=s["wedged"],
                   worker_errors=s["worker_errors"],
                   faults_injected=s["faults_injected"])
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}")
    return rec


def _run_chaos(args) -> int:
    """The --serve --chaos grid: seeded fault-injection replays validating
    the recovery machinery end to end (CI's chaos-smoke gate)."""
    import jax

    requests = 16 if args.smoke else 48
    dev = jax.devices()[0]
    doc = {
        "meta": make_meta(
            device_kind=dev.device_kind,
            platform=dev.platform,
            devices=jax.device_count(),
            interpret_kernels=dev.platform != "tpu",
            python=platform.python_version(),
            jax=jax.__version__,
            note="chaos replay: seeded FaultPlan against the Zipf tape; "
                 "clean_success_rate counts non-poisoned requests only",
        ),
        "results": [],
    }
    ok = True
    for rec in (bench_chaos_fallback(requests),
                bench_chaos_kill(max(8, requests // 2))):
        doc["results"].append(rec)
        ok = ok and rec["ok"]
        status = ("clean_success={:.3f} violations={}".format(
                      rec["clean_success_rate"], rec["violations"])
                  if "clean_success_rate" in rec
                  else f"failed: {rec.get('error')}")
        print(f"{rec['mode']:16s} ok={rec['ok']} {status}")
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {len(doc['results'])} records to {args.out}")
    return 0 if ok else 1


def _run_serve(args) -> int:
    """The --serve grid: per-backend Zipf replays + the burst speedup."""
    import jax

    requests = 24 if args.smoke else 96
    # a multiple of max_batch=32 (partially-filled batches linger for the
    # full coalesce window) and large enough that per-burst fixed costs
    # don't swamp the per-launch overhead the coalescer amortizes
    burst = 128
    dev = jax.devices()[0]
    doc = {
        "meta": make_meta(
            device_kind=dev.device_kind,
            platform=dev.platform,
            devices=jax.device_count(),
            interpret_kernels=dev.platform != "tpu",
            python=platform.python_version(),
            jax=jax.__version__,
            note="FFT serving layer: seeded Zipf mixed-shape replay per "
                 "backend (p50/p95/p99 enqueue-to-complete) + coalesced "
                 "vs serial same-shape burst",
        ),
        "results": [],
    }
    for backend in SERVE_BACKENDS:
        rec = bench_serve_replay(backend, requests, args.smoke)
        doc["results"].append(rec)
        status = (f"p50={rec['p50_ms']:8.1f} ms  p99={rec['p99_ms']:8.1f} ms "
                  f"{rec['rps']:6.1f} rps  coalesce={rec['coalesce_rate']:.2f}"
                  if rec["ok"] else f"failed: {rec['error']}")
        print(f"serve_replay {rec['backend']:16s} {status}")
    rec = bench_serve_burst(burst)
    doc["results"].append(rec)
    if rec["ok"]:
        print(f"serve_burst  {'coalesced/serial':16s} "
              f"{rec['serial_rps']:6.1f} -> {rec['coalesced_rps']:6.1f} rps "
              f"({rec['speedup']:.1f}x)")
    else:
        print(f"serve_burst  failed: {rec['error']}")
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {len(doc['results'])} records to {args.out}")
    return 0


def _fan_out_devices(args, device_counts: list[int]) -> int:
    """Run the scaling grid: one subprocess per device count (the XLA host
    device count is frozen at first jax init), merge into one document."""
    merged = {"meta": None, "results": []}
    for n in device_counts:
        fd, out = tempfile.mkstemp(suffix=f".dev{n}.json")
        os.close(fd)
        cmd = [sys.executable, os.path.abspath(__file__),
               "--_worker", "--out", out,
               "--batch", str(args.batch), "--reps", str(args.reps),
               "--warmups", str(args.warmups)]
        if args.smoke:
            cmd.append("--smoke")
        if args.extents:
            cmd += ["--extents"] + [str(e) for e in args.extents]
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
        flags.append(f"--xla_force_host_platform_device_count={n}")
        env["XLA_FLAGS"] = " ".join(flags)
        print(f"--- devices={n} ---")
        subprocess.run(cmd, check=True, env=env)
        with open(out) as f:
            doc = json.load(f)
        os.unlink(out)
        if merged["meta"] is None:
            merged["meta"] = dict(doc["meta"])
            merged["meta"]["device_counts"] = []
            merged["meta"]["workers"] = []
        merged["meta"]["device_counts"].append(n)
        # preserve every worker's full meta (device kind / platform / jax /
        # reps per count), not just the first one's, so bench_diff can
        # attribute provenance per device-count axis point
        merged["meta"]["workers"].append({"devices": n, **doc["meta"]})
        merged["results"].extend(doc["results"])
    with open(args.out, "w") as f:
        json.dump(merged, f, indent=1)
        f.write("\n")
    _maybe_report(args)
    print(f"wrote {len(merged['results'])} records "
          f"({len(device_counts)}-point device axis) to {args.out}")
    return 0


def _maybe_report(args) -> None:
    """Emit the gearshifft-style Fig. 7 (backend x extent class x achieved
    roofline fraction) from the document just written."""
    if not getattr(args, "report", None):
        return
    report = fig7_report(load_bench(args.out))
    with open(args.report, "w") as f:
        f.write(report)
    print(f"wrote Fig. 7 report to {args.report}")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="BENCH_PR5.json")
    p.add_argument("--backends", nargs="+", default=None)
    p.add_argument("--extents", nargs="+", default=None,
                   help="extent specs like 4096 64x64 16x16x16")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--reps", type=int, default=5)
    p.add_argument("--warmups", type=int, default=1)
    p.add_argument("--smoke", action="store_true",
                   help="tiny grid + 1 rep (CI interpret-mode smoke)")
    p.add_argument("--devices", nargs="+", type=int, default=None,
                   help="device-count scaling axis, e.g. --devices 1 2 4 8 "
                        "(one subprocess per count; benches xla + the "
                        "distributed decompositions)")
    p.add_argument("--serve", action="store_true",
                   help="bench the FFT serving layer instead of raw "
                        "transforms: per-backend Zipf mixed-shape replays "
                        "(tail latency, GiB/s, coalesce rate) + the "
                        "coalesced-vs-serial burst speedup")
    p.add_argument("--chaos", action="store_true",
                   help="with --serve: run the seeded fault-injection "
                        "replays (fallback-chain recovery, watchdog worker "
                        "restart) instead of the perf grid; exits nonzero "
                        "if any recovery invariant is violated")
    p.add_argument("--report", default=None, metavar="PATH",
                   help="also write the gearshifft-style Fig. 7 markdown "
                        "(backend x extent class x achieved roofline "
                        "fraction) rendered from the written document")
    p.add_argument("--_worker", action="store_true", help=argparse.SUPPRESS)
    args = p.parse_args(argv)

    if args.serve and args.chaos:
        return _run_chaos(args)
    if args.serve:
        return _run_serve(args)
    if args.devices:
        return _fan_out_devices(args, args.devices)

    scaling = args._worker   # per-device-count subprocess: the scaling grid
    if args.smoke:
        extents = list(args.extents
                       or (SMOKE_SCALING_EXTENTS if scaling else SMOKE_EXTENTS))
        reps, warmups = 1, 0
    else:
        extents = list(args.extents
                       or (SCALING_EXTENTS if scaling else DEFAULT_EXTENTS))
        reps, warmups = args.reps, args.warmups
    if args.backends:
        backends = list(args.backends)
    elif scaling:
        backends = ["xla", *DIST_BACKENDS]   # dist vs the vendor reference
    else:
        backends = list(DEFAULT_BACKENDS)

    from repro.core.extents import parse_extents
    grid = [parse_extents(str(e)) for e in extents]

    import jax
    dev = jax.devices()[0]
    n_dev = jax.device_count()
    doc = {
        "meta": make_meta(
            device_kind=dev.device_kind,
            platform=dev.platform,
            devices=n_dev,
            interpret_kernels=dev.platform != "tpu",
            python=platform.python_version(),
            jax=jax.__version__,
            batch=args.batch,
            reps=reps,
            note="forward c64 transform, min-of-reps (mean/sd/n per row); "
                 "gib_per_s assumes the one-read+one-write algorithmic "
                 "minimum; roofline_frac is the achieved fraction of the "
                 "modeled device roofline (5*N*log2(N) flops, planner "
                 "bytes-moved model)",
        ),
        "results": [],
    }
    for ext in grid:
        for backend in backends:
            if backend in DIST_BACKENDS:
                rec = bench_dist_backend(backend, ext, args.batch, reps,
                                         warmups)
            else:
                rec = bench_backend(backend, ext, args.batch, reps, warmups)
                rec["devices"] = 1 if not scaling else n_dev
            doc["results"].append(rec)
            status = (f"{rec['time_ms']:9.3f} ms  {rec['gib_per_s']:7.2f} GiB/s"
                      if rec["ok"] else f"infeasible: {rec['error']}")
            print(f"{rec['extent']:>12s} {backend:16s} {status}")
    if ROOFLINE_FALLBACKS:
        print(f"{len(ROOFLINE_FALLBACKS)} row(s) used the 2x-signal-bytes "
              "roofline fallback (model called them infeasible):")
        for what, why in ROOFLINE_FALLBACKS:
            print(f"  {what}: {why}")
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    _maybe_report(args)
    print(f"wrote {len(doc['results'])} records to {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
