"""Per-backend FFT throughput over a fixed extent grid — the PR-over-PR
perf trajectory record.

Times the *forward transform only* (the hot path the tentpole kernels
optimize), via the same ``build_forward`` the planner's MEASURE sweep uses,
and writes one JSON document:

    PYTHONPATH=src python tools/bench_compare.py --out BENCH_PR4.json
    PYTHONPATH=src python tools/bench_compare.py --smoke --out /tmp/b.json

``--smoke`` shrinks the grid/reps to seconds for the CI interpret-mode run.
The grid spans 1D, 2D, and 3D extents (``--extents 4096 64x64 16x16x16``
syntax) so the ND planning work — fused rank-2 kernel vs separable per-axis
application with its swapaxes traffic — shows up in the trajectory, and all
three paper extent classes (powerof2, radix357 rows like 3072, oddshape
rows like 6859 = 19^3) so the mixed-radix kernel and the fused chirp-Z
path are measured against the xla / jnp-bluestein fallbacks they replace.
Throughput is complex-signal GiB/s moved at the *algorithmic minimum* of
one HBM read + one write — so a fused one-pass kernel scores its real
bandwidth while a log-N staged backend is penalized for its extra passes,
which is exactly the trajectory worth recording (paper Fig. 8).
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import numpy as np

DEFAULT_EXTENTS = ("1024", "4096", "16384", "65536",        # 1D powerof2
                   "3072", "18432",                         # 1D radix357
                   "6859",                                  # 1D oddshape 19^3
                   "64x64", "256x256",                      # 2D (fft2 range)
                   "32x32x32")                              # 3D
SMOKE_EXTENTS = ("256", "1024", "12", "19", "16x16", "8x8x8")

DEFAULT_BACKENDS = ("xla", "stockham", "fourstep", "fourstep_pallas",
                    "stockham_pallas", "sixstep", "fft2_pallas",
                    "chirpz_pallas", "bluestein")


def bench_backend(backend: str, extents: tuple[int, ...], batch: int,
                  reps: int, warmups: int) -> dict:
    import jax
    from repro.core.client import Problem
    from repro.core.extents import classify
    from repro.core.plan import Candidate, backend_supports
    from repro.core.clients.jax_fft import build_forward

    problem = Problem(extents, "Outplace_Complex", "float", batch=batch)
    rec = {"backend": backend, "extent": "x".join(map(str, extents)),
           "rank": len(extents), "batch": batch,
           "class": classify(extents)}
    if not backend_supports(backend, problem):
        rec.update(ok=False, error="unsupported extents/rank")
        return rec
    try:
        fn = build_forward(problem, Candidate(backend))
        rng = np.random.default_rng(0)
        shape = (batch, *extents)
        x = (rng.standard_normal(shape) +
             1j * rng.standard_normal(shape)).astype(np.complex64)
        xd = jax.device_put(x)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(xd))
        rec["compile_ms"] = (time.perf_counter() - t0) * 1e3
        for _ in range(warmups):
            jax.block_until_ready(fn(xd))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(xd))
            best = min(best, time.perf_counter() - t0)
        rec["time_ms"] = best * 1e3
        moved = 2 * x.nbytes          # one read + one write of the signal
        rec["gib_per_s"] = moved / best / 2**30
        rec["ok"] = True
    except Exception as e:  # infeasible extent for this backend: record it
        rec.update(ok=False, error=f"{type(e).__name__}: {e}")
    return rec


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="BENCH_PR5.json")
    p.add_argument("--backends", nargs="+", default=list(DEFAULT_BACKENDS))
    p.add_argument("--extents", nargs="+", default=None,
                   help="extent specs like 4096 64x64 16x16x16")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--reps", type=int, default=5)
    p.add_argument("--warmups", type=int, default=1)
    p.add_argument("--smoke", action="store_true",
                   help="tiny grid + 1 rep (CI interpret-mode smoke)")
    args = p.parse_args(argv)
    if args.smoke:
        extents = list(args.extents or SMOKE_EXTENTS)
        reps, warmups = 1, 0
    else:
        extents = list(args.extents or DEFAULT_EXTENTS)
        reps, warmups = args.reps, args.warmups

    from repro.core.extents import parse_extents
    grid = [parse_extents(str(e)) for e in extents]

    import jax
    dev = jax.devices()[0]
    doc = {
        "meta": {
            "device_kind": dev.device_kind,
            "platform": dev.platform,
            "interpret_kernels": dev.platform != "tpu",
            "python": platform.python_version(),
            "jax": jax.__version__,
            "batch": args.batch,
            "reps": reps,
            "note": "forward c64 transform, min-of-reps; gib_per_s assumes "
                    "the one-read+one-write algorithmic minimum",
        },
        "results": [],
    }
    for ext in grid:
        for backend in args.backends:
            rec = bench_backend(backend, ext, args.batch, reps, warmups)
            doc["results"].append(rec)
            status = (f"{rec['time_ms']:9.3f} ms  {rec['gib_per_s']:7.2f} GiB/s"
                      if rec["ok"] else f"infeasible: {rec['error']}")
            print(f"{rec['extent']:>12s} {backend:16s} {status}")
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {len(doc['results'])} records to {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
