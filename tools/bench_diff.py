"""Noise-aware regression gate between two BENCH_*.json trajectory docs.

    PYTHONPATH=src python tools/bench_diff.py BENCH_PR5.json BENCH_PR7.json
    PYTHONPATH=src python tools/bench_diff.py baseline.json candidate.json \\
        --smoke --md bench_diff.md

Rows are aligned by (mode, backend, extent, kind, precision, rank,
devices) through the shared comparison core (``repro.core.compare``), so
schema-1 documents (the committed BENCH_PR3..PR7) diff against schema-2
ones.  A slowdown only counts as a regression when it clears *every* gate:
the pooled-standard-error sigma test (from the per-row ``sd_ms``/``n``
columns — zero-information for 1-rep rows), the relative min-effect floor,
and the absolute floor.  ``--smoke`` selects the loose preset for 1-rep
interpret-mode CI runs where only feasibility losses and order-of-magnitude
slowdowns are trustworthy signals.

Prints the markdown delta report (also written to ``--md``) and exits
nonzero when the candidate regresses the baseline.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.compare import (BenchFormatError, SMOKE_THRESHOLDS,  # noqa: E402
                                Thresholds, diff_docs, load_bench,
                                markdown_report)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("baseline", help="baseline BENCH_*.json")
    p.add_argument("candidate", help="candidate BENCH_*.json")
    p.add_argument("--md", default=None,
                   help="also write the markdown report to this path")
    p.add_argument("--smoke", action="store_true",
                   help="smoke-grade thresholds (1-rep grids: gate only on "
                        "feasibility losses and order-of-magnitude "
                        "slowdowns)")
    p.add_argument("--sigma", type=float, default=None,
                   help="noise gate: |delta| must exceed sigma x pooled "
                        "standard error (default 3)")
    p.add_argument("--min-rel", type=float, default=None,
                   help="min-effect floor as a fraction of the baseline "
                        "(default 0.10; smoke preset 4.0)")
    p.add_argument("--min-abs-ms", type=float, default=None,
                   help="absolute floor in metric units (default 0.05)")
    p.add_argument("--fail-on-missing", action="store_true",
                   help="also exit nonzero when baseline rows are missing "
                        "from the candidate (same-grid CI diffs)")
    p.add_argument("--no-fail", action="store_true",
                   help="always exit 0 (report-only mode)")
    args = p.parse_args(argv)

    base = SMOKE_THRESHOLDS if args.smoke else Thresholds()
    th = Thresholds(
        sigma=args.sigma if args.sigma is not None else base.sigma,
        min_rel=args.min_rel if args.min_rel is not None else base.min_rel,
        min_abs_ms=(args.min_abs_ms if args.min_abs_ms is not None
                    else base.min_abs_ms),
        name=base.name if (args.sigma is None and args.min_rel is None
                           and args.min_abs_ms is None) else "custom",
    )
    try:
        doc_a = load_bench(args.baseline)
        doc_b = load_bench(args.candidate)
    except (OSError, BenchFormatError) as e:
        print(f"bench_diff: {e}", file=sys.stderr)
        return 2

    res = diff_docs(doc_a, doc_b, th)
    report = markdown_report(res)
    print(report, end="")
    if args.md:
        with open(args.md, "w") as f:
            f.write(report)
    if args.no_fail:
        return 0
    if res.has_regression:
        return 1
    if args.fail_on_missing and res.count("removed"):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
