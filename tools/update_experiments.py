"""Fill EXPERIMENTS.md markers from dry-run artifacts.

  PYTHONPATH=src python tools/update_experiments.py
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.roofline.analysis import load_rows, markdown_table, row_from_record  # noqa: E402

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
EXP = os.path.join(ROOT, "EXPERIMENTS.md")
TUNED = os.path.join(ROOT, "experiments", "dryrun")
BASE = os.path.join(ROOT, "experiments", "dryrun_baseline")

PERF_CELLS = [("starcoder2-7b", "prefill_32k"),
              ("gemma3-27b", "train_4k"),
              ("deepseek-v2-lite-16b", "train_4k"),
              ("granite-moe-1b-a400m", "train_4k"),
              ("internlm2-20b", "decode_32k"),
              ("llama-3.2-vision-90b", "train_4k")]


def _load(d, arch, shape):
    p = os.path.join(d, f"{arch}_{shape}_16-16.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def perf_table() -> str:
    lines = ["| cell | metric | baseline | tuned | change |",
             "|---|---|---|---|---|"]
    for arch, shape in PERF_CELLS:
        b = _load(BASE, arch, shape)
        t = _load(TUNED, arch, shape)
        if not b or not t or b["status"] != "ok" or t["status"] != "ok":
            continue
        rb, rt = row_from_record(b), row_from_record(t)
        bt = b["memory"]["temp_size_in_bytes"] / 2**30
        tt = t["memory"]["temp_size_in_bytes"] / 2**30
        bc = b["collectives"]["total_bytes"] / 2**30
        tc = t["collectives"]["total_bytes"] / 2**30
        fits_b = "FITS" if bt + b["memory"]["argument_size_in_bytes"] / 2**30 < 14 else "OOM"
        fits_t = "FITS" if tt + t["memory"]["argument_size_in_bytes"] / 2**30 < 14 else "OOM"
        cell = f"{arch} × {shape}"
        lines.append(f"| {cell} | temp GiB/chip | {bt:.1f} ({fits_b}) | {tt:.1f} ({fits_t}) | {tt/bt:.2f}x |")
        lines.append(f"| | collective GiB/chip | {bc:.1f} | {tc:.1f} | {tc/bc:.2f}x |")
        lines.append(f"| | bound step time (s) | {rb.bound_time():.2f} | {rt.bound_time():.2f} | {rt.bound_time()/rb.bound_time():.2f}x |")
        lines.append(f"| | roofline frac | {rb.roofline_fraction:.1%} | {rt.roofline_fraction:.1%} | — |")
    return "\n".join(lines)


def main() -> None:
    rows = load_rows(TUNED, "16x16")
    table = markdown_table(rows)
    with open(os.path.join(ROOT, "experiments", "roofline_table.md"), "w") as f:
        f.write(table + "\n")
    with open(EXP) as f:
        text = f.read()
    text = text.replace("<!-- ROOFLINE_TABLE -->", table)
    text = text.replace("<!-- PERF_TABLE -->", perf_table())
    with open(EXP, "w") as f:
        f.write(text)
    ok = sum(1 for r in rows if r.status == "ok")
    print(f"updated EXPERIMENTS.md: {len(rows)} rows ({ok} ok)")


if __name__ == "__main__":
    main()
