#!/usr/bin/env python
"""Pre-generate FFT planning wisdom over the support matrix.

The offline analogue of fftw's ``wisdom`` utility, and the canonical
replacement for the deprecated ``python -m repro.core.wisdom`` shim: sweep
a grid of problems spanning the paper's three extent classes (powerof2 /
radix357 / oddshape), ranks 1-3, and both transform kinds, run the
planner's real measurement sweep for each (``near=False`` — a
pregeneration run must never inherit a neighbor's pick), and save one
schema-v3 wisdom pack whose records carry ``measured_ms`` + ``rigor``
provenance.  The pack then serves two consumers:

* a warm :class:`repro.core.suite.Session` (or the serve engine) loads it
  and every matrix problem plans as an exact ``wisdom`` hit — no sweep,
  the CI fit-smoke step asserts this — while unseen same-class shapes get
  nearest-neighbor ``wisdom_near`` plans;
* ``tools/fit_costmodel.py`` consumes the ``measured_ms`` rows as
  training data alongside the BENCH trajectory documents.

    PYTHONPATH=src python tools/pregen_wisdom.py \\
        --out benchmarks/baselines/wisdom_cpu.json

The default matrix is sized for the CI CPU device kind (interpret-mode
Pallas kernels make big extents minutes-per-sweep); ``--extents`` widens
it with bench_compare's ``4096 64x64 16x16x16`` syntax on real hardware.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.client import Problem  # noqa: E402
from repro.core.extents import classify, parse_extents  # noqa: E402

#: The default support matrix: every paper extent class at CI-feasible
#: sizes, ranks 1-3.  powerof2 rows exercise the staged/fused kernel
#: crossover, radix357 the mixed-radix path, oddshape the chirp-Z /
#: Bluestein fallbacks.
DEFAULT_EXTENTS = (
    # rank 1
    "64", "256", "1024", "4096",          # powerof2
    "48", "384", "1080",                  # radix357
    "121", "1001",                        # oddshape (11^2, 7*11*13)
    # rank 2
    "32x32", "64x64",                     # powerof2
    "48x48",                              # radix357
    # rank 3
    "16x16x16",                           # powerof2
)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="pre-generate schema-v3 FFT planning wisdom")
    ap.add_argument("--out", default=None,
                    help="pack path (default: benchmarks/baselines/"
                         "wisdom_<device_kind>.json)")
    ap.add_argument("--extents", nargs="*", default=list(DEFAULT_EXTENTS),
                    help="extent grid, bench_compare syntax "
                         "(4096 64x64 16x16x16)")
    ap.add_argument("--kinds", nargs="*",
                    default=["Outplace_Complex", "Outplace_Real"])
    ap.add_argument("--precisions", nargs="*", default=["float"])
    ap.add_argument("--batch", type=int, nargs="*", default=[1])
    ap.add_argument("--rigor", choices=["measure", "patient"],
                    default="measure",
                    help="sweep rigor recorded into the pack (measure: "
                         "feasible candidates; patient: + mixed per-axis "
                         "assignments)")
    args = ap.parse_args(argv)

    import jax

    from repro.core.clients.jax_fft import build_forward
    from repro.core.plan import PlanRigor, make_plan
    from repro.core.wisdom import Wisdom

    rigor = PlanRigor(args.rigor)
    device_kind = jax.devices()[0].device_kind
    out = args.out or os.path.join("benchmarks", "baselines",
                                   f"wisdom_{device_kind}.json")
    wisdom = Wisdom(out, device_kind=device_kind)

    problems = [Problem(parse_extents(ext), kind, prec, batch=b)
                for ext in args.extents
                for kind in args.kinds
                for prec in args.precisions
                for b in args.batch]
    print(f"sweeping {len(problems)} problems at rigor={rigor.value} "
          f"on {device_kind!r} -> {out}")
    t_start = time.perf_counter()
    for i, problem in enumerate(problems):
        t0 = time.perf_counter()
        plan = make_plan(problem, rigor,
                         build=lambda c, p=problem: build_forward(p, c),
                         wisdom=wisdom, near=False)
        dt = time.perf_counter() - t0
        pick = plan.candidate.key() if plan and plan.candidate else "NULL"
        best = (min(plan.measured_ms.values())
                if plan and plan.measured_ms else float("nan"))
        print(f"  [{i + 1:3d}/{len(problems)}] "
              f"{problem.signature():<34} {classify(problem.extents):<9} "
              f"-> {pick:<28} best={best:8.3f} ms  (swept {dt:6.1f} s)")
    wisdom.save()
    print(f"wrote {len(wisdom)} wisdom entries to {out} "
          f"in {time.perf_counter() - t_start:.0f} s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
