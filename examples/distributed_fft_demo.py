"""Distributed pencil-FFT demo on 8 simulated devices: the pod-scale FFT
path of DESIGN.md §2, validated against numpy.

Re-execs itself with XLA_FLAGS so the host presents 8 devices.

  PYTHONPATH=src python examples/distributed_fft_demo.py
"""

import os
import sys

if os.environ.get("XLA_FLAGS", "").find("host_platform_device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import numpy as np                                    # noqa: E402
import jax                                            # noqa: E402
import jax.numpy as jnp                               # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.fft import distributed as dist             # noqa: E402
from repro.launch.mesh import make_mesh               # noqa: E402


def main() -> None:
    mesh = make_mesh((2, 4), ("data", "model"))
    shape = (32, 16, 64)
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
         ).astype(np.complex64)
    xd = jax.device_put(jnp.asarray(x),
                        NamedSharding(mesh, P("data", "model", None)))
    fft3d = dist.make_fft3d(mesh, "data", "model", shape)
    with mesh:
        y = fft3d(xd)
    err = np.abs(np.asarray(y) - np.fft.fftn(x)).max()
    print(f"3D pencil FFT {shape} on mesh {dict(mesh.shape)}: "
          f"max |err| = {err:.2e}")
    print("per-device shards:", xd.sharding.shard_shape(xd.shape))

    n = 1 << 14
    x1 = (rng.standard_normal(n) + 1j * rng.standard_normal(n)).astype(np.complex64)
    mesh1 = make_mesh((8,), ("data",))
    x1d = jax.device_put(jnp.asarray(x1), NamedSharding(mesh1, P("data")))
    fft1d, (n1, n2) = dist.make_fft1d(mesh1, "data", n)
    with mesh1:
        y1 = fft1d(x1d)
    nat = np.asarray(dist.transposed_to_natural(jnp.asarray(y1), n1, n2))
    err1 = np.abs(nat - np.fft.fft(x1)).max() / np.abs(np.fft.fft(x1)).max()
    print(f"1D distributed four-step n={n} (n1={n1}, n2={n2}): "
          f"rel err = {err1:.2e} (transposed-out layout)")

    # transposed-in inverse: round trip without any reordering pass
    ifft1d, _ = dist.make_ifft1d(mesh1, "data", n)
    with mesh1:
        xr = ifft1d(y1)
    err2 = np.abs(np.asarray(xr) - x1).max()
    print(f"1D inverse (TRANSPOSED_IN): roundtrip err = {err2:.2e}")

    # the same path measured through the declarative Suite API
    from repro.core.suite import Session, SuiteSpec                   # noqa: E402

    spec = SuiteSpec(clients=("DistFFT1D",), extents=("4096",),
                     kinds=("Outplace_Complex",), precisions=("float",),
                     warmups=1, repetitions=3, output=None, verbose=True)
    results = Session().run(spec)
    for (lib, ext, prec, kind, rigor, op, mean, sd, cnt) in \
            results.aggregate(op="execute_forward"):
        print(f"{lib} n={ext} on 8 devices: execute_forward "
              f"{mean*1e3:.1f} us (n={cnt})")
    stats = results.plan_stats
    print(f"plan cache: {stats.hits} hits, {stats.misses} misses")


if __name__ == "__main__":
    main()
