"""The full gearshifft-style suite run: every backend x transform kind x
extent class, with planner rigors and wisdom — a scaled-down version of the
paper's experimental section that finishes in minutes on CPU.

Demonstrates the programmatic Suite API: declarative ``SuiteSpec``s (extent
sweeps included) executed by one shared ``Session``, result sets
concatenated and written once.

  PYTHONPATH=src python examples/fft_benchmark_suite.py [-o suite.csv]
"""

import argparse
import os
import tempfile
from dataclasses import replace

from repro.core.plan import PlanRigor
from repro.core.suite import ResultSet, Session, SuiteSpec, SweepSpec
from repro.core.wisdom import generate

MAIN_SPEC = SuiteSpec(
    clients=("XlaFFT", "Stockham", "FourStep", "Bluestein"),
    sweeps=(SweepSpec("powerof2", rank=1, min_exp=6, max_exp=12),
            SweepSpec("powerof2", rank=3, min_exp=3, max_exp=5),
            SweepSpec("radix357", rank=1, count=4, start=96),
            SweepSpec("oddshape", rank=1, count=3)),
    kinds=("Outplace_Real", "Outplace_Complex", "Inplace_Real"),
    precisions=("float", "double"),
    warmups=1, plan_cache=False, output=None, verbose=True)

RIGOR_SPEC = SuiteSpec(
    clients=("Planned",), extents=("1024", "4096"),
    kinds=("Outplace_Real",), precisions=("float",),
    warmups=1, plan_cache=False, output=None, verbose=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("-o", "--output", default="suite.csv")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()

    session = Session()
    results = [session.run(replace(MAIN_SPEC, repetitions=args.reps))]

    # planner rigors on a canonical subset, with fresh wisdom
    with tempfile.TemporaryDirectory() as td:
        wpath = os.path.join(td, "w.json")
        generate([(1024,), (4096,)], wpath, rigor=PlanRigor.MEASURE)
        for rigor in (PlanRigor.ESTIMATE, PlanRigor.MEASURE,
                      PlanRigor.WISDOM_ONLY):
            results.append(session.run(replace(
                RIGOR_SPEC, repetitions=args.reps, rigor=rigor.value,
                wisdom=wpath)))

    combined = ResultSet.concat(results)
    path = combined.save(args.output)
    print(f"\nwrote {combined.n_rows} rows to {path} "
          f"({combined.n_failures} failed configs, e.g. Stockham on non-pow2 "
          f"extents — recorded, not fatal)")


if __name__ == "__main__":
    main()
