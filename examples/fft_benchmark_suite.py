"""The full gearshifft-style suite run: every backend x transform kind x
extent class, with planner rigors and wisdom — a scaled-down version of the
paper's experimental section that finishes in minutes on CPU.

  PYTHONPATH=src python examples/fft_benchmark_suite.py [-o suite.csv]
"""

import argparse
import os
import tempfile

from repro.core.benchmark import Benchmark, BenchmarkConfig
from repro.core.client import Context
from repro.core.extents import (oddshape_extents, powerof2_extents,
                                radix357_extents)
from repro.core.plan import PlanRigor
from repro.core.tree import build_tree
from repro.core.wisdom import generate
from repro.core.clients.jax_fft import (BluesteinClient, FourStepClient,
                                        PlannedClient, StockhamClient,
                                        XlaFFTClient)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("-o", "--output", default="suite.csv")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()

    extents = (list(powerof2_extents(1, 6, 12)) +
               list(powerof2_extents(3, 3, 5)) +
               list(radix357_extents(1, count=4, start=96)) +
               list(oddshape_extents(1, count=3)))
    clients = [XlaFFTClient, StockhamClient, FourStepClient, BluesteinClient]
    nodes = build_tree(clients, extents,
                       kinds=("Outplace_Real", "Outplace_Complex",
                              "Inplace_Real"),
                       precisions=("float", "double"))
    cfg = BenchmarkConfig(warmups=1, repetitions=args.reps, output=args.output)
    writer = Benchmark(Context(), cfg).run_nodes(nodes, verbose=True)

    # planner rigors on a canonical subset, with fresh wisdom
    with tempfile.TemporaryDirectory() as td:
        wisdom = generate([(1024,), (4096,)], os.path.join(td, "w.json"),
                          rigor=PlanRigor.MEASURE)
        for rigor in (PlanRigor.ESTIMATE, PlanRigor.MEASURE,
                      PlanRigor.WISDOM_ONLY):
            nodes = build_tree([PlannedClient], [(1024,), (4096,)],
                               kinds=("Outplace_Real",), precisions=("float",))
            cfg2 = BenchmarkConfig(warmups=1, repetitions=args.reps,
                                   rigor=rigor, output=args.output)
            bench = Benchmark(Context(), cfg2)
            bench.writer = writer  # append into the same CSV
            bench.run_nodes(nodes, wisdom=wisdom, verbose=True)

    path = writer.save()
    n_fail = sum(1 for r in writer.rows if not r.success)
    print(f"\nwrote {len(writer.rows)} rows to {path} ({n_fail} failed "
          f"configs, e.g. Stockham on non-pow2 extents — recorded, not fatal)")


if __name__ == "__main__":
    main()
