"""End-to-end training driver: a ~100M-param qwen3-family model trained for a
few hundred steps on the deterministic synthetic pipeline, with
checkpoint/restart and preemption handling active.

On CPU the default runs a ~20M variant so a few hundred steps finish in
minutes; pass --full-100m on real hardware (or be patient) for the 100M
config. Resume works across invocations: re-running continues from the last
checkpoint.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
from dataclasses import replace

import jax

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.model import Model
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config("qwen3-1.7b")
    if args.full_100m:
        cfg = replace(base, n_layers=10, d_model=640, n_heads=10,
                      n_kv_heads=5, head_dim=64, d_ff=2560, vocab_size=49152)
    else:
        cfg = replace(base, n_layers=6, d_model=256, n_heads=8, n_kv_heads=4,
                      head_dim=32, d_ff=1024, vocab_size=8192)
    model = Model(cfg, remat=False)
    n_params = sum(p.size for p in jax.tree.leaves(
        jax.eval_shape(model.init_params, jax.random.PRNGKey(0))))
    print(f"[train_lm] {cfg.name} variant: {n_params/1e6:.1f}M params")

    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))
    tcfg = TrainConfig(steps=args.steps, checkpoint_every=100,
                       checkpoint_dir=args.ckpt, log_every=20,
                       opt=OptConfig(lr=1e-3, warmup_steps=20,
                                     total_steps=args.steps))
    out = Trainer(model, data, tcfg).run(verbose=True)
    print(f"[train_lm] done: step={out['step']} final loss={out['loss']:.4f}")


if __name__ == "__main__":
    main()
