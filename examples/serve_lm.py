"""Batched serving example: continuous batching over a reduced model.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b --requests 8
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
