"""Quickstart: benchmark two FFT problems through the gearshifft-style API
and print the standardized CSV (paper §2.2 usage example).

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.benchmark import Benchmark, BenchmarkConfig
from repro.core.client import Context
from repro.core.extents import parse_extents
from repro.core.tree import build_tree, select
from repro.core.clients.jax_fft import FourStepClient, XlaFFTClient


def main() -> None:
    # the paper's CLI example:  gearshifft_clfft -e 128x128 1024 -r */float/*/Inplace_Real
    extents = [parse_extents("128x128"), parse_extents("1024")]
    nodes = build_tree([XlaFFTClient, FourStepClient], extents)
    nodes = select(nodes, "*/float/*/Inplace_Real")
    cfg = BenchmarkConfig(warmups=1, repetitions=3, output="result.csv")
    writer = Benchmark(Context(), cfg).run_nodes(nodes, verbose=True)
    writer.save()
    print("\naggregated (execute_forward):")
    for row in writer.aggregate(op="execute_forward"):
        lib, ext, prec, kind, rigor, op, mean, sd, n = row
        print(f"  {lib:10s} {ext:>9s} {kind:14s} {mean:8.3f} ms ± {sd:.3f}")
    print("\nfull per-op rows written to result.csv")


if __name__ == "__main__":
    main()
