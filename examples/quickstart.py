"""Quickstart: benchmark two FFT problems through the declarative Suite API
and print the standardized CSV (paper §2.2 usage example).

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.suite import Session, SuiteSpec


def main() -> None:
    # the paper's CLI example:  gearshifft_clfft -e 128x128 1024 -r */float/*/Inplace_Real
    spec = SuiteSpec(clients=("XlaFFT", "FourStep"),
                     extents=("128x128", "1024"),
                     select="*/float/*/Inplace_Real",
                     warmups=1, repetitions=3, output="result.csv",
                     verbose=True)
    results = Session().run(spec)
    print("\naggregated (execute_forward):")
    for row in results.aggregate(op="execute_forward"):
        lib, ext, prec, kind, rigor, op, mean, sd, n = row
        print(f"  {lib:10s} {ext:>9s} {kind:14s} {mean:8.3f} ms ± {sd:.3f}")
    print("\nfull per-op rows written to result.csv")
    spec.save("quickstart.toml")
    print("spec saved: replay with  python -m repro.core.cli --config quickstart.toml")


if __name__ == "__main__":
    main()
