"""Mixture-of-Experts FFN with token-choice top-k routing.

Expert parallelism strategy (DESIGN.md §6): activations between blocks are
replicated over the 'model' axis (Megatron TP convention), so each model-rank
selects the tokens routed to ITS local experts, runs the expert FFNs on a
static-capacity buffer, scatters weighted outputs back, and one psum over
'model' completes the layer — the same collective volume as a dense TP MLP,
with no (T, E, C) GShard dispatch tensor (the classical memory hog).

Dispatch is sort-free: per local expert, a cumsum over the routing mask gives
each token its capacity slot; overflow tokens are dropped (capacity_factor
bounds drops, aux loss balances).  All shapes static -> compiles at any mesh.

Two entry modes:
  ep_axis=None : single-device / data-parallel-only (smoke tests); local
                 experts == all experts, no collective.
  ep_axis='model' (under shard_map): params arrive pre-sliced (E_local, ...)
                 and the output psum runs over the axis.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import Params, _init


def init_moe(key, d: int, d_ff: int, n_experts: int, n_shared: int = 0,
             d_ff_shared: int | None = None) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": {"w": _init(ks[0], (d, n_experts), scale=d ** -0.5)},
        "up": _init(ks[1], (n_experts, d, d_ff)),
        "gate": _init(ks[2], (n_experts, d, d_ff)),
        "down": _init(ks[3], (n_experts, d_ff, d)),
    }
    if n_shared:
        dffs = d_ff_shared or d_ff * n_shared
        from .layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, dffs, gated=True)
    return p


def _route(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int):
    """x: (T, d) -> (top_idx (T,k), top_w (T,k) normalized, aux_loss)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    e = router_w.shape[-1]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32).sum(1)  # (T, E)
    fe = jnp.mean(one_hot, axis=0)
    aux = e * jnp.sum(fe * me)
    return top_idx, top_w.astype(x.dtype), aux


def moe_ffn(p: Params, x: jnp.ndarray, *, top_k: int, capacity_factor: float = 1.25,
            ep_axis: str | None = None, expert_offset: int = 0,
            n_experts_total: int | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d). Returns (y, aux_loss).

    Expert weights in ``p`` have leading dim E_local; with ep_axis set they
    are this rank's slice [expert_offset : expert_offset+E_local] of the
    global expert table and y is psum'd over ep_axis.
    """
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e_local = p["up"].shape[0]
    e_total = n_experts_total or e_local
    top_idx, top_w, aux = _route(p["router"]["w"], xt, top_k)
    cap = int(t * top_k / e_total * capacity_factor) or 1

    def one_expert(wu, wg, wd, eid):
        sel = (top_idx == eid)                       # (T, k)
        w_tok = (top_w * sel).sum(-1)                # (T,)
        routed = sel.any(-1)                         # (T,)
        pos = jnp.cumsum(routed) - 1                 # slot per routed token
        keep = routed & (pos < cap)
        slot = jnp.where(keep, pos, cap)             # overflow -> trash row
        buf = jnp.zeros((cap + 1, d), xt.dtype).at[slot].set(
            jnp.where(keep[:, None], xt, 0))
        h = jax.nn.silu(buf @ wg.astype(xt.dtype)) * (buf @ wu.astype(xt.dtype))
        out = h @ wd.astype(xt.dtype)                # (cap+1, d_model)
        y_tok = out[slot] * (keep * w_tok)[:, None]  # gather back, weight
        return y_tok

    eids = expert_offset + jnp.arange(e_local)
    y = jax.lax.map(
        lambda args: one_expert(*args),
        (p["up"], p["gate"], p["down"], eids)).sum(0)

    if "shared" in p:
        # with ep_axis set the shared-expert weights are TP-sharded on d_ff,
        # so its output is PARTIAL and must ride the same psum as the routed
        # experts; single-device it is simply the full shared MLP.
        from .layers import mlp
        y = y + mlp(p["shared"], x, gated=True).reshape(t, d)

    if ep_axis is not None:
        y = jax.lax.psum(y, ep_axis)
        aux = jax.lax.pmean(aux, ep_axis)
    return y.reshape(b, s, d), aux
