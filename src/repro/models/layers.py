"""Shared model layers — functional style: params are plain dict pytrees,
every layer is ``fn(params, x, ...) -> y`` plus an ``init_*`` returning the
param tree.  No framework dependency; scan-over-layers stacks these trees.

Conventions:
- compute dtype is the activation dtype (bf16 in production configs);
  reductions (norms, softmax) in float32.
- weights are stored in ``param_dtype`` (f32) and cast at use; the sharding
  rules in models/sharding.py match on the param path names used here.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def _init(key, shape, scale: float | None = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# norm
# --------------------------------------------------------------------------
def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# dense / mlp
# --------------------------------------------------------------------------
def init_dense(key, d_in: int, d_out: int, scale: float | None = None) -> Params:
    return {"w": _init(key, (d_in, d_out), scale)}


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"].astype(x.dtype)


def init_mlp(key, d: int, d_ff: int, gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"up": init_dense(ks[0], d, d_ff),
                 "down": init_dense(ks[1], d_ff, d)}
    if gated:
        p["gate"] = init_dense(ks[2], d, d_ff)
    return p


def mlp(p: Params, x: jnp.ndarray, *, gated: bool = True,
        act: str = "silu") -> jnp.ndarray:
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    up = dense(p["up"], x)
    h = a(dense(p["gate"], x)) * up if gated else a(up)
    return dense(p["down"], h)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------
def rope_table(positions: jnp.ndarray, head_dim: int,
               theta: float = 1e4) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given integer positions: each (..., head_dim/2)."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D); cos/sin: (S, D/2) (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)  # broadcast over head axis
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------
def init_embedding(key, vocab: int, d: int) -> Params:
    return {"table": _init(key, (vocab, d), scale=1.0)}


def embed(p: Params, tokens: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Logits against the embedding table (or a separate lm head table)."""
    return x @ p["table"].astype(x.dtype).T
