"""The composable decoder: every assigned architecture is built from the
same scan-over-layers skeleton, dispatched on ``cfg.block_kind``.

Design points (DESIGN.md §5/§6):
- scan over stacked layer params keeps HLO size O(1) in depth (62-100 layer
  configs compile in minutes on one host core);
- per-layer *flags* (gemma local/global, hymba SWA/global) ride along as
  scanned arrays so heterogeneous attention patterns share one block body;
- heterogeneous *structures* (llama-vision self/cross, xlstm mLSTM/sLSTM)
  scan over repeating UNITS with sub-stacked params;
- decode uses dense (non-blocked) attention so GSPMD can shard the KV axis
  (flash-decoding emerges from the sharded softmax reductions);
- MoE layers run in a shard_map island (models/moe.py) when a mesh is
  present: expert-parallel over 'model', ZeRO-gathered over 'data'.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.configs.base import ArchConfig
from . import attention as A
from . import layers as L
from . import moe as M
from . import ssm as S
from .sharding import Sharder

Params = Any


# ==========================================================================
# builder
# ==========================================================================
class Model:
    def __init__(self, cfg: ArchConfig, mesh=None, remat: bool = True):
        self.cfg = cfg
        self.mesh = mesh
        self.remat = remat and cfg.n_layers > 2
        self.sh = Sharder(mesh)

    # --------------------------- init ------------------------------------
    def init_params(self, rng) -> Params:
        cfg = self.cfg
        k_embed, k_layers, k_out, k_extra = jax.random.split(rng, 4)
        params: dict[str, Any] = {}

        if cfg.n_codebooks:
            ks = jax.random.split(k_embed, cfg.n_codebooks)
            params["embed"] = {"table": jnp.stack(
                [L.init_embedding(k, cfg.vocab_size, cfg.d_model)["table"]
                 for k in ks])}          # (nq, V, d)
        else:
            params["embed"] = L.init_embedding(k_embed, cfg.vocab_size, cfg.d_model)

        params["final_norm"] = L.init_rmsnorm(cfg.d_model)
        if not cfg.tie_embeddings:
            if cfg.n_codebooks:
                ks = jax.random.split(k_out, cfg.n_codebooks)
                params["lm_head"] = {"table": jnp.stack(
                    [L.init_embedding(k, cfg.vocab_size, cfg.d_model)["table"]
                     for k in ks])}
            else:
                params["lm_head"] = L.init_embedding(k_out, cfg.vocab_size, cfg.d_model)
        if cfg.n_meta_tokens:
            params["meta_tokens"] = L._init(k_extra, (cfg.n_meta_tokens, cfg.d_model),
                                            scale=0.02)

        params.update(self._init_layers(k_layers))
        return params

    def _stack(self, key, n: int, init_one):
        keys = jax.random.split(key, n)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[init_one(k) for k in keys])

    def _init_layers(self, key) -> dict:
        cfg = self.cfg
        kind = cfg.block_kind
        k1, k2 = jax.random.split(key)

        if kind in ("gqa", "gemma", "musicgen"):
            def one(k):
                ka, km = jax.random.split(k)
                return {"ln1": L.init_rmsnorm(cfg.d_model),
                        "attn": A.init_attention(ka, cfg.d_model, cfg.n_heads,
                                                 cfg.n_kv_heads, cfg.head_dim,
                                                 cfg.qk_norm),
                        "ln2": L.init_rmsnorm(cfg.d_model),
                        "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, cfg.mlp_gated)}
            return {"layers": self._stack(k1, cfg.n_layers, one)}

        if kind == "gqa_moe":
            def one(k):
                ka, km = jax.random.split(k)
                return {"ln1": L.init_rmsnorm(cfg.d_model),
                        "attn": A.init_attention(ka, cfg.d_model, cfg.n_heads,
                                                 cfg.n_kv_heads, cfg.head_dim,
                                                 cfg.qk_norm),
                        "ln2": L.init_rmsnorm(cfg.d_model),
                        "moe": M.init_moe(km, cfg.d_model, cfg.d_ff_expert,
                                          cfg.n_experts, cfg.n_shared_experts)}
            return {"layers": self._stack(k1, cfg.n_layers, one)}

        if kind == "mla_moe":
            def mla_kwargs():
                return dict(kv_lora=cfg.kv_lora_rank, nope_dim=cfg.qk_nope_dim,
                            rope_dim=cfg.qk_rope_dim, v_dim=cfg.v_head_dim)

            def one_moe(k):
                ka, km = jax.random.split(k)
                return {"ln1": L.init_rmsnorm(cfg.d_model),
                        "attn": A.init_mla(ka, cfg.d_model, cfg.n_heads, **mla_kwargs()),
                        "ln2": L.init_rmsnorm(cfg.d_model),
                        "moe": M.init_moe(km, cfg.d_model, cfg.d_ff_expert,
                                          cfg.n_experts, cfg.n_shared_experts,
                                          d_ff_shared=cfg.d_ff_expert * max(cfg.n_shared_experts, 1))}

            def one_dense(k):
                ka, km = jax.random.split(k)
                return {"ln1": L.init_rmsnorm(cfg.d_model),
                        "attn": A.init_mla(ka, cfg.d_model, cfg.n_heads, **mla_kwargs()),
                        "ln2": L.init_rmsnorm(cfg.d_model),
                        "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff_dense, True)}
            nd = cfg.first_dense_layers
            return {"dense_layers": self._stack(k2, nd, one_dense),
                    "layers": self._stack(k1, cfg.n_layers - nd, one_moe)}

        if kind == "vlm":
            per = cfg.cross_every
            n_units = cfg.n_layers // per
            n_self = per - 1

            def one_unit(k):
                ks, kc, km = jax.random.split(k, 3)

                def one_self(kk):
                    ka, km2 = jax.random.split(kk)
                    return {"ln1": L.init_rmsnorm(cfg.d_model),
                            "attn": A.init_attention(ka, cfg.d_model, cfg.n_heads,
                                                     cfg.n_kv_heads, cfg.head_dim),
                            "ln2": L.init_rmsnorm(cfg.d_model),
                            "mlp": L.init_mlp(km2, cfg.d_model, cfg.d_ff, True)}
                self_stack = self._stack(ks, n_self, one_self)
                cross = {"ln1": L.init_rmsnorm(cfg.d_model),
                         "attn": A.init_cross_attention(kc, cfg.d_model, cfg.n_heads,
                                                        cfg.n_kv_heads, cfg.head_dim),
                         "gate": jnp.zeros((1,), jnp.float32),
                         "ln2": L.init_rmsnorm(cfg.d_model),
                         "mlp": L.init_mlp(km, cfg.d_model, cfg.d_ff, True)}
                return {"self": self_stack, "cross": cross}
            return {"units": self._stack(k1, n_units, one_unit)}

        if kind == "xlstm":
            n_units = cfg.n_layers // 2

            def one_unit(k):
                km, ks = jax.random.split(k)
                return {"m_ln": L.init_rmsnorm(cfg.d_model),
                        "mlstm": S.init_mlstm(km, cfg.d_model, cfg.n_heads,
                                              conv_k=cfg.conv_kernel),
                        "s_ln": L.init_rmsnorm(cfg.d_model),
                        "slstm": S.init_slstm(ks, cfg.d_model, cfg.n_heads)}
            return {"units": self._stack(k1, n_units, one_unit)}

        if kind == "hymba":
            def one(k):
                ka, km, kf = jax.random.split(k, 3)
                return {"ln1": L.init_rmsnorm(cfg.d_model),
                        "attn": A.init_attention(ka, cfg.d_model, cfg.n_heads,
                                                 cfg.n_kv_heads, cfg.head_dim),
                        "mamba": S.init_mamba(km, cfg.d_model, cfg.d_inner,
                                              cfg.ssm_state, cfg.conv_kernel),
                        "mix_norm_a": L.init_rmsnorm(cfg.d_model),
                        "mix_norm_m": L.init_rmsnorm(cfg.d_model),
                        "ln2": L.init_rmsnorm(cfg.d_model),
                        "mlp": L.init_mlp(kf, cfg.d_model, cfg.d_ff, True)}
            return {"layers": self._stack(k1, cfg.n_layers, one)}

        raise ValueError(f"unknown block_kind {kind}")

    # --------------------------- flags ------------------------------------
    def _layer_flags(self) -> jnp.ndarray | None:
        """Per-layer is_global booleans for gemma/hymba patterns."""
        cfg = self.cfg
        if cfg.block_kind == "gemma":
            idx = jnp.arange(cfg.n_layers)
            return (idx % cfg.global_every) == (cfg.global_every - 1)
        if cfg.block_kind == "hymba":
            idx = jnp.arange(cfg.n_layers)
            return (idx == 0) | (idx == cfg.n_layers // 2) | (idx == cfg.n_layers - 1)
        return None

    # --------------------------- embed/unembed ----------------------------
    def _embed(self, params, tokens) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.n_codebooks:
            tables = params["embed"]["table"].astype(cfg.dtype)  # (nq, V, d)
            return sum(tables[q][tokens[..., q]] for q in range(cfg.n_codebooks))
        return L.embed(params["embed"], tokens, cfg.dtype)

    def _unembed(self, params, x) -> jnp.ndarray:
        cfg = self.cfg
        head = params.get("lm_head", params["embed"])
        if cfg.n_codebooks:
            tables = head["table"].astype(x.dtype)  # (nq, V, d)
            return jnp.einsum("bsd,qvd->bsqv", x, tables)
        return L.unembed(head, x)

    # --------------------------- blocks ------------------------------------
    def _attn_block(self, p, x, *, positions, is_global=None, cache=None,
                    kv_len=None, mla: bool = False):
        cfg = self.cfg
        sh = self.sh
        h = L.rms_norm(p["ln1"], x)
        if mla:
            y, new_cache = A.mla_attention(
                p["attn"], h, n_heads=cfg.n_heads, kv_lora=cfg.kv_lora_rank,
                nope_dim=cfg.qk_nope_dim, rope_dim=cfg.qk_rope_dim,
                v_dim=cfg.v_head_dim, positions=positions,
                rope_theta=cfg.rope_theta, cache=cache, kv_len=kv_len,
                sharder=self.sh if self.mesh is not None else None)
        else:
            y, new_cache = A.attention(
                p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim, positions=positions,
                rope_theta=cfg.rope_theta, window=cfg.window,
                is_global=is_global, qk_norm=cfg.qk_norm, cache=cache,
                kv_len=kv_len, cp_mesh=self._cp_mesh(), cp_dp=sh.dp,
                sharder=sh if self.mesh is not None else None)
        return sh.acts(x + y), new_cache

    def _cp_mesh(self):
        """Context-parallel mesh when head-TP is impossible (heads % tp)."""
        if self.mesh is None:
            return None
        if self.cfg.n_heads % self.mesh.shape[self.sh.tp] == 0:
            return None
        return self.mesh

    def _ffn_block(self, p, x):
        if "moe" in p:
            y, aux = self._moe(p["moe"], L.rms_norm(p["ln2"], x))
        else:
            y, aux = L.mlp(p["mlp"], L.rms_norm(p["ln2"], x),
                           gated=self.cfg.mlp_gated, act=self.cfg.mlp_act), 0.0
        return self.sh.acts(x + y), aux

    def _moe(self, p, x):
        cfg, sh = self.cfg, self.sh
        if self.mesh is None:
            return M.moe_ffn(p, x, top_k=cfg.top_k,
                             capacity_factor=cfg.capacity_factor)
        dp = sh.dp if x.shape[0] % sh.dp_size == 0 and x.shape[0] > 1 else None
        xspec = P(dp, None, None)
        wspec: dict = {"router": {"w": P(None, None)},
                       "up": P("model", None, "data"),
                       "gate": P("model", None, "data"),
                       "down": P("model", "data", None)}
        if "shared" in p:
            wspec["shared"] = {"up": {"w": P(None, "model")},
                               "gate": {"w": P(None, "model")},
                               "down": {"w": P("model", None)}}

        e_total = cfg.n_experts
        tp_size = self.mesh.shape["model"]

        def island(w, xx):
            # ZeRO gather of this layer's expert slice over 'data'; cast to
            # the compute dtype BEFORE the gather — halves the AG bytes
            # (§Perf iteration 4)
            w = dict(w)
            cd = xx.dtype
            w["up"] = jax.lax.all_gather(w["up"].astype(cd), "data", axis=2, tiled=True)
            w["gate"] = jax.lax.all_gather(w["gate"].astype(cd), "data", axis=2, tiled=True)
            w["down"] = jax.lax.all_gather(w["down"].astype(cd), "data", axis=1, tiled=True)
            off = jax.lax.axis_index("model") * (e_total // tp_size)
            y, aux = M.moe_ffn(w, xx, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               ep_axis="model", expert_offset=off,
                               n_experts_total=e_total)
            aux = jax.lax.pmean(aux, sh.dp) if dp is not None else aux
            return y, aux

        fn = _shard_map(island, mesh=self.mesh,
                           in_specs=(wspec, xspec),
                           out_specs=(xspec, P()))
        return fn(p, x)

    # --------------------------- forward (train/prefill) -------------------
    def forward(self, params, tokens, *, image_embeds=None, cache=None,
                kv_len=None, last_token_only: bool = False):
        """Returns (logits, aux_loss, new_cache). cache None => no caching
        (training). For prefill pass empty caches and kv_len=0;
        last_token_only skips the (B,S,V) logits transient (prefill only
        needs the final position)."""
        cfg = self.cfg
        sh = self.sh
        # SP residual only where its memory win matters (training): prefill
        # measured 24x more collective traffic under SP auto-resharding.
        sh.sp = cache is None
        x = self._embed(params, tokens)
        b, s = x.shape[:2]
        n_meta = 0
        if cfg.n_meta_tokens and cache is None or \
           (cfg.n_meta_tokens and kv_len is not None and isinstance(kv_len, int) and kv_len == 0):
            meta = jnp.broadcast_to(params["meta_tokens"].astype(x.dtype),
                                    (b, cfg.n_meta_tokens, x.shape[-1]))
            x = jnp.concatenate([meta, x], axis=1)
            n_meta = cfg.n_meta_tokens
            s = x.shape[1]
        x = sh.acts(x)
        positions = jnp.arange(s) if kv_len is None else kv_len + jnp.arange(s)
        flags = self._layer_flags()
        aux_total = 0.0

        kind = cfg.block_kind
        if kind in ("gqa", "gemma", "musicgen", "gqa_moe", "hymba"):
            x, aux_total, new_cache = self._run_flat_stack(
                params["layers"], x, positions, flags, cache, kv_len)
        elif kind == "mla_moe":
            dcache = cache["dense"] if cache is not None else None
            x, aux0, dnew = self._run_flat_stack(params["dense_layers"], x,
                                                 positions, None, dcache,
                                                 kv_len, mla=True)
            mcache = cache["moe"] if cache is not None else None
            x, aux1, mnew = self._run_flat_stack(params["layers"], x,
                                                 positions, None, mcache,
                                                 kv_len, mla=True)
            aux_total = aux0 + aux1
            new_cache = None if cache is None else {"dense": dnew, "moe": mnew}
        elif kind == "vlm":
            x, new_cache = self._run_vlm(params["units"], x, positions,
                                         image_embeds, cache, kv_len)
        elif kind == "xlstm":
            x, new_cache = self._run_xlstm(params["units"], x, cache)
        else:
            raise ValueError(kind)

        x = L.rms_norm(params["final_norm"], x)
        if n_meta:
            x = x[:, n_meta:]
        if last_token_only:
            x = x[:, -1:]
        logits = sh.logits(self._unembed(params, x))
        return logits, aux_total, new_cache

    # ------------------ flat homogeneous stacks (scan) ---------------------
    def _run_flat_stack(self, stack, x, positions, flags, cache, kv_len,
                        mla: bool = False):
        cfg = self.cfg
        is_hymba = cfg.block_kind == "hymba"

        def body(carry, inp):
            x = carry
            p = inp["p"]
            flag = inp.get("flag")
            c_in = inp.get("cache")
            if is_hymba:
                x, new_c, aux = self._hymba_layer(p, x, positions, flag, c_in, kv_len)
            else:
                x, new_c = self._attn_block(p, x, positions=positions,
                                            is_global=flag, cache=c_in,
                                            kv_len=kv_len, mla=mla)
                x, aux = self._ffn_block(p, x)
            return x, {"cache": new_c, "aux": aux}

        xs: dict[str, Any] = {"p": stack}
        if flags is not None:
            xs["flag"] = flags[:jax.tree.leaves(stack)[0].shape[0]]
        if cache is not None:
            xs["cache"] = cache

        body_fn = body
        if self.remat:
            body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, ys = jax.lax.scan(body_fn, x, xs)
        aux = jnp.sum(ys["aux"]) if isinstance(ys["aux"], jnp.ndarray) else 0.0
        new_cache = ys["cache"] if cache is not None else None
        return x, aux, new_cache

    def _hymba_layer(self, p, x, positions, flag, cache, kv_len):
        cfg = self.cfg
        h = L.rms_norm(p["ln1"], x)
        a_cache = m_conv = m_ssm = None
        if cache is not None:
            a_cache = {"k": cache["k"], "v": cache["v"]}
            m_conv, m_ssm = cache["conv"], cache["ssm"]
        ya, new_a = A.attention(p["attn"], h, n_heads=cfg.n_heads,
                                n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
                                positions=positions, rope_theta=cfg.rope_theta,
                                window=cfg.window, is_global=flag,
                                cache=a_cache, kv_len=kv_len,
                                cp_mesh=self._cp_mesh(), cp_dp=self.sh.dp,
                                sharder=self.sh if self.mesh is not None else None)
        ym, (new_conv, new_ssm) = S.mamba_mix(
            p["mamba"], h, m_conv, m_ssm,
            sharder=self.sh if self.mesh is not None else None)
        # normalized fusion of the parallel heads (hymba mean-of-norms)
        y = 0.5 * (L.rms_norm(p["mix_norm_a"], ya) + L.rms_norm(p["mix_norm_m"], ym))
        x = self.sh.acts(x + y)
        x, aux = self._ffn_block(p, x)
        new_cache = None
        if cache is not None:
            new_cache = {"k": new_a["k"], "v": new_a["v"],
                         "conv": new_conv, "ssm": new_ssm}
        return x, new_cache, aux

    # ------------------------------ vlm ------------------------------------
    def _run_vlm(self, units, x, positions, image_embeds, cache, kv_len):
        cfg = self.cfg

        def unit_body(carry, inp):
            x = carry
            u = inp["p"]
            c_in = inp.get("cache")

            def self_body(xx, sinp):
                sp = sinp["p"]
                sc = sinp.get("cache")
                xx, new_c = self._attn_block(sp, xx, positions=positions,
                                             cache=sc, kv_len=kv_len)
                xx, _ = self._ffn_block(sp, xx)
                return xx, {"cache": new_c}

            sxs: dict[str, Any] = {"p": u["self"]}
            if c_in is not None:
                sxs["cache"] = c_in["self"]
            x, sys_ = jax.lax.scan(self_body, x, sxs)

            cp = u["cross"]
            h = L.rms_norm(cp["ln1"], x)
            y = A.cross_attention(cp["attn"], h, image_embeds,
                                  n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                  head_dim=cfg.head_dim)
            x = self.sh.acts(x + jnp.tanh(cp["gate"]).astype(x.dtype) * y)
            y2 = L.mlp(cp["mlp"], L.rms_norm(cp["ln2"], x), gated=True)
            x = self.sh.acts(x + y2)
            new_c = {"self": sys_["cache"]} if c_in is not None else None
            return x, {"cache": new_c}

        xs: dict[str, Any] = {"p": units}
        if cache is not None:
            xs["cache"] = cache
        body = unit_body
        if self.remat:
            body = jax.checkpoint(unit_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, ys = jax.lax.scan(body, x, xs)
        return x, (ys["cache"] if cache is not None else None)

    # ------------------------------ xlstm -----------------------------------
    def _run_xlstm(self, units, x, cache):
        cfg = self.cfg
        decode = cache is not None and x.shape[1] == 1

        def unit_body(carry, inp):
            x = carry
            u = inp["p"]
            c = inp.get("cache")
            if decode:
                ym, new_m = S.mlstm_decode(u["mlstm"], L.rms_norm(u["m_ln"], x),
                                           c["mlstm"], cfg.n_heads)
            elif c is not None:  # prefill: seed + hand back the state
                ym, new_m = S.mlstm_sequence(u["mlstm"], L.rms_norm(u["m_ln"], x),
                                             cfg.n_heads, state=c["mlstm"],
                                             return_state=True)
            else:
                ym = S.mlstm_sequence(u["mlstm"], L.rms_norm(u["m_ln"], x),
                                      cfg.n_heads)
                new_m = None
            x = x + ym
            ys_, new_s = S.slstm_sequence(u["slstm"], L.rms_norm(u["s_ln"], x),
                                          cfg.n_heads,
                                          state=(c["slstm"] if c is not None else None))
            x = self.sh.acts(x + ys_)
            new_c = {"mlstm": new_m, "slstm": new_s} if c is not None else None
            return x, {"cache": new_c}

        xs: dict[str, Any] = {"p": units}
        if cache is not None:
            xs["cache"] = cache
        body = unit_body
        if self.remat:
            body = jax.checkpoint(unit_body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        x, ys = jax.lax.scan(body, x, xs)
        return x, (ys["cache"] if cache is not None else None)

    # --------------------------- loss / steps ------------------------------
    def loss_fn(self, params, batch):
        tokens = batch["tokens"]
        logits, aux, _ = self.forward(params, tokens,
                                      image_embeds=batch.get("image_embeds"))
        logits = logits[:, :-1].astype(jnp.float32)
        targets = tokens[:, 1:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        loss = nll.mean()
        return loss + 0.01 * aux, {"loss": loss, "aux": aux}

    # cache plumbing -------------------------------------------------------
    def _cache_layout(self, batch_size: int, max_len: int) -> Any:
        """Pytree of (shape, dtype, PartitionSpec, fill) cache descriptors."""
        cfg = self.cfg
        sh = self.sh
        dt = cfg.dtype
        total = max_len + cfg.n_meta_tokens

        def leaf(shape, dtype=dt, fill=0.0, **axkw):
            return (shape, dtype, sh.kv_cache_spec(shape, **axkw), fill)

        def rep(shape, dtype=jnp.float32, fill=0.0):
            # replicated-or-batch-sharded small state (recurrent states)
            spec = sh.kv_cache_spec(shape, batch_axis=1, seq_axis=1,
                                    head_axis=None)
            return (shape, dtype, spec, fill)

        def kv(n_layers):
            shape = (n_layers, batch_size, total, cfg.n_kv_heads, cfg.head_dim)
            return {"k": leaf(shape), "v": leaf(shape)}

        kind = cfg.block_kind
        if kind in ("gqa", "gemma", "musicgen", "gqa_moe"):
            return kv(cfg.n_layers)
        if kind == "mla_moe":
            def mla_cache(n):
                return {"c_kv": leaf((n, batch_size, total, cfg.kv_lora_rank),
                                     head_axis=None),
                        "k_rope": leaf((n, batch_size, total, cfg.qk_rope_dim),
                                       head_axis=None)}
            return {"dense": mla_cache(cfg.first_dense_layers),
                    "moe": mla_cache(cfg.n_layers - cfg.first_dense_layers)}
        if kind == "vlm":
            per = cfg.cross_every
            n_units = cfg.n_layers // per
            shape = (n_units, per - 1, batch_size, total, cfg.n_kv_heads,
                     cfg.head_dim)
            mk = lambda: leaf(shape, batch_axis=2, seq_axis=3, head_axis=4)
            return {"self": {"k": mk(), "v": mk()}}
        if kind == "xlstm":
            nu = cfg.n_layers // 2
            di = cfg.d_model * 2
            dh_m = di // cfg.n_heads
            dh_s = cfg.d_model // cfg.n_heads
            return {"mlstm": {"c": rep((nu, batch_size, cfg.n_heads, dh_m, dh_m)),
                              "n": rep((nu, batch_size, cfg.n_heads, dh_m)),
                              "m": rep((nu, batch_size, cfg.n_heads), fill=-1e30),
                              "conv": rep((nu, batch_size, cfg.conv_kernel - 1, di))},
                    "slstm": {"c": rep((nu, batch_size, cfg.n_heads, dh_s)),
                              "n": rep((nu, batch_size, cfg.n_heads, dh_s)),
                              "h": rep((nu, batch_size, cfg.n_heads, dh_s)),
                              "m": rep((nu, batch_size, cfg.n_heads, dh_s),
                                       fill=-1e30)}}
        if kind == "hymba":
            base = kv(cfg.n_layers)
            return {"k": base["k"], "v": base["v"],
                    "conv": rep((cfg.n_layers, batch_size,
                                 cfg.conv_kernel - 1, cfg.d_inner), dtype=dt),
                    "ssm": rep((cfg.n_layers, batch_size, cfg.d_inner,
                                cfg.ssm_state))}
        raise ValueError(kind)

    @staticmethod
    def _is_leaf(x):
        return isinstance(x, tuple) and len(x) == 4 and isinstance(x[0], tuple)

    def cache_specs(self, batch_size: int, max_len: int) -> Any:
        """PartitionSpec pytree for the cache (dryrun in_shardings)."""
        return jax.tree.map(lambda d: d[2],
                            self._cache_layout(batch_size, max_len),
                            is_leaf=self._is_leaf)

    def cache_shapes(self, batch_size: int, max_len: int) -> Any:
        return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d[0], d[1]),
                            self._cache_layout(batch_size, max_len),
                            is_leaf=self._is_leaf)

    def init_cache(self, batch_size: int, max_len: int) -> Any:
        def make(d):
            shape, dtype, spec, fill = d
            x = jnp.full(shape, fill, dtype) if fill else jnp.zeros(shape, dtype)
            return self.sh(x, *spec) if self.mesh is not None else x
        return jax.tree.map(make, self._cache_layout(batch_size, max_len),
                            is_leaf=self._is_leaf)

    def prefill(self, params, tokens, cache, image_embeds=None):
        logits, _, cache = self.forward(params, tokens, cache=cache, kv_len=0,
                                        image_embeds=image_embeds,
                                        last_token_only=True)
        return logits, cache

    def decode_step(self, params, tokens, cache, pos, image_embeds=None):
        """One-token decode. pos: scalar current length (excl. meta)."""
        kv_len = pos + self.cfg.n_meta_tokens if self.cfg.n_meta_tokens else pos
        logits, _, cache = self.forward(params, tokens, cache=cache,
                                        kv_len=kv_len, image_embeds=image_embeds)
        return logits, cache
