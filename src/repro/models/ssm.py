"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM) and Mamba-style SSM.

All are O(S) in sequence length with O(1) decode state — these are the
architectures the long_500k shape runs on (DESIGN.md §5).

mLSTM: matrix-memory LSTM with exponential gating (arXiv:2405.04517).
  Training uses the stabilized CHUNKWISE form: quadratic attention within a
  chunk (MXU-friendly), exact recurrent state handoff between chunks via
  lax.scan; numerically stabilized with running max-exponents (the paper's
  m-state).  Decode uses the O(1) recurrent update.

sLSTM: scalar-memory LSTM with hidden-to-hidden recurrence -> inherently
  sequential; lax.scan over time (block-diagonal per-head recurrence).

Mamba: selective SSM (input-dependent dt/B/C, diagonal A). Chunked
  associative scan: parallel within chunks, scanned across chunks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Params, _init, init_dense, dense

F32 = jnp.float32


# ==========================================================================
# causal depthwise conv (mamba/mLSTM front conv)
# ==========================================================================
def init_conv1d(key, d: int, k: int) -> Params:
    return {"w": _init(key, (k, d), scale=k ** -0.5)}


def conv1d(p: Params, x: jnp.ndarray, state: jnp.ndarray | None = None):
    """x: (B, S, D) causal depthwise conv; state: (B, k-1, D) history for
    decode. Returns (y, new_state)."""
    k = p["w"].shape[0]
    w = p["w"].astype(x.dtype)
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(x[:, :0])
    return y, new_state


# ==========================================================================
# mLSTM
# ==========================================================================
def init_mlstm(key, d: int, n_heads: int, proj_factor: float = 2.0,
               conv_k: int = 4) -> Params:
    di = int(d * proj_factor)
    ks = jax.random.split(key, 8)
    return {
        "in_up": init_dense(ks[0], d, 2 * di),          # x branch + gate branch
        "conv": init_conv1d(ks[1], di, conv_k),
        "wq": init_dense(ks[2], di, di),
        "wk": init_dense(ks[3], di, di),
        "wv": init_dense(ks[4], di, di),
        "wif": {"w": _init(ks[5], (di, 2 * n_heads), scale=di ** -0.5),
                "b": jnp.concatenate([jnp.zeros((n_heads,), F32),
                                      3.0 * jnp.ones((n_heads,), F32)])},
        "skip": init_dense(ks[6], di, di),
        "out": init_dense(ks[7], di, d),
        "mnorm": {"scale": jnp.ones((di,), F32)},
    }


def _mlstm_chunk(q, k, v, li, lf, state):
    """One stabilized chunk. q,k,v: (B,H,L,dh) f32; li,lf: (B,H,L) f32 logs.
    state = (C (B,H,dh,dh), n (B,H,dh), m (B,H)). Returns (h, new_state)."""
    L = q.shape[2]
    cum = jnp.cumsum(lf, axis=-1)                      # (B,H,L)
    total = cum[..., -1:]
    m_prev = state[2][..., None]                       # (B,H,1)

    # intra-chunk exponents D[a,b] = cum[a] - cum[b] + li[b]  (a >= b)
    dmat = cum[..., :, None] - cum[..., None, :] + li[..., None, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    dmat = jnp.where(causal, dmat, -jnp.inf)
    # inter exponent for query a: cum[a] + m_prev
    g = cum + m_prev                                   # (B,H,L)
    m_q = jnp.maximum(jnp.max(dmat, axis=-1), g)       # (B,H,L)

    scale = q.shape[-1] ** -0.5
    w_exp = jnp.exp(dmat - m_q[..., None])              # gate weights only
    scores = jnp.einsum("bhad,bhcd->bhac", q, k) * scale
    w_intra = scores * w_exp
    h_intra = jnp.einsum("bhac,bhcd->bhad", w_intra, v)
    qc = jnp.einsum("bhad,bhde->bhae", q * scale, state[0])
    h_inter = qc * jnp.exp(g - m_q)[..., None]
    num = h_intra + h_inter

    # normalizer state uses the GATE weights only (q enters once, via the
    # final |q . n| dot) — matches the recurrent form n_t = f n + i k
    n_intra = jnp.einsum("bhac,bhcd->bhad", w_exp, k)
    n_inter = state[1][..., None, :] * jnp.exp(g - m_q)[..., None]
    # denominator: max(|q . n|, exp(-m_q)) in stabilized units
    dot = jnp.einsum("bhad,bhad->bha", q * scale, n_intra + n_inter)
    den = jnp.maximum(jnp.abs(dot), jnp.exp(-m_q))
    h = num / den[..., None]

    # state handoff
    a_b = total - cum + li                             # (B,H,L)
    m_new = jnp.maximum(state[2] + total[..., 0], jnp.max(a_b, axis=-1))
    carry_scale = jnp.exp(state[2] + total[..., 0] - m_new)
    w_state = jnp.exp(a_b - m_new[..., None])          # (B,H,L)
    c_new = state[0] * carry_scale[..., None, None] + \
        jnp.einsum("bhld,bhle->bhde", k * w_state[..., None], v)
    n_new = state[1] * carry_scale[..., None] + (k * w_state[..., None]).sum(2)
    return h, (c_new, n_new, m_new)


def mlstm_sequence(p: Params, x: jnp.ndarray, n_heads: int,
                   chunk: int = 128, state: dict | None = None,
                   return_state: bool = False):
    """Full-sequence mLSTM block (training/prefill). x: (B, S, d).
    ``state`` (the decode-cache dict) seeds the recurrence; with
    ``return_state`` the final (c, n, m, conv) is returned so prefill hands
    off to decode."""
    b, s, d = x.shape
    up = dense(p["in_up"], x)
    di = up.shape[-1] // 2
    xb, zb = up[..., :di], up[..., di:]
    conv_in = state["conv"].astype(xb.dtype) if state is not None else None
    cx, conv_state = conv1d(p["conv"], xb, conv_in)
    cx = jax.nn.silu(cx)
    dh = di // n_heads

    def heads(t):
        return t.reshape(b, s, n_heads, dh).transpose(0, 2, 1, 3).astype(F32)

    q, k = heads(dense(p["wq"], cx)), heads(dense(p["wk"], cx))
    v = heads(dense(p["wv"], xb))
    gates = (xb.astype(F32) @ p["wif"]["w"]) + p["wif"]["b"]
    li = gates[..., :n_heads].transpose(0, 2, 1)           # log input gate (pre-exp)
    lf = jax.nn.log_sigmoid(gates[..., n_heads:]).transpose(0, 2, 1)

    lc = min(chunk, s)
    nchunks = -(-s // lc)
    pad = nchunks * lc - s
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0))) for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))

    def split(t):
        return jnp.moveaxis(t.reshape(b, n_heads, nchunks, lc, *t.shape[3:]), 2, 0)

    if state is not None:
        state0 = (state["c"].astype(F32), state["n"].astype(F32),
                  state["m"].astype(F32))
    else:
        state0 = (jnp.zeros((b, n_heads, dh, dh), F32),
                  jnp.zeros((b, n_heads, dh), F32),
                  jnp.full((b, n_heads), -1e30, F32))

    def step(st, inp):
        qc, kc, vc, lic, lfc = inp
        h, st = _mlstm_chunk(qc, kc, vc, lic, lfc, st)
        return st, h

    state_f, hs = jax.lax.scan(step, state0, (split(q), split(k), split(v),
                                              split(li), split(lf)))
    h = jnp.moveaxis(hs, 0, 2).reshape(b, n_heads, nchunks * lc, dh)[:, :, :s]
    h = h.transpose(0, 2, 1, 3).reshape(b, s, di)
    # head-wise norm + learnable skip + output gate
    from .layers import rms_norm
    h = rms_norm(p["mnorm"], h.astype(x.dtype))
    h = h + dense(p["skip"], cx)
    h = h * jax.nn.silu(zb)
    y = dense(p["out"], h)
    if return_state:
        new_state = {"c": state_f[0], "n": state_f[1], "m": state_f[2],
                     "conv": conv_state.astype(F32)}
        return y, new_state
    return y


def mlstm_decode_init(b: int, n_heads: int, di: int, conv_k: int, dtype=F32):
    dh = di // n_heads
    return {"c": jnp.zeros((b, n_heads, dh, dh), dtype),
            "n": jnp.zeros((b, n_heads, dh), dtype),
            "m": jnp.full((b, n_heads), -1e30, dtype),
            "conv": jnp.zeros((b, conv_k - 1, di), dtype)}


def mlstm_decode(p: Params, x: jnp.ndarray, cache: dict, n_heads: int):
    """One-token step. x: (B, 1, d). Returns (y, cache)."""
    b = x.shape[0]
    up = dense(p["in_up"], x)
    di = up.shape[-1] // 2
    xb, zb = up[..., :di], up[..., di:]
    cx, conv_state = conv1d(p["conv"], xb, cache["conv"].astype(xb.dtype))
    cx = jax.nn.silu(cx)
    dh = di // n_heads
    hshape = (b, n_heads, dh)
    q = dense(p["wq"], cx)[:, 0].reshape(hshape).astype(F32) * dh ** -0.5
    k = dense(p["wk"], cx)[:, 0].reshape(hshape).astype(F32)
    v = dense(p["wv"], xb)[:, 0].reshape(hshape).astype(F32)
    gates = (xb[:, 0].astype(F32) @ p["wif"]["w"]) + p["wif"]["b"]
    li, lf = gates[..., :n_heads], jax.nn.log_sigmoid(gates[..., n_heads:])
    m_new = jnp.maximum(lf + cache["m"], li)
    fs = jnp.exp(lf + cache["m"] - m_new)[..., None]
    is_ = jnp.exp(li - m_new)[..., None]
    c = cache["c"] * fs[..., None] + is_[..., None] * k[..., :, None] * v[..., None, :]
    n = cache["n"] * fs + is_ * k
    num = jnp.einsum("bhd,bhde->bhe", q, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))[..., None]
    h = (num / den).reshape(b, 1, di)
    from .layers import rms_norm
    h = rms_norm(p["mnorm"], h.astype(x.dtype))
    h = h + dense(p["skip"], cx)
    h = h * jax.nn.silu(zb)
    new_cache = {"c": c, "n": n, "m": m_new, "conv": conv_state}
    return dense(p["out"], h), new_cache


# ==========================================================================
# sLSTM
# ==========================================================================
def init_slstm(key, d: int, n_heads: int) -> Params:
    dh = d // n_heads
    ks = jax.random.split(key, 4)
    return {
        "wx": {"w": _init(ks[0], (d, 4 * d), scale=d ** -0.5)},
        "rh": {"w": _init(ks[1], (n_heads, dh, 4 * dh), scale=dh ** -0.5)},
        "bias": jnp.concatenate([jnp.zeros((2 * d,), F32),
                                 3.0 * jnp.ones((d,), F32),
                                 jnp.zeros((d,), F32)]),
        "gnorm": {"scale": jnp.ones((d,), F32)},
        "up": init_dense(ks[2], d, int(d * 4 / 3)),
        "down": init_dense(ks[3], int(d * 4 / 3), d),
    }


def slstm_sequence(p: Params, x: jnp.ndarray, n_heads: int,
                   state: dict | None = None):
    """x: (B, S, d) scanned over time (true recurrence). Returns (y, state)."""
    b, s, d = x.shape
    dh = d // n_heads
    wx = (x.astype(F32) @ p["wx"]["w"]) + p["bias"]      # (B,S,4d)
    wx = wx.reshape(b, s, 4, n_heads, dh)

    if state is None:
        z = jnp.zeros((b, n_heads, dh), F32)
        state = {"c": z, "n": z, "h": z, "m": jnp.full((b, n_heads, dh), -1e30, F32)}

    rh = p["rh"]["w"]  # (H, dh, 4dh)

    def step(st, wxt):
        rec = jnp.einsum("bhd,hde->bhe", st["h"], rh).reshape(b, n_heads, 4, dh)
        zi = jnp.tanh(wxt[:, 0] + rec[:, :, 0])
        ii = wxt[:, 1] + rec[:, :, 1]
        ff = wxt[:, 2] + rec[:, :, 2]
        oo = jax.nn.sigmoid(wxt[:, 3] + rec[:, :, 3])
        lf = jax.nn.log_sigmoid(ff)
        m_new = jnp.maximum(lf + st["m"], ii)
        fs = jnp.exp(lf + st["m"] - m_new)
        is_ = jnp.exp(ii - m_new)
        c = fs * st["c"] + is_ * zi
        n = fs * st["n"] + is_
        h = oo * c / jnp.maximum(jnp.abs(n), jnp.exp(-m_new))
        return {"c": c, "n": n, "h": h, "m": m_new}, h

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    from .layers import rms_norm
    h = rms_norm(p["gnorm"], h)
    y = dense(p["down"], jax.nn.gelu(dense(p["up"], h)))
    return y, state


# ==========================================================================
# Mamba (selective SSM)
# ==========================================================================
def init_mamba(key, d: int, d_inner: int, state: int = 16, conv_k: int = 4,
               dt_rank: int | None = None) -> Params:
    dt_rank = dt_rank or max(1, d // 16)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": init_dense(ks[0], d, 2 * d_inner),
        "conv": init_conv1d(ks[1], d_inner, conv_k),
        "wx_bc": init_dense(ks[2], d_inner, 2 * state),
        "wx_dt": init_dense(ks[3], d_inner, dt_rank),
        "w_dt": {"w": _init(ks[4], (dt_rank, d_inner), scale=dt_rank ** -0.5),
                 "b": jnp.log(jnp.expm1(0.01)) * jnp.ones((d_inner,), F32)},
        "a_log": jnp.log(jnp.tile(jnp.arange(1, state + 1, dtype=F32), (d_inner, 1))),
        "d_skip": jnp.ones((d_inner,), F32),
        "out_proj": init_dense(ks[5], d_inner, d),
    }


def _mamba_scan(decay, binp, h0, chunk: int):
    """h_t = decay_t * h_{t-1} + binp_t, scanned in chunks.
    decay/binp: (B, S, di, st) f32; h0: (B, di, st). Returns (hs, h_final)."""
    b, s, di, st = decay.shape
    lc = min(chunk, s)
    nch = -(-s // lc)
    pad = nch * lc - s
    if pad:
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=1.0)
        binp = jnp.pad(binp, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dec = jnp.moveaxis(decay.reshape(b, nch, lc, di, st), 1, 0)
    bin_ = jnp.moveaxis(binp.reshape(b, nch, lc, di, st), 1, 0)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def step(h, inp):
        d_c, b_c = inp
        acc_d, acc_b = jax.lax.associative_scan(assoc, (d_c, b_c), axis=1)
        hs = acc_d * h[:, None] + acc_b           # (B, lc, di, st)
        return hs[:, -1], hs

    h_final, chunks = jax.lax.scan(step, h0, (dec, bin_))
    hs = jnp.moveaxis(chunks, 0, 1).reshape(b, nch * lc, di, st)[:, :s]
    return hs, h_final


def mamba_mix(p: Params, x: jnp.ndarray, conv_state=None, ssm_state=None,
              chunk: int = 128, sharder=None):
    """Mamba mixer. x: (B,S,d). Returns (y, (conv_state, ssm_state)).
    States given -> decode mode (S small, typically 1).
    sharder: shard the d_inner channel axis over TP — the (B,S,di,st) scan
    tensors are the hybrid archs' dominant activation memory."""
    b, s, _ = x.shape
    di = p["in_proj"]["w"].shape[-1] // 2
    st = p["a_log"].shape[-1]

    def ch(t):  # channel-shard (last-but-one or last axis == di)
        if sharder is None or sharder.mesh is None or \
           di % sharder.mesh.shape[sharder.tp]:
            return t
        ax = t.ndim - 1 - (1 if t.shape[-1] == st else 0)
        spec = [None] * t.ndim
        if t.shape[0] % sharder.dp_size == 0 and t.shape[0] > 1:
            spec[0] = sharder.dp
        spec[ax] = sharder.tp
        return sharder(t, *spec)

    xz = dense(p["in_proj"], x)
    xb, z = xz[..., :di], xz[..., di:]
    cx, conv_state = conv1d(p["conv"], ch(xb), conv_state)
    cx = jax.nn.silu(cx)

    bc = dense(p["wx_bc"], cx).astype(F32)
    bmat, cmat = bc[..., :st], bc[..., st:]
    dt = dense(p["wx_dt"], cx).astype(F32) @ p["w_dt"]["w"] + p["w_dt"]["b"]
    dt = jax.nn.softplus(dt)                                  # (B,S,di)
    a = -jnp.exp(p["a_log"])                                  # (di, st)
    decay = ch(jnp.exp(dt[..., None] * a))                    # (B,S,di,st)
    binp = ch((dt * cx.astype(F32))[..., None] * bmat[:, :, None, :])
    if ssm_state is None:
        ssm_state = jnp.zeros((b, di, st), F32)
    hs, h_fin = _mamba_scan(decay, binp, ssm_state, chunk)
    y = jnp.einsum("bsdk,bsk->bsd", hs, cmat)
    y = y + cx.astype(F32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return dense(p["out_proj"], y), (conv_state, h_fin)
