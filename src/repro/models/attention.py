"""Attention: blocked flash-style softmax attention (pure JAX), GQA/MQA,
sliding-window, cross-attention, MLA (DeepSeek multi-head latent attention),
and the sequence-sharded decode path for long contexts.

The blocked implementation is the memory workhorse: scores never materialize
beyond (Bq x Bk) tiles, so prefill_32k and train_4k lower without O(S^2)
buffers — the same online-softmax recurrence a Pallas/TPU flash kernel uses,
expressed with lax.scan so XLA fuses it. (GPU papers implement this as a CUDA
kernel; on TPU the scan body is already MXU matmuls + VPU rescaling, see
DESIGN.md §2.)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

from .layers import Params, _init, apply_rope, init_dense, dense, rope_table

NEG_INF = -1e30


# --------------------------------------------------------------------------
# blocked attention core
# --------------------------------------------------------------------------
def _block_mask(q_pos, k_pos, *, causal: bool, window: int, is_global,
                kv_len) -> jnp.ndarray:
    """(Bq, Bk) bool mask. window>0 limits lookback; is_global (traced bool
    or None) switches window off per-layer; kv_len (traced or None) masks
    cache tail."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window:
        in_win = (q_pos[:, None] - k_pos[None, :]) < window
        if is_global is None:
            m &= in_win
        else:
            m &= jnp.logical_or(is_global, in_win)
    if kv_len is not None:
        m &= k_pos[None, :] < kv_len
    return m


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "softmax_scale",
                                             "vma"))
def blocked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      q_offset: jnp.ndarray | int = 0,
                      causal: bool = True, window: int = 0,
                      is_global=None, kv_len=None,
                      block_q: int = 512, block_k: int = 512,
                      softmax_scale: float | None = None,
                      vma: tuple[str, ...] = ()) -> jnp.ndarray:
    """q: (B, Sq, H, D); k, v: (B, Skv, KH, Dk/Dv) with H % KH == 0 (GQA).

    Returns (B, Sq, H, Dv).  Online softmax over KV blocks, scanned over Q
    blocks; f32 accumulation.
    """
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    rep = h // kh

    if sq <= 4:
        # decode path: one dense pass, no scan -> GSPMD can shard the KV
        # sequence axis (flash-decoding emerges from the sharded softmax).
        return _dense_attention(q, k, v, q_offset=q_offset, causal=causal,
                                window=window, is_global=is_global,
                                kv_len=kv_len, scale=scale)

    bq = min(block_q, sq)
    nq = -(-sq // bq)
    pad_q = nq * bq - sq
    bk = min(block_k, skv)
    nk = -(-skv // bk)
    pad_k = nk * bk - skv

    qf = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kf = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vf = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    # pad positions beyond the real kv range so masks kill them
    k_positions = jnp.arange(nk * bk)
    kv_len_eff = jnp.asarray(skv if kv_len is None else kv_len)

    qf = qf.reshape(b, nq, bq, h, d)
    kf = kf.reshape(b, nk, bk, kh, d)
    vf = vf.reshape(b, nk, bk, kh, dv)

    def q_block(carry, qi):
        qb, qpos = qi  # (B, bq, H, D), (bq,)

        def kv_block(state, ki):
            m_prev, l_prev, acc = state
            kb, vb, kpos = ki
            # grouped GQA: contract per kv-head group — NO jnp.repeat (a
            # repeat over a sharded head axis forces a full reshard)
            qg = qb.reshape(b, bq, kh, rep, d)
            s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(qpos, kpos, causal=causal, window=window,
                               is_global=is_global, kv_len=kv_len_eff)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kh, rep, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, rep, bq), jnp.float32)
        a0 = jnp.zeros((b, kh, rep, bq, dv), jnp.float32)
        if vma:  # under shard_map: mark carries varying over manual axes
            m0, l0, a0 = (jax.lax.pvary(t, vma) for t in (m0, l0, a0))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0),
             k_positions.reshape(nk, bk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, KH, rep, bq, Dv) -> (B, bq, H, Dv)
        return carry, jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, bq, h, dv)

    q_positions = (jnp.arange(nq * bq) + q_offset).reshape(nq, bq)
    _, blocks = jax.lax.scan(q_block, 0, (jnp.moveaxis(qf, 1, 0), q_positions))
    out = jnp.moveaxis(blocks, 0, 1).reshape(b, nq * bq, h, dv)
    return out[:, :sq].astype(q.dtype)


def _dense_attention(q, k, v, *, q_offset, causal, window, is_global,
                     kv_len, scale):
    """Decode path. Grouped GQA einsums (no repeat over the sharded head
    axis); softmax reductions over a sharded KV-sequence axis lower to the
    psum-combine of flash-decoding under GSPMD."""
    b, sq, h, d = q.shape
    skv, kh = k.shape[1], k.shape[2]
    rep = h // kh
    qg = q.reshape(b, sq, kh, rep, d)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    mask = _block_mask(jnp.arange(sq) + q_offset, jnp.arange(skv),
                       causal=causal, window=window, is_global=is_global,
                       kv_len=kv_len)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


# --------------------------------------------------------------------------
# context-parallel attention (sequence sharded over the TP axis)
# --------------------------------------------------------------------------
def context_parallel_attention(q, k, v, *, mesh, dp, tp: str = "model",
                               causal=True, window=0, is_global=None,
                               block_q=512, block_k=512,
                               softmax_scale=None):
    """Shard the QUERY sequence over the tp axis; each rank runs blocked
    attention for its slab against the full K/V (replicated over tp — KV for
    GQA models is small).  Used when n_heads % tp_size != 0, where head-TP
    would otherwise leave attention unsharded and GSPMD emits an all-reduce
    per block pair (the starcoder2 2.4 TB/step pathology).  Causality is
    preserved by passing the slab's absolute q_offset.
    """
    p = mesh.shape[tp]
    sq = q.shape[1]
    pad = (-sq) % p
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    dpb = dp if q.shape[0] % _dp_size(mesh, dp) == 0 and q.shape[0] > 1 else None
    qspec = P(dpb, tp, None, None)
    kvspec = P(dpb, None, None, None)
    slab = (sq + pad) // p

    vma = tuple(mesh.axis_names)

    def body(qb, kb, vb):
        off = jax.lax.axis_index(tp) * slab
        return blocked_attention(qb, kb, vb, q_offset=off, causal=causal,
                                 window=window, is_global=is_global,
                                 block_q=min(block_q, slab), block_k=block_k,
                                 softmax_scale=softmax_scale, vma=vma)

    out = _shard_map(body, mesh=mesh, in_specs=(qspec, kvspec, kvspec),
                        out_specs=qspec)(q, k, v)
    return out[:, :sq] if pad else out


def _dp_size(mesh, dp) -> int:
    n = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        n *= mesh.shape[a]
    return n


# --------------------------------------------------------------------------
# GQA self-attention layer
# --------------------------------------------------------------------------
def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": init_dense(ks[0], d_model, n_heads * head_dim),
        "wk": init_dense(ks[1], d_model, n_kv * head_dim),
        "wv": init_dense(ks[2], d_model, n_kv * head_dim),
        "wo": init_dense(ks[3], n_heads * head_dim, d_model),
    }
    if qk_norm:
        p["q_norm"] = {"scale": jnp.ones((head_dim,), jnp.float32)}
        p["k_norm"] = {"scale": jnp.ones((head_dim,), jnp.float32)}
    return p


def _head_norm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


def attention(p: Params, x: jnp.ndarray, *, n_heads: int, n_kv: int,
              head_dim: int, positions: jnp.ndarray, rope_theta: float = 1e4,
              window: int = 0, is_global=None, qk_norm: bool = False,
              cache: dict | None = None, kv_len=None,
              block_q: int = 512, block_k: int = 512,
              cp_mesh=None, cp_dp=("data",),
              sharder=None) -> tuple[jnp.ndarray, dict | None]:
    """Self attention with optional KV cache.

    Train/prefill: positions (S,) (prefill passes kv_len=0 and a cache to
    fill; attention runs over the fresh block — correct since prefill starts
    the sequence).  Decode: cache holds {'k','v'} (B, Smax, KH, D), kv_len is
    the current length, x is the new token(s).
    cp_mesh: enable context-parallel attention (sequence sharded over the TP
    axis) — used when head-TP is impossible (n_heads % tp != 0).
    Returns (y, updated_cache).
    """
    b, s, _ = x.shape
    q = dense(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = dense(p["wk"], x).reshape(b, s, n_kv, head_dim)
    v = dense(p["wv"], x).reshape(b, s, n_kv, head_dim)
    if qk_norm:
        q = _head_norm(p["q_norm"], q)
        k = _head_norm(p["k_norm"], k)
    cos, sin = rope_table(positions, head_dim, rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # §Perf iterations 5/6: at 32k prefill, GSPMD's own propagation (q-row
    # sharding inside each block, S^2/tp compute, no reshard) beats an
    # explicit head-TP boundary by 6.6x attention flops — so NO constraint
    # for long sequences. For short-seq training under the SP residual, the
    # measured auto-propagation produces a reshard storm (120k all-gathers,
    # 7.7 TB/step on gemma) — there the explicit seq->heads boundary wins.
    if sharder is not None and cache is None and cp_mesh is None and s <= 8192:
        q, k, v = sharder.heads(q), sharder.heads(k), sharder.heads(v)

    new_cache = None
    if cache is not None:
        start = kv_len if kv_len is not None else 0
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), start, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), start, axis=1)
        new_cache = {"k": ck, "v": cv}

    if cache is not None and s <= 4:  # decode: dense pass over the cache
        y = blocked_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                              q_offset=positions[0], causal=True,
                              window=window, is_global=is_global,
                              kv_len=(kv_len + s) if kv_len is not None else None,
                              block_q=block_q, block_k=block_k)
    elif cp_mesh is not None:  # train/prefill, context parallel
        y = context_parallel_attention(q, k, v, mesh=cp_mesh, dp=cp_dp,
                                       causal=True, window=window,
                                       is_global=is_global, block_q=block_q,
                                       block_k=block_k)
    else:  # train/prefill, head-TP
        y = blocked_attention(q, k, v, q_offset=0, causal=True, window=window,
                              is_global=is_global, block_q=block_q,
                              block_k=block_k)
    return dense(p["wo"], y.reshape(b, s, n_heads * head_dim)), new_cache


# --------------------------------------------------------------------------
# cross-attention (VLM decoder layers; KV from precomputed vision tokens)
# --------------------------------------------------------------------------
def init_cross_attention(key, d_model: int, n_heads: int, n_kv: int,
                         head_dim: int, d_kv_in: int | None = None) -> Params:
    ks = jax.random.split(key, 4)
    d_kv_in = d_kv_in or d_model
    return {
        "wq": init_dense(ks[0], d_model, n_heads * head_dim),
        "wk": init_dense(ks[1], d_kv_in, n_kv * head_dim),
        "wv": init_dense(ks[2], d_kv_in, n_kv * head_dim),
        "wo": init_dense(ks[3], n_heads * head_dim, d_model),
    }


def cross_attention(p: Params, x: jnp.ndarray, kv_src: jnp.ndarray, *,
                    n_heads: int, n_kv: int, head_dim: int,
                    block_q: int = 512, block_k: int = 512) -> jnp.ndarray:
    b, s, _ = x.shape
    skv = kv_src.shape[1]
    q = dense(p["wq"], x).reshape(b, s, n_heads, head_dim)
    k = dense(p["wk"], kv_src).reshape(b, skv, n_kv, head_dim)
    v = dense(p["wv"], kv_src).reshape(b, skv, n_kv, head_dim)
    y = blocked_attention(q, k, v, causal=False, block_q=block_q, block_k=block_k)
    return dense(p["wo"], y.reshape(b, s, n_heads * head_dim))


# --------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek V2)
# --------------------------------------------------------------------------
def init_mla(key, d_model: int, n_heads: int, *, kv_lora: int, nope_dim: int,
             rope_dim: int, v_dim: int) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "wq": init_dense(ks[0], d_model, n_heads * (nope_dim + rope_dim)),
        "wdkv": init_dense(ks[1], d_model, kv_lora + rope_dim),
        "kv_norm": {"scale": jnp.ones((kv_lora,), jnp.float32)},
        "wuk": init_dense(ks[2], kv_lora, n_heads * nope_dim),
        "wuv": init_dense(ks[3], kv_lora, n_heads * v_dim),
        "wo": init_dense(ks[4], n_heads * v_dim, d_model),
    }


def mla_attention(p: Params, x: jnp.ndarray, *, n_heads: int, kv_lora: int,
                  nope_dim: int, rope_dim: int, v_dim: int,
                  positions: jnp.ndarray, rope_theta: float = 1e4,
                  cache: dict | None = None, kv_len=None,
                  block_q: int = 512, block_k: int = 512,
                  sharder=None) -> tuple[jnp.ndarray, dict | None]:
    """Train/prefill path: decompress K up-front, run blocked attention.
    Decode path (cache given): ABSORBED form — scores live in the kv_lora
    latent space, cache stores only (c_kv, k_rope): the paper-exact memory
    win (576 vs 2*H*D floats per position).
    """
    b, s, _ = x.shape
    hd = nope_dim + rope_dim
    q = dense(p["wq"], x).reshape(b, s, n_heads, hd)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    cos, sin = rope_table(positions, rope_dim, rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    dkv = dense(p["wdkv"], x)
    c_kv = _head_norm(p["kv_norm"], dkv[..., :kv_lora])
    k_rope = apply_rope(dkv[..., None, kv_lora:], cos, sin)  # (B,S,1,rope)

    if cache is None:
        wuk = p["wuk"]["w"].astype(x.dtype).reshape(kv_lora, n_heads, nope_dim)
        k_nope = jnp.einsum("bsc,chd->bshd", c_kv, wuk)
        v = jnp.einsum("bsc,chd->bshd", c_kv,
                       p["wuv"]["w"].astype(x.dtype).reshape(kv_lora, n_heads, v_dim))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, n_heads, rope_dim))], -1)
        qq = jnp.concatenate([q_nope, q_rope], -1)
        del sharder  # see §Perf iteration 5 note in attention()
        y = blocked_attention(qq, k, v, causal=True, block_q=block_q,
                              block_k=block_k, softmax_scale=hd ** -0.5)
        new_cache = None
    else:
        # absorbed decode: q_abs = W_uk^T q_nope  in latent space
        start = kv_len if kv_len is not None else 0
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), start, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype), start, axis=1)
        new_cache = {"c_kv": cc, "k_rope": cr}
        wuk = p["wuk"]["w"].astype(x.dtype).reshape(kv_lora, n_heads, nope_dim)
        q_abs = jnp.einsum("bshd,chd->bshc", q_nope, wuk)     # (B,S,H,kv_lora)
        qq = jnp.concatenate([q_abs, q_rope], -1)             # (B,S,H,kv_lora+rope)
        kk = jnp.concatenate([cc, cr], -1)[:, :, None, :].astype(x.dtype)  # (B,Smax,1,c+r)
        y_lat = blocked_attention(qq, kk, kk[..., :kv_lora],
                                  q_offset=positions[0], causal=True,
                                  kv_len=(kv_len + s) if kv_len is not None else None,
                                  block_q=block_q, block_k=block_k,
                                  softmax_scale=hd ** -0.5)   # (B,S,H,kv_lora)
        wuv = p["wuv"]["w"].astype(x.dtype).reshape(kv_lora, n_heads, v_dim)
        y = jnp.einsum("bshc,chd->bshd", y_lat, wuv)
        return dense(p["wo"], y.reshape(b, s, n_heads * v_dim)), new_cache

    return dense(p["wo"], y.reshape(b, s, n_heads * v_dim)), new_cache
