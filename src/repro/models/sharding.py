"""Sharding rules: param-path -> PartitionSpec, plus activation constraint
helpers.

Baseline layout (DESIGN.md §6): Megatron tensor parallelism on the 'model'
axis (attention heads / d_ff / experts / vocab), ZeRO-3 FSDP on the 'data'
axis (the largest non-TP dim of every weight), batch over ('pod','data').
XLA GSPMD materializes the ZeRO all-gathers just-in-time because weights are
sharded on 'data' while activations are batch-sharded on it.

Everything dispatches on leaf *path names* produced by the layer inits in
models/layers.py — no framework metadata needed.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

TP = "model"
FSDP = "data"


def _rule(path: str, shape: tuple[int, ...]) -> P:
    """PartitionSpec for one param. ``path`` is '/'-joined key path."""
    nd = len(shape)
    leaf = path.rsplit("/", 1)[-1]

    # --- embeddings & heads: (V, d) vocab-TP, d-FSDP
    if "table" in leaf or "embed" in path or "lm_head" in path:
        return P(TP, FSDP) if nd == 2 else P(None)
    if "meta_tokens" in path:
        return P(None, None)

    # --- MoE expert stacks: (E, d, ff) / (E, ff, d) (+ optional layer dim)
    if any(k in path for k in ("moe/up", "moe/gate", "moe/down")):
        if nd == 3:
            return P(TP, None, FSDP)
        if nd == 4:  # scanned: (L, E, ...)
            return P(None, TP, None, FSDP)
    if "router" in path:
        return P(*([None] * nd))

    # --- attention projections
    if leaf == "w":
        if any(k in path for k in ("wq", "wk", "wv", "in_up", "in_proj",
                                   "up", "gate", "wx")):
            # (d_in, big) -> TP on the wide output dim, FSDP on input dim
            if nd == 2:
                return P(FSDP, TP)
            if nd == 3:  # scanned (L, d_in, big)
                return P(None, FSDP, TP)
        if any(k in path for k in ("wo", "down", "out", "out_proj", "wuk",
                                   "wuv")):
            # (big, d_out) -> TP on input dim, FSDP on output dim
            if nd == 2:
                return P(TP, FSDP)
            if nd == 3:
                return P(None, TP, FSDP)
        if "wdkv" in path or "w_dt" in path or "wx_bc" in path or "wx_dt" in path:
            if nd == 2:
                return P(FSDP, None)
            if nd == 3:
                return P(None, FSDP, None)
        if "rh" in path:  # (H, dh, 4dh) slstm recurrence
            return P(*([None] * nd)) if nd < 3 else P(*([None] * (nd - 3)), TP, None, None)
        if "conv" in path:
            return P(*([None] * nd))
        # fallback 2D: FSDP x TP
        if nd >= 2:
            return P(*([None] * (nd - 2)), FSDP, TP)
    # --- norms, biases, gates, scalars: replicate
    return P(*([None] * nd))


def _fit_to_mesh(spec: P, shape: tuple[int, ...], mesh: Mesh | None) -> P:
    """Drop sharded axes whose mesh size does not divide the dim (odd vocab
    sizes like 49155, small head counts); keeps the rest of the spec."""
    if mesh is None:
        return spec
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if dim % size == 0 else None)
    return P(*out)


def param_specs(params: Any, mesh: Mesh | None = None) -> Any:
    """Pytree of PartitionSpecs matching ``params`` (mesh-divisibility
    checked when a mesh is given)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    paths = ["/".join(_key_str(k) for k in kp) for kp, _ in flat]
    specs = [_fit_to_mesh(_rule(p, np.shape(v)), np.shape(v), mesh)
             for p, (_, v) in zip(paths, flat)]
    tree = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(tree, specs)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


class Sharder:
    """Activation-constraint helper; identity when no mesh is active."""

    def __init__(self, mesh: Mesh | None = None, dp=("data",), tp: str = TP,
                 pod_in_dp: bool = True):
        self.mesh = mesh
        if mesh is not None and pod_in_dp and "pod" in mesh.axis_names:
            dp = ("pod",) + tuple(a for a in dp if a != "pod")
        self.dp = tuple(dp)
        self.tp = tp

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        out = 1
        for a in self.dp:
            out *= self.mesh.shape[a]
        return out

    def __call__(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def batch(self, x):
        """Shard dim 0 over dp axes (if divisible), rest replicated."""
        if self.mesh is None:
            return x
        if x.shape[0] % self.dp_size == 0:
            return self(x, self.dp, *([None] * (x.ndim - 1)))
        return x

    sp = True  # sequence-parallel residual stream (Megatron-SP layout)

    def acts(self, x):
        """(B, S, d) activations between blocks: batch over dp; with SP the
        sequence axis is additionally sharded over tp, so scan-over-layers
        carries (the dominant remat memory) shrink by the TP degree."""
        if self.mesh is None:
            return x
        b_ok = x.shape[0] % self.dp_size == 0 and x.shape[0] > 1
        s_ok = (self.sp and x.ndim >= 3 and
                x.shape[1] % self.mesh.shape[self.tp] == 0 and x.shape[1] > 1)
        if not b_ok and not s_ok:
            return x
        return self(x, self.dp if b_ok else None,
                    self.tp if s_ok else None, *([None] * (x.ndim - 2)))

    def heads(self, x):
        """(B, S, H, dh): batch over dp, heads over tp."""
        if self.mesh is None:
            return x
        b_ok = x.shape[0] % self.dp_size == 0
        h_ok = x.shape[2] % self.mesh.shape[self.tp] == 0
        return self(x, self.dp if b_ok else None, None,
                    self.tp if h_ok else None, None)

    def kv_cache_spec(self, shape, batch_axis: int = 1, seq_axis: int = 2,
                      head_axis: int | None = 3) -> P:
        """Spec for a stacked cache (L, B, Smax, KH, dh) [axes configurable]:
        batch over dp if divisible, else sequence over dp (long-context
        decode); heads over tp when divisible, else the sequence axis takes
        tp too (few-KV-head models at 32k x 128 would not fit otherwise)."""
        if self.mesh is None:
            return P()
        specs: list = [None] * len(shape)
        if shape[batch_axis] % self.dp_size == 0 and shape[batch_axis] > 1:
            specs[batch_axis] = self.dp
        elif shape[seq_axis] % self.dp_size == 0:
            specs[seq_axis] = self.dp
        tp_n = self.mesh.shape[self.tp]
        if head_axis is not None and shape[head_axis] % tp_n == 0:
            specs[head_axis] = self.tp
        elif specs[seq_axis] is None and shape[seq_axis] % tp_n == 0:
            specs[seq_axis] = self.tp
        elif specs[seq_axis] == self.dp and shape[seq_axis] % (self.dp_size * tp_n) == 0:
            specs[seq_axis] = (*self.dp, self.tp)
        return P(*specs)

    def kv_cache(self, x, batch_axis: int = 1, seq_axis: int = 2,
                 head_axis: int | None = 3):
        if self.mesh is None:
            return x
        spec = self.kv_cache_spec(x.shape, batch_axis, seq_axis, head_axis)
        return self(x, *spec)

    def logits(self, x):
        if self.mesh is None:
            return x
        b_ok = x.shape[0] % self.dp_size == 0
        v_ok = x.shape[-1] % self.mesh.shape[self.tp] == 0
        return self(x, self.dp if b_ok else None,
                    *([None] * (x.ndim - 2)), self.tp if v_ok else None)
