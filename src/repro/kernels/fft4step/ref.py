"""Pure-jnp oracle for the fft4step kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.fft.reference import dft_matrix, twiddles


def fft4step_ref(xr: jnp.ndarray, xi: jnp.ndarray, n1: int, n2: int,
                 inverse: bool = False):
    """Four-step FFT on planes. x*: (B, n1, n2) f32 (row-major signal view).

    Returns (yr, yi) each (B, n2, n1) — the TRANSPOSED four-step output, i.e.
    flattening the last two axes yields the natural-order spectrum.
    Forward unnormalized, inverse without 1/n (callers normalize).
    """
    x = (xr + 1j * xi).astype(jnp.complex128)
    w1 = dft_matrix(n1, inverse=inverse, dtype=jnp.complex128)
    w2 = dft_matrix(n2, inverse=inverse, dtype=jnp.complex128)
    t = twiddles(n1, n2, inverse=inverse, dtype=jnp.complex128)
    b = jnp.einsum("kj,bjn->bkn", w1, x)          # column DFTs (over j1)
    c = b * t                                      # twiddle
    d = jnp.einsum("bkn,nm->bkm", c, w2)           # row DFTs (over j2)
    d = jnp.swapaxes(d, -1, -2)                    # (B, n2, n1)
    return jnp.real(d).astype(xr.dtype), jnp.imag(d).astype(xr.dtype)
