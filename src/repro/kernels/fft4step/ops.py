"""jit'd public wrapper for the fft4step kernel: complex API, factor choice,
padding, normalization."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.fft.reference import dft_matrix, twiddles
from .fft4step import fft4step, DEFAULT_TILE_B


def choose_factors(n: int) -> tuple[int, int]:
    """Pick n = n1*n2 with both factors <= 128 and as square as possible
    (square split balances the two matmul shapes on the MXU)."""
    best = None
    for n1 in range(min(128, n), 0, -1):
        if n % n1 == 0 and n // n1 <= 128:
            n2 = n // n1
            score = abs(n1 - n2)
            if best is None or score < best[0]:
                best = (score, n1, n2)
    if best is None:
        raise ValueError(f"n={n} has no n1*n2 factorization with both <= 128 "
                         "(max single-kernel n is 16384); compose kernels or "
                         "use the fourstep jnp path")
    return best[1], best[2]


@functools.partial(jax.jit, static_argnames=("inverse", "interpret", "tile_b"))
def fft(x: jnp.ndarray, inverse: bool = False, *, interpret: bool = False,
        tile_b: int = DEFAULT_TILE_B) -> jnp.ndarray:
    """Four-step FFT along the last axis via the fused Pallas kernel.

    Supports any n with an n1*n2 (<=128 each) factorization, i.e. n <= 16384
    for powers of two. numpy semantics (inverse applies 1/n).
    """
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    n1, n2 = choose_factors(n)
    # planes carry the problem's real dtype (f64 for c128 inputs)
    rdt = jnp.float64 if x.dtype == jnp.complex128 else jnp.float32
    batch_shape = x.shape[:-1]
    flat = x.reshape(-1, n1, n2)
    b = flat.shape[0]
    tile = min(tile_b, max(1, b))
    pad = (-b) % tile

    xr = jnp.real(flat).astype(rdt)
    xi = jnp.imag(flat).astype(rdt)
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0), (0, 0)))

    w1 = dft_matrix(n1, inverse=inverse, dtype=jnp.complex128)
    w2 = dft_matrix(n2, inverse=inverse, dtype=jnp.complex128)
    t = twiddles(n1, n2, inverse=inverse, dtype=jnp.complex128)
    planes = lambda z: (jnp.real(z).astype(rdt), jnp.imag(z).astype(rdt))
    w1r, w1i = planes(w1)
    w2r, w2i = planes(w2)
    tr, ti = planes(t)

    yr, yi = fft4step(xr, xi, w1r, w1i, w2r, w2i, tr, ti,
                      n1=n1, n2=n2, tile_b=tile, interpret=interpret)
    y = (yr[:b] + 1j * yi[:b]).reshape(*batch_shape, n).astype(x.dtype)
    if inverse:
        y = y / n
    return y
