"""Pallas TPU kernel: fused four-step FFT, fully resident in VMEM.

One kernel pass computes, for a tile of TILE_B independent signals of length
n = n1*n2 (n1, n2 <= 128):

    D = (W1 @ A * T) @ W2 ;  out = D^T        (paper Eq. 2 as matmuls)

- 8 real (MXU) matmuls per complex signal tile (2 complex matmuls),
- twiddle multiply and transpose fused between them (VPU, no HBM round-trip).

A butterfly FFT of n=16384 touches HBM log2(n)=14 times if staged naively;
this kernel reads the signal from HBM exactly once and writes it once —
the arithmetic-intensity transformation that moves the FFT from the paper's
"memory-bound above 1 MiB" regime toward the MXU roofline on TPU.

VMEM at TILE_B=8, n=16384: in/out planes 4 x 8 x 64 KiB = 2 MiB, DFT matrices
4 x 64 KiB, twiddles 2 x 64 KiB -> ~2.5 MiB of ~16 MiB/core.

BlockSpec layout (grid over batch tiles):
  x_re, x_im : (TILE_B, n1, n2) VMEM, block i -> batch tile i
  w1_*       : (n1, n1) VMEM broadcast;  w2_* : (n2, n2) VMEM broadcast
  t_*        : (n1, n2) VMEM broadcast (twiddle grid)
  y_re, y_im : (TILE_B, n2, n1) VMEM (transposed four-step output)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_B = 8


def _fft4step_kernel(xr_ref, xi_ref, w1r_ref, w1i_ref, w2r_ref, w2i_ref,
                     tr_ref, ti_ref, yr_ref, yi_ref):
    xr = xr_ref[...]  # (TB, n1, n2)
    xi = xi_ref[...]
    w1r, w1i = w1r_ref[...], w1i_ref[...]
    w2r, w2i = w2r_ref[...], w2i_ref[...]
    tr, ti = tr_ref[...], ti_ref[...]

    # accumulate in the plane dtype (f32 planes for c64 problems, f64 for
    # c128 — double runs in interpret mode / on f64-capable backends)
    dot = functools.partial(jax.lax.dot_general,
                            preferred_element_type=xr.dtype)
    # column DFTs: B[b,k,n] = sum_j W1[k,j] X[b,j,n]  (contract j with dim 1)
    dims = (((1,), (1,)), ((), ()))  # w1 (k,j) . x (b,j,n) -> (k,b,n)
    br = dot(w1r, xr, dims) - dot(w1i, xi, dims)
    bi = dot(w1r, xi, dims) + dot(w1i, xr, dims)
    # twiddle multiply, broadcast over batch dim (axis 1 here)
    t_r = tr[:, None, :]
    t_i = ti[:, None, :]
    cr = br * t_r - bi * t_i
    ci = br * t_i + bi * t_r
    # row DFTs: D[k,b,m] = sum_n C[k,b,n] W2[n,m]
    dims2 = (((2,), (0,)), ((), ()))
    dr = dot(cr, w2r, dims2) - dot(ci, w2i, dims2)
    di = dot(cr, w2i, dims2) + dot(ci, w2r, dims2)
    # output transpose: (k,b,m) -> (b,m,k) == (TB, n2, n1)
    yr_ref[...] = jnp.transpose(dr, (1, 2, 0))
    yi_ref[...] = jnp.transpose(di, (1, 2, 0))


@functools.partial(jax.jit,
                   static_argnames=("n1", "n2", "tile_b", "interpret"))
def fft4step(xr, xi, w1r, w1i, w2r, w2i, tr, ti, *, n1: int, n2: int,
             tile_b: int = DEFAULT_TILE_B, interpret: bool = False):
    """x planes: (B, n1, n2) f32; returns y planes (B, n2, n1)."""
    b = xr.shape[0]
    tile_b = min(tile_b, b)
    assert b % tile_b == 0, f"batch {b} % tile {tile_b} != 0 (ops.py pads)"
    grid = (b // tile_b,)
    sig_in = pl.BlockSpec((tile_b, n1, n2), lambda i: (i, 0, 0))
    sig_out = pl.BlockSpec((tile_b, n2, n1), lambda i: (i, 0, 0))
    m1 = pl.BlockSpec((n1, n1), lambda i: (0, 0))
    m2 = pl.BlockSpec((n2, n2), lambda i: (0, 0))
    tw = pl.BlockSpec((n1, n2), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((b, n2, n1), xr.dtype)] * 2
    yr, yi = pl.pallas_call(
        _fft4step_kernel,
        grid=grid,
        in_specs=[sig_in, sig_in, m1, m1, m2, m2, tw, tw],
        out_specs=[sig_out, sig_out],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, w1r, w1i, w2r, w2i, tr, ti)
    return yr, yi
