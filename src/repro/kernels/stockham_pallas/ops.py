"""jit'd public wrapper for the stockham_pallas kernel: complex API, mixed-
radix schedule + twiddle packing (host-side float64), batch tiling/padding,
normalization."""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .stockham_pallas import (DEFAULT_TILE_B, radix_schedule, smooth7,
                              stockham_pallas)

#: Soft VMEM budget steering the default batch tile (in/out/stage planes;
#: real VMEM is ~16 MiB/core, leave headroom for twiddles + double buffers).
VMEM_BUDGET_BYTES = 4 << 20

#: Largest single-kernel n: bounded by holding one (tile_b=1) signal's
#: working planes in VMEM.  Larger transforms go through the six-step path.
MAX_N = 1 << 20


def pack_twiddles(n: int, radices: tuple[int, ...], inverse: bool,
                  real_dtype) -> tuple[np.ndarray, np.ndarray,
                                       tuple[tuple[int, ...], ...]]:
    """Per-stage twiddle planes W_cur^{p*u} (u = 1..r-1, p < cur/r) packed
    into one (1, L) pair, plus static per-(stage, u) offsets.

    Angles use exact integer reduction of p*u mod cur before the float64
    conversion, so phases stay accurate for n in the millions even when the
    planes are float32.
    """
    sign = 2.0 if inverse else -2.0
    re_chunks, im_chunks, offsets = [], [], []
    off, cur = 0, n
    for r in radices:
        m = cur // r
        stage_offs = []
        p = np.arange(m, dtype=np.int64)
        for u in range(1, r):
            ang = (sign * np.pi / cur) * ((u * p) % cur).astype(np.float64)
            re_chunks.append(np.cos(ang))
            im_chunks.append(np.sin(ang))
            stage_offs.append(off)
            off += m
        offsets.append(tuple(stage_offs))
        cur = m
    pad = (-off) % 128 or (128 if off == 0 else 0)  # lane-align the pack
    re_chunks.append(np.zeros(pad))
    im_chunks.append(np.zeros(pad))
    twr = np.concatenate(re_chunks)[None, :].astype(real_dtype)
    twi = np.concatenate(im_chunks)[None, :].astype(real_dtype)
    return twr, twi, tuple(offsets)


def default_tile_b(n: int, batch: int, itemsize: int, *, planes: int = 6,
                   cap: int = 256) -> int:
    """Largest power-of-two batch tile whose working planes fit the VMEM
    budget.  ``planes`` is the live-plane estimate per signal row (~6 here:
    in/out/stage temporaries; the rank-2 kernel passes 8 for its transpose
    temporaries), ``cap`` the kernel's tile ceiling."""
    per_row = planes * n * itemsize
    tile = max(1, VMEM_BUDGET_BYTES // max(1, per_row))
    tile = 1 << (tile.bit_length() - 1)
    return max(1, min(tile, cap, batch))


@functools.partial(jax.jit,
                   static_argnames=("inverse", "tile_b", "radix", "interpret"))
def fft(x: jnp.ndarray, inverse: bool = False, *, tile_b: int | None = None,
        radix: int = 8, interpret: bool = False) -> jnp.ndarray:
    """Fused Stockham FFT along the last axis via the Pallas kernel.

    7-smooth (2^a*3^b*5^c*7^d) lengths up to ``MAX_N``; all mixed-radix
    stages run on a VMEM-resident batch tile, so the signal touches HBM once
    each way.  numpy semantics (inverse applies 1/n).  ``tile_b``/``radix``
    are the PATIENT-searchable knobs (``radix`` sizes the pow2 work stages;
    ``tile_b=None`` sizes the tile to VMEM).
    """
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    if not smooth7(n):
        raise ValueError("stockham_pallas requires a 7-smooth "
                         f"(2^a*3^b*5^c*7^d) length, got {n}")
    if n > MAX_N:
        raise ValueError(f"stockham_pallas caps at n={MAX_N}; "
                         "use the sixstep backend beyond that")
    if n == 1:
        return x   # length-1 DFT is the identity (1/n factor is 1 too)

    real_dtype = jnp.float64 if x.dtype == jnp.complex128 else jnp.float32
    batch_shape = x.shape[:-1]
    flat = x.reshape(-1, n)
    b = flat.shape[0]
    tile = tile_b if tile_b is not None else default_tile_b(
        n, b, jnp.dtype(real_dtype).itemsize)
    tile = min(tile, max(1, b))
    pad = (-b) % tile

    xr = jnp.real(flat).astype(real_dtype)
    xi = jnp.imag(flat).astype(real_dtype)
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0)))

    radices = radix_schedule(n, radix)
    twr, twi, offsets = pack_twiddles(n, radices, inverse, real_dtype)
    yr, yi = stockham_pallas(xr, xi, jnp.asarray(twr), jnp.asarray(twi),
                             n=n, radices=radices, offsets=offsets,
                             inverse=inverse, tile_b=tile, interpret=interpret)
    y = (yr[:b] + 1j * yi[:b]).reshape(*batch_shape, n).astype(x.dtype)
    if inverse:
        y = y / n
    return y
