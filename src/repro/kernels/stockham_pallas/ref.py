"""Pure-jnp oracle for the stockham_pallas kernel: the same general-radix
DIF Stockham recursion on complex arrays, one stage per HBM pass."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .stockham_pallas import radix_schedule


def stockham_ref(x: jnp.ndarray, radix: int = 8,
                 inverse: bool = False) -> jnp.ndarray:
    """General-radix Stockham FFT along the last axis (7-smooth length).

    Mirrors the kernel's stage schedule exactly — radix-7/5/3 odd stages,
    then radix-``radix`` work stages with a 4/2 cleanup — so kernel-vs-ref
    comparisons isolate the Pallas lowering, not the factorization.
    Forward unnormalized, inverse applies 1/n (numpy semantics).
    """
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    batch = x.shape[:-1]
    sign = 2.0 if inverse else -2.0

    cur = n
    for r in radix_schedule(n, radix):
        m = cur // r
        s = n // cur
        v = x.reshape(*batch, r, m, s)
        wr = np.exp(1j * (sign * np.pi / r) * np.arange(r, dtype=np.float64))
        p = np.arange(m, dtype=np.int64)
        rows = []
        for u in range(r):
            acc = sum(v[..., t, :, :] * complex(wr[(t * u) % r])
                      for t in range(r))
            ang = (sign * np.pi / cur) * ((u * p) % cur).astype(np.float64)
            tw = jnp.asarray(np.exp(1j * ang), dtype=x.dtype)
            rows.append(acc * tw[:, None])
        x = jnp.stack(rows, axis=-2).reshape(*batch, n)   # (..., m, r, s)
        cur = m

    if inverse:
        x = x / n
    return x
