"""Pallas TPU kernel: fused multi-stage Stockham FFT, fully resident in VMEM.

The pure-jnp Stockham backend (``repro/fft/stockham.py``) pays one HBM
round-trip per radix-2 stage — log2(N) passes over the signal, which is
exactly the "memory-bound above 1 MiB" regime of the paper's Fig. 8.  This
kernel runs *every* stage of the autosort chain on a VMEM-resident batch
tile: the signal is read from HBM once, transformed through a static radix
schedule (radix-3/5/7 work stages for the odd factors, then radix-8/4
stages with a radix-2 cleanup for the power-of-two part), and written once.
Any 7-smooth length n = 2^a * 3^b * 5^c * 7^d — the paper's powerof2 AND
radix357 extent classes — is therefore a single HBM touch.

Stage math (DIF Stockham, same derivation as the jnp module): with the
buffer holding x[q + s*(p + m*t)] for a stage of size ``cur`` = r*m at
stride ``s`` (cur*s == N invariant), one radix-r stage computes

    y[q + s*(u + r*p)] = ( sum_t x[q + s*(p + m*t)] * W_r^{t u} )
                         * W_cur^{p u} ,    u < r, p < m

then recurses with (cur, s) <- (m, r*s).  The W_r butterfly constants are
Python-float literals resolved at trace time (multiplies by 0/±1/±i are
elided); the W_cur^{p u} stage twiddles are precomputed host-side in
float64 (exact integer reduction of p*u mod cur) and passed as two packed
(1, L) plane operands, sliced per stage at static offsets.

Layout (grid over batch tiles; all shapes static):
  x_re, x_im : (TILE_B, n) VMEM, block i -> batch tile i
  tw_re/im   : (1, L) VMEM broadcast — per-stage twiddles, concatenated
  y_re, y_im : (TILE_B, n) VMEM

Planes carry the problem's real dtype (float32, or float64 for c128), so
double precision works in interpret mode and on f64-capable backends.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_B = 8

#: Tunable radix schedules the planner may request (largest pow2 work stage;
#: odd factors always run as their own radix-3/5/7 stages).
RADICES = (2, 4, 8)

#: The prime factors the stage chain can express (paper's radix357 class).
SMOOTH_PRIMES = (2, 3, 5, 7)


def smooth7(n: int) -> bool:
    """Is ``n`` of the form 2^a * 3^b * 5^c * 7^d (n >= 1)?"""
    if n < 1:
        return False
    for p in SMOOTH_PRIMES:
        while n % p == 0:
            n //= p
    return n == 1


def radix_schedule(n: int, radix: int = 8) -> tuple[int, ...]:
    """Static mixed-radix stage schedule for a 7-smooth ``n``: the odd prime
    factors first as radix-7/5/3 work stages, then ``radix`` power-of-two
    work stages with a single 4/2 cleanup (e.g. n=3*2^10, radix=8 ->
    (3, 8, 8, 8, 2)).  The stage product is exactly ``n``."""
    if not smooth7(n):
        raise ValueError("stockham_pallas requires a 7-smooth "
                         f"(2^a*3^b*5^c*7^d) length, got {n}")
    if radix not in RADICES:
        raise ValueError(f"radix must be one of {RADICES}, got {radix}")
    out = []
    m = n
    for p in (7, 5, 3):
        while m % p == 0:
            out.append(p)
            m //= p
    k = m.bit_length() - 1
    step = radix.bit_length() - 1
    while k >= step:
        out.append(radix)
        k -= step
    if k == 2:
        out.append(4)
    elif k == 1:
        out.append(2)
    return tuple(out)


def _root(k: int, r: int, inverse: bool) -> tuple[float, float]:
    """W_r^k as (re, im) Python floats, with exact 0/±1 on the axes so the
    butterfly elides those multiplies entirely."""
    k = k % r
    ang = 2.0 * math.pi * k / r
    c, s = math.cos(ang), math.sin(ang)
    for v in (-1.0, 0.0, 1.0):
        if abs(c - v) < 1e-12:
            c = v
        if abs(s - v) < 1e-12:
            s = v
    return c, (s if inverse else -s)


def _butterfly(parts, r: int, inverse: bool):
    """r-point DFT across ``parts`` (list of (re, im) plane pairs).

    Returns the r outputs; multiplies by W_r^k in {1, -1, ±i} are folded
    into adds/swaps, so radix-2/4 stages are multiply-free and radix-8
    spends its multiplies only on the +-(1±i)/sqrt(2) terms.
    """
    outs = []
    for u in range(r):
        br, bi = parts[0]          # t = 0 term: W_r^0 == 1
        for t in range(1, r):
            c, s = _root(t * u, r, inverse)
            ar, ai = parts[t]
            if (c, s) == (1.0, 0.0):
                br, bi = br + ar, bi + ai
            elif (c, s) == (-1.0, 0.0):
                br, bi = br - ar, bi - ai
            elif (c, s) == (0.0, -1.0):   # multiply by -i
                br, bi = br + ai, bi - ar
            elif (c, s) == (0.0, 1.0):    # multiply by +i
                br, bi = br - ai, bi + ar
            else:
                br = br + ar * c - ai * s
                bi = bi + ar * s + ai * c
        outs.append((br, bi))
    return outs


def apply_stages(xr, xi, twr, twi, *, n: int, radices: tuple[int, ...],
                 offsets: tuple[tuple[int, ...], ...], inverse: bool):
    """Run the whole Stockham stage chain along the LAST axis of the
    VMEM-resident planes ``xr``/``xi`` (any leading batch dims).  Shared by
    the rank-1 kernel and the fused rank-2 kernel (which calls it once per
    axis around an in-VMEM transpose).  ``twr``/``twi`` are the packed
    per-stage twiddle vectors, ``offsets`` the static per-(stage, u) slice
    starts from ``ops.pack_twiddles``."""
    lead = xr.shape[:-1]
    ones = (1,) * len(lead)
    cur = n
    for stage, r in enumerate(radices):
        m = cur // r
        s = n // cur                   # stride invariant: cur * s == n
        vr = xr.reshape(*lead, r, m, s)
        vi = xi.reshape(*lead, r, m, s)
        parts = [(vr[..., t, :, :], vi[..., t, :, :]) for t in range(r)]
        outs = _butterfly(parts, r, inverse)
        rows = [outs[0]]               # u = 0: twiddle is all-ones
        for u in range(1, r):
            off = offsets[stage][u - 1]
            wr = twr[off:off + m].reshape(*ones, m, 1)
            wi = twi[off:off + m].reshape(*ones, m, 1)
            br, bi = outs[u]
            rows.append((br * wr - bi * wi, br * wi + bi * wr))
        xr = jnp.stack([p[0] for p in rows], axis=-2).reshape(*lead, n)
        xi = jnp.stack([p[1] for p in rows], axis=-2).reshape(*lead, n)
        cur = m
    return xr, xi


def _stockham_kernel(xr_ref, xi_ref, twr_ref, twi_ref, yr_ref, yi_ref, *,
                     n: int, radices: tuple[int, ...],
                     offsets: tuple[tuple[int, ...], ...], inverse: bool):
    yr_ref[...], yi_ref[...] = apply_stages(
        xr_ref[...], xi_ref[...], twr_ref[0], twi_ref[0],
        n=n, radices=radices, offsets=offsets, inverse=inverse)


@functools.partial(
    jax.jit, static_argnames=("n", "radices", "offsets", "inverse",
                              "tile_b", "interpret"))
def stockham_pallas(xr, xi, twr, twi, *, n: int, radices: tuple[int, ...],
                    offsets: tuple[tuple[int, ...], ...], inverse: bool,
                    tile_b: int = DEFAULT_TILE_B, interpret: bool = False):
    """x planes: (B, n); returns y planes (B, n), natural order, one HBM
    read + one HBM write of the signal regardless of log2(n)."""
    b = xr.shape[0]
    tile_b = min(tile_b, b)
    assert b % tile_b == 0, f"batch {b} % tile {tile_b} != 0 (ops.py pads)"
    grid = (b // tile_b,)
    sig = pl.BlockSpec((tile_b, n), lambda i: (i, 0))
    tw = pl.BlockSpec(twr.shape, lambda i: (0, 0))
    kernel = functools.partial(_stockham_kernel, n=n, radices=radices,
                               offsets=offsets, inverse=inverse)
    out_shape = [jax.ShapeDtypeStruct((b, n), xr.dtype)] * 2
    yr, yi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[sig, sig, tw, tw],
        out_specs=[sig, sig],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, twr, twi)
    return yr, yi
