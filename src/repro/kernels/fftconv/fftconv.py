"""Pallas TPU kernel: fused frequency-domain depthwise convolution.

FFT -> pointwise filter -> inverse FFT, entirely in VMEM, using the square
four-step factorization n = k*k (k <= 128).  With n1 == n2 the transposed
four-step output *viewed as a 2-D array* is exactly the natural-order
spectrum reshaped (n1, n2), so the spectral multiply and the inverse
transform chain with ZERO data-movement between them — the whole
Hyena-style long-conv mixer becomes 14 MXU matmuls per signal tile with one
HBM read and one HBM write.  (An unfused jnp path costs 3 separate FFT
kernels + 2 elementwise HBM round-trips.)

Grid: (channels, batch_tiles).  Per step:
  x    : (1, TILE_B, k, k) real signal tile (imag = 0 exploited: forward
         column-DFT needs only 2 real matmuls instead of 4)
  hf_* : (1, k, k) filter spectrum planes for this channel (natural order
         reshaped (k, k)); 1/n inverse normalization pre-folded in
  wf_*/wi_* : (k, k) forward/inverse DFT matrices;  tf_*/ti_* twiddles
  y    : (1, TILE_B, k, k) real output tile (natural time order when
         flattened)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_B = 4


def _fourstep_core(xr, xi, wr, wi, tr, ti):
    """One four-step pass on (TB, k, k) planes -> transposed (TB, k, k)."""
    dot = functools.partial(jax.lax.dot_general,
                            preferred_element_type=jnp.float32)
    dims = (((1,), (1,)), ((), ()))  # W (k,j) . x (b,j,n) -> (k,b,n)
    if xi is None:  # real input: half the column-DFT matmuls
        br = dot(wr, xr, dims)
        bi = dot(wi, xr, dims)
    else:
        br = dot(wr, xr, dims) - dot(wi, xi, dims)
        bi = dot(wr, xi, dims) + dot(wi, xr, dims)
    t_r, t_i = tr[:, None, :], ti[:, None, :]
    cr = br * t_r - bi * t_i
    ci = br * t_i + bi * t_r
    dims2 = (((2,), (0,)), ((), ()))
    dr = dot(cr, wr, dims2) - dot(ci, wi, dims2)
    di = dot(cr, wi, dims2) + dot(ci, wr, dims2)
    return jnp.transpose(dr, (1, 2, 0)), jnp.transpose(di, (1, 2, 0))


def _fftconv_kernel(x_ref, hfr_ref, hfi_ref, wfr_ref, wfi_ref, wir_ref,
                    wii_ref, tfr_ref, tfi_ref, tir_ref, tii_ref, y_ref):
    x = x_ref[0]          # (TB, k, k)
    hfr = hfr_ref[0]      # (k, k)
    hfi = hfi_ref[0]
    # forward transform of the real signal
    xfr, xfi = _fourstep_core(x, None, wfr_ref[...], wfi_ref[...],
                              tfr_ref[...], tfi_ref[...])
    # spectral multiply (transposed layout == natural-order (k,k) view)
    er = xfr * hfr - xfi * hfi
    ei = xfr * hfi + xfi * hfr
    # inverse transform (matrices/twiddles conjugated; 1/n folded into hf)
    yr, _ = _fourstep_core(er, ei, wir_ref[...], wii_ref[...],
                           tir_ref[...], tii_ref[...])
    y_ref[0] = yr


@functools.partial(jax.jit, static_argnames=("k", "tile_b", "interpret"))
def fftconv_kernel(x, hfr, hfi, wfr, wfi, wir, wii, tfr, tfi, tir, tii, *,
                   k: int, tile_b: int = DEFAULT_TILE_B, interpret: bool = False):
    """x: (C, B, k, k) real; hf*: (C, k, k); returns y (C, B, k, k)."""
    c, b = x.shape[0], x.shape[1]
    tile_b = min(tile_b, b)
    assert b % tile_b == 0
    grid = (c, b // tile_b)
    sig = pl.BlockSpec((1, tile_b, k, k), lambda ci, bi: (ci, bi, 0, 0))
    hspec = pl.BlockSpec((1, k, k), lambda ci, bi: (ci, 0, 0))
    mat = pl.BlockSpec((k, k), lambda ci, bi: (0, 0))
    return pl.pallas_call(
        _fftconv_kernel,
        grid=grid,
        in_specs=[sig, hspec, hspec] + [mat] * 8,
        out_specs=sig,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, hfr, hfi, wfr, wfi, wir, wii, tfr, tfi, tir, tii)
