"""jit'd public wrapper for the fused fftconv kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.fft.reference import dft_matrix, twiddles
from .fftconv import fftconv_kernel, DEFAULT_TILE_B


def _next_square_pow2(v: int) -> int:
    """Smallest 4^m >= v (so n = k*k with k = 2^m <= 128)."""
    n = 1
    while n < v:
        n *= 4
    if n > 128 * 128:
        raise ValueError(f"fused fftconv supports n <= 16384, need {v}")
    return n


@functools.partial(jax.jit, static_argnames=("interpret", "tile_b"))
def fftconv(x: jnp.ndarray, h: jnp.ndarray, *, interpret: bool = False,
            tile_b: int = DEFAULT_TILE_B) -> jnp.ndarray:
    """Causal depthwise convolution via the fused Pallas kernel.

    x: (C, B, L) real activations (channel-major);  h: (C, K) real filters,
    K <= L.  Returns (C, B, L) = linear causal conv, f32.
    """
    c, b, L = x.shape
    K = h.shape[-1]
    n = _next_square_pow2(L + K - 1)
    k = int(round(n ** 0.5))

    # filter spectra (natural order), inverse normalization folded in
    hf = jnp.fft.fft(h.astype(jnp.float32), n=n, axis=-1) / n
    hfr = jnp.real(hf).astype(jnp.float32).reshape(c, k, k)
    hfi = jnp.imag(hf).astype(jnp.float32).reshape(c, k, k)

    f32 = lambda z: (jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32))
    wfr, wfi = f32(dft_matrix(k, dtype=jnp.complex128))
    wir, wii = f32(dft_matrix(k, inverse=True, dtype=jnp.complex128))
    tfr, tfi = f32(twiddles(k, k, dtype=jnp.complex128))
    tir, tii = f32(twiddles(k, k, inverse=True, dtype=jnp.complex128))

    tile = min(tile_b, max(1, b))
    pad_b = (-b) % tile
    xp = jnp.zeros((c, b + pad_b, n), jnp.float32).at[:, :b, :L].set(x)
    xp = xp.reshape(c, b + pad_b, k, k)

    y = fftconv_kernel(xp, hfr, hfi, wfr, wfi, wir, wii, tfr, tfi, tir, tii,
                       k=k, tile_b=tile, interpret=interpret)
    return y.reshape(c, b + pad_b, n)[:, :b, :L].astype(x.dtype)
