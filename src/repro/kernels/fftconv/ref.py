"""Pure-jnp oracle for the fused fftconv kernel."""

from __future__ import annotations

import jax.numpy as jnp


def fftconv_ref(x: jnp.ndarray, h: jnp.ndarray, n: int) -> jnp.ndarray:
    """Circular depthwise convolution at length n via the frequency domain.

    x: (C, B, L) real;  h: (C, K) real filters;  returns (C, B, L) where
    y = irfft( fft(pad(x, n)) * fft(pad(h, n)) )[:L]  — with n >= L + K - 1
    this equals causal linear convolution.
    """
    L = x.shape[-1]
    xf = jnp.fft.fft(x, n=n, axis=-1)
    hf = jnp.fft.fft(h, n=n, axis=-1)
    y = jnp.fft.ifft(xf * hf[:, None, :], axis=-1)
    return jnp.real(y[..., :L]).astype(x.dtype)
