"""jit'd public wrapper for the fft2_pallas kernel: complex rank-2 API,
per-axis radix schedules + one shared twiddle pack (host-side float64),
batch tiling/padding, normalization."""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from ..stockham_pallas.stockham_pallas import radix_schedule
from ..stockham_pallas.ops import pack_twiddles
from ..stockham_pallas.ops import default_tile_b as _default_tile_b
from .fft2_pallas import DEFAULT_TILE_B, fft2_pallas

#: Largest n1*n2 tile a single kernel instance may hold: bounded by the
#: working planes of one (tile_b=1) tile in VMEM.  Larger rank-2 problems
#: go through the separable per-axis path.
MAX_ELEMS = 1 << 18


def pack_twiddles2(n1: int, n2: int, radices1, radices2, inverse: bool,
                   real_dtype):
    """Both axes' stage twiddles in one (1, L) pair: the n2 (row) pack
    first, then the n1 (column) pack with its offsets shifted past it.
    Each per-axis pack comes from the rank-1 kernel's ``pack_twiddles``
    (float64 angles, exact integer mod reduction, lane-aligned)."""
    twr2, twi2, off2 = pack_twiddles(n2, radices2, inverse, real_dtype)
    twr1, twi1, off1 = pack_twiddles(n1, radices1, inverse, real_dtype)
    shift = twr2.shape[1]
    off1 = tuple(tuple(o + shift for o in stage) for stage in off1)
    twr = np.concatenate([twr2, twr1], axis=1)
    twi = np.concatenate([twi2, twi1], axis=1)
    return twr, twi, off1, off2


def default_tile_b(n_elems: int, batch: int, itemsize: int) -> int:
    """The shared VMEM-budget heuristic at this kernel's plane count (~8:
    in/out/stage/transpose temporaries) and tile ceiling."""
    return _default_tile_b(n_elems, batch, itemsize, planes=8, cap=64)


@functools.partial(jax.jit,
                   static_argnames=("inverse", "tile_b", "radix", "interpret"))
def fft2(x: jnp.ndarray, inverse: bool = False, *, tile_b: int | None = None,
         radix: int = 8, interpret: bool = False) -> jnp.ndarray:
    """Fused rank-2 FFT over the last TWO axes via the Pallas kernel.

    Power-of-two extents with n1*n2 <= ``MAX_ELEMS``; row stages, in-VMEM
    transpose, and column stages all run on a VMEM-resident batch tile, so
    the signal touches HBM once each way.  numpy semantics (inverse applies
    1/(n1*n2)).  ``tile_b``/``radix`` are the PATIENT-searchable knobs;
    ``tile_b=None`` sizes the tile to VMEM.
    """
    if x.ndim < 2:
        raise ValueError(f"fft2 needs rank >= 2 input, got shape {x.shape}")
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n1, n2 = x.shape[-2], x.shape[-1]
    if (n1 & (n1 - 1)) or (n2 & (n2 - 1)):
        raise ValueError(
            f"fft2_pallas requires power-of-two extents, got {n1}x{n2}")
    if n1 * n2 > MAX_ELEMS:
        raise ValueError(f"fft2_pallas caps at n1*n2={MAX_ELEMS}; "
                         "use the separable per-axis path beyond that")
    if n1 * n2 == 1:
        return x

    real_dtype = jnp.float64 if x.dtype == jnp.complex128 else jnp.float32
    batch_shape = x.shape[:-2]
    flat = x.reshape(-1, n1, n2)
    b = flat.shape[0]
    tile = tile_b if tile_b is not None else default_tile_b(
        n1 * n2, b, jnp.dtype(real_dtype).itemsize)
    tile = min(tile, max(1, b))
    pad = (-b) % tile

    xr = jnp.real(flat).astype(real_dtype)
    xi = jnp.imag(flat).astype(real_dtype)
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0), (0, 0)))
        xi = jnp.pad(xi, ((0, pad), (0, 0), (0, 0)))

    radices1 = radix_schedule(n1, radix)
    radices2 = radix_schedule(n2, radix)
    twr, twi, off1, off2 = pack_twiddles2(n1, n2, radices1, radices2,
                                          inverse, real_dtype)
    yr, yi = fft2_pallas(xr, xi, jnp.asarray(twr), jnp.asarray(twi),
                         n1=n1, n2=n2, radices1=radices1, radices2=radices2,
                         offsets1=off1, offsets2=off2, inverse=inverse,
                         tile_b=tile, interpret=interpret)
    y = (yr[:b] + 1j * yi[:b]).reshape(*batch_shape, n1, n2).astype(x.dtype)
    if inverse:
        y = y / (n1 * n2)
    return y
