"""Pallas TPU kernel: fused rank-2 FFT, the whole n1 x n2 tile in VMEM.

The separable path for a 2D transform runs the inner axis fused (one HBM
touch via stockham_pallas), but the outer axis still pays a swapaxes pass
in, its own transform, and a swapaxes pass out — 2*log2(n)+2 HBM touches on
the staged baseline, and never fewer than ~4 even with fused 1-D kernels.
This kernel does the classical small-2D trick instead: hold the full
n1 x n2 tile in VMEM, run the row (last-axis) Stockham stages, transpose
*in VMEM*, run the column stages, transpose back — so a small-extent 2D FFT
reads and writes HBM exactly once each way.

Layout (grid over batch tiles; all shapes static):
  x_re, x_im : (TILE_B, n1, n2) VMEM, block i -> batch tile i
  tw_re/im   : (1, L) VMEM broadcast — both axes' per-stage twiddles packed
               back to back (n2 stages first, then n1 stages at shifted
               offsets), precomputed host-side in float64
  y_re, y_im : (TILE_B, n1, n2) VMEM, natural order

The stage math is exactly ``stockham_pallas.apply_stages`` — the same
radix-8/4 work stages with a 4/2 cleanup, butterfly constants folded to
adds/swaps — applied once per axis around ``jnp.swapaxes`` on the resident
planes.  Feasibility is VMEM-capped (see ``ops.MAX_ELEMS``); the planner's
cost model charges one HBM touch inside the budget and infinity past it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..stockham_pallas.stockham_pallas import apply_stages

DEFAULT_TILE_B = 4


def _fft2_kernel(xr_ref, xi_ref, twr_ref, twi_ref, yr_ref, yi_ref, *,
                 n1: int, n2: int,
                 radices1: tuple[int, ...], radices2: tuple[int, ...],
                 offsets1: tuple[tuple[int, ...], ...],
                 offsets2: tuple[tuple[int, ...], ...], inverse: bool):
    xr = xr_ref[...]                   # (TB, n1, n2)
    xi = xi_ref[...]
    twr = twr_ref[0]                   # (L,) both axes' packed twiddles
    twi = twi_ref[0]
    # row transform: all n2 stages on the resident tile
    xr, xi = apply_stages(xr, xi, twr, twi, n=n2, radices=radices2,
                          offsets=offsets2, inverse=inverse)
    # in-VMEM transpose; column stages are row stages of the transpose
    xr = jnp.swapaxes(xr, -1, -2)      # (TB, n2, n1)
    xi = jnp.swapaxes(xi, -1, -2)
    xr, xi = apply_stages(xr, xi, twr, twi, n=n1, radices=radices1,
                          offsets=offsets1, inverse=inverse)
    yr_ref[...] = jnp.swapaxes(xr, -1, -2)
    yi_ref[...] = jnp.swapaxes(xi, -1, -2)


@functools.partial(
    jax.jit, static_argnames=("n1", "n2", "radices1", "radices2", "offsets1",
                              "offsets2", "inverse", "tile_b", "interpret"))
def fft2_pallas(xr, xi, twr, twi, *, n1: int, n2: int,
                radices1: tuple[int, ...], radices2: tuple[int, ...],
                offsets1: tuple[tuple[int, ...], ...],
                offsets2: tuple[tuple[int, ...], ...], inverse: bool,
                tile_b: int = DEFAULT_TILE_B, interpret: bool = False):
    """x planes: (B, n1, n2); returns y planes (B, n1, n2), natural order,
    one HBM read + one HBM write of the signal for the whole 2D transform."""
    b = xr.shape[0]
    tile_b = min(tile_b, b)
    assert b % tile_b == 0, f"batch {b} % tile {tile_b} != 0 (ops.py pads)"
    grid = (b // tile_b,)
    sig = pl.BlockSpec((tile_b, n1, n2), lambda i: (i, 0, 0))
    tw = pl.BlockSpec(twr.shape, lambda i: (0, 0))
    kernel = functools.partial(_fft2_kernel, n1=n1, n2=n2,
                               radices1=radices1, radices2=radices2,
                               offsets1=offsets1, offsets2=offsets2,
                               inverse=inverse)
    out_shape = [jax.ShapeDtypeStruct((b, n1, n2), xr.dtype)] * 2
    yr, yi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[sig, sig, tw, tw],
        out_specs=[sig, sig],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, twr, twi)
    return yr, yi
