"""Pure-jnp oracle for the fft2_pallas kernel: the same per-axis general-
radix Stockham recursion applied row-wise, transposed, column-wise — one
HBM pass per stage, so kernel-vs-ref comparisons isolate the fused Pallas
lowering (single tile residency + in-VMEM transpose), not the math."""

from __future__ import annotations

import jax.numpy as jnp

from ..stockham_pallas.ref import stockham_ref


def fft2_ref(x: jnp.ndarray, radix: int = 8,
             inverse: bool = False) -> jnp.ndarray:
    """General-radix rank-2 Stockham FFT over the last two axes (power-of-
    two extents).  Forward unnormalized; inverse applies 1/(n1*n2) — the
    two per-axis 1/n factors compose (numpy semantics), matching ops.fft2."""
    y = stockham_ref(x, radix=radix, inverse=inverse)          # rows (n2)
    y = jnp.swapaxes(y, -1, -2)
    y = stockham_ref(y, radix=radix, inverse=inverse)          # columns (n1)
    return jnp.swapaxes(y, -1, -2)
