"""Pure-jnp oracle for the dft_matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.fft.reference import dft_matrix


def dft_ref(xr: jnp.ndarray, xi: jnp.ndarray, inverse: bool = False):
    """Batched direct DFT on real/imag planes. x*: (B, n) float32.

    Returns (yr, yi) each (B, n). Forward unnormalized; inverse has NO 1/n
    (matches the kernel; callers normalize).
    """
    n = xr.shape[-1]
    w = dft_matrix(n, inverse=inverse, dtype=jnp.complex128)
    wr = jnp.real(w).astype(xr.dtype)
    wi = jnp.imag(w).astype(xr.dtype)
    # (x_r + i x_i) @ (W_r + i W_i); W symmetric so x @ W == W @ x convention-free
    yr = xr @ wr - xi @ wi
    yi = xr @ wi + xi @ wr
    return yr, yi
