"""jit'd public wrapper for the dft_matmul kernel: complex API, padding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.fft.reference import dft_matrix
from .dft_matmul import dft_matmul, DEFAULT_TILE_B


def _pad_rows(a: jnp.ndarray, mult: int) -> jnp.ndarray:
    b = a.shape[0]
    rem = (-b) % mult
    if rem:
        a = jnp.pad(a, ((0, rem), (0, 0)))
    return a


@functools.partial(jax.jit, static_argnames=("inverse", "interpret", "tile_b"))
def dft(x: jnp.ndarray, inverse: bool = False, *, interpret: bool = False,
        tile_b: int = DEFAULT_TILE_B) -> jnp.ndarray:
    """Direct DFT along the last axis via the Pallas MXU kernel.

    x: complex, any batch shape, last-axis length n <= 128 recommended.
    Forward unnormalized, inverse 1/n (numpy semantics).
    """
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    batch_shape = x.shape[:-1]
    flat = x.reshape(-1, n)
    b = flat.shape[0]

    # planes carry the problem's real dtype (float64 for complex128), so
    # double-precision problems keep double-precision accumulation
    real_dtype = jnp.float64 if x.dtype == jnp.complex128 else jnp.float32
    w = dft_matrix(n, inverse=inverse, dtype=jnp.complex128)
    wr = jnp.real(w).astype(real_dtype)
    wi = jnp.imag(w).astype(real_dtype)

    tile = min(tile_b, max(8, b))
    xr = _pad_rows(jnp.real(flat).astype(real_dtype), tile)
    xi = _pad_rows(jnp.imag(flat).astype(real_dtype), tile)
    yr, yi = dft_matmul(xr, xi, wr, wi, tile_b=tile, interpret=interpret)
    y = (yr[:b] + 1j * yi[:b]).reshape(*batch_shape, n).astype(x.dtype)
    if inverse:
        y = y / n
    return y
