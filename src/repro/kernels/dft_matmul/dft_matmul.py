"""Pallas TPU kernel: batched small-n DFT as a dense MXU matmul.

The TPU-native base case of the four-step decomposition (DESIGN.md §2): an
n-point DFT with n <= 128 is a single (B_tile, n) x (n, n) matmul against the
DFT matrix — systolic-array work at full MXU utilization, vs. a butterfly
chain that would run on the VPU and be bound by VMEM shuffles.

Complex data is carried as separate real/imag f32 planes (Pallas TPU has no
complex dtype); one complex matmul = 4 real matmuls fused in one kernel pass
so the x tiles are read from VMEM once.

BlockSpec layout (grid over batch tiles):
  x_re, x_im : (TILE_B, n)  VMEM, block i -> rows [i*TILE_B, (i+1)*TILE_B)
  w_re, w_im : (n, n)       VMEM, broadcast to every grid step
  y_re, y_im : (TILE_B, n)  VMEM
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_B = 256  # 256 rows x 128 cols x 4B x 6 planes ~ 0.8 MB VMEM


def _dft_kernel(xr_ref, xi_ref, wr_ref, wi_ref, yr_ref, yi_ref):
    xr = xr_ref[...]
    xi = xi_ref[...]
    wr = wr_ref[...]
    wi = wi_ref[...]
    # complex matmul on the MXU; accumulate in the plane dtype (f32, or f64
    # for complex128 problems — the conformance matrix's 1e-8 double bar)
    pet = xr.dtype
    yr_ref[...] = jnp.dot(xr, wr, preferred_element_type=pet) - \
                  jnp.dot(xi, wi, preferred_element_type=pet)
    yi_ref[...] = jnp.dot(xr, wi, preferred_element_type=pet) + \
                  jnp.dot(xi, wr, preferred_element_type=pet)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def dft_matmul(xr: jnp.ndarray, xi: jnp.ndarray, wr: jnp.ndarray, wi: jnp.ndarray,
               *, tile_b: int = DEFAULT_TILE_B, interpret: bool = False):
    """Batched DFT planes (B, n) @ DFT matrix (n, n). B % tile_b may be != 0;
    ops.py pads. n should be a multiple of the 128 lane width for peak MXU
    use (smaller n still correct, just padded by Mosaic)."""
    b, n = xr.shape
    tile_b = min(tile_b, b)
    assert b % tile_b == 0, f"batch {b} not divisible by tile {tile_b}"
    grid = (b // tile_b,)
    row_spec = pl.BlockSpec((tile_b, n), lambda i: (i, 0))
    mat_spec = pl.BlockSpec((n, n), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((b, n), xr.dtype)] * 2
    yr, yi = pl.pallas_call(
        _dft_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, mat_spec, mat_spec],
        out_specs=[row_spec, row_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, wi)
    return yr, yi
