"""Gradient compression: int8 quantization with error feedback.

Distributed-optimization trick (DESIGN.md §6): before the data-parallel
all-reduce, gradients are quantized to int8 with a per-leaf f32 scale;
the quantization residual is fed back into the next step's gradient
(error-feedback / EF-SGD), which keeps convergence unbiased in expectation.
Cuts DP all-reduce bytes 4x (f32) / 2x (bf16).

Used by the trainer when ``grad_compression=True``; the quantize/dequantize
pair brackets the psum so XLA lowers an int8 all-reduce.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, residual: Any | None):
    """Apply error feedback, quantize. Returns ((q_tree, scale_tree), new_residual)."""
    if residual is not None:
        grads = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    qs = jax.tree.map(quantize, grads,
                      is_leaf=lambda x: isinstance(x, jnp.ndarray))
    q_tree = jax.tree.map(lambda t: t[0], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    s_tree = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree.map(dequantize, q_tree, s_tree)
    new_residual = jax.tree.map(lambda g, d: g.astype(jnp.float32) - d, grads, deq)
    return (q_tree, s_tree), new_residual


def decompress_tree(q_tree: Any, s_tree: Any) -> Any:
    return jax.tree.map(dequantize, q_tree, s_tree)


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
