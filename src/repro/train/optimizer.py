"""AdamW + cosine schedule + global-norm clipping, hand-rolled (no optax).

Optimizer state is a pytree congruent with params, so the ZeRO-3 sharding
rules (models/sharding.py) apply verbatim to m/v — sharded optimizer state
falls out for free.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _decay_mask(params: Any) -> Any:
    """No weight decay on norms/scalars (ndim < 2)."""
    return jax.tree.map(lambda p: jnp.asarray(p.ndim >= 2, jnp.float32), params)


def adamw_update(cfg: OptConfig, params: Any, grads: Any, state: dict):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t
    mask = _decay_mask(params)

    def upd(p, g, m, v, dm):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        update = update + cfg.weight_decay * dm * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m_new, v_new

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_dm = jax.tree.leaves(mask)
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_dm)]
    new_p = jax.tree.unflatten(tree, [o[0] for o in out])
    new_m = jax.tree.unflatten(tree, [o[1] for o in out])
    new_v = jax.tree.unflatten(tree, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
