"""Checkpointing: atomic, mesh-independent, resumable.

- Params/opt-state are saved in LOGICAL layout (host numpy arrays), never in
  device layout, so a checkpoint written on a (16,16) mesh restores onto
  (2,16,16) or a single CPU — elastic restart = load + re-shard (the
  in_shardings of the restarted train_step do the placement).
- Writes go to a temp dir and are os.replace'd into place: a preempted writer
  never corrupts the latest checkpoint (atomic-rename protocol).
- A small JSON manifest carries step + data-pipeline cursor; restore returns
  it so the deterministic pipeline resumes exactly.
- ``keep`` rotates old checkpoints; ``save_async`` offloads the host write to
  a thread so the accelerator keeps stepping (overlap trick).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import numpy as np
import jax


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, v in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out[key] = np.asarray(v)
    return out


def _unflatten_into(tree: Any, table: dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for kp, v in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        arr = table[key]
        assert arr.shape == tuple(np.shape(v)), f"shape mismatch at {key}"
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, params: Any, opt_state: Any | None = None,
             extra: dict | None = None) -> str:
        self.wait()  # one async write in flight at a time
        host = {"params": _flatten(jax.device_get(params))}
        if opt_state is not None:
            host["opt"] = _flatten(jax.device_get(opt_state))
        return self._write(step, host, extra or {})

    def save_async(self, step: int, params: Any, opt_state: Any | None = None,
                   extra: dict | None = None) -> None:
        self.wait()
        host = {"params": _flatten(jax.device_get(params))}
        if opt_state is not None:
            host["opt"] = _flatten(jax.device_get(opt_state))
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, extra: dict) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, table in host.items():
            np.savez(os.path.join(tmp, f"{name}.npz"), **table)
        manifest = {"step": step, **extra}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._rotate()
        return final

    def _rotate(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, params_template: Any, opt_template: Any | None = None,
                step: int | None = None):
        """Returns (params, opt_state, manifest). Templates provide the tree
        structure + shapes (e.g. from jax.eval_shape on init)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        ptab = dict(np.load(os.path.join(d, "params.npz")))
        params = _unflatten_into(params_template, ptab)
        opt_state = None
        if opt_template is not None and os.path.exists(os.path.join(d, "opt.npz")):
            otab = dict(np.load(os.path.join(d, "opt.npz")))
            opt_state = _unflatten_into(opt_template, otab)
        return params, opt_state, manifest
