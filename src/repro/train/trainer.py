"""Trainer: jit/pjit train loop with checkpoint/restart, preemption handling,
straggler watchdog, microbatch gradient accumulation, and optional int8
gradient compression.

Fault-tolerance model (DESIGN.md §6):
- SIGTERM/SIGINT => finish the in-flight step, checkpoint, exit(0): a
  preempted worker restarts from step N+1 (tested in tests/test_train.py).
- Checkpoints are mesh-independent (train/checkpoint.py): elastic restart on
  a different mesh re-shards at load.
- The deterministic data pipeline (data/pipeline.py) is indexed by step, so
  restart never replays or skips batches.
- Straggler watchdog: steps slower than ``straggler_factor`` x the running
  median are logged with their step index; at pod scale the same hook feeds
  the hot-spare pod swap (documented, not simulated here).
"""

from __future__ import annotations

import signal
import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.model import Model
from repro.models.sharding import param_specs
from .checkpoint import CheckpointManager
from .optimizer import OptConfig, adamw_update, init_opt_state
from . import compression


@dataclass
class TrainConfig:
    steps: int = 100
    microbatches: int = 1          # gradient accumulation
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    grad_compression: bool = False
    straggler_factor: float = 2.0
    log_every: int = 10
    opt: OptConfig = field(default_factory=OptConfig)


def build_train_step(model: Model, opt_cfg: OptConfig, microbatches: int = 1,
                     grad_compression: bool = False) -> Callable:
    """Returns train_step(params, opt_state, batch[, residual]) ->
    (params, opt_state, metrics[, residual])."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def grads_of(params, batch):
        if microbatches == 1:
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
            return grads, metrics
        # accumulate over microbatches (PP-style pipelining analogue)
        def split(x):
            b = x.shape[0]
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(acc, mbatch):
            (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, mbatch)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, metrics
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, metrics = jax.lax.scan(body, zero, mb)
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return grads, metrics

    if not grad_compression:
        def train_step(params, opt_state, batch):
            grads, metrics = grads_of(params, batch)
            params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
            return params, opt_state, {**metrics, **om}
        return train_step

    def train_step_ef(params, opt_state, batch, residual):
        grads, metrics = grads_of(params, batch)
        (q, s), residual = compression.compress_tree(grads, residual)
        grads = compression.decompress_tree(q, s)  # int8 ride through the DP psum
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **om}, residual
    return train_step_ef


class Trainer:
    def __init__(self, model: Model, data, cfg: TrainConfig, mesh=None):
        self.model = model
        self.data = data
        self.cfg = cfg
        self.mesh = mesh
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, cfg.keep_checkpoints)
        self._stop = False
        self._step_times: list[float] = []
        self.stragglers: list[int] = []

    def _install_signals(self):
        def handler(signum, frame):
            self._stop = True  # finish current step, checkpoint, exit
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not the main thread (tests)

    # ------------------------------------------------------------------
    def run(self, rng=None, resume: bool = True, verbose: bool = True) -> dict:
        cfg = self.cfg
        model = self.model
        self._install_signals()

        rng = rng if rng is not None else jax.random.PRNGKey(0)
        params = model.init_params(rng)
        opt_state = init_opt_state(params)
        start_step = 0
        if resume and self.ckpt.latest_step() is not None:
            params, opt_state, manifest = self.ckpt.restore(params, opt_state)
            start_step = manifest["step"]
            if verbose:
                print(f"[trainer] resumed from step {start_step}")

        if self.mesh is not None:
            specs = param_specs(params, self.mesh)
            shard = lambda t, s: jax.device_put(
                t, jax.tree.map(lambda sp: NamedSharding(self.mesh, sp), s))
            params = shard(params, specs)
            opt_state = {"m": shard(opt_state["m"], specs),
                         "v": shard(opt_state["v"], specs),
                         "step": opt_state["step"]}

        step_fn = jax.jit(build_train_step(model, cfg.opt, cfg.microbatches,
                                           cfg.grad_compression),
                          donate_argnums=(0, 1))
        residual = compression.init_residual(params) if cfg.grad_compression else None

        metrics = {}
        step = start_step
        while step < cfg.steps and not self._stop:
            batch = self.data.batch(step)
            t0 = time.perf_counter()
            if cfg.grad_compression:
                params, opt_state, metrics, residual = step_fn(
                    params, opt_state, batch, residual)
            else:
                params, opt_state, metrics = step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._watchdog(step, dt)
            step += 1
            if verbose and step % cfg.log_every == 0:
                print(f"[trainer] step {step} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if step % cfg.checkpoint_every == 0 or self._stop or step == cfg.steps:
                self.ckpt.save(step, params, opt_state,
                               extra={"preempted": self._stop})
        self.ckpt.wait()
        return {"step": step, "loss": float(metrics.get("loss", float("nan"))),
                "params": params, "preempted": self._stop,
                "stragglers": list(self.stragglers)}

    def _watchdog(self, step: int, dt: float) -> None:
        self._step_times.append(dt)
        hist = self._step_times[-50:]
        if len(hist) >= 5:
            med = statistics.median(hist)
            if dt > self.cfg.straggler_factor * med:
                self.stragglers.append(step)
