"""xlstm-350m [arXiv:2405.04517]. Alternating mLSTM/sLSTM blocks (1:1),
no separate FFN (d_ff=0; blocks carry their own projections)."""
import jax.numpy as jnp
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m", family="ssm", block_kind="xlstm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    conv_kernel=4, dtype=jnp.bfloat16, sub_quadratic=True,
    notes="O(1)-state decode; chunkwise-parallel mLSTM for train/prefill",
))
