"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
import jax.numpy as jnp
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-1b-a400m", family="moe", block_kind="gqa_moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=0, d_ff_expert=512, vocab_size=49155,
    n_experts=32, top_k=8,
    rope_theta=1e4, dtype=jnp.bfloat16,
    notes="32 experts top-8; GQA kv=8; SwiGLU experts",
))
