"""hymba-1.5b [arXiv:2411.13676]. Parallel attention+mamba heads per layer,
128 meta tokens, sliding window except 3 global layers (first/middle/last)."""
import jax.numpy as jnp
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b", family="hybrid", block_kind="hymba",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, d_inner=1600, conv_kernel=4, n_meta_tokens=128,
    window=1024, global_every=16,
    rope_theta=1e4, dtype=jnp.bfloat16, sub_quadratic=True,
    notes="parallel attn+mamba; SWA + 3 global layers; meta tokens prepended",
))
