"""starcoder2-7b [arXiv:2402.19173]. Assigned config line: GQA kv=4, RoPE.

Upstream uses a 4k sliding window; the assignment line specifies plain GQA +
RoPE so the default is global attention (long_500k skipped). Set window=4096
to reproduce the upstream SWA variant.
"""
import jax.numpy as jnp
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-7b", family="dense", block_kind="gqa",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab_size=49152,
    mlp_gated=False, mlp_act="gelu", rope_theta=1e5, dtype=jnp.bfloat16,
    notes="non-gated GELU MLP (d_ff=4d)",
))
