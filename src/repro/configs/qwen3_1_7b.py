"""qwen3-1.7b [hf:Qwen/Qwen3-1.7B family]."""
import jax.numpy as jnp
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-1.7b", family="dense", block_kind="gqa",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=6144, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, dtype=jnp.bfloat16,
    notes="qk-norm GQA; tied embeddings",
))
