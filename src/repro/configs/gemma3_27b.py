"""gemma3-27b [hf:google/gemma-3-27b-pt pattern; spec from assignment].

5:1 local:global attention (window 1024, global every 6th layer), qk-norm.
sub_quadratic: local layers bound KV; global-layer KV is sequence-sharded
for long_500k decode (DESIGN.md §5).
"""
import jax.numpy as jnp
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-27b", family="dense", block_kind="gemma",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    qk_norm=True, window=1024, global_every=6,
    mlp_act="gelu", rope_theta=1e4, dtype=jnp.bfloat16,
    sub_quadratic=True,
    notes="5:1 local:global; 128k context target",
))
