"""ArchConfig: one dataclass describes every assigned architecture; the
model builder (models/model.py) dispatches on ``block_kind``.

Shapes (assigned): each arch runs the same four input shapes; ``input_specs``
returns ShapeDtypeStruct stand-ins (dry-run: no allocation).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | vlm | ssm | hybrid | audio
    block_kind: str                # gqa | gqa_moe | mla_moe | gemma | vlm | xlstm | hymba | musicgen
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: int = 0                # sliding-window size (0 = global)
    global_every: int = 0          # every k-th layer global (gemma/hymba pattern)
    mlp_gated: bool = True
    mlp_act: str = "silu"
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    d_ff_dense: int = 0
    capacity_factor: float = 1.25
    # MLA
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM / hybrid
    ssm_state: int = 0
    d_inner: int = 0
    conv_kernel: int = 4
    n_meta_tokens: int = 0
    # VLM
    cross_every: int = 0           # every k-th layer is cross-attention
    n_image_tokens: int = 0
    # audio
    n_codebooks: int = 0
    # misc
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    sub_quadratic: bool = False    # can run long_500k
    notes: str = ""

    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 4) if self.block_kind != "vlm" else 5,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1)) or 1),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            d_ff_expert=32 if self.d_ff_expert else 0,
            d_ff_dense=128 if self.d_ff_dense else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            d_inner=128 if self.d_inner else 0,
            n_meta_tokens=min(self.n_meta_tokens, 8),
            n_image_tokens=min(self.n_image_tokens, 16) if self.n_image_tokens else 0,
            cross_every=self.cross_every,
            global_every=self.global_every,
            window=min(self.window, 16) if self.window else 0,
        )
        small.update(overrides)
        return replace(self, **small)


# --------------------------------------------------------------------------
# shapes
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_supported(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "skip(full-attn)"
    return True, ""


def input_specs(cfg: ArchConfig, shape: str) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a given shape."""
    sp = SHAPES[shape]
    b, s = sp.global_batch, sp.seq_len
    i32 = jnp.int32
    tok_shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks else (b, s)
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if sp.mode == "train":
        specs["tokens"] = jax.ShapeDtypeStruct(tok_shape, i32)
    elif sp.mode == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct(tok_shape, i32)
    else:  # decode: one new token, cache of length s
        one = (b, 1, cfg.n_codebooks) if cfg.n_codebooks else (b, 1)
        specs["tokens"] = jax.ShapeDtypeStruct(one, i32)
        specs["pos"] = jax.ShapeDtypeStruct((), i32)
    if cfg.block_kind == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_image_tokens, cfg.d_model), cfg.dtype)
    return specs


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    from importlib import import_module
    for mod in ("granite_moe_1b_a400m", "deepseek_v2_lite_16b", "gemma3_27b",
                "starcoder2_7b", "qwen3_1_7b", "internlm2_20b",
                "llama_3_2_vision_90b", "xlstm_350m", "hymba_1_5b",
                "musicgen_medium"):
        import_module(f"repro.configs.{mod}")
