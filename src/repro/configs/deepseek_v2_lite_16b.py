"""deepseek-v2-lite-16b [arXiv:2405.04434].

Spec line says 'MoE 64e top-6'; the bracket note '160 routed' is full V2 —
we implement 64 routed + 2 shared (DeepSeek-V2-Lite), layer 0 dense
(d_ff 10944). MLA: kv_lora=512, nope 128 + rope 64, v 128.
"""
import jax.numpy as jnp
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-lite-16b", family="moe", block_kind="mla_moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=192,
    d_ff=0, d_ff_expert=1408, d_ff_dense=10944, first_dense_layers=1,
    vocab_size=102400, n_experts=64, n_shared_experts=2, top_k=6,
    kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    rope_theta=1e4, dtype=jnp.bfloat16,
    notes="MLA absorbed decode caches (c_kv 512 + k_rope 64) per token",
))
