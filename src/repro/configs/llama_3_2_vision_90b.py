"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-90B-Vision pattern].

100 layers = 80 self-attention + 20 cross-attention (every 5th layer cross);
vision frontend is a STUB: input_specs provides precomputed patch embeddings
(B, 1601, d_model) that the cross-attn layers attend to.
"""
import jax.numpy as jnp
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-90b", family="vlm", block_kind="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    cross_every=5, n_image_tokens=1601,
    rope_theta=5e5, dtype=jnp.bfloat16, tie_embeddings=False,
    notes="cross-attn image layers; vision encoder stubbed",
))
