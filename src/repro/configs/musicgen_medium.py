"""musicgen-medium [arXiv:2306.05284]. Decoder-only over EnCodec tokens:
4 codebooks, sum-of-embeddings input, 4 output heads. Audio frontend
(EnCodec) is a STUB — input_specs provides the token grid (B, S, 4)."""
import jax.numpy as jnp
from .base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="musicgen-medium", family="audio", block_kind="musicgen",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    n_codebooks=4, mlp_gated=False, mlp_act="gelu",
    rope_theta=1e4, dtype=jnp.bfloat16, tie_embeddings=False,
    notes="MHA (kv=24); delay-pattern handled in the data pipeline",
))
