import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, record memory/cost/collective analysis for §Roofline.

MUST keep the two lines above as the very first statements — jax locks the
device count on first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
Outputs one JSON per cell under experiments/dryrun/.
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (SHAPES, get_config, input_specs, list_configs,
                                shape_supported)
from repro.launch.mesh import make_production_mesh, dp_axes
from repro.models.model import Model
from repro.models.sharding import param_specs
from repro.roofline.hlo_parse import analyze as hlo_analyze
from repro.train.optimizer import init_opt_state, OptConfig
from repro.train.trainer import build_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
OUT_DIR = os.path.abspath(os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                       "../../../experiments/dryrun"))


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_spec(mesh, specs: dict, cfg) -> dict:
    dp = dp_axes(mesh)
    out = {}
    for k, s in specs.items():
        if k == "pos":
            out[k] = P()
        elif s.shape and s.shape[0] % _size(mesh, dp) == 0 and s.shape[0] > 1:
            out[k] = P(dp, *([None] * (len(s.shape) - 1)))
        else:
            out[k] = P(*([None] * len(s.shape)))
    return out


def _size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def lower_cell(arch: str, shape: str, multi_pod: bool, opt_level: str = "base"):
    """Lower + compile one cell. Returns (record, compiled, lowered)."""
    cfg = get_config(arch)
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": "multi" if multi_pod else "single",
                "status": why}, None, None

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg, mesh=mesh, remat=True)
    sp = SHAPES[shape]
    specs = input_specs(cfg, shape)
    rng = jax.random.PRNGKey(0)

    t0 = time.perf_counter()
    p_shapes = jax.eval_shape(model.init_params, rng)
    pspecs = param_specs(p_shapes, mesh)
    p_shard = _named(mesh, pspecs)
    in_batch = {k: v for k, v in specs.items()}
    b_spec = _batch_spec(mesh, specs, cfg)
    b_shard = _named(mesh, jax.tree.map(lambda s: s, b_spec,
                                        is_leaf=lambda x: isinstance(x, P)))

    with mesh:
        if sp.mode == "train":
            o_shapes = jax.eval_shape(init_opt_state, p_shapes)
            o_shard = {"m": p_shard, "v": p_shard,
                       "step": NamedSharding(mesh, P())}
            # Iteration 2 (EXPERIMENTS.md §Perf) tried microbatching alone
            # (M=16): fits but 16x per-microbatch gradient reductions.
            # Iteration 3: sequence-parallel activations (Sharder.sp) shrink
            # the remat carries by the TP degree; a light M=4 covers the
            # unsharded loss/logits transients. Tuned = SP + M=4
            # (M=16 for the 90B VLM: 5-layer remat units hold 5x activations).
            micro = 1
            if opt_level != "paper":
                micro = 16 if cfg.block_kind == "vlm" else 4
                # each microbatch must still shard over dp
                micro = min(micro, max(1, sp.global_batch // _size(mesh, dp_axes(mesh))))
            step = build_train_step(model, OptConfig(), microbatches=micro)
            fn = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_shapes, o_shapes, in_batch)
        elif sp.mode == "prefill":
            cache_shapes = model.cache_shapes(sp.global_batch, sp.seq_len)
            cache_shard = _named(mesh, model.cache_specs(sp.global_batch,
                                                         sp.seq_len))
            def prefill(params, tokens, cache, image_embeds=None):
                return model.prefill(params, tokens, cache,
                                     image_embeds=image_embeds)
            args = [p_shapes, specs["tokens"], cache_shapes]
            shards = [p_shard, b_shard["tokens"], cache_shard]
            if "image_embeds" in specs:
                args.append(specs["image_embeds"])
                shards.append(b_shard["image_embeds"])
            fn = jax.jit(prefill, in_shardings=tuple(shards),
                         donate_argnums=(2,))
            lowered = fn.lower(*args)
        else:  # decode
            cache_shapes = model.cache_shapes(sp.global_batch, sp.seq_len)
            cache_shard = _named(mesh, model.cache_specs(sp.global_batch,
                                                         sp.seq_len))
            def decode(params, tokens, cache, pos, image_embeds=None):
                return model.decode_step(params, tokens, cache, pos,
                                         image_embeds=image_embeds)
            args = [p_shapes, specs["tokens"], cache_shapes, specs["pos"]]
            shards = [p_shard, b_shard["tokens"], cache_shard,
                      NamedSharding(mesh, P())]
            if "image_embeds" in specs:
                args.append(specs["image_embeds"])
                shards.append(b_shard["image_embeds"])
            fn = jax.jit(decode, in_shardings=tuple(shards),
                         donate_argnums=(2,))
            lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = hlo_analyze(compiled.as_text())
    record = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "mode": sp.mode,
        "opt_level": opt_level,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": _mem_dict(mem),
        # raw cost_analysis (NOT loop-aware — kept for reference)
        "xla_flops_per_device": cost.get("flops", 0.0),
        "xla_bytes_per_device": cost.get("bytes accessed", 0.0),
        # loop-aware reconstruction (roofline/hlo_parse.py)
        "flops_per_device": hlo["dot_flops"],
        "dot_bytes_per_device": hlo["dot_bytes"],
        "collectives": {"total_bytes": hlo["collective_total"],
                        "by_kind": hlo["collective_bytes"],
                        "counts": hlo["collective_counts"]},
    }
    return record, compiled, lowered


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True,
             opt_level: str = "tuned"):
    try:
        record, compiled, _ = lower_cell(arch, shape, multi_pod, opt_level)
    except Exception as e:
        record = {"arch": arch, "shape": shape,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "status": f"ERROR: {type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
        compiled = None
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = f"{arch}_{shape}_{record['mesh'].replace('x', '-')}.json"
    with open(os.path.join(OUT_DIR, tag), "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        st = record["status"]
        extra = ""
        if st == "ok":
            mem_gb = record["memory"].get("argument_size_in_bytes", 0) / 2**30
            extra = (f" compile={record['compile_s']:.0f}s "
                     f"args/dev={mem_gb:.2f}GiB "
                     f"flops/dev={record['flops_per_device']:.3g} "
                     f"coll/dev={record['collectives']['total_bytes']/2**20:.0f}MiB")
        print(f"[dryrun] {arch} x {shape} x {record['mesh']}: {st}{extra}",
              flush=True)
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt-level", default="tuned", choices=["paper", "tuned"])
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args(argv)
    global OUT_DIR
    if args.out_dir:
        OUT_DIR = os.path.abspath(args.out_dir)

    archs = list_configs() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if (args.both_meshes or (args.all and not args.multi_pod)) \
        else [args.multi_pod]

    n_bad = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mp, opt_level=args.opt_level)
                if str(rec["status"]).startswith("ERROR"):
                    n_bad += 1
    print(f"[dryrun] done, {n_bad} failures")
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
