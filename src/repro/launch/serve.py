"""Batched serving driver: continuous-batching style loop on top of
prefill + decode_step.

A minimal but real serving path: requests arrive with prompts, get packed
into a fixed-size batch with per-slot positions; each engine step decodes
one token for every active slot; finished slots are refilled from the queue
(continuous batching). Greedy or temperature sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 8 --max-new 32
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models.model import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) or (S, nq)
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-slot continuous batching engine."""

    def __init__(self, model: Model, params, batch_slots: int, max_len: int):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.cache = model.init_cache(batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.active: list[Request | None] = [None] * batch_slots
        cfg = model.cfg
        tok_shape = (batch_slots, 1, cfg.n_codebooks) if cfg.n_codebooks \
            else (batch_slots, 1)
        self.next_tok = np.zeros(tok_shape, np.int32)
        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(self._prefill_impl)

    def _prefill_impl(self, params, tokens, cache, slot):
        """Prefill one slot: runs the sequence through and scatters the
        resulting KV into the batch cache at ``slot``."""
        small = self.model.init_cache(1, self.max_len)
        last, small = self.model.prefill(params, tokens, small)
        # generic scatter: every cache leaf has exactly one axis == slots
        def scatter(big, one):
            ax = _batch_axis(big.shape, self.slots, one.shape)
            idx = [slice(None)] * big.ndim
            idx[ax] = slot
            return big.at[tuple(idx)].set(jnp.squeeze(one, ax))
        cache = jax.tree.map(scatter, cache, small)
        return last, cache

    def submit(self, req: Request) -> bool:
        for i in range(self.slots):
            if self.active[i] is None:
                prompt = jnp.asarray(req.prompt)[None]
                last, self.cache = self._prefill_one(
                    self.params, prompt, self.cache, i)
                tok = np.asarray(jnp.argmax(last[0, -1], axis=-1))
                self.next_tok[i, 0] = tok
                self.pos[i] = req.prompt.shape[0]
                self.active[i] = req
                req.out.append(tok)
                return True
        return False

    def step(self) -> int:
        """Decode one token for all active slots. Returns #active."""
        if all(r is None for r in self.active):
            return 0
        pos = jnp.asarray(int(self.pos.max()))  # uniform step position
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(self.next_tok),
                                          self.cache, pos)
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        n_active = 0
        for i, req in enumerate(self.active):
            if req is None:
                continue
            tok = toks[i]
            req.out.append(tok)
            self.pos[i] += 1
            self.next_tok[i, 0] = tok
            if len(req.out) >= req.max_new or self.pos[i] >= self.max_len - 1:
                req.done = True
                self.active[i] = None
            else:
                n_active += 1
        return n_active


def _batch_axis(big_shape, slots, one_shape) -> int:
    for ax, (b, o) in enumerate(zip(big_shape, one_shape)):
        if b == slots and o == 1:
            return ax
    raise ValueError(f"no batch axis: {big_shape} vs {one_shape}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrink the config for smoke runs "
                         "(--no-reduced for the full architecture)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, args.slots, args.max_len)

    rng = np.random.default_rng(0)
    shape = (args.prompt_len, cfg.n_codebooks) if cfg.n_codebooks \
        else (args.prompt_len,)
    queue = [Request(i, rng.integers(0, cfg.vocab_size, shape).astype(np.int32),
                     args.max_new) for i in range(args.requests)]
    done: list[Request] = []
    t0 = time.perf_counter()
    steps = 0
    pending = list(queue)
    while pending or any(r is not None for r in engine.active):
        while pending and engine.submit(pending[0]):
            pending.pop(0)
        engine.step()
        steps += 1
        done = [r for r in queue if r.done]
        if steps > 10_000:
            break
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in queue)
    print(f"[serve] {len(done)}/{len(queue)} requests, {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok/dt:.1f} tok/s, {steps} engine steps)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
