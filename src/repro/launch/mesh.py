"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).

Topology: TPU v5e pod = 16x16 = 256 chips; multi-pod adds the leading 'pod'
axis (2 pods = 512 chips for the dry-run; the same code scales the pod axis
to any count — data parallelism over pods, DCN-connected).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit Auto axis types keep GSPMD semantics stable
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # jax 0.4.x: meshes are Auto-typed implicitly
    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape, axes):
    """General helper (tests, examples) with stable Auto axis types."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_kwargs(len(axes)))


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# active mesh: the planner's gate for distributed candidates
# ---------------------------------------------------------------------------
# The planner (repro.core.plan) enumerates mesh-sharded FFT candidates
# (dist1d / slab / pencil) only when a mesh is *active*: planning must never
# offer an 8-device decomposition to a process that owns one device.  The
# active mesh is process-global state, set explicitly by the launcher (or a
# client that decided to scale out) — device discovery alone never activates
# it, so single-device planning semantics are unchanged by default.
_ACTIVE_MESH = None


def set_active_mesh(mesh) -> None:
    """Install ``mesh`` (or ``None`` to clear) as the planning mesh."""
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_active_mesh():
    """The mesh distributed candidates plan against, or ``None``."""
    return _ACTIVE_MESH


class use_mesh:
    """Context manager: activate ``mesh`` for planning, restore on exit."""

    def __init__(self, mesh):
        self.mesh = mesh
        self._prev = None

    def __enter__(self):
        self._prev = get_active_mesh()
        set_active_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_active_mesh(self._prev)
        return False


def flat_mesh(devices=None, name: str = "data"):
    """A 1D mesh over ``devices`` (default: every visible device)."""
    import numpy as np
    from jax.sharding import Mesh

    devs = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.array(devs), (name,))


def reshaped_mesh(mesh, shape, names=None):
    """The same devices as ``mesh`` re-viewed with ``shape`` (row-major).

    The distributed candidates carry a mesh *shape* key (``pencil[2x4]``);
    this turns the active mesh into one matching that shape regardless of
    how the launcher factored its axes.
    """
    import math
    import numpy as np
    from jax.sharding import Mesh

    shape = tuple(int(s) for s in shape)
    devs = np.asarray(mesh.devices).reshape(-1)
    if math.prod(shape) != devs.size:
        raise ValueError(f"mesh of {devs.size} devices cannot be viewed "
                         f"as shape {shape}")
    if names is None:
        names = tuple(f"d{i}" for i in range(len(shape)))
    return Mesh(devs.reshape(shape), tuple(names))


