"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).

Topology: TPU v5e pod = 16x16 = 256 chips; multi-pod adds the leading 'pod'
axis (2 pods = 512 chips for the dry-run; the same code scales the pod axis
to any count — data parallelism over pods, DCN-connected).
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit Auto axis types keep GSPMD semantics stable
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # jax 0.4.x: meshes are Auto-typed implicitly
    def _axis_kwargs(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape, axes):
    """General helper (tests, examples) with stable Auto axis types."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_kwargs(len(axes)))


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
