"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --reduced --steps 200 --batch 8 --seq 128

--reduced trains the smoke-size config on CPU (the examples use this);
full-size configs on a real pod use the same entry point with --mesh.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.mesh import make_mesh
from repro.models.model import Model
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '4x2' => data x model over visible devices")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None
    if args.mesh:
        shape = tuple(int(v) for v in args.mesh.split("x"))
        axes = ("data", "model")[:len(shape)] if len(shape) <= 2 \
            else ("pod", "data", "model")
        mesh = make_mesh(shape, axes)

    model = Model(cfg, mesh=mesh, remat=not args.reduced)
    data = SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        n_codebooks=cfg.n_codebooks))
    tcfg = TrainConfig(
        steps=args.steps, microbatches=args.microbatches,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        grad_compression=args.grad_compression,
        opt=OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps))
    trainer = Trainer(model, data, tcfg, mesh=mesh)
    out = trainer.run(rng=jax.random.PRNGKey(args.seed))
    print(f"[train] finished at step {out['step']} loss={out['loss']:.4f} "
          f"stragglers={out['stragglers']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
