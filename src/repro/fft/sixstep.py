"""Six-step (Bailey) FFT composing the fused Pallas kernels — the large-N
path that lifts the fft4step kernel's n <= 16384 cap to 2^20 and beyond.

Factor n = n1 * n2 and evaluate paper Eq. 2 as two *fused-kernel* passes
with explicit transposes between them (Bailey's six steps, hence the name):

  1. view x as A[j1, j2], transpose            -> At[j2, j1]
  2. n2 batched length-n1 FFTs (contiguous)    -> Bt[j2, k1]   stockham_pallas
  3. twiddle multiply  Bt *= W_n^{j2 k1}
  4. transpose                                 -> Ct[k1, j2]
  5. n1 batched length-n2 FFTs (contiguous)    -> D[k1, k2]    fft4step kernel
  6. transpose + flatten: X[k1 + k2*n1] = D[k1, k2]

The residual length-n1 transforms run in the in-VMEM Stockham kernel
(radix-8/4/2 chain, one HBM touch) and the length-n2 transforms in the
fused four-step MXU kernel (one HBM touch), so the whole transform moves
the signal through HBM a constant ~5 times — vs log2(n) passes for the
staged jnp Stockham at n where neither single kernel fits.

Feasibility: power-of-two n with n1 <= MAX_RESIDUAL_N and n2 <=
fft4step's 16384, i.e. any power of two up to 2^24 with the default
split.  numpy semantics (inverse applies 1/n — composed from the two
sub-transforms' own 1/n1 and 1/n2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fft4step import ops as fourstep_ops
from repro.kernels.stockham_pallas import ops as stockham_ops

from .reference import twiddles

#: fft4step kernel cap: n2 = n2a * n2b with both factors <= 128.
MAX_KERNEL_N2 = 128 * 128

#: Residual (Stockham-side) cap: keeps the length-n1 planes comfortably
#: in-VMEM at useful batch tiles.
MAX_RESIDUAL_N = 1 << 10

#: Largest extent the default split supports.
MAX_N = MAX_KERNEL_N2 * MAX_RESIDUAL_N  # 2^24


def choose_split(n: int, n1: int | None = None) -> tuple[int, int]:
    """Pick n = n1 * n2: n2 (four-step side) as large as the fused kernel
    allows, n1 the power-of-two residual.  An explicit planner-supplied
    ``n1`` wins when it is valid for this n; otherwise fall back to the
    default so one tuned knob can't break other axes of an nd transform.
    """
    if n & (n - 1) or n < 4:
        raise ValueError(f"sixstep requires power-of-two n >= 4, got {n}")
    if n1 is not None and 2 <= n1 <= MAX_RESIDUAL_N and n % n1 == 0 \
            and (n1 & (n1 - 1)) == 0 and 2 <= n // n1 <= MAX_KERNEL_N2:
        return n1, n // n1
    k = n.bit_length() - 1
    k2 = min(14, k - 1)          # 2^14 == 16384, the fft4step kernel cap
    return 1 << (k - k2), 1 << k2


@functools.partial(jax.jit,
                   static_argnames=("inverse", "n1", "tile_b", "interpret"))
def fft(x: jnp.ndarray, inverse: bool = False, *, n1: int | None = None,
        tile_b: int | None = None, interpret: bool = False) -> jnp.ndarray:
    """Six-step FFT along the last axis via the two fused Pallas kernels.

    ``n1`` (residual split) and ``tile_b`` (batch tile of both kernels) are
    the PATIENT-searchable knobs.  jit'd with static knobs like the sibling
    ops modules, so the host-side float64 twiddle grid is built once at
    trace time, not per call.
    """
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    if n < 4 and (n & (n - 1)) == 0:
        # below the smallest n1*n2 split there is nothing to compose; run
        # the single fused kernel directly.  This keeps the backend usable
        # on the packed-real innermost axis, whose engine length is n//2.
        return stockham_ops.fft(x, inverse=inverse, tile_b=tile_b,
                                interpret=interpret)
    n1, n2 = choose_split(n, n1)
    batch = x.shape[:-1]

    a = x.reshape(*batch, n1, n2)
    at = jnp.swapaxes(a, -1, -2)                        # (..., n2, n1)
    bt = stockham_ops.fft(at, inverse=inverse, tile_b=tile_b,
                          interpret=interpret)          # length-n1 FFTs
    c = bt * twiddles(n2, n1, inverse=inverse, dtype=x.dtype)
    ct = jnp.swapaxes(c, -1, -2)                        # (..., n1, n2)
    kw = {} if tile_b is None else {"tile_b": tile_b}
    d = fourstep_ops.fft(ct, inverse=inverse, interpret=interpret,
                         **kw)                          # length-n2 FFTs
    # the sub-transforms' own 1/n1 and 1/n2 compose to the inverse's 1/n
    return jnp.swapaxes(d, -1, -2).reshape(*batch, n)


def ifft(x: jnp.ndarray) -> jnp.ndarray:
    return fft(x, inverse=True)
