"""N-dimensional transforms by separable axis application.

A rank-d FFT is d batched 1-D transforms with axis moves in between — the
formulation every library in the paper uses internally.  ``rfftn`` transforms
the *last* axis real-to-complex first, then complex axes (numpy layout).
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp

from . import rfft as _rfft

CFFT = Callable[..., jnp.ndarray]


def fftn(x: jnp.ndarray, cfft: CFFT, axes: Sequence[int] | None = None,
         inverse: bool = False) -> jnp.ndarray:
    axes = tuple(range(x.ndim)) if axes is None else tuple(axes)
    for ax in axes:
        x = jnp.moveaxis(cfft(jnp.moveaxis(x, ax, -1), inverse=inverse), -1, ax)
    return x


def rfftn(x: jnp.ndarray, cfft: CFFT, axes: Sequence[int] | None = None) -> jnp.ndarray:
    axes = tuple(range(x.ndim)) if axes is None else tuple(axes)
    last, rest = axes[-1], axes[:-1]
    y = jnp.moveaxis(_rfft.rfft(jnp.moveaxis(x, last, -1), cfft), -1, last)
    for ax in rest:
        y = jnp.moveaxis(cfft(jnp.moveaxis(y, ax, -1)), -1, ax)
    return y


def irfftn(y: jnp.ndarray, shape: Sequence[int], cfft: CFFT,
           axes: Sequence[int] | None = None) -> jnp.ndarray:
    axes = tuple(range(y.ndim)) if axes is None else tuple(axes)
    last, rest = axes[-1], axes[:-1]
    for ax in rest:
        y = jnp.moveaxis(cfft(jnp.moveaxis(y, ax, -1), inverse=True), -1, ax)
    n_last = shape[-1] if len(shape) else y.shape[last]
    return jnp.moveaxis(_rfft.irfft(jnp.moveaxis(y, last, -1), n_last, cfft), -1, last)
