"""N-dimensional transforms by separable axis application.

A rank-d FFT is d batched 1-D transforms with axis moves in between — the
formulation every library in the paper uses internally.  ``rfftn`` transforms
the *last* axis real-to-complex first, then complex axes (numpy layout).

Every engine transforms the last axis of a batched array, so per axis we need
at most one transpose in and its inverse out — and none at all when the axis
*is* the last one (the common innermost case, and the whole transform for
rank 1).  The previous ``moveaxis(cfft(moveaxis(...)))`` paid the double
transpose unconditionally; on rank-2/3 problems that was a full extra pair of
HBM passes per transform.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp

from . import rfft as _rfft

CFFT = Callable[..., jnp.ndarray]


def _apply_last(x: jnp.ndarray, ax: int, fn: Callable[[jnp.ndarray], jnp.ndarray]
                ) -> jnp.ndarray:
    """Apply a last-axis transform along ``ax`` with the minimum transposes:
    zero when ``ax`` is already last, one swap in / one swap out otherwise
    (``swapaxes`` is its own inverse and touches no other axes)."""
    ax = ax % x.ndim
    if ax == x.ndim - 1:
        return fn(x)
    return jnp.swapaxes(fn(jnp.swapaxes(x, ax, -1)), ax, -1)


def fftn(x: jnp.ndarray, cfft: CFFT, axes: Sequence[int] | None = None,
         inverse: bool = False) -> jnp.ndarray:
    axes = tuple(range(x.ndim)) if axes is None else tuple(axes)
    for ax in axes:
        x = _apply_last(x, ax, lambda v: cfft(v, inverse=inverse))
    return x


def rfftn(x: jnp.ndarray, cfft: CFFT, axes: Sequence[int] | None = None) -> jnp.ndarray:
    axes = tuple(range(x.ndim)) if axes is None else tuple(axes)
    last, rest = axes[-1], axes[:-1]
    y = _apply_last(x, last, lambda v: _rfft.rfft(v, cfft))
    for ax in rest:
        y = _apply_last(y, ax, cfft)
    return y


def irfftn(y: jnp.ndarray, shape: Sequence[int], cfft: CFFT,
           axes: Sequence[int] | None = None) -> jnp.ndarray:
    axes = tuple(range(y.ndim)) if axes is None else tuple(axes)
    last, rest = axes[-1], axes[:-1]
    for ax in rest:
        y = _apply_last(y, ax, lambda v: cfft(v, inverse=True))
    n_last = shape[-1] if len(shape) else y.shape[last]
    return _apply_last(y, last, lambda v: _rfft.irfft(v, n_last, cfft))
