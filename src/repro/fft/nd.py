"""N-dimensional transforms by separable axis application.

A rank-d FFT is d batched 1-D transforms with axis moves in between — the
formulation every library in the paper uses internally.  ``rfftn`` transforms
the *last* axis real-to-complex first, then complex axes (numpy layout).

Every engine transforms the last axis of a batched array, so per axis we need
at most one transpose in and its inverse out — and none at all when the axis
*is* the last one (the common innermost case, and the whole transform for
rank 1).  The previous ``moveaxis(cfft(moveaxis(...)))`` paid the double
transpose unconditionally; on rank-2/3 problems that was a full extra pair of
HBM passes per transform.

The planner is ND-native: a per-axis candidate assignment maps each axis to
its own engine, so ``cfft`` may be a single callable (same engine every
axis) **or** a sequence of callables aligned with ``axes`` — e.g. the tiny
outer axis of a (4, 65536) problem on the matmul-DFT kernel while the long
inner axis runs the fused Stockham kernel.
"""

from __future__ import annotations

from typing import Callable, Sequence, Union

import jax.numpy as jnp

from . import rfft as _rfft

CFFT = Callable[..., jnp.ndarray]
CFFTS = Union[CFFT, Sequence[CFFT]]


def _per_axis(cfft: CFFTS, n_axes: int) -> Sequence[CFFT]:
    """Normalize ``cfft`` to one engine per axis."""
    if callable(cfft):
        return (cfft,) * n_axes
    fns = tuple(cfft)
    if len(fns) != n_axes:
        raise ValueError(f"{len(fns)} engines for {n_axes} axes")
    return fns


def _apply_last(x: jnp.ndarray, ax: int, fn: Callable[[jnp.ndarray], jnp.ndarray]
                ) -> jnp.ndarray:
    """Apply a last-axis transform along ``ax`` with the minimum transposes:
    zero when ``ax`` is already last, one swap in / one swap out otherwise
    (``swapaxes`` is its own inverse and touches no other axes)."""
    ax = ax % x.ndim
    if ax == x.ndim - 1:
        return fn(x)
    return jnp.swapaxes(fn(jnp.swapaxes(x, ax, -1)), ax, -1)


def fftn(x: jnp.ndarray, cfft: CFFTS, axes: Sequence[int] | None = None,
         inverse: bool = False) -> jnp.ndarray:
    axes = tuple(range(x.ndim)) if axes is None else tuple(axes)
    for ax, fn in zip(axes, _per_axis(cfft, len(axes))):
        x = _apply_last(x, ax, lambda v, f=fn: f(v, inverse=inverse))
    return x


def rfftn(x: jnp.ndarray, cfft: CFFTS, axes: Sequence[int] | None = None) -> jnp.ndarray:
    axes = tuple(range(x.ndim)) if axes is None else tuple(axes)
    fns = _per_axis(cfft, len(axes))
    last, rest = axes[-1], axes[:-1]
    y = _apply_last(x, last, lambda v: _rfft.rfft(v, fns[-1]))
    for ax, fn in zip(rest, fns[:-1]):
        y = _apply_last(y, ax, fn)
    return y


def irfftn(y: jnp.ndarray, shape: Sequence[int], cfft: CFFTS,
           axes: Sequence[int] | None = None) -> jnp.ndarray:
    axes = tuple(range(y.ndim)) if axes is None else tuple(axes)
    fns = _per_axis(cfft, len(axes))
    last, rest = axes[-1], axes[:-1]
    for ax, fn in zip(rest, fns[:-1]):
        y = _apply_last(y, ax, lambda v, f=fn: f(v, inverse=True))
    n_last = shape[-1] if len(shape) else y.shape[last]
    return _apply_last(y, last, lambda v: _rfft.irfft(v, n_last, fns[-1]))
