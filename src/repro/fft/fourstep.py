"""Four-step (Bailey) FFT formulated as dense matmuls — the MXU-native path.

This is the TPU hardware adaptation of the paper's butterfly-based libraries
(DESIGN.md §2): instead of a radix-2 butterfly chain (memory-bound, VPU work),
factor n = n1 * n2 with n1 <= 128 and express the transform as

    X[k1 + k2*n1] = sum_{j2} ( W_n^{j2 k1} * sum_{j1} x[j1*n2 + j2] W_n1^{j1 k1} )
                    * W_n2^{j2 k2}                           (paper Eq. 2)

i.e.  D = (W_n1 @ A  *  T) @ W_n2,  out = transpose(D).flatten()

where A = x.reshape(n1, n2), W_r is the dense r-point DFT matrix and
T[k1, j2] = W_n^{k1 j2} the twiddle grid.  Every flop lands in a matmul, so on
TPU the whole transform runs on the 128x128 systolic MXU at high arithmetic
intensity; the length-n2 row transform recurses until n2 <= 128.

The Pallas kernel in ``repro/kernels/fft4step`` implements the n <= 16384 case
(two 128-wide matmuls + fused twiddle, all resident in VMEM); this module is
the algorithmic form, the jit-able fallback, and the oracle decomposition for
larger n.
"""

from __future__ import annotations

import jax.numpy as jnp

from .reference import dft_matrix, twiddles

# Largest radix handled by a single dense DFT matmul; 128 == MXU tile edge.
MAX_RADIX = 128


def _base_dft(x: jnp.ndarray, inverse: bool) -> jnp.ndarray:
    """Direct DFT via one matmul; n <= MAX_RADIX. W is symmetric -> x @ W."""
    n = x.shape[-1]
    w = dft_matrix(n, inverse=inverse, dtype=x.dtype)
    return x @ w


def _split(n: int) -> tuple[int, int]:
    """Factor n = n1 * n2 with n1 as large as possible but <= MAX_RADIX."""
    for cand in (128, 64, 32, 16, 8, 4, 2):
        if n % cand == 0:
            return cand, n // cand
    # odd composite: peel the smallest odd prime factor <= MAX_RADIX
    for cand in range(3, MAX_RADIX + 1, 2):
        if n % cand == 0:
            return cand, n // cand
    raise ValueError(
        f"fourstep cannot factor n={n} with radices <= {MAX_RADIX}; "
        "use the bluestein backend for large-prime lengths")


def _fft_unnormalized(x: jnp.ndarray, inverse: bool) -> jnp.ndarray:
    n = x.shape[-1]
    if n <= MAX_RADIX:
        return _base_dft(x, inverse)
    n1, n2 = _split(n)
    batch = x.shape[:-1]
    a = x.reshape(*batch, n1, n2)
    w1 = dft_matrix(n1, inverse=inverse, dtype=x.dtype)
    # column FFTs: B[k1, j2] = sum_j1 W[k1, j1] A[j1, j2]
    b = jnp.einsum("kj,...jn->...kn", w1, a)
    c = b * twiddles(n1, n2, inverse=inverse, dtype=x.dtype)
    # row FFTs of length n2 (recursive), batched over k1
    d = _fft_unnormalized(c, inverse)
    # output permutation: X[k1 + k2*n1] = D[k1, k2] -> transpose, flatten
    return jnp.swapaxes(d, -1, -2).reshape(*batch, n)


def fft(x: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
    """Four-step FFT along the last axis. Length must factor into {2..128}
    radices (any power of two, and most smooth sizes).

    Forward unnormalized, inverse scaled by 1/n (numpy semantics).
    """
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    y = _fft_unnormalized(x, inverse)
    if inverse:
        y = y / x.shape[-1]
    return y


def ifft(x: jnp.ndarray) -> jnp.ndarray:
    return fft(x, inverse=True)
