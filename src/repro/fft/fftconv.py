"""FFT-based long convolution — the model-side consumer of the FFT stack.

Hyena/H3-style sequence mixing: y = irfft( rfft(x_pad) * rfft(h_pad) ) with
zero padding to 2*seq (linear, not circular, convolution).  This is how the
paper's technique enters the LM architectures (DESIGN.md §3): a depthwise
frequency-domain convolution whose FFT engine is *plan-selected* by the
gearshifft planner (backend + factorization chosen per extent), exactly like
an FFT client in the benchmark suite.

Cost: O(L log L) vs O(L*K) for direct conv — the sub-quadratic mixer used by
the ssm/hybrid long-context paths.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.extents import next_pow2 as _next_pow2


@partial(jax.jit, static_argnames=("backend",))
def fftconv(x: jnp.ndarray, h: jnp.ndarray, backend: str = "xla") -> jnp.ndarray:
    """Depthwise linear convolution via FFT.

    x: (..., L, D) activations;  h: (K, D) or (L, D) depthwise filters.
    Returns (..., L, D): causal convolution y[t] = sum_{s<=t} x[s] h[t-s].

    backend: 'xla' uses jnp.fft (XLA FFT HLO); 'stockham' / 'fourstep' route
    through the in-repo engines (used by tests & the benchmark suite; on TPU
    the planner picks the Pallas fourstep kernel for supported extents).
    """
    L = x.shape[-2]
    m = _next_pow2(2 * L)
    xt = jnp.swapaxes(x, -1, -2)  # (..., D, L): transform the time axis
    ht = jnp.swapaxes(h, -1, -2)  # (D, K)
    if backend == "xla":
        xf = jnp.fft.rfft(xt, n=m, axis=-1)
        hf = jnp.fft.rfft(ht, n=m, axis=-1)
        y = jnp.fft.irfft(xf * hf, n=m, axis=-1)[..., :L]
    else:
        from . import fourstep, stockham, rfft as _rfft
        eng = {"stockham": stockham.fft, "fourstep": fourstep.fft}[backend]
        pad_x = jnp.zeros((*xt.shape[:-1], m), xt.dtype).at[..., :L].set(xt)
        pad_h = jnp.zeros((*ht.shape[:-1], m), ht.dtype).at[..., :ht.shape[-1]].set(ht)
        xf = _rfft.rfft(pad_x, eng)
        hf = _rfft.rfft(pad_h, eng)
        y = _rfft.irfft(xf * hf, m, eng)[..., :L]
    return jnp.swapaxes(y, -1, -2).astype(x.dtype)
