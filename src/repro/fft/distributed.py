"""Distributed FFTs over a device mesh — the pod-scale extension of the paper.

gearshifft benchmarks single-device libraries; real HPC FFT workloads (the
paper's motivating image-reconstruction pipelines) outgrow one device.  We
add mesh-parallel transforms built from shard_map + all_to_all, the
TPU-native analogue of FFTW-MPI / cuFFTMp pencil decompositions:

1D ("four-step across the mesh"): view n = n1*n2 as an (n1, n2) matrix with
   rows sharded.  all_to_all transposes between the column pass and the row
   pass; twiddles are computed per-shard from the device's axis_index.
   Output in TRANSPOSED spectrum order (k = k1 + k2*n1), exactly like
   FFTW-MPI's `FFTW_MPI_TRANSPOSED_OUT` — callers either accept the layout
   (self-inverse round trips, spectral filtering) or pay one more all_to_all.

2D/3D pencil: shard the leading axes, FFT the local axis, all_to_all to
   rotate the next axis into locality, repeat.  Collective volume per device
   per rotation = local block size — the canonical pencil cost model used
   in EXPERIMENTS.md §Roofline.

Axis-name convention: collectives take mesh axis names (str or tuple); the
production mesh uses ('pod','data','model') so 3D transforms shard over
('pod','data') x 'model'.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map

from . import fourstep


# ---------------------------------------------------------------------------
# 1D: distributed four-step
# ---------------------------------------------------------------------------
def _axis_size(a):
    if hasattr(jax.lax, "axis_size"):  # jax >= 0.5
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)          # jax 0.4.x: constant-folded size


def _combined_index(axes: tuple[str, ...]):
    """Row-major device index over one or more mesh axes (static sizes)."""
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def fft1d_shard(x_block: jnp.ndarray, n1: int, n2: int, p: int,
                axes: tuple[str, ...], inverse: bool = False) -> jnp.ndarray:
    """Per-shard body (call under shard_map). x_block: (n1/P, n2) complex,
    rows of the (n1, n2) four-step matrix view, row-sharded over ``axes``.

    Returns (n1/P, n2): block-row k1-slab of D[k1, k2] — flattening device-
    major gives the transposed spectrum X[k1 + k2*n1].

    Inverse note: the two sub-transform passes apply 1/n1 and 1/n2, so the
    global 1/n = 1/(n1*n2) normalization comes out exactly — no correction.
    """
    axis = axes if len(axes) > 1 else axes[0]
    n = n1 * n2
    # transpose: rows sharded -> columns sharded, j1 fully local
    xt = jax.lax.all_to_all(x_block, axis, split_axis=1, concat_axis=0,
                            tiled=True)                    # (n1, n2/P)
    # column DFTs (over j1)
    xt = jnp.moveaxis(fourstep.fft(jnp.moveaxis(xt, 0, -1), inverse=inverse), -1, 0)
    # twiddle T[k1, j2_global] with j2_global = idx*(n2/P) + local
    idx = _combined_index(axes)
    k1 = jnp.arange(n1)
    j2 = idx * (n2 // p) + jnp.arange(n2 // p)
    sign = 2.0 if inverse else -2.0
    ang = (sign * jnp.pi / n) * (k1[:, None] * j2[None, :]).astype(jnp.float64)
    xt = xt * jnp.exp(1j * ang).astype(xt.dtype)
    # transpose back: k1 sharded, j2 local
    xb = jax.lax.all_to_all(xt, axis, split_axis=0, concat_axis=1,
                            tiled=True)                    # (n1/P, n2)
    # row DFTs (over j2)
    return fourstep.fft(xb, inverse=inverse)


def _choose_1d_factors(n: int, p: int) -> tuple[int, int]:
    """n = n1*n2 with p | n1 (row-sharding) and both as square as possible."""
    best = None
    n1 = p
    while n1 <= n:
        if n % n1 == 0:
            n2 = n // n1
            score = abs(n1 - n2)
            if best is None or score < best[0]:
                best = (score, n1, n2)
        n1 += p
    if best is None:
        raise ValueError(f"cannot shard n={n} over {p} devices")
    return best[1], best[2]


def make_fft1d(mesh: Mesh, axis: str | tuple[str, ...], n: int,
               inverse: bool = False):
    """Build a jit-able distributed 1D FFT over ``mesh[axis]``.

    Input: (n,) complex sharded contiguously over ``axis``;
    output: transposed-order spectrum, same sharding.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    n1, n2 = _choose_1d_factors(n, p)
    spec_in = P(axes)

    def body(xb):
        # xb arrives (n/P,) = (n1/P * n2,) row-major rows of the matrix view
        blk = xb.reshape(n1 // p, n2)
        out = fft1d_shard(blk, n1, n2, p, axes, inverse=inverse)
        return out.reshape(-1)

    fn = shard_map(body, mesh=mesh, in_specs=(spec_in,), out_specs=spec_in)
    return jax.jit(fn), (n1, n2)


def transposed_to_natural(y: jnp.ndarray, n1: int, n2: int) -> jnp.ndarray:
    """Undo the transposed spectrum order (host-side/test helper)."""
    return y.reshape(n1, n2).T.reshape(-1)


def ifft1d_shard(y_block: jnp.ndarray, n1: int, n2: int, p: int,
                 axes: tuple[str, ...]) -> jnp.ndarray:
    """Inverse per-shard body consuming the TRANSPOSED spectrum produced by
    :func:`fft1d_shard` (FFTW_MPI_TRANSPOSED_IN analogue).

    y_block: (n1/P, n2) block-row k1-slab of Y[k1, k2] = X[k1 + k2*n1].
    Returns (n1/P, n2) rows of the natural-order signal x[j1*n2 + j2].

    Derivation (x[j] = 1/n sum_k X[k] W_n^{+jk}, j = j1*n2 + j2,
    k = k1 + k2*n1; the cross term W_n^{+ j1*n2*k2*n1} = 1):

        x[j1, j2] = 1/n1 sum_k1 W_{n1}^{+j1 k1} W_n^{+j2 k1}
                    (1/n2 sum_k2 W_{n2}^{+j2 k2} Y[k1, k2])

    i.e. the forward pipeline mirrored: row IDFTs (over k2, local) ->
    twiddle -> transpose -> column IDFTs (over k1).  The two sub-transform
    passes apply 1/n2 and 1/n1, so the global 1/n normalization comes out
    exactly.  Same collective count as forward: two all_to_alls.
    """
    axis = axes if len(axes) > 1 else axes[0]
    n = n1 * n2
    # row IDFTs (over k2) — k2 is fully local, no communication
    b = fourstep.fft(y_block, inverse=True)                # (n1/P, n2)
    # twiddle W_n^{+ k1_global j2} with k1_global = idx*(n1/P) + local
    idx = _combined_index(axes)
    k1 = idx * (n1 // p) + jnp.arange(n1 // p)
    j2 = jnp.arange(n2)
    ang = (2.0 * jnp.pi / n) * (k1[:, None] * j2[None, :]).astype(jnp.float64)
    b = b * jnp.exp(1j * ang).astype(b.dtype)
    # transpose: k1 sharded -> k1 fully local, j2 sharded
    bt = jax.lax.all_to_all(b, axis, split_axis=1, concat_axis=0,
                            tiled=True)                    # (n1, n2/P)
    # column IDFTs (over k1)
    bt = jnp.moveaxis(fourstep.fft(jnp.moveaxis(bt, 0, -1), inverse=True),
                      -1, 0)                               # x[j1, j2-slab]
    # transpose back: rows j1 sharded, j2 local -> natural row-major layout
    return jax.lax.all_to_all(bt, axis, split_axis=0, concat_axis=1,
                              tiled=True)                  # (n1/P, n2)


def make_ifft1d(mesh: Mesh, axis: str | tuple[str, ...], n: int):
    """Build a jit-able inverse of :func:`make_fft1d`'s transform.

    Input: the (n,) transposed-order spectrum sharded over ``axis`` exactly
    as ``make_fft1d`` emitted it; output: the natural-order signal with the
    same sharding — so ifft1d(fft1d(x)) == x without any reordering pass.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    n1, n2 = _choose_1d_factors(n, p)
    spec = P(axes)

    def body(yb):
        blk = yb.reshape(n1 // p, n2)
        out = ifft1d_shard(blk, n1, n2, p, axes)
        return out.reshape(-1)

    fn = shard_map(body, mesh=mesh, in_specs=(spec,), out_specs=spec)
    return jax.jit(fn), (n1, n2)


# ---------------------------------------------------------------------------
# 2D/3D: pencil decomposition
# ---------------------------------------------------------------------------
def fft3d_shard(x_block: jnp.ndarray, row_axis, col_axis,
                inverse: bool = False) -> jnp.ndarray:
    """Per-shard pencil 3D FFT body (call under shard_map).

    Global array (X, Y, Z); block (X/Pr, Y/Pc, Z) with X sharded over
    ``row_axis`` (size Pr), Y over ``col_axis`` (size Pc).  Returns block of
    the spectrum in (X/Pr, Y/Pc, Z) layout after full 3 axis transforms.
    """
    eng = functools.partial(fourstep.fft, inverse=inverse)
    # 1) FFT along Z (local)
    x = eng(x_block)
    # 2) rotate Y into locality: split Z over col_axis, gather Y
    x = jax.lax.all_to_all(x, col_axis, split_axis=2, concat_axis=1, tiled=True)
    #    now (X/Pr, Y, Z/Pc); FFT along Y
    x = jnp.moveaxis(eng(jnp.moveaxis(x, 1, -1)), -1, 1)
    # 3) rotate X into locality: split Y over row_axis, gather X
    x = jax.lax.all_to_all(x, row_axis, split_axis=1, concat_axis=0, tiled=True)
    #    now (X, Y/Pr, Z/Pc); FFT along X
    x = jnp.moveaxis(eng(jnp.moveaxis(x, 0, -1)), -1, 0)
    # 4) restore canonical sharding (X/Pr, Y/Pc, Z): undo both rotations
    x = jax.lax.all_to_all(x, row_axis, split_axis=0, concat_axis=1, tiled=True)
    x = jax.lax.all_to_all(x, col_axis, split_axis=1, concat_axis=2, tiled=True)
    return x


def make_fft3d(mesh: Mesh, row_axis, col_axis, shape: Sequence[int],
               inverse: bool = False, keep_transposed: bool = False):
    """Build a jit-able pencil 3D FFT.

    Input/output: (X, Y, Z) complex with sharding P(row_axis, col_axis, None).
    ``keep_transposed`` skips step 4 (output sharded (X, Y/Pr, Z/Pc)) —
    the cheaper layout when a roundtrip (e.g. spectral conv) follows.
    """
    row_t = row_axis if isinstance(row_axis, str) else tuple(row_axis)
    col_t = col_axis if isinstance(col_axis, str) else tuple(col_axis)

    def body(xb):
        if keep_transposed:
            eng = functools.partial(fourstep.fft, inverse=inverse)
            x = eng(xb)
            x = jax.lax.all_to_all(x, col_t, split_axis=2, concat_axis=1, tiled=True)
            x = jnp.moveaxis(eng(jnp.moveaxis(x, 1, -1)), -1, 1)
            x = jax.lax.all_to_all(x, row_t, split_axis=1, concat_axis=0, tiled=True)
            return jnp.moveaxis(eng(jnp.moveaxis(x, 0, -1)), -1, 0)
        return fft3d_shard(xb, row_t, col_t, inverse=inverse)

    in_spec = P(row_t, col_t, None)
    out_spec = P(None, row_t, col_t) if keep_transposed else in_spec
    fn = shard_map(body, mesh=mesh, in_specs=(in_spec,), out_specs=out_spec)
    return jax.jit(fn)


def sharding_for(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
