"""Distributed FFTs over a device mesh — the pod-scale extension of the paper.

gearshifft benchmarks single-device libraries; real HPC FFT workloads (the
paper's motivating image-reconstruction pipelines) outgrow one device.  We
add mesh-parallel transforms built from shard_map + all_to_all, the
TPU-native analogue of FFTW-MPI / cuFFTMp pencil decompositions:

1D ("four-step across the mesh"): view n = n1*n2 as an (n1, n2) matrix with
   rows sharded.  all_to_all transposes between the column pass and the row
   pass; twiddles are computed per-shard from the device's axis_index.
   Output in TRANSPOSED spectrum order (k = k1 + k2*n1), exactly like
   FFTW-MPI's `FFTW_MPI_TRANSPOSED_OUT` — callers either accept the layout
   (self-inverse round trips, spectral filtering) or pay one more all_to_all.

2D/3D pencil: shard the leading axes, FFT the local axis, all_to_all to
   rotate the next axis into locality, repeat.  Collective volume per device
   per rotation = local block size — the canonical pencil cost model used
   in EXPERIMENTS.md §Roofline.

Axis-name convention: collectives take mesh axis names (str or tuple); the
production mesh uses ('pod','data','model') so 3D transforms shard over
('pod','data') x 'model'.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map


def _shard_map(body, mesh, in_specs, out_specs):
    """shard_map with replication checking off when supported: pallas_call
    has no replication rule, and the planned local engines are Pallas
    kernels.  Our bodies keep every output dim explicitly sharded or
    device-invariant, so the check adds nothing here."""
    import inspect
    params = inspect.signature(shard_map).parameters
    for kw in ("check_rep", "check_vma"):
        if kw in params:
            return shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **{kw: False})
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

from . import fourstep
from .nd import _apply_last

#: A local engine: ``cfft(x, inverse=False)`` transforming the LAST axis —
#: the same contract ``nd.fftn`` consumes, so the per-shard transforms of a
#: distributed plan run through exactly the engines the planner picked
#: (stockham_pallas / fft mixed-radix / chirp-Z / ...), not a hard-coded
#: baseline.
Engine = "Callable[..., jnp.ndarray]"


def _engines_for(rank: int, engines) -> tuple:
    """Normalize ``engines`` to one local engine per global axis (default:
    the matmul four-step jnp baseline, the pre-planner behavior)."""
    if engines is None:
        return (fourstep.fft,) * rank
    if callable(engines):
        return (engines,) * rank
    fns = tuple(engines)
    if len(fns) != rank:
        raise ValueError(f"{len(fns)} local engines for rank {rank}")
    return fns


# ---------------------------------------------------------------------------
# 1D: distributed four-step
# ---------------------------------------------------------------------------
def _axis_size(a):
    if hasattr(jax.lax, "axis_size"):  # jax >= 0.5
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)          # jax 0.4.x: constant-folded size


def _combined_index(axes: tuple[str, ...]):
    """Row-major device index over one or more mesh axes (static sizes)."""
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def fft1d_shard(x_block: jnp.ndarray, n1: int, n2: int, p: int,
                axes: tuple[str, ...], inverse: bool = False,
                engines=None) -> jnp.ndarray:
    """Per-shard body (call under shard_map). x_block: (n1/P, n2) complex,
    rows of the (n1, n2) four-step matrix view, row-sharded over ``axes``.

    Returns (n1/P, n2): block-row k1-slab of D[k1, k2] — flattening device-
    major gives the transposed spectrum X[k1 + k2*n1].

    Inverse note: the two sub-transform passes apply 1/n1 and 1/n2, so the
    global 1/n = 1/(n1*n2) normalization comes out exactly — no correction.
    """
    axis = axes if len(axes) > 1 else axes[0]
    eng1, eng2 = _engines_for(2, engines)   # column (n1) / row (n2) engines
    n = n1 * n2
    # transpose: rows sharded -> columns sharded, j1 fully local
    xt = jax.lax.all_to_all(x_block, axis, split_axis=1, concat_axis=0,
                            tiled=True)                    # (n1, n2/P)
    # column DFTs (over j1)
    xt = jnp.moveaxis(eng1(jnp.moveaxis(xt, 0, -1), inverse=inverse), -1, 0)
    # twiddle T[k1, j2_global] with j2_global = idx*(n2/P) + local
    idx = _combined_index(axes)
    k1 = jnp.arange(n1)
    j2 = idx * (n2 // p) + jnp.arange(n2 // p)
    sign = 2.0 if inverse else -2.0
    ang = (sign * jnp.pi / n) * (k1[:, None] * j2[None, :]).astype(jnp.float64)
    xt = xt * jnp.exp(1j * ang).astype(xt.dtype)
    # transpose back: k1 sharded, j2 local
    xb = jax.lax.all_to_all(xt, axis, split_axis=0, concat_axis=1,
                            tiled=True)                    # (n1/P, n2)
    # row DFTs (over j2)
    return eng2(xb, inverse=inverse)


def _choose_1d_factors(n: int, p: int) -> tuple[int, int]:
    """n = n1*n2 with p | n1 AND p | n2 (every tiled all_to_all in the
    pipeline — including the optional natural-order untranspose — splits one
    of the two factors over the p devices), both as square as possible."""
    best = None
    n1 = p
    while n1 <= n:
        if n % n1 == 0:
            n2 = n // n1
            if n2 % p == 0:
                score = abs(n1 - n2)
                if best is None or score < best[0]:
                    best = (score, n1, n2)
        n1 += p
    if best is None:
        raise ValueError(f"cannot shard n={n} over {p} devices")
    return best[1], best[2]


def can_shard_1d(n: int, p: int) -> bool:
    """Feasibility probe for the planner: does an (n1, n2) factorization
    with p | n1 and p | n2 exist?"""
    try:
        _choose_1d_factors(n, p)
        return True
    except ValueError:
        return False


def make_fft1d(mesh: Mesh, axis: str | tuple[str, ...], n: int,
               inverse: bool = False, natural: bool = False, engines=None):
    """Build a jit-able distributed 1D FFT over ``mesh[axis]``.

    Input: (n,) complex sharded contiguously over ``axis``; output: the
    spectrum with the same sharding — TRANSPOSED order (k = k1 + k2*n1
    block-cyclic, FFTW_MPI_TRANSPOSED_OUT) by default, or natural order for
    one extra all_to_all when ``natural=True``.  ``engines`` routes the two
    local sub-transform passes (lengths n1 and n2) through planner-selected
    engines.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    n1, n2 = _choose_1d_factors(n, p)
    spec_in = P(axes)
    a2a_axis = axes if len(axes) > 1 else axes[0]

    def body(xb):
        # xb arrives (n/P,) = (n1/P * n2,) row-major rows of the matrix view
        blk = xb.reshape(n1 // p, n2)
        out = fft1d_shard(blk, n1, n2, p, axes, inverse=inverse,
                          engines=engines)                 # (n1/P, n2)
        if natural:
            # untranspose: D[k1, k2] -> Y[k2, k1]; flattened device-major
            # this is exactly X[k1 + k2*n1] in contiguous natural order
            out = jax.lax.all_to_all(out, a2a_axis, split_axis=1,
                                     concat_axis=0, tiled=True)  # (n1, n2/P)
            out = out.T                                    # (n2/P, n1)
        return out.reshape(-1)

    fn = _shard_map(body, mesh, (spec_in,), spec_in)
    return jax.jit(fn), (n1, n2)


def transposed_to_natural(y: jnp.ndarray, n1: int, n2: int) -> jnp.ndarray:
    """Undo the transposed spectrum order (host-side/test helper)."""
    return y.reshape(n1, n2).T.reshape(-1)


def ifft1d_shard(y_block: jnp.ndarray, n1: int, n2: int, p: int,
                 axes: tuple[str, ...], engines=None) -> jnp.ndarray:
    """Inverse per-shard body consuming the TRANSPOSED spectrum produced by
    :func:`fft1d_shard` (FFTW_MPI_TRANSPOSED_IN analogue).

    y_block: (n1/P, n2) block-row k1-slab of Y[k1, k2] = X[k1 + k2*n1].
    Returns (n1/P, n2) rows of the natural-order signal x[j1*n2 + j2].

    Derivation (x[j] = 1/n sum_k X[k] W_n^{+jk}, j = j1*n2 + j2,
    k = k1 + k2*n1; the cross term W_n^{+ j1*n2*k2*n1} = 1):

        x[j1, j2] = 1/n1 sum_k1 W_{n1}^{+j1 k1} W_n^{+j2 k1}
                    (1/n2 sum_k2 W_{n2}^{+j2 k2} Y[k1, k2])

    i.e. the forward pipeline mirrored: row IDFTs (over k2, local) ->
    twiddle -> transpose -> column IDFTs (over k1).  The two sub-transform
    passes apply 1/n2 and 1/n1, so the global 1/n normalization comes out
    exactly.  Same collective count as forward: two all_to_alls.
    """
    axis = axes if len(axes) > 1 else axes[0]
    eng1, eng2 = _engines_for(2, engines)   # column (n1) / row (n2) engines
    n = n1 * n2
    # row IDFTs (over k2) — k2 is fully local, no communication
    b = eng2(y_block, inverse=True)                        # (n1/P, n2)
    # twiddle W_n^{+ k1_global j2} with k1_global = idx*(n1/P) + local
    idx = _combined_index(axes)
    k1 = idx * (n1 // p) + jnp.arange(n1 // p)
    j2 = jnp.arange(n2)
    ang = (2.0 * jnp.pi / n) * (k1[:, None] * j2[None, :]).astype(jnp.float64)
    b = b * jnp.exp(1j * ang).astype(b.dtype)
    # transpose: k1 sharded -> k1 fully local, j2 sharded
    bt = jax.lax.all_to_all(b, axis, split_axis=1, concat_axis=0,
                            tiled=True)                    # (n1, n2/P)
    # column IDFTs (over k1)
    bt = jnp.moveaxis(eng1(jnp.moveaxis(bt, 0, -1), inverse=True),
                      -1, 0)                               # x[j1, j2-slab]
    # transpose back: rows j1 sharded, j2 local -> natural row-major layout
    return jax.lax.all_to_all(bt, axis, split_axis=0, concat_axis=1,
                              tiled=True)                  # (n1/P, n2)


def make_ifft1d(mesh: Mesh, axis: str | tuple[str, ...], n: int,
                natural: bool = False, engines=None):
    """Build a jit-able inverse of :func:`make_fft1d`'s transform.

    Input: the (n,) spectrum sharded over ``axis`` exactly as ``make_fft1d``
    emitted it — transposed order by default, natural order when
    ``natural=True`` (matching a forward built with ``natural=True``);
    output: the natural-order signal with the same sharding — so
    ifft1d(fft1d(x)) == x without any host-side reordering in either mode.
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    n1, n2 = _choose_1d_factors(n, p)
    spec = P(axes)
    a2a_axis = axes if len(axes) > 1 else axes[0]

    def body(yb):
        if natural:
            # mirror the forward's untranspose: natural block (n2/P, n1)
            # -> local transpose -> all_to_all back to (n1/P, n2) k1-slabs
            blk = yb.reshape(n2 // p, n1).T                # (n1, n2/P)
            blk = jax.lax.all_to_all(blk, a2a_axis, split_axis=0,
                                     concat_axis=1, tiled=True)  # (n1/P, n2)
        else:
            blk = yb.reshape(n1 // p, n2)
        out = ifft1d_shard(blk, n1, n2, p, axes, engines=engines)
        return out.reshape(-1)

    fn = _shard_map(body, mesh, (spec,), spec)
    return jax.jit(fn), (n1, n2)


# ---------------------------------------------------------------------------
# ND planned decompositions: slab (1D mesh) and pencil (2D mesh)
# ---------------------------------------------------------------------------
# Both builders take arrays shaped (batch, *shape) — the leading batch dim is
# always present (batch=1 for unbatched problems) and never sharded.  Local
# per-axis transforms run through planner-selected ``engines`` (one per
# global axis, same contract as nd.fftn's per-axis engine list).  Output is
# TRANSPOSED-sharded by default (the cheap layout); ``natural=True`` pays the
# restoring all_to_all(s) so the output sharding matches the input's.

def slab_divisible(shape: Sequence[int], p: int) -> bool:
    """Slab feasibility: p | d0 (input sharding) and p | d1 (the transpose
    all_to_all splits d1 over the mesh)."""
    shape = tuple(shape)
    return (len(shape) >= 2 and p >= 1
            and shape[0] % p == 0 and shape[1] % p == 0)


def pencil_divisible(shape: Sequence[int], pr: int, pc: int) -> bool:
    """Pencil feasibility over a (pr, pc) mesh for a rank-3 transform:
    pr | X, pc | Y (input sharding); pc | Z (first rotation splits Z);
    pr | Y (second rotation splits Y)."""
    shape = tuple(shape)
    if len(shape) != 3:
        return False
    X, Y, Z = shape
    return X % pr == 0 and Y % pc == 0 and Z % pc == 0 and Y % pr == 0


def make_slab_fftnd(mesh: Mesh, axis: str | tuple[str, ...],
                    shape: Sequence[int], *, inverse: bool = False,
                    natural: bool = False, engines=None):
    """Build a jit-able slab-decomposed ND FFT (rank 2 or 3, 1D mesh).

    Global array (batch, d0, d1[, d2]) with d0 sharded over ``axis``.  All
    inner axes (d1[, d2]) transform locally; ONE all_to_all rotates d0 into
    locality (splitting d1) for its transform.  Output sharding: d1-sharded
    TRANSPOSED layout by default, or the input's d0-sharded layout for one
    extra all_to_all when ``natural=True``.  ``inverse`` builds the matching
    inverse: it consumes whichever layout the forward with the same
    ``natural`` emitted and always returns the natural d0-sharded signal.

    Returns ``(fn, in_spec, out_spec)``.
    """
    shape = tuple(int(d) for d in shape)
    rank = len(shape)
    if rank not in (2, 3):
        raise ValueError(f"slab decomposition is rank-2/3 only, got {shape}")
    ax_t = axis if isinstance(axis, str) else tuple(axis)
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    if not slab_divisible(shape, p):
        raise ValueError(f"slab: {p} devices must divide d0={shape[0]} "
                         f"and d1={shape[1]}")
    engs = _engines_for(rank, engines)
    tail = (None,) * (rank - 1)
    slab_spec = P(None, ax_t, *tail)                    # d0 sharded
    trans_spec = P(None, None, ax_t, *tail[1:])         # d1 sharded

    def run(x, block_ax, g):
        return _apply_last(x, block_ax,
                           functools.partial(engs[g], inverse=inverse))

    if not inverse or natural:
        # Forward pipeline.  Also the natural-in inverse: the transform is
        # fully separable (no cross-axis twiddle), so the inverse is the
        # same decomposition with inverse per-axis engines.
        def body(xb):                                   # (B, d0/P, d1[, d2])
            for g in range(rank - 1, 0, -1):            # inner axes, local
                xb = run(xb, g + 1, g)
            xb = jax.lax.all_to_all(xb, ax_t, split_axis=2, concat_axis=1,
                                    tiled=True)         # (B, d0, d1/P[, d2])
            xb = run(xb, 1, 0)                          # d0, now local
            if natural:
                xb = jax.lax.all_to_all(xb, ax_t, split_axis=1,
                                        concat_axis=2, tiled=True)
            return xb

        in_spec = slab_spec
        out_spec = slab_spec if natural else trans_spec
    else:
        # TRANSPOSED-in inverse: mirror of the forward, ending natural.
        def body(yb):                                   # (B, d0, d1/P[, d2])
            yb = run(yb, 1, 0)                          # d0, local
            yb = jax.lax.all_to_all(yb, ax_t, split_axis=1, concat_axis=2,
                                    tiled=True)         # (B, d0/P, d1[, d2])
            for g in range(1, rank):                    # inner axes, local
                yb = run(yb, g + 1, g)
            return yb

        in_spec = trans_spec
        out_spec = slab_spec

    fn = _shard_map(body, mesh, (in_spec,), out_spec)
    return jax.jit(fn), in_spec, out_spec


def make_pencil_fftnd(mesh: Mesh, row_axis, col_axis, shape: Sequence[int],
                      *, inverse: bool = False, natural: bool = False,
                      engines=None):
    """Build a jit-able pencil-decomposed 3D FFT over a (Pr, Pc) mesh.

    Global array (batch, X, Y, Z) with X sharded over ``row_axis`` (Pr) and
    Y over ``col_axis`` (Pc).  Z transforms locally; each remaining axis is
    rotated into locality by one all_to_all (2 rotations total).  Output:
    (X, Y/Pr, Z/Pc)-sharded TRANSPOSED layout by default, or the input's
    pencil layout for two extra all_to_alls when ``natural=True``.
    ``inverse`` consumes whichever layout the matching forward emitted and
    returns the natural pencil-sharded signal.

    Returns ``(fn, in_spec, out_spec)``.
    """
    shape = tuple(int(d) for d in shape)
    if len(shape) != 3:
        raise ValueError(f"pencil decomposition is rank-3 only, got {shape}")
    row_t = row_axis if isinstance(row_axis, str) else tuple(row_axis)
    col_t = col_axis if isinstance(col_axis, str) else tuple(col_axis)
    rows = (row_axis,) if isinstance(row_axis, str) else tuple(row_axis)
    cols = (col_axis,) if isinstance(col_axis, str) else tuple(col_axis)
    pr = 1
    for a in rows:
        pr *= mesh.shape[a]
    pc = 1
    for a in cols:
        pc *= mesh.shape[a]
    if not pencil_divisible(shape, pr, pc):
        raise ValueError(f"pencil: mesh ({pr}x{pc}) incompatible with "
                         f"shape {shape} (need pr|X, pc|Y, pc|Z, pr|Y)")
    engs = _engines_for(3, engines)
    pencil_spec = P(None, row_t, col_t, None)           # (B, X/Pr, Y/Pc, Z)
    trans_spec = P(None, None, row_t, col_t)            # (B, X, Y/Pr, Z/Pc)

    def run(x, block_ax, g):
        return _apply_last(x, block_ax,
                           functools.partial(engs[g], inverse=inverse))

    if not inverse or natural:
        # Forward pipeline (and, separability again, the natural-in inverse).
        def body(xb):                                   # (B, X/Pr, Y/Pc, Z)
            xb = run(xb, 3, 2)                          # Z, local
            xb = jax.lax.all_to_all(xb, col_t, split_axis=3, concat_axis=2,
                                    tiled=True)         # (B, X/Pr, Y, Z/Pc)
            xb = run(xb, 2, 1)                          # Y, local
            xb = jax.lax.all_to_all(xb, row_t, split_axis=2, concat_axis=1,
                                    tiled=True)         # (B, X, Y/Pr, Z/Pc)
            xb = run(xb, 1, 0)                          # X, local
            if natural:
                xb = jax.lax.all_to_all(xb, row_t, split_axis=1,
                                        concat_axis=2, tiled=True)
                xb = jax.lax.all_to_all(xb, col_t, split_axis=2,
                                        concat_axis=3, tiled=True)
            return xb

        in_spec = pencil_spec
        out_spec = pencil_spec if natural else trans_spec
    else:
        # TRANSPOSED-in inverse: exact mirror, ending natural.
        def body(yb):                                   # (B, X, Y/Pr, Z/Pc)
            yb = run(yb, 1, 0)                          # X, local
            yb = jax.lax.all_to_all(yb, row_t, split_axis=1, concat_axis=2,
                                    tiled=True)         # (B, X/Pr, Y, Z/Pc)
            yb = run(yb, 2, 1)                          # Y, local
            yb = jax.lax.all_to_all(yb, col_t, split_axis=2, concat_axis=3,
                                    tiled=True)         # (B, X/Pr, Y/Pc, Z)
            yb = run(yb, 3, 2)                          # Z, local
            return yb

        in_spec = trans_spec
        out_spec = pencil_spec

    fn = _shard_map(body, mesh, (in_spec,), out_spec)
    return jax.jit(fn), in_spec, out_spec


# ---------------------------------------------------------------------------
# 2D/3D: pencil decomposition
# ---------------------------------------------------------------------------
def fft3d_shard(x_block: jnp.ndarray, row_axis, col_axis,
                inverse: bool = False) -> jnp.ndarray:
    """Per-shard pencil 3D FFT body (call under shard_map).

    Global array (X, Y, Z); block (X/Pr, Y/Pc, Z) with X sharded over
    ``row_axis`` (size Pr), Y over ``col_axis`` (size Pc).  Returns block of
    the spectrum in (X/Pr, Y/Pc, Z) layout after full 3 axis transforms.
    """
    eng = functools.partial(fourstep.fft, inverse=inverse)
    # 1) FFT along Z (local)
    x = eng(x_block)
    # 2) rotate Y into locality: split Z over col_axis, gather Y
    x = jax.lax.all_to_all(x, col_axis, split_axis=2, concat_axis=1, tiled=True)
    #    now (X/Pr, Y, Z/Pc); FFT along Y
    x = jnp.moveaxis(eng(jnp.moveaxis(x, 1, -1)), -1, 1)
    # 3) rotate X into locality: split Y over row_axis, gather X
    x = jax.lax.all_to_all(x, row_axis, split_axis=1, concat_axis=0, tiled=True)
    #    now (X, Y/Pr, Z/Pc); FFT along X
    x = jnp.moveaxis(eng(jnp.moveaxis(x, 0, -1)), -1, 0)
    # 4) restore canonical sharding (X/Pr, Y/Pc, Z): undo both rotations
    x = jax.lax.all_to_all(x, row_axis, split_axis=0, concat_axis=1, tiled=True)
    x = jax.lax.all_to_all(x, col_axis, split_axis=1, concat_axis=2, tiled=True)
    return x


def make_fft3d(mesh: Mesh, row_axis, col_axis, shape: Sequence[int],
               inverse: bool = False, keep_transposed: bool = False):
    """Build a jit-able pencil 3D FFT.

    Input/output: (X, Y, Z) complex with sharding P(row_axis, col_axis, None).
    ``keep_transposed`` skips step 4 (output sharded (X, Y/Pr, Z/Pc)) —
    the cheaper layout when a roundtrip (e.g. spectral conv) follows.
    """
    row_t = row_axis if isinstance(row_axis, str) else tuple(row_axis)
    col_t = col_axis if isinstance(col_axis, str) else tuple(col_axis)

    def body(xb):
        if keep_transposed:
            eng = functools.partial(fourstep.fft, inverse=inverse)
            x = eng(xb)
            x = jax.lax.all_to_all(x, col_t, split_axis=2, concat_axis=1, tiled=True)
            x = jnp.moveaxis(eng(jnp.moveaxis(x, 1, -1)), -1, 1)
            x = jax.lax.all_to_all(x, row_t, split_axis=1, concat_axis=0, tiled=True)
            return jnp.moveaxis(eng(jnp.moveaxis(x, 0, -1)), -1, 0)
        return fft3d_shard(xb, row_t, col_t, inverse=inverse)

    in_spec = P(row_t, col_t, None)
    out_spec = P(None, row_t, col_t) if keep_transposed else in_spec
    fn = _shard_map(body, mesh, (in_spec,), out_spec)
    return jax.jit(fn)


def sharding_for(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
