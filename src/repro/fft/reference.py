"""Pure-jnp reference FFTs — the oracles every backend is validated against.

``jnp.fft`` lowers to XLA's native FFT HLO (DUCC on CPU, dedicated lowering on
TPU).  These wrappers pin down the exact conventions (sign, normalization,
half-spectrum layout) used throughout repro so that every hand-written backend
(stockham / fourstep / bluestein / pallas kernels) asserts against one source
of truth.

Conventions (numpy-compatible):
  forward :  X[k] = sum_j x[j] * exp(-2*pi*i*j*k / n)       (no scaling)
  inverse :  x[j] = (1/n) * sum_k X[k] * exp(+2*pi*i*j*k / n)
  rfft    :  returns n//2 + 1 coefficients along the transformed axis
"""

from __future__ import annotations

import jax.numpy as jnp


def fft(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Forward complex-to-complex DFT along ``axis``."""
    return jnp.fft.fft(x, axis=axis)


def ifft(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Inverse complex-to-complex DFT along ``axis`` (1/n normalized)."""
    return jnp.fft.ifft(x, axis=axis)


def rfft(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Real-to-complex forward transform (half spectrum, n//2+1 bins)."""
    return jnp.fft.rfft(x, axis=axis)


def irfft(x: jnp.ndarray, n: int, axis: int = -1) -> jnp.ndarray:
    """Complex-to-real inverse transform. ``n`` is the real output length."""
    return jnp.fft.irfft(x, n=n, axis=axis)


def fftn(x: jnp.ndarray, axes=None) -> jnp.ndarray:
    return jnp.fft.fftn(x, axes=axes)


def ifftn(x: jnp.ndarray, axes=None) -> jnp.ndarray:
    return jnp.fft.ifftn(x, axes=axes)


def rfftn(x: jnp.ndarray, axes=None) -> jnp.ndarray:
    return jnp.fft.rfftn(x, axes=axes)


def irfftn(x: jnp.ndarray, shape, axes=None) -> jnp.ndarray:
    return jnp.fft.irfftn(x, s=shape, axes=axes)


def dft_matrix(n: int, inverse: bool = False, dtype=jnp.complex64) -> jnp.ndarray:
    """The dense n x n DFT matrix W with W[j,k] = exp(-+ 2 pi i j k / n).

    The direct-matmul backend and the MXU four-step kernels contract against
    exactly this matrix; inverse includes NO 1/n factor (applied by callers).

    Angles are computed host-side in numpy float64 with the j*k product
    reduced mod n in integer arithmetic — a jnp computation would silently
    truncate to float32 under the default x64-disabled config, costing
    accuracy at large n.
    """
    import numpy as np
    j = np.arange(n, dtype=np.int64)
    sign = 2.0 if inverse else -2.0
    ang = (sign * np.pi / n) * ((j[:, None] * j[None, :]) % n).astype(np.float64)
    return jnp.asarray(np.exp(1j * ang), dtype=_canonical(dtype))


def twiddles(n1: int, n2: int, inverse: bool = False, dtype=jnp.complex64) -> jnp.ndarray:
    """Four-step twiddle factors T[j1, k2] = exp(-+ 2 pi i j1 k2 / (n1*n2)).

    Same numerical care as :func:`dft_matrix`: numpy float64 angles with
    exact integer reduction of j1*k2 mod n.
    """
    import numpy as np
    n = n1 * n2
    sign = 2.0 if inverse else -2.0
    j1 = np.arange(n1, dtype=np.int64)
    k2 = np.arange(n2, dtype=np.int64)
    ang = (sign * np.pi / n) * ((j1[:, None] * k2[None, :]) % n).astype(np.float64)
    return jnp.asarray(np.exp(1j * ang), dtype=_canonical(dtype))


def half_roots(n: int, inverse: bool = False, dtype=jnp.complex64) -> jnp.ndarray:
    """The first n//2 of the n-th unit roots e^{-+ 2 pi i k / n} — the
    radix-2 Stockham stage twiddles and the R2C pack/unpack twiddles.
    numpy float64 angles, cast once (same audit as :func:`dft_matrix`)."""
    import numpy as np
    sign = 2.0 if inverse else -2.0
    ang = (sign * np.pi / n) * np.arange(n // 2, dtype=np.float64)
    return jnp.asarray(np.exp(1j * ang), dtype=_canonical(dtype))


def _canonical(dtype):
    """Requested dtype under the active x64 config (a c128 request with x64
    disabled means c64, without the per-call truncation warning)."""
    from jax import dtypes
    return dtypes.canonicalize_dtype(jnp.dtype(dtype))
