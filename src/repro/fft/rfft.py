"""Real-to-complex (R2C) and complex-to-real (C2R) transforms.

The paper's headline experiments are single-precision R2C 3D transforms; real
input halves both the memory traffic and the flops vs. C2C (paper Fig. 8a).
We implement the classical half-length packing trick so every complex backend
(stockham / fourstep / bluestein / pallas) gets an R2C variant for free:

  even n:  z[j] = x[2j] + i x[2j+1]  (length n/2 complex), Z = cfft(z), then
           X[k] = (Z[k] + conj(Z[-k]))/2  -  (i/2) e^{-2pi i k/n} (Z[k] - conj(Z[-k]))
           for k = 0..n/2 (with Z indices mod n/2) — n/2+1 outputs.
  odd n:   fall back to full complex transform of the realified input.

``rfftn_packed``/``irfftn_packed`` generalize the trick to *whole-transform*
complex engines (the fused rank-2 Pallas kernel, or anything transforming
several trailing axes at once): because the axis-0..d-2 DFTs are linear and
commute with the last-axis pack, the packed signal can run through one fused
rank-d complex transform and unpack afterwards — the reversal ``Z[-k]``
simply becomes the index reversal mod *every* transformed axis
(``FFT(conj a)[k] = conj(FFT(a)[-k])`` per axis).  Real kinds therefore plan
through the packed path on top of **any** selected complex backend,
separable or fused.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from .reference import half_roots as _pack_twiddle
# (shared float64-angle twiddles — see reference.half_roots for the audit)

CFFT = Callable[..., jnp.ndarray]  # (x, inverse=False) -> y, along last axis


def _real_dtype(dtype) -> jnp.dtype:
    return jnp.float64 if dtype == jnp.complex128 else jnp.float32


def _complex_dtype(dtype) -> jnp.dtype:
    return jnp.complex128 if dtype == jnp.float64 else jnp.complex64


def rfft(x: jnp.ndarray, cfft: CFFT) -> jnp.ndarray:
    """Forward R2C along the last axis using complex engine ``cfft``.

    Returns n//2+1 coefficients (numpy rfft layout).
    """
    n = x.shape[-1]
    cdtype = _complex_dtype(x.dtype)
    if n % 2:  # odd length: no packing trick; pay the full transform
        return cfft(x.astype(cdtype))[..., : n // 2 + 1]

    h = n // 2
    z = x[..., 0::2].astype(cdtype) + 1j * x[..., 1::2].astype(cdtype)
    zf = cfft(z)  # (..., h)
    zrev = jnp.roll(jnp.flip(zf, axis=-1), 1, axis=-1)  # Z[-k mod h]
    even = 0.5 * (zf + jnp.conj(zrev))
    odd = -0.5j * (zf - jnp.conj(zrev))
    tw = _pack_twiddle(n, inverse=False, dtype=cdtype)
    half = even + tw * odd  # X[0..h-1]
    # X[h] (Nyquist) = even[0] - odd[0] evaluated at k=h: e^{-i pi} = -1
    nyq = (even[..., :1] - odd[..., :1])
    return jnp.concatenate([half, nyq], axis=-1)


def irfft(y: jnp.ndarray, n: int, cfft: CFFT) -> jnp.ndarray:
    """Inverse C2R along the last axis (input n//2+1 bins, output length n)."""
    cdtype = y.dtype if jnp.issubdtype(y.dtype, jnp.complexfloating) else _complex_dtype(y.dtype)
    y = y.astype(cdtype)
    if n % 2:
        # reconstruct the full spectrum by Hermitian symmetry, full C2C inverse
        tail = jnp.conj(jnp.flip(y[..., 1:], axis=-1))
        full = jnp.concatenate([y, tail], axis=-1)
        return jnp.real(cfft(full, inverse=True)).astype(_real_dtype(cdtype))

    h = n // 2
    half, nyq = y[..., :h], y[..., h:h + 1]
    half_rev = jnp.roll(jnp.flip(half, axis=-1), 1, axis=-1)
    half_rev = half_rev.at[..., 0].set(nyq[..., 0])  # X[-0] slot carries X[h]
    even = 0.5 * (half + jnp.conj(half_rev))
    odd = 0.5 * (half - jnp.conj(half_rev)) * _pack_twiddle(n, inverse=True,
                                                           dtype=cdtype)
    z = even + 1j * odd
    zt = cfft(z, inverse=True)
    out = jnp.empty((*y.shape[:-1], n), dtype=_real_dtype(cdtype))
    out = out.at[..., 0::2].set(jnp.real(zt))
    out = out.at[..., 1::2].set(jnp.imag(zt))
    return out


# ---------------------------------------------------------------------------
# packed real transforms over a fused rank-d complex engine
# ---------------------------------------------------------------------------
def _rev_mod(a: jnp.ndarray, axes) -> jnp.ndarray:
    """Index reversal mod the extent on each given axis:
    ``out[..., k, ...] = a[..., (-k) % n, ...]``."""
    for ax in axes:
        a = jnp.roll(jnp.flip(a, axis=ax), 1, axis=ax)
    return a


def rfftn_packed(x: jnp.ndarray, cfftn: CFFT, rank: int) -> jnp.ndarray:
    """Forward R2C over the trailing ``rank`` axes using the whole-transform
    complex engine ``cfftn`` (e.g. the fused rank-2 Pallas kernel).

    Output shape: last axis becomes n//2 + 1 bins (numpy rfftn layout).
    Even last extents run the packed half-length trick through ONE fused
    complex transform; odd extents pay the full complex transform.
    """
    n = x.shape[-1]
    cdtype = _complex_dtype(x.dtype)
    t_axes = tuple(range(-rank, 0))
    if n % 2:
        return cfftn(x.astype(cdtype))[..., : n // 2 + 1]

    h = n // 2
    z = x[..., 0::2].astype(cdtype) + 1j * x[..., 1::2].astype(cdtype)
    zf = cfftn(z)                        # fused rank-d transform of the pack
    zrev = _rev_mod(zf, t_axes)          # Z[(-k) mod shape] on every axis
    even = 0.5 * (zf + jnp.conj(zrev))
    odd = -0.5j * (zf - jnp.conj(zrev))
    tw = _pack_twiddle(n, inverse=False, dtype=cdtype)
    half = even + tw * odd               # X[..., 0..h-1]
    nyq = even[..., :1] - odd[..., :1]   # k_last = h: tw = e^{-i pi} = -1
    return jnp.concatenate([half, nyq], axis=-1)


def irfftn_packed(y: jnp.ndarray, shape, cfftn: CFFT) -> jnp.ndarray:
    """Inverse C2R over the trailing ``len(shape)`` axes using a
    whole-transform complex engine (input n//2+1 bins on the last axis)."""
    shape = tuple(shape)
    rank, n = len(shape), shape[-1]
    cdtype = y.dtype if jnp.issubdtype(y.dtype, jnp.complexfloating) \
        else _complex_dtype(y.dtype)
    y = y.astype(cdtype)
    outer_axes = tuple(range(-rank, -1))
    if n % 2:
        # Hermitian reconstruction of the full last axis, full C2C inverse:
        # X[k_outer, n-k] = conj(X[-k_outer, k])
        tail = jnp.conj(_rev_mod(jnp.flip(y[..., 1:], axis=-1), outer_axes))
        full = jnp.concatenate([y, tail], axis=-1)
        return jnp.real(cfftn(full, inverse=True)).astype(_real_dtype(cdtype))

    h = n // 2
    half, nyq = y[..., :h], y[..., h:h + 1]
    half_rev = jnp.roll(jnp.flip(half, axis=-1), 1, axis=-1)
    half_rev = half_rev.at[..., :1].set(nyq)      # X[-0] slot carries X[h]
    g = jnp.conj(_rev_mod(half_rev, outer_axes))  # E - tw*O at (k_outer, k)
    even = 0.5 * (half + g)
    odd = 0.5 * (half - g) * _pack_twiddle(n, inverse=True, dtype=cdtype)
    z = even + 1j * odd
    zt = cfftn(z, inverse=True)
    out = jnp.empty((*y.shape[:-1], n), dtype=_real_dtype(cdtype))
    out = out.at[..., 0::2].set(jnp.real(zt))
    out = out.at[..., 1::2].set(jnp.imag(zt))
    return out
