"""Real-to-complex (R2C) and complex-to-real (C2R) transforms.

The paper's headline experiments are single-precision R2C 3D transforms; real
input halves both the memory traffic and the flops vs. C2C (paper Fig. 8a).
We implement the classical half-length packing trick so every complex backend
(stockham / fourstep / bluestein / pallas) gets an R2C variant for free:

  even n:  z[j] = x[2j] + i x[2j+1]  (length n/2 complex), Z = cfft(z), then
           X[k] = (Z[k] + conj(Z[-k]))/2  -  (i/2) e^{-2pi i k/n} (Z[k] - conj(Z[-k]))
           for k = 0..n/2 (with Z indices mod n/2) — n/2+1 outputs.
  odd n:   fall back to full complex transform of the realified input.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from .reference import half_roots as _pack_twiddle
# (shared float64-angle twiddles — see reference.half_roots for the audit)

CFFT = Callable[..., jnp.ndarray]  # (x, inverse=False) -> y, along last axis


def _real_dtype(dtype) -> jnp.dtype:
    return jnp.float64 if dtype == jnp.complex128 else jnp.float32


def _complex_dtype(dtype) -> jnp.dtype:
    return jnp.complex128 if dtype == jnp.float64 else jnp.complex64


def rfft(x: jnp.ndarray, cfft: CFFT) -> jnp.ndarray:
    """Forward R2C along the last axis using complex engine ``cfft``.

    Returns n//2+1 coefficients (numpy rfft layout).
    """
    n = x.shape[-1]
    cdtype = _complex_dtype(x.dtype)
    if n % 2:  # odd length: no packing trick; pay the full transform
        return cfft(x.astype(cdtype))[..., : n // 2 + 1]

    h = n // 2
    z = x[..., 0::2].astype(cdtype) + 1j * x[..., 1::2].astype(cdtype)
    zf = cfft(z)  # (..., h)
    zrev = jnp.roll(jnp.flip(zf, axis=-1), 1, axis=-1)  # Z[-k mod h]
    even = 0.5 * (zf + jnp.conj(zrev))
    odd = -0.5j * (zf - jnp.conj(zrev))
    tw = _pack_twiddle(n, inverse=False, dtype=cdtype)
    half = even + tw * odd  # X[0..h-1]
    # X[h] (Nyquist) = even[0] - odd[0] evaluated at k=h: e^{-i pi} = -1
    nyq = (even[..., :1] - odd[..., :1])
    return jnp.concatenate([half, nyq], axis=-1)


def irfft(y: jnp.ndarray, n: int, cfft: CFFT) -> jnp.ndarray:
    """Inverse C2R along the last axis (input n//2+1 bins, output length n)."""
    cdtype = y.dtype if jnp.issubdtype(y.dtype, jnp.complexfloating) else _complex_dtype(y.dtype)
    y = y.astype(cdtype)
    if n % 2:
        # reconstruct the full spectrum by Hermitian symmetry, full C2C inverse
        tail = jnp.conj(jnp.flip(y[..., 1:], axis=-1))
        full = jnp.concatenate([y, tail], axis=-1)
        return jnp.real(cfft(full, inverse=True)).astype(_real_dtype(cdtype))

    h = n // 2
    half, nyq = y[..., :h], y[..., h:h + 1]
    half_rev = jnp.roll(jnp.flip(half, axis=-1), 1, axis=-1)
    half_rev = half_rev.at[..., 0].set(nyq[..., 0])  # X[-0] slot carries X[h]
    even = 0.5 * (half + jnp.conj(half_rev))
    odd = 0.5 * (half - jnp.conj(half_rev)) * _pack_twiddle(n, inverse=True,
                                                           dtype=cdtype)
    z = even + 1j * odd
    zt = cfft(z, inverse=True)
    out = jnp.empty((*y.shape[:-1], n), dtype=_real_dtype(cdtype))
    out = out.at[..., 0::2].set(jnp.real(zt))
    out = out.at[..., 1::2].set(jnp.imag(zt))
    return out
