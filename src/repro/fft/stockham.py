"""Iterative Stockham autosort radix-2 FFT in pure jnp.

This is the classical GPU-friendly formulation the paper cites ([29],
Stockham 1966): no bit-reversal pass, the permutation is absorbed into the
per-stage data layout.  On TPU this maps to VPU work (adds + complex
multiplies with reshapes between stages) and is therefore the *memory-bound*
backend; the MXU-native path lives in ``fourstep.py``.  Kept because (a) it is
the faithful algorithmic baseline, (b) it is the in-VMEM engine for odd
power-of-two residual factors.

Stage derivation (DIF Stockham, OTFFT formulation): with N = n * s fixed and
the buffer indexed as x[q + s*p] (p < n position inside each length-n
sub-transform, q < s the stride/batch index), one stage computes

    y[q + s*(2p + 0)] =  x[q + s*p] + x[q + s*(p + n/2)]
    y[q + s*(2p + 1)] = (x[q + s*p] - x[q + s*(p + n/2)]) * w_n^p ,  p < n/2

then recurses with (n, s) <- (n/2, 2s).  After log2(N) stages the output is in
natural order.  In array form each stage is a reshape to (..., 2, n/2, s),
a butterfly, and a reshape back — which is exactly what we do below.
"""

from __future__ import annotations

import jax.numpy as jnp

from .reference import half_roots as _stage_twiddle  # noqa: F401
# (float64-angle twiddles; the shared helper replaced a jnp computation that
# silently truncated to float32 under the default x64-disabled config)


def fft(x: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
    """Radix-2 Stockham FFT along the last axis. Requires power-of-two length.

    Forward is unnormalized; inverse applies the 1/N factor (numpy semantics).
    Works on any complex dtype; batch dims are carried through.
    """
    n_total = x.shape[-1]
    if n_total & (n_total - 1):
        raise ValueError(f"stockham requires power-of-two length, got {n_total}")
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    batch = x.shape[:-1]

    n, s = n_total, 1
    while n > 1:
        m = n // 2
        w = _stage_twiddle(n, inverse, x.dtype)  # (m,)
        v = x.reshape(*batch, 2, m, s)
        a, b = v[..., 0, :, :], v[..., 1, :, :]
        ya = a + b
        yb = (a - b) * w[:, None]
        x = jnp.stack([ya, yb], axis=-2).reshape(*batch, n_total)  # (..., m, 2, s)
        n, s = m, 2 * s

    if inverse:
        x = x / n_total
    return x


def ifft(x: jnp.ndarray) -> jnp.ndarray:
    return fft(x, inverse=True)
