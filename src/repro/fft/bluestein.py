"""Bluestein chirp-Z FFT for arbitrary (incl. large-prime) lengths.

The paper's "oddshape" extents (e.g. powers of 19) hit this path in fftw/cuFFT;
we implement it on top of our power-of-two engines so every extent class from
the paper's Fig. 7 is representable.

Identity: with jk = (j^2 + k^2 - (k-j)^2) / 2,

    X[k] = c[k] * sum_j (x[j] c[j]) * conj(c)[k - j],   c[j] = e^{-i pi j^2 / n}

i.e. a linear convolution of a[j] = x[j] c[j] with b[j] = conj(c)[j], which we
evaluate circularly at size m = next_pow2(2n - 1) via the Stockham engine.

Numerical care: j^2 / n is reduced mod 2 in *integer* arithmetic (pi j^2 / n
has period 2n in j^2) before the float conversion, so chirp phases stay
accurate for n in the millions even in float32.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import stockham


def _chirp(n: int, dtype) -> jnp.ndarray:
    j = np.arange(n, dtype=np.int64)
    jsq_mod = (j * j) % (2 * n)  # exact integer reduction
    ang = -np.pi * jsq_mod.astype(np.float64) / n
    return jnp.asarray(np.exp(1j * ang), dtype=dtype)


def _next_pow2(v: int) -> int:
    m = 1
    while m < v:
        m *= 2
    return m


def fft(x: jnp.ndarray, inverse: bool = False) -> jnp.ndarray:
    """Chirp-Z DFT along the last axis; works for ANY length n."""
    if not jnp.issubdtype(x.dtype, jnp.complexfloating):
        x = x.astype(jnp.complex64)
    n = x.shape[-1]
    if n == 1:
        return x
    c = _chirp(n, x.dtype)
    if inverse:
        c = jnp.conj(c)
    m = _next_pow2(2 * n - 1)

    a = jnp.zeros((*x.shape[:-1], m), dtype=x.dtype).at[..., :n].set(x * c)
    # b[j] = conj(c)[|j|] placed circularly: b[0..n-1] and b[m-n+1..m-1]
    bc = jnp.conj(c)
    b = jnp.zeros((m,), dtype=x.dtype)
    b = b.at[:n].set(bc)
    b = b.at[m - n + 1:].set(bc[1:][::-1])

    fa = stockham.fft(a)
    fb = stockham.fft(b)
    conv = stockham.fft(fa * fb, inverse=True)
    y = conv[..., :n] * c
    if inverse:
        y = y / n
    return y


def ifft(x: jnp.ndarray) -> jnp.ndarray:
    return fft(x, inverse=True)
