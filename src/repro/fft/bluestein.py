"""Bluestein chirp-Z FFT for arbitrary (incl. large-prime) lengths.

The paper's "oddshape" extents (e.g. powers of 19) hit this path in fftw/cuFFT;
we implement it on top of our power-of-two engines so every extent class from
the paper's Fig. 7 is representable.

Identity: with jk = (j^2 + k^2 - (k-j)^2) / 2,

    X[k] = c[k] * sum_j (x[j] c[j]) * conj(c)[k - j],   c[j] = e^{-i pi j^2 / n}

i.e. a linear convolution of a[j] = x[j] c[j] with b[j] = conj(c)[j], which we
evaluate circularly at any padded size m >= 2n - 1: next_pow2(2n - 1) for
the pow2-only engines, the (often much closer) smallest 7-SMOOTH m for the
mixed-radix Pallas kernel — e.g. n = 18432 convolves at 36864 instead of
65536, nearly halving the padded work.

Engine selection (the planner's ``chirpz_pallas`` backend vs the staged
``bluestein`` baseline): the two per-call padded pow2 transforms run through
a selectable engine — the fused in-VMEM ``stockham_pallas`` kernel, the
``sixstep`` composition for padded lengths past the VMEM tile budget, or the
staged pure-jnp ``stockham`` fallback.  ``engine="auto"`` picks by padded
length.

Host-side setup is cached, not recomputed per call: the chirp c and the
padded filter spectrum FFT(b) depend only on (n, dtype, direction), so they
are built once in numpy float64 — the filter via an exact host DFT, making
the third internal transform of the classical formulation disappear from
the per-call path entirely — and memoized (mirroring the twiddle-pack
pattern in ``kernels/stockham_pallas/ops.py``).

Numerical care: j^2 / n is reduced mod 2 in *integer* arithmetic (pi j^2 / n
has period 2n in j^2) before the float conversion, so chirp phases stay
accurate for n in the millions even in float32.  Real inputs promote to the
complex dtype of matching width — float32 -> complex64, float64 ->
complex128 — so double-precision data never silently loses precision.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.extents import next_pow2 as _next_pow2, next_smooth

from . import stockham
from .reference import _canonical

#: Padded-length thresholds for ``engine="auto"``: the fused single-kernel
#: Stockham path up to its useful VMEM batch-tile budget, the six-step
#: composition beyond, the staged jnp fallback past the six-step cap.
PALLAS_SINGLE_MAX_M = 1 << 15
SIXSTEP_MAX_M = 1 << 24

#: Engines the ``engine`` knob accepts ("auto" resolves by padded length).
ENGINES = ("auto", "stockham", "stockham_pallas", "sixstep")

#: (n, m, dtype name, inverse) -> (chirp, padded filter spectrum) HOST pair.
#: Bounded: a near-cap c128 entry is ~400 MB of host arrays, so a long
#: oddshape sweep must evict (insertion order — oldest problems first)
#: instead of growing host RSS for the process lifetime.
_TABLES: dict = {}
_TABLES_MAX = 32


def resolve_engine(n: int, engine: str = "auto",
                   interpret: bool = False) -> tuple[str, int]:
    """Resolve the ``engine`` knob and the padded length m >= 2n - 1 it
    convolves at.  The mixed-radix kernel accepts any 7-smooth m, so it
    pads far tighter than the pow2-only engines; under interpret mode
    (off-TPU conformance runs) "auto" keeps the staged jnp engine, where
    the Pallas interpreter would be pure overhead — an EXPLICIT engine
    choice still forces the fused kernels anywhere."""
    lo = 2 * n - 1
    if engine == "auto":
        if interpret:
            engine = "stockham"
        elif next_smooth(lo) <= PALLAS_SINGLE_MAX_M:
            engine = "stockham_pallas"
        elif _next_pow2(lo) <= SIXSTEP_MAX_M:
            engine = "sixstep"
        else:
            engine = "stockham"
    if engine not in ENGINES:
        raise ValueError(f"chirp engine must be one of {ENGINES}, "
                         f"got {engine!r}")
    m = next_smooth(lo) if engine == "stockham_pallas" else _next_pow2(lo)
    return engine, m


def _complex_dtype(dtype) -> jnp.dtype:
    """f32 -> c64, f64 -> c128; complex dtypes pass through (the dtype
    mapping bugfix: real float64 input used to downcast to complex64)."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.complexfloating):
        return dtype
    wide = jnp.complex128 if dtype == jnp.float64 else jnp.complex64
    return jnp.dtype(_canonical(wide))


def _build_tables(n: int, m: int, dtype, inverse: bool):
    """Host-side float64 chirp + padded filter spectrum (exact numpy DFT)."""
    j = np.arange(n, dtype=np.int64)
    jsq_mod = (j * j) % (2 * n)  # exact integer reduction
    ang = np.pi * jsq_mod.astype(np.float64) / n
    c = np.exp((1j if inverse else -1j) * ang)
    # b[j] = conj(c)[|j|] placed circularly: b[0..n-1] and b[m-n+1..m-1]
    bc = np.conj(c)
    b = np.zeros(m, dtype=np.complex128)
    b[:n] = bc
    b[m - n + 1:] = bc[1:][::-1]
    fb = np.fft.fft(b)
    dt = np.dtype(jnp.dtype(dtype).name)
    return c.astype(dt), fb.astype(dt)


def chirp_tables(n: int, m: int, dtype, inverse: bool = False):
    """The (chirp, filter spectrum) pair for one (n, m, dtype, direction),
    memoized so repeated un-jitted calls do no host trig work.  The cache
    holds HOST numpy arrays — never traced values, so a table built while
    tracing one jit can safely serve every later call — and jnp folds them
    in as constants at the use site."""
    key = (n, m, jnp.dtype(dtype).name, bool(inverse))
    out = _TABLES.get(key)
    if out is None:
        while len(_TABLES) >= _TABLES_MAX:
            _TABLES.pop(next(iter(_TABLES)))
        out = _TABLES[key] = _build_tables(n, m, dtype, inverse)
    return out


def _padded_engine(engine: str, tile_b, interpret: bool):
    """cfft(x, inverse=False) used for the two padded length-m transforms
    (``engine`` already resolved by :func:`resolve_engine`)."""
    if engine == "stockham":
        return stockham.fft
    if engine == "stockham_pallas":
        from repro.kernels.stockham_pallas import ops as sp_ops
        return lambda v, inverse=False: sp_ops.fft(
            v, inverse=inverse, tile_b=tile_b, interpret=interpret)
    if engine == "sixstep":
        from . import sixstep
        return lambda v, inverse=False: sixstep.fft(
            v, inverse=inverse, tile_b=tile_b, interpret=interpret)
    raise ValueError(f"chirp engine must be one of {ENGINES}, got {engine!r}")


def fft(x: jnp.ndarray, inverse: bool = False, *, engine: str = "stockham",
        tile_b: int | None = None, interpret: bool = False) -> jnp.ndarray:
    """Chirp-Z DFT along the last axis; works for ANY length n.

    ``engine`` selects the padded pow2 engine ("stockham" keeps the staged
    jnp baseline; "auto"/"stockham_pallas"/"sixstep" are the fused-kernel
    chirp path the planner exposes as ``chirpz_pallas``).  ``engine`` and
    ``tile_b`` are the PATIENT-searchable knobs.
    """
    x = x.astype(_complex_dtype(x.dtype))
    n = x.shape[-1]
    if n == 1:
        return x
    engine, m = resolve_engine(n, engine, interpret)
    c, fb = chirp_tables(n, m, x.dtype, inverse)
    cfft = _padded_engine(engine, tile_b, interpret)

    a = jnp.zeros((*x.shape[:-1], m), dtype=x.dtype).at[..., :n].set(x * c)
    conv = cfft(cfft(a) * fb, inverse=True)
    y = conv[..., :n] * c
    if inverse:
        y = y / n
    return y


def ifft(x: jnp.ndarray) -> jnp.ndarray:
    return fft(x, inverse=True)
