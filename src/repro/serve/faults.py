"""Deterministic fault injection for the planner and the serve engine.

A production FFT service meets failures the offline suite never sees: a
backend whose kernel won't compile for some extent, an executable that
raises (or silently emits NaNs) on device, a stalled host↔device transfer,
a worker thread that dies mid-batch.  Reproducing those against real
hardware is flaky by construction, so this module makes every failure mode
*injectable and seeded*: a :class:`FaultPlan` is a small, declarative
registry of :class:`FaultRule`\\ s, matched by (site, backend, extents,
kind, request id, nth matching call), that the serve engine and the
planner's build path consult at well-defined injection points.

Sites (where a rule can fire):

    build      the executable compile path (``FFTService._executable`` /
               a wrapped ``make_plan`` build callable)
    dispatch   host staging + device upload (``FFTService._dispatch``)
    execute    device completion / result fetch (``FFTService._retire``)

Fault kinds and their effect at the injection point:

    compile_error    raise :class:`FaultInjected` from the build
    execute_error    raise :class:`FaultInjected` at retire
    nan_output       corrupt the batch (or one request's rows) with NaNs
    transfer_stall   sleep ``stall_ms`` in the dispatch path
    latency_spike    sleep ``stall_ms`` at retire (slow batch, no error)
    kill_worker      raise :class:`WorkerKilled` (a BaseException that
                     escapes the engine's per-batch error handling and
                     kills the worker thread — the watchdog's test case)

Determinism: matching is pure bookkeeping — each rule counts the calls it
matches and fires on calls ``after <= n < after + times`` (``times = -1``
means forever).  The same request tape against the same plan fires the
same faults; there is no randomness anywhere in the hot path.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, fields
from typing import Optional, Sequence


class FaultInjected(RuntimeError):
    """An injected failure (compile or execute site).  Deliberately an
    ordinary ``Exception`` so it exercises exactly the handling paths a
    real backend failure would."""

    retryable = True


class WorkerKilled(BaseException):
    """An injected worker death.  Derives from ``BaseException`` so it
    escapes the engine's ``except Exception`` batch handling the way a
    real thread-killing condition would, leaving in-flight requests for
    the watchdog to fail."""


#: Every injectable failure mode, mapped to the site where it fires.
FAULT_SITES = {
    "compile_error": "build",
    "execute_error": "execute",
    "nan_output": "execute",
    "transfer_stall": "dispatch",
    "latency_spike": "execute",
    "kill_worker": "dispatch",
}
FAULT_KINDS = tuple(FAULT_SITES)


@dataclass(frozen=True)
class FaultRule:
    """One injectable failure, matched by coordinates + nth-call window.

    ``backend='*'`` / ``kind='*'`` / ``extents=None`` / ``rid=None`` are
    wildcards.  ``rid`` pins a rule to one specific request — the "poison
    request" the batch-bisection machinery must isolate.
    """

    fault: str                         # one of FAULT_KINDS
    backend: str = "*"                 # backend key or '*'
    extents: Optional[tuple[int, ...]] = None   # transform extents or any
    kind: str = "*"                    # FFT kind (Outplace_Complex, ...)
    rid: Optional[int] = None          # pin to one request id (poison)
    after: int = 0                     # skip the first `after` matches
    times: int = -1                    # fire this many times (-1 = forever)
    stall_ms: float = 25.0             # sleep for stall/latency faults

    def __post_init__(self):
        if self.fault not in FAULT_SITES:
            raise ValueError(f"unknown fault {self.fault!r}; "
                             f"known: {FAULT_KINDS}")
        if self.extents is not None:
            object.__setattr__(self, "extents",
                               tuple(int(v) for v in self.extents))
        if self.after < 0 or self.times < -1:
            raise ValueError(f"bad fault window: after={self.after} "
                             f"times={self.times}")

    @property
    def site(self) -> str:
        return FAULT_SITES[self.fault]

    def matches(self, site: str, backend: str, extents: tuple[int, ...],
                kind: str, rids: Sequence[int] = ()) -> bool:
        """Coordinate match only — the nth-call window is FaultPlan's."""
        if site != self.site:
            return False
        if self.backend != "*" and backend != self.backend:
            return False
        if self.extents is not None and tuple(extents) != self.extents:
            return False
        if self.kind != "*" and kind != self.kind:
            return False
        if self.rid is not None and self.rid not in rids:
            return False
        return True

    def to_dict(self) -> dict:
        d = {"fault": self.fault}
        for f in fields(self):
            v = getattr(self, f.name)
            if f.name != "fault" and v != f.default:
                d[f.name] = list(v) if f.name == "extents" and v else v
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultRule":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FaultRule key(s) {sorted(unknown)}; "
                             f"known: {', '.join(sorted(known))}")
        return cls(**d)


class FaultPlan:
    """A seeded, deterministic schedule of injectable failures.

    Thread-safe: the per-rule match counters sit behind one lock, so the
    nth-call windows stay exact under concurrent serve workers.  ``seed``
    rides along for round-trip completeness (and so chaos configs carry
    one identity), but matching itself is deterministic counting.
    """

    def __init__(self, rules: Sequence["FaultRule | dict"] = (),
                 seed: int = 0):
        self.rules: tuple[FaultRule, ...] = tuple(
            r if isinstance(r, FaultRule) else FaultRule.from_dict(dict(r))
            for r in rules)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._matched = [0] * len(self.rules)
        self._fired = [0] * len(self.rules)

    def __bool__(self) -> bool:
        return bool(self.rules)

    def check(self, site: str, backend: str = "*",
              extents: tuple[int, ...] = (), kind: str = "*",
              rids: Sequence[int] = ()) -> list[FaultRule]:
        """Advance every matching rule's counter; return the rules whose
        nth-call window covers this call (i.e. the faults to apply now)."""
        firing: list[FaultRule] = []
        with self._lock:
            for i, rule in enumerate(self.rules):
                if not rule.matches(site, backend, extents, kind, rids):
                    continue
                n = self._matched[i]
                self._matched[i] += 1
                if n < rule.after:
                    continue
                if rule.times >= 0 and n >= rule.after + rule.times:
                    continue
                self._fired[i] += 1
                firing.append(rule)
        return firing

    @property
    def injected(self) -> int:
        with self._lock:
            return sum(self._fired)

    def is_poison(self, extents: tuple[int, ...], kind: str,
                  rid: Optional[int] = None) -> bool:
        """Is a request with these coordinates *unrecoverably* doomed by
        this plan — an always-on (``after=0, times=-1``) error fault that
        matches every backend (so no fallback candidate escapes it), or
        any unbounded error fault pinned to this exact request id?"""
        for rule in self.rules:
            if rule.fault not in ("compile_error", "execute_error",
                                  "nan_output"):
                continue
            if rule.times != -1 or rule.after != 0:
                continue
            if rule.extents is not None and tuple(extents) != rule.extents:
                continue
            if rule.kind != "*" and kind != rule.kind:
                continue
            if rule.rid is not None:
                if rid is not None and rid == rule.rid:
                    return True
                continue
            if rule.backend == "*":
                return True
        return False

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "seed": self.seed,
                "injected": sum(self._fired),
                "rules": [{**r.to_dict(), "matched": m, "fired": f}
                          for r, m, f in zip(self.rules, self._matched,
                                             self._fired)],
            }

    def to_dict(self) -> dict:
        return {"seed": self.seed,
                "rules": [r.to_dict() for r in self.rules]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls(rules=d.get("rules", ()), seed=d.get("seed", 0))


def faulty_build(build, fault_plan: Optional[FaultPlan], problem):
    """Wrap a planner ``build(candidate)`` callable so build-site rules in
    ``fault_plan`` fire before the real compile — the injection point for
    :func:`repro.core.plan.make_plan`'s fallback walk, kept here so the
    core planner never imports the serve layer."""
    if fault_plan is None:
        return build

    def wrapped(cand):
        for rule in fault_plan.check("build", cand.backend, problem.extents,
                                     problem.kind):
            if rule.fault == "compile_error":
                raise FaultInjected(
                    f"injected compile error: {cand.key()} @ "
                    f"{problem.signature()}")
        return build(cand)

    return wrapped
