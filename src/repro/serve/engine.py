"""The FFT service engine: a long-lived worker loop over a Session.

Architecture (README "FFT service" section has the sketch):

    submit() ──▶ RequestQueue (bounded: backpressure) ──▶ Coalescer
                                                            │ batches
                                                            ▼
                  ┌──────────────── worker loop ────────────────────┐
                  │ stage rows into a host buffer (pow2 bucket)     │
                  │ upload + dispatch donated executable (async)    │
                  │ retire oldest in-flight batch, slice results    │
                  └─────────────────────────────────────────────────┘

Perf machinery:

* **Coalescing** — same-plan requests stack on the batch axis of one
  compiled executable (see :mod:`repro.serve.coalescer`).
* **Batch buckets** — coalesced row counts are rounded up to powers of two,
  so at most log2(max_batch) executables exist per plan instead of one per
  observed batch size; slack rows are staged but sliced away (counted in
  the metrics as ``padded_rows``).
* **Donated buffers** — executables are jitted with ``donate_argnums=(0,)``:
  XLA reuses the uploaded staging buffer for scratch/output instead of
  allocating fresh device memory per launch.
* **Double buffering** — dispatch is asynchronous; up to ``inflight``
  batches are on device while the worker stages the next host buffer, so
  host staging overlaps device compute.  Two alternating host staging
  arrays per (plan, bucket) avoid re-allocation.

Robustness: a bounded queue (backpressure), per-request deadlines (expired
requests complete with a clean timeout error *before* wasting a launch),
and engine exceptions that fail only the affected batch — the worker loop
itself never wedges.

Concurrency: the PlanCache is shared with the owning Session — its lookups
are single-flight and lock-guarded (PR 7), so several workers (or a worker
plus a foreground ``Session.run``) race safely on cold plans.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, fields
from typing import Any, Optional

import numpy as np

from ..core.client import Problem
from ..core.extents import classify, format_extents, next_pow2
from ..core.plan import Candidate, PlanCache, PlanRigor, make_plan
from ..core.results import Row
from .coalescer import Batch, Coalescer
from .metrics import ServiceMetrics
from .queue import RequestQueue
from .request import (FFTRequest, RequestTimeout, ServeError, make_request)


@dataclass(frozen=True)
class ServeConfig:
    """Service tuning knobs (all plain data: round-trips via to/from_dict
    like every other spec in the suite)."""

    max_queue: int = 1024            # bounded intake: the backpressure knob
    coalesce_window_ms: float = 2.0  # linger for stragglers; 0 = serial FIFO
    max_batch: int = 32              # row budget per coalesced launch
    workers: int = 1                 # consumer threads
    inflight: int = 2                # double-buffer depth per worker
    rigor: str = "estimate"          # planner rigor for request-time plans
    backend: Optional[str] = None    # pin one backend (bench per-library)
    timeout_ms: Optional[float] = None   # default per-request deadline
    bucket_batches: bool = True      # pow2-pad coalesced rows
    record_requests: bool = True     # keep per-request rows for ResultSet

    def __post_init__(self):
        if self.max_queue < 1 or self.max_batch < 1 or self.workers < 1 \
                or self.inflight < 1:
            raise ValueError(f"bad ServeConfig bounds: {self}")
        if self.rigor not in {r.value for r in PlanRigor}:
            raise ValueError(f"unknown rigor {self.rigor!r}")

    def to_dict(self) -> dict:
        d = {}
        for f in fields(self):
            v = getattr(self, f.name)
            if v is not None:
                d[f.name] = v
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServeConfig":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ServeConfig key(s) {sorted(unknown)}; "
                             f"known: {', '.join(sorted(known))}")
        return cls(**d)


class _Inflight:
    """One dispatched batch awaiting retirement."""

    __slots__ = ("batch", "out", "row_spans", "t_dispatch")

    def __init__(self, batch: Batch, out: Any,
                 row_spans: list[tuple[int, int]], t_dispatch: float):
        self.batch = batch
        self.out = out
        self.row_spans = row_spans
        self.t_dispatch = t_dispatch


class FFTService:
    """Long-lived FFT serving loop on top of a Session.

    Use as a context manager (``with FFTService(session) as svc``) or call
    :meth:`start` / :meth:`stop` explicitly.  ``submit`` returns the request
    itself, which doubles as the completion future.
    """

    def __init__(self, session=None, config: ServeConfig = ServeConfig(),
                 wisdom=None):
        from ..core.suite import Session

        self.session = session if session is not None else Session()
        self.config = config
        self.wisdom = wisdom if wisdom is not None \
            else getattr(self.session, "_wisdom", None)
        self.queue = RequestQueue(config.max_queue)
        self.metrics = ServiceMetrics()
        self._coalescer = Coalescer(self.queue,
                                    window_ms=config.coalesce_window_ms,
                                    max_rows=config.max_batch)
        self._threads: list[threading.Thread] = []
        self._staging: dict[tuple, list[np.ndarray]] = {}
        self._staging_flip: dict[tuple, int] = {}
        self._staging_lock = threading.Lock()
        self._rows: list[Row] = []
        self._rows_lock = threading.Lock()
        self._started = False
        self._worker_errors: list[BaseException] = []

    # --- lifecycle ---------------------------------------------------------
    def start(self) -> "FFTService":
        if self._started:
            return self
        self._started = True
        for i in range(self.config.workers):
            t = threading.Thread(target=self._worker_loop,
                                 name=f"fft-serve-{i}", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def stop(self, drain: bool = True) -> dict:
        """Shut down: close the intake, let workers drain what is queued
        (``drain=False`` fails queued requests instead), join, and return
        the final metrics snapshot."""
        if not drain:
            failed = []
            while True:
                req = self.queue.get(timeout=0)
                if req is None:
                    break
                failed.append(req)
            for req in failed:
                self._fail(req, ServeError("service stopped"))
        self.queue.close()
        for t in self._threads:
            t.join(timeout=60)
        self._threads.clear()
        self._started = False
        return self.report()

    def __enter__(self) -> "FFTService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --- intake ------------------------------------------------------------
    def submit(self, payload: np.ndarray, kind: str = "Outplace_Complex",
               precision: Optional[str] = None, rank: Optional[int] = None,
               timeout_ms: Optional[float] = None, block: bool = True,
               block_timeout: Optional[float] = None) -> FFTRequest:
        """Enqueue one forward-FFT job; returns its future.

        ``block=False`` sheds load instead of waiting on a full queue
        (raises :class:`QueueFull`).  ``timeout_ms`` overrides the service
        default deadline for this request.
        """
        if not self._started:
            raise ServeError("service not started (use 'with FFTService(...)'"
                             " or call start())")
        if timeout_ms is None:
            timeout_ms = self.config.timeout_ms
        req = make_request(payload, kind=kind, precision=precision,
                           rank=rank, timeout_ms=timeout_ms)
        if req.rows > self.config.max_batch:
            raise ServeError(
                f"request rows {req.rows} exceed max_batch "
                f"{self.config.max_batch}")
        self.metrics.on_submit()
        self.queue.put(req, block=block, timeout=block_timeout)
        return req

    def submit_many(self, payloads, kind: str = "Outplace_Complex",
                    precision: Optional[str] = None,
                    rank: Optional[int] = None,
                    timeout_ms: Optional[float] = None, block: bool = True,
                    block_timeout: Optional[float] = None
                    ) -> list[FFTRequest]:
        """Enqueue a burst of jobs in one shot (single queue lock + one
        worker wakeup, vs a lock/notify/GIL-handoff per ``submit``) —
        all-or-nothing on a full queue.  All payloads share the kind /
        precision / deadline; returns the request futures in order."""
        if not self._started:
            raise ServeError("service not started (use 'with FFTService(...)'"
                             " or call start())")
        if timeout_ms is None:
            timeout_ms = self.config.timeout_ms
        reqs = [make_request(p, kind=kind, precision=precision, rank=rank,
                             timeout_ms=timeout_ms) for p in payloads]
        for req in reqs:
            if req.rows > self.config.max_batch:
                raise ServeError(
                    f"request rows {req.rows} exceed max_batch "
                    f"{self.config.max_batch}")
        self.metrics.on_submit(len(reqs))
        self.queue.put_many(reqs, block=block, timeout=block_timeout)
        return reqs

    def prewarm(self, extents, kind: str = "Outplace_Complex",
                precision: str = "float") -> int:
        """Compile the executables this plan's traffic can hit — every pow2
        batch bucket up to ``max_batch`` — before opening the doors, so
        steady-state percentiles measure serving, not XLA compiles.
        Returns the number of bucket executables now warm."""
        batch = Batch(key=(tuple(int(v) for v in extents), kind, precision))
        n, bucket = 0, 1
        while bucket <= self.config.max_batch:
            self._executable(batch, bucket)
            n += 1
            if not self.config.bucket_batches:
                break   # unbucketed rows are unbounded; warm bucket 1 only
            bucket *= 2
        return n

    # --- worker loop -------------------------------------------------------
    def _worker_loop(self) -> None:
        pending: deque[_Inflight] = deque()
        try:
            while True:
                # With work in flight, poll without blocking so an idle
                # queue retires batches instead of stalling them behind
                # the inflight threshold.
                batch = self._coalescer.next_batch(
                    poll_ms=0.0 if pending else 50.0)
                if batch is None:
                    if pending:
                        self._retire(pending.popleft())
                        continue
                    if self.queue.closed:
                        break
                    continue
                inflight = self._dispatch(batch)
                if inflight is not None:
                    pending.append(inflight)
                while len(pending) >= self.config.inflight:
                    self._retire(pending.popleft())
        except BaseException as e:      # defensive: never die silently
            self._worker_errors.append(e)
        finally:
            while pending:
                self._retire(pending.popleft())

    def _dispatch(self, batch: Batch) -> Optional[_Inflight]:
        now = time.perf_counter()
        live: list[FFTRequest] = []
        for req in batch.requests:
            req.t_dispatch = now
            req.coalesced = batch.n_requests
            if req.expired(now):
                self._fail(req, RequestTimeout(
                    f"request {req.rid} expired in queue "
                    f"(waited {req.queue_ms:.1f} ms)"), timeout=True)
            else:
                live.append(req)
        if not live:
            return None
        batch.requests = live
        rows = batch.rows
        bucket = next_pow2(rows) if self.config.bucket_batches else rows
        try:
            compiled = self._executable(batch, bucket)
            staged = self._stage(batch, bucket)
            import jax
            device_in = jax.device_put(staged)
            out = compiled(device_in)   # async dispatch: do not block here
        except Exception as e:
            for req in live:
                self._fail(req, ServeError(
                    f"engine error: {type(e).__name__}: {e}"))
            return None
        self.metrics.on_batch(batch.n_requests, rows, bucket - rows)
        spans = []
        r0 = 0
        for req in live:
            spans.append((r0, r0 + req.rows))
            r0 += req.rows
        return _Inflight(batch, out, spans, now)

    def _retire(self, inflight: _Inflight) -> None:
        batch = inflight.batch
        try:
            import jax
            jax.block_until_ready(inflight.out)
            host_out = np.asarray(inflight.out)
        except Exception as e:
            for req in batch.requests:
                self._fail(req, ServeError(
                    f"engine error: {type(e).__name__}: {e}"))
            return
        now = time.perf_counter()
        for req, (r0, r1) in zip(batch.requests, inflight.row_spans):
            if req.expired(now):
                self._fail(req, RequestTimeout(
                    f"request {req.rid} missed its deadline "
                    f"(completed {req.latency_ms:.1f} ms after enqueue)"),
                    timeout=True)
                continue
            req._complete(result=host_out[r0:r1])
            self.metrics.on_complete(req.latency_ms, req.queue_ms,
                                     req.signal_bytes)
            self._record(req, success=True)

    # --- plan + staging ----------------------------------------------------
    def _plan_candidate(self, problem: Problem) -> Candidate:
        if self.config.backend is not None:
            return Candidate(self.config.backend)
        rigor = PlanRigor(self.config.rigor)
        cache = self.session.plan_cache
        key = PlanCache.plan_key(self.session.device_kind, problem, rigor,
                                 scope="serve")
        plan, _ = cache.plan(
            key, lambda: make_plan(problem, rigor, wisdom=self.wisdom))
        if plan is None:
            raise ServeError(f"NULL plan for {problem.signature()} "
                             f"(wisdom miss under wisdom_only rigor)")
        return plan.candidate

    def _executable(self, batch: Batch, bucket: int):
        """The AOT-compiled, donated executable for this plan at the bucket
        batch size — built once per (plan, bucket) via the shared
        single-flight PlanCache."""
        import jax
        from ..core.clients.jax_fft import forward_fn

        problem = Problem(batch.extents, batch.kind, batch.precision,
                          batch=bucket)
        cand = self._plan_candidate(problem)
        key = PlanCache.executable_key(self.session.device_kind, problem,
                                       cand, "serve_forward")

        def build():
            # Donation only pays off when XLA can alias input to output —
            # c2c transforms, where shapes and dtypes match.  For r2c the
            # real input can never back the complex output, and donating
            # it just emits a warning per compile.
            donate = (0,) if problem.complex_input else ()
            fn = jax.jit(forward_fn(problem, cand), donate_argnums=donate)
            spec = jax.ShapeDtypeStruct((bucket, *batch.extents),
                                        problem.input_dtype.name)
            return fn.lower(spec).compile()

        compiled, _, _ = self.session.plan_cache.executable(key, build)
        return compiled

    def _stage(self, batch: Batch, bucket: int) -> np.ndarray:
        """Copy request payloads into one of two alternating host staging
        buffers (double buffering: buffer k-1 may still be uploading while
        we fill buffer k)."""
        problem = Problem(batch.extents, batch.kind, batch.precision)
        skey = (batch.key, bucket)
        with self._staging_lock:
            bufs = self._staging.get(skey)
            if bufs is None:
                shape = (bucket, *batch.extents)
                bufs = [np.zeros(shape, dtype=problem.input_dtype)
                        for _ in range(2)]
                self._staging[skey] = bufs
                self._staging_flip[skey] = 0
            flip = self._staging_flip[skey]
            self._staging_flip[skey] = 1 - flip
        buf = bufs[flip]
        r0 = 0
        for req in batch.requests:
            buf[r0:r0 + req.rows] = req.payload
            r0 += req.rows
        return buf

    # --- bookkeeping -------------------------------------------------------
    def _fail(self, req: FFTRequest, err: ServeError,
              timeout: bool = False) -> None:
        req._complete(error=err)
        self.metrics.on_error(timeout=timeout)
        self._record(req, success=False, error=str(err))

    def _record(self, req: FFTRequest, success: bool,
                error: str = "") -> None:
        if not self.config.record_requests:
            return
        try:
            device = self.session.device_kind
        except Exception:
            device = "?"
        row = Row(library="ServeFFT", device=device,
                  extents=format_extents(req.extents),
                  rank=len(req.extents),
                  extent_class=classify(req.extents),
                  precision=req.precision, kind=req.kind,
                  rigor=self.config.rigor, run=req.rid, op="serve_request",
                  time_ms=req.latency_ms if success else 0.0,
                  bytes=req.signal_bytes, success=success, error=error)
        with self._rows_lock:
            self._rows.append(row)

    def rows(self) -> list[Row]:
        """Per-request result rows (op ``serve_request``; failed requests
        carry their error) — feed them to a ResultSet for the shared
        percentile aggregation."""
        with self._rows_lock:
            return list(self._rows)

    def result_set(self):
        from ..core.results import columns_for
        from ..core.suite import ResultSet

        return ResultSet(self.rows(), columns_for(False),
                         plan_stats=self.session.plan_cache.stats)

    def report(self) -> dict:
        """Metrics snapshot including the shared plan cache's counters."""
        return self.metrics.snapshot(plan_stats=self.session.plan_cache.stats)
