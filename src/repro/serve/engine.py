"""The FFT service engine: a long-lived worker loop over a Session.

Architecture (README "FFT service" section has the sketch):

    submit() ──▶ RequestQueue (bounded: backpressure) ──▶ Coalescer
                                                            │ batches
                                                            ▼
                  ┌──────────────── worker loop ────────────────────┐
                  │ stage rows into a host buffer (pow2 bucket)     │
                  │ upload + dispatch donated executable (async)    │
                  │ retire oldest in-flight batch, slice results    │
                  └─────────────────────────────────────────────────┘

Perf machinery:

* **Coalescing** — same-plan requests stack on the batch axis of one
  compiled executable (see :mod:`repro.serve.coalescer`).
* **Batch buckets** — coalesced row counts are rounded up to powers of two,
  so at most log2(max_batch) executables exist per plan instead of one per
  observed batch size; slack rows are staged but sliced away (counted in
  the metrics as ``padded_rows``).
* **Donated buffers** — executables are jitted with ``donate_argnums=(0,)``:
  XLA reuses the uploaded staging buffer for scratch/output instead of
  allocating fresh device memory per launch.
* **Double buffering** — dispatch is asynchronous; up to ``inflight``
  batches are on device while the worker stages the next host buffer, so
  host staging overlaps device compute.  Two alternating host staging
  arrays per (plan, bucket) avoid re-allocation.

Fault tolerance (README "Failure semantics" section):

* **Fallback chains** — an executable that fails to build (or a batch that
  fails to execute) demotes the service to the next candidate by modeled
  cost, with ``xla`` the always-feasible terminal fallback; the (backend,
  problem-class) pair is quarantined in a :class:`CircuitBreaker`, and a
  quarantine that opens is persisted to wisdom as a demotion.
* **Retries** — requests carry ``retries_left``; retryable failures
  re-enqueue through a jittered exponential-backoff timer.
* **Bisection** — a failed coalesced batch splits in two and each half is
  re-dispatched, so one poison request cannot fail its batchmates.
* **Watchdog** — a supervisor thread detects a dead worker, fails its
  in-flight requests cleanly, and restarts the thread; ``stop()`` reports
  (and raises on) workers still wedged after the join deadline.
* **Fault injection** — a seeded :class:`FaultPlan` (``ServeConfig.faults``)
  fires deterministic failures at the build / dispatch / execute sites so
  every path above is testable without real hardware faults.

Concurrency: the PlanCache is shared with the owning Session — its lookups
are single-flight and lock-guarded (PR 7), so several workers (or a worker
plus a foreground ``Session.run``) race safely on cold plans.
"""

from __future__ import annotations

import random
import threading
import time
from collections import deque
from dataclasses import dataclass, fields
from typing import Any, Optional

import numpy as np

from ..core.client import Problem
from ..core.extents import classify, format_extents, next_pow2
from ..core.plan import (Candidate, CircuitBreaker, PlanCache, PlanRigor,
                         breaker_key, fallback_chain, make_plan)
from ..core.results import Row
from .coalescer import Batch, Coalescer
from .faults import FaultInjected, FaultPlan, WorkerKilled
from .metrics import ServiceMetrics
from .queue import RequestQueue
from .request import (FFTRequest, QueueFull, RequestTimeout, ServeError,
                      make_request)


class WorkerWedged(ServeError):
    """``stop()`` gave up on one or more workers that would not join within
    the configured deadline.  ``snapshot`` carries the final report (with
    ``wedged_workers`` naming the stuck threads) so the caller still gets
    the metrics it came for."""

    retryable = False

    def __init__(self, msg: str, snapshot: Optional[dict] = None):
        super().__init__(msg)
        self.snapshot = snapshot or {}


@dataclass(frozen=True)
class ServeConfig:
    """Service tuning knobs (all plain data: round-trips via to/from_dict
    like every other spec in the suite)."""

    max_queue: int = 1024            # bounded intake: the backpressure knob
    coalesce_window_ms: float = 2.0  # linger for stragglers; 0 = serial FIFO
    max_batch: int = 32              # row budget per coalesced launch
    workers: int = 1                 # consumer threads
    inflight: int = 2                # double-buffer depth per worker
    rigor: str = "estimate"          # planner rigor for request-time plans
    backend: Optional[str] = None    # pin one backend (bench per-library)
    costmodel: Optional[str] = None  # fitted coefficient-table path: plans
    #                                  and fallback chains rank under it
    timeout_ms: Optional[float] = None   # default per-request deadline
    bucket_batches: bool = True      # pow2-pad coalesced rows
    record_requests: bool = True     # keep per-request rows for ResultSet
    # --- fault tolerance ----------------------------------------------------
    fallback: bool = True            # demote past failed plan candidates
    max_retries: int = 2             # re-enqueues per request on failure
    backoff_base_ms: float = 0.5     # first-retry backoff (doubles per try)
    backoff_max_ms: float = 50.0     # backoff cap
    bisect_batches: bool = True      # split failed coalesced batches in two
    probe_output: bool = True        # reject non-finite outputs at retire
    breaker_threshold: int = 3       # consecutive failures to quarantine
    breaker_cooldown_s: float = 5.0  # quarantine time before half-open probe
    watchdog_interval_s: float = 0.25    # worker liveness poll; 0 = off
    join_timeout_s: float = 60.0     # stop(): per-worker join deadline
    drain_timeout_s: float = 60.0    # stop(drain=True): total drain budget
    faults: tuple = ()               # FaultRule dicts (chaos injection)

    def __post_init__(self):
        if self.max_queue < 1 or self.max_batch < 1 or self.workers < 1 \
                or self.inflight < 1:
            raise ValueError(f"bad ServeConfig bounds: {self}")
        if self.rigor not in {r.value for r in PlanRigor}:
            raise ValueError(f"unknown rigor {self.rigor!r}")
        if self.max_retries < 0 or self.breaker_threshold < 1:
            raise ValueError(f"bad ServeConfig fault-tolerance bounds: {self}")
        # normalize fault rules to a tuple of plain dicts (validated by
        # round-tripping each through FaultRule) so configs stay JSON-ready
        # and equality/round-trip semantics match every other spec
        from .faults import FaultRule
        rules = tuple(
            (r if isinstance(r, FaultRule)
             else FaultRule.from_dict(dict(r))).to_dict()
            for r in self.faults)
        object.__setattr__(self, "faults", rules)

    def to_dict(self) -> dict:
        d = {}
        for f in fields(self):
            v = getattr(self, f.name)
            if f.name == "faults":
                if v:
                    d[f.name] = [dict(r) for r in v]
            elif v is not None:
                d[f.name] = v
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServeConfig":
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ServeConfig key(s) {sorted(unknown)}; "
                             f"known: {', '.join(sorted(known))}")
        return cls(**d)


class _Inflight:
    """One dispatched batch awaiting retirement."""

    __slots__ = ("batch", "out", "row_spans", "t_dispatch", "cand")

    def __init__(self, batch: Batch, out: Any,
                 row_spans: list[tuple[int, int]], t_dispatch: float,
                 cand: Optional[Candidate] = None):
        self.batch = batch
        self.out = out
        self.row_spans = row_spans
        self.t_dispatch = t_dispatch
        self.cand = cand


class FFTService:
    """Long-lived FFT serving loop on top of a Session.

    Use as a context manager (``with FFTService(session) as svc``) or call
    :meth:`start` / :meth:`stop` explicitly.  ``submit`` returns the request
    itself, which doubles as the completion future.
    """

    def __init__(self, session=None, config: ServeConfig = ServeConfig(),
                 wisdom=None, fault_plan: Optional[FaultPlan] = None):
        from ..core.suite import Session

        self.session = session if session is not None else Session()
        self.config = config
        self.wisdom = wisdom if wisdom is not None \
            else getattr(self.session, "_wisdom", None)
        self.fault_plan = fault_plan if fault_plan is not None \
            else (FaultPlan(config.faults) if config.faults else None)
        self.breaker = CircuitBreaker(threshold=config.breaker_threshold,
                                      cooldown_s=config.breaker_cooldown_s)
        self.queue = RequestQueue(config.max_queue)
        self.metrics = ServiceMetrics()
        self._coalescer = Coalescer(self.queue,
                                    window_ms=config.coalesce_window_ms,
                                    max_rows=config.max_batch)
        self._threads: list[threading.Thread] = []
        self._threads_lock = threading.Lock()
        self._staging: dict[tuple, list[np.ndarray]] = {}
        self._staging_flip: dict[tuple, int] = {}
        self._staging_lock = threading.Lock()
        self._chains: dict[str, list[Candidate]] = {}
        self._chains_lock = threading.Lock()
        self._cost_model = None   # resolved lazily: device discovery needs jax
        self._rows: list[Row] = []
        self._rows_lock = threading.Lock()
        self._started = False
        self._worker_errors: list[BaseException] = []
        # watchdog state: per-worker in-flight registries so a dead worker's
        # requests can be failed cleanly instead of hanging their futures
        self._pending_by_worker: dict[str, deque] = {}
        self._orphans: dict[str, list[FFTRequest]] = {}
        self._worker_state_lock = threading.Lock()
        self._worker_seq = 0
        self._watchdog: Optional[threading.Thread] = None
        self._stop_event = threading.Event()

    # --- lifecycle ---------------------------------------------------------
    def start(self) -> "FFTService":
        if self._started:
            return self
        self._started = True
        self._stop_event.clear()
        with self._threads_lock:
            for i in range(self.config.workers):
                t = threading.Thread(target=self._worker_loop,
                                     name=f"fft-serve-{i}", daemon=True)
                t.start()
                self._threads.append(t)
        if self.config.watchdog_interval_s > 0:
            self._watchdog = threading.Thread(target=self._watchdog_loop,
                                              name="fft-serve-watchdog",
                                              daemon=True)
            self._watchdog.start()
        return self

    def stop(self, drain: bool = True) -> dict:
        """Shut down: close the intake, let workers drain what is queued
        (``drain=False`` fails queued requests instead), join, and return
        the final metrics snapshot (``worker_errors`` / ``wedged_workers``
        included).

        Bounded: each worker gets at most ``join_timeout_s`` and the drain
        as a whole at most ``drain_timeout_s`` — when the budget runs out,
        still-queued requests are failed (so a still-feeding producer can't
        hold shutdown hostage) and any worker that *still* won't join is
        reported wedged via :class:`WorkerWedged` rather than silently
        abandoned."""
        self._stop_event.set()           # watchdog: no more restarts
        if self._watchdog is not None:
            self._watchdog.join(timeout=5.0)
            self._watchdog = None
        if not drain:
            failed = []
            while True:
                req = self.queue.get(timeout=0)
                if req is None:
                    break
                failed.append(req)
            for req in failed:
                self._fail(req, ServeError("service stopped"))
        self.queue.close()
        deadline = time.perf_counter() + self.config.drain_timeout_s
        with self._threads_lock:
            threads = list(self._threads)
        for t in threads:
            budget = min(self.config.join_timeout_s,
                         deadline - time.perf_counter())
            t.join(timeout=max(0.0, budget))
        still = [t for t in threads if t.is_alive()]
        if still and drain:
            # drain budget blown: shed the remaining queue so the workers
            # can reach their shutdown signal, then give one last grace join
            while True:
                req = self.queue.get(timeout=0)
                if req is None:
                    break
                self._fail(req, ServeError(
                    f"service stopping: drain deadline "
                    f"({self.config.drain_timeout_s:.0f}s) exceeded"))
            for t in still:
                t.join(timeout=1.0)
            still = [t for t in still if t.is_alive()]
        wedged = [t.name for t in still]
        if wedged:
            self.metrics.on_wedge(len(wedged))
        with self._threads_lock:
            self._threads.clear()
        self._started = False
        snap = self.report()
        snap["wedged_workers"] = wedged
        if wedged:
            raise WorkerWedged(
                f"{len(wedged)} worker(s) failed to join within "
                f"join_timeout_s={self.config.join_timeout_s:.0f}: "
                f"{', '.join(wedged)}", snapshot=snap)
        return snap

    def __enter__(self) -> "FFTService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # --- intake ------------------------------------------------------------
    def submit(self, payload: np.ndarray, kind: str = "Outplace_Complex",
               precision: Optional[str] = None, rank: Optional[int] = None,
               timeout_ms: Optional[float] = None, block: bool = True,
               block_timeout: Optional[float] = None) -> FFTRequest:
        """Enqueue one forward-FFT job; returns its future.

        ``block=False`` sheds load instead of waiting on a full queue
        (raises :class:`QueueFull`).  ``timeout_ms`` overrides the service
        default deadline for this request.
        """
        if not self._started:
            raise ServeError("service not started (use 'with FFTService(...)'"
                             " or call start())")
        if timeout_ms is None:
            timeout_ms = self.config.timeout_ms
        req = make_request(payload, kind=kind, precision=precision,
                           rank=rank, timeout_ms=timeout_ms,
                           retries=self.config.max_retries)
        if req.rows > self.config.max_batch:
            raise ServeError(
                f"request rows {req.rows} exceed max_batch "
                f"{self.config.max_batch}")
        self.metrics.on_submit()
        try:
            self.queue.put(req, block=block, timeout=block_timeout)
        except QueueFull:
            self.metrics.on_shed()
            raise
        return req

    def submit_many(self, payloads, kind: str = "Outplace_Complex",
                    precision: Optional[str] = None,
                    rank: Optional[int] = None,
                    timeout_ms: Optional[float] = None, block: bool = True,
                    block_timeout: Optional[float] = None
                    ) -> list[FFTRequest]:
        """Enqueue a burst of jobs in one shot (single queue lock + one
        worker wakeup, vs a lock/notify/GIL-handoff per ``submit``) —
        all-or-nothing on a full queue.  All payloads share the kind /
        precision / deadline; returns the request futures in order."""
        if not self._started:
            raise ServeError("service not started (use 'with FFTService(...)'"
                             " or call start())")
        if timeout_ms is None:
            timeout_ms = self.config.timeout_ms
        reqs = [make_request(p, kind=kind, precision=precision, rank=rank,
                             timeout_ms=timeout_ms,
                             retries=self.config.max_retries)
                for p in payloads]
        for req in reqs:
            if req.rows > self.config.max_batch:
                raise ServeError(
                    f"request rows {req.rows} exceed max_batch "
                    f"{self.config.max_batch}")
        self.metrics.on_submit(len(reqs))
        try:
            self.queue.put_many(reqs, block=block, timeout=block_timeout)
        except QueueFull:
            self.metrics.on_shed(len(reqs))
            raise
        return reqs

    def prewarm(self, extents, kind: str = "Outplace_Complex",
                precision: str = "float") -> int:
        """Compile the executables this plan's traffic can hit — every pow2
        batch bucket up to ``max_batch`` — before opening the doors, so
        steady-state percentiles measure serving, not XLA compiles.
        Returns the number of bucket executables now warm."""
        batch = Batch(key=(tuple(int(v) for v in extents), kind, precision))
        n, bucket = 0, 1
        while bucket <= self.config.max_batch:
            self._executable(batch, bucket)
            n += 1
            if not self.config.bucket_batches:
                break   # unbucketed rows are unbounded; warm bucket 1 only
            bucket *= 2
        return n

    # --- worker loop -------------------------------------------------------
    def _worker_loop(self) -> None:
        name = threading.current_thread().name
        pending: deque[_Inflight] = deque()
        with self._worker_state_lock:
            self._pending_by_worker[name] = pending
        batch: Optional[Batch] = None
        try:
            while True:
                batch = None
                # With work in flight, poll without blocking so an idle
                # queue retires batches instead of stalling them behind
                # the inflight threshold.
                batch = self._coalescer.next_batch(
                    poll_ms=0.0 if pending else 50.0)
                if batch is None:
                    if pending:
                        self._retire(pending.popleft())
                        continue
                    if self.queue.closed:
                        break
                    continue
                inflight = self._dispatch(batch)
                batch = None
                if inflight is not None:
                    pending.append(inflight)
                while len(pending) >= self.config.inflight:
                    self._retire(pending.popleft())
        except WorkerKilled as e:
            # dirty death: leave the current batch and the pending registry
            # behind for the watchdog to fail + restart — exactly what a
            # real thread-killing failure would look like
            with self._worker_state_lock:
                self._orphans[name] = (list(batch.requests)
                                       if batch is not None else [])
            self._worker_errors.append(e)
            return
        except BaseException as e:      # defensive: never die silently
            self._worker_errors.append(e)
        while pending:
            self._retire(pending.popleft())
        with self._worker_state_lock:
            self._pending_by_worker.pop(name, None)

    # --- watchdog ----------------------------------------------------------
    def _watchdog_loop(self) -> None:
        """Supervise the workers: a thread that died while the service is
        live gets its in-flight requests failed cleanly (their futures
        complete with an error instead of hanging) and is replaced."""
        while not self._stop_event.wait(self.config.watchdog_interval_s):
            with self._threads_lock:
                threads = list(self._threads)
            for t in threads:
                if t.is_alive():
                    continue
                if self.queue.closed or self._stop_event.is_set():
                    continue    # clean shutdown exits are not deaths
                self._restart_worker(t)

    def _restart_worker(self, dead: threading.Thread) -> None:
        with self._worker_state_lock:
            orphans = self._orphans.pop(dead.name, [])
            pending = self._pending_by_worker.pop(dead.name, None)
        if pending:
            orphans = orphans + [req for inf in pending
                                 for req in inf.batch.requests]
        for req in orphans:
            if not req.done():
                self._fail(req, ServeError(
                    f"worker {dead.name} died with request {req.rid} in "
                    f"flight; failed by watchdog"))
        with self._threads_lock:
            if dead in self._threads:
                self._threads.remove(dead)
            self._worker_seq += 1
            nt = threading.Thread(target=self._worker_loop,
                                  name=f"fft-serve-r{self._worker_seq}",
                                  daemon=True)
            self._threads.append(nt)
        self.metrics.on_worker_restart()
        nt.start()

    # --- fault injection ---------------------------------------------------
    def _apply_faults(self, site: str, backend: str, batch: Batch) -> list:
        """Fire any matching FaultPlan rules at ``site``.  Sleeps are
        applied here; ``kill_worker`` raises :class:`WorkerKilled` (a
        BaseException — it escapes the engine's batch error handling);
        ``compile_error`` raises inline (the build site calls this from
        inside the executable builder).  Error/corruption rules for the
        execute site are returned for the caller to apply."""
        if self.fault_plan is None:
            return []
        rules = self.fault_plan.check(
            site, backend=backend, extents=batch.extents, kind=batch.kind,
            rids=[r.rid for r in batch.requests])
        if rules:
            self.metrics.on_fault(len(rules))
        for rule in rules:
            if rule.fault in ("transfer_stall", "latency_spike"):
                time.sleep(rule.stall_ms / 1e3)
            elif rule.fault == "kill_worker":
                raise WorkerKilled(
                    f"injected worker kill at {site} "
                    f"({format_extents(batch.extents)})")
            elif rule.fault == "compile_error":
                raise FaultInjected(
                    f"injected compile error: {backend} @ "
                    f"{format_extents(batch.extents)}")
        return rules

    # --- dispatch / retire -------------------------------------------------
    def _dispatch(self, batch: Batch) -> Optional[_Inflight]:
        now = time.perf_counter()
        live: list[FFTRequest] = []
        for req in batch.requests:
            req.t_dispatch = now
            req.coalesced = batch.n_requests
            if req.expired(now):
                limit = ((req.deadline - req.t_enqueue) * 1e3
                         if req.deadline is not None else float("nan"))
                self._fail(req, RequestTimeout(
                    f"request {req.rid} expired in queue: waited "
                    f"{req.queue_ms:.1f} ms against a {limit:.0f} ms "
                    f"deadline (queue depth {len(self.queue)}/"
                    f"{self.queue.maxsize})"), timeout=True)
            else:
                live.append(req)
        if not live:
            return None
        batch.requests = live
        rows = batch.rows
        bucket = next_pow2(rows) if self.config.bucket_batches else rows
        cand: Optional[Candidate] = None
        try:
            cand, compiled = self._executable(batch, bucket)
            self._apply_faults("dispatch", cand.backend, batch)
            staged = self._stage(batch, bucket)
            import jax
            device_in = jax.device_put(staged)
            out = compiled(device_in)   # async dispatch: do not block here
        except Exception as e:
            self._handle_failure(batch, e, cand)
            return None
        self.metrics.on_batch(batch.n_requests, rows, bucket - rows)
        spans = []
        r0 = 0
        for req in live:
            spans.append((r0, r0 + req.rows))
            r0 += req.rows
        return _Inflight(batch, out, spans, now, cand)

    def _retire(self, inflight: _Inflight) -> None:
        batch = inflight.batch
        cand = inflight.cand
        try:
            rules = self._apply_faults(
                "execute", cand.backend if cand else "*", batch)
            for rule in rules:
                if rule.fault == "execute_error":
                    raise FaultInjected(
                        f"injected execute error: "
                        f"{cand.key() if cand else '?'} @ "
                        f"{format_extents(batch.extents)}")
            import jax
            jax.block_until_ready(inflight.out)
            host_out = np.asarray(inflight.out)
            nan_rules = [r for r in rules if r.fault == "nan_output"]
            if nan_rules:
                host_out = np.array(host_out)   # corrupt a private copy
                for rule in nan_rules:
                    if rule.rid is None:
                        host_out[:] = np.nan
                    else:
                        for req, (r0, r1) in zip(batch.requests,
                                                 inflight.row_spans):
                            if req.rid == rule.rid:
                                host_out[r0:r1] = np.nan
        except Exception as e:
            self._handle_failure(batch, e, cand)
            return
        now = time.perf_counter()
        problem = Problem(batch.extents, batch.kind, batch.precision)
        any_ok = False
        for req, (r0, r1) in zip(batch.requests, inflight.row_spans):
            if req.expired(now):
                limit = ((req.deadline - req.t_enqueue) * 1e3
                         if req.deadline is not None else float("nan"))
                self._fail(req, RequestTimeout(
                    f"request {req.rid} missed its {limit:.0f} ms deadline "
                    f"(completed {req.latency_ms:.1f} ms after enqueue)"),
                    timeout=True)
                continue
            out = host_out[r0:r1]
            if self.config.probe_output and not np.isfinite(out).all():
                # 'computed garbage' failure mode: per-request, so a poison
                # payload in a coalesced batch fails alone
                self._retry_or_fail(req, ServeError(
                    f"non-finite output from "
                    f"{cand.key() if cand else 'engine'} for request "
                    f"{req.rid}"))
                continue
            req._complete(result=out)
            any_ok = True
            self.metrics.on_complete(req.latency_ms, req.queue_ms,
                                     req.signal_bytes,
                                     retried=req.attempts > 0)
            self._record(req, success=True)
        if any_ok and cand is not None:
            # a delivered batch is the half-open probe's success signal
            self.breaker.record_success(breaker_key(cand.backend, problem))

    # --- failure handling --------------------------------------------------
    def _handle_failure(self, batch: Batch, err: Exception,
                        cand: Optional[Candidate]) -> None:
        """A batch failed at dispatch or execute.  Book the failure against
        the candidate's breaker entry, then isolate: multi-request batches
        bisect (one poison request must not fail its batchmates), single
        requests retry with backoff or fail cleanly."""
        problem = Problem(batch.extents, batch.kind, batch.precision)
        if cand is not None:
            state = self.breaker.record_failure(
                breaker_key(cand.backend, problem))
            if state == CircuitBreaker.OPEN \
                    and not (cand.backend == "xla" and not cand.axes):
                self._record_demotion(problem, cand.backend)
        reqs = list(batch.requests)
        if len(reqs) > 1 and self.config.bisect_batches:
            self.metrics.on_bisect()
            mid = len(reqs) // 2
            for half in (reqs[:mid], reqs[mid:]):
                sub = Batch(key=batch.key, requests=list(half))
                inflight = self._dispatch(sub)
                if inflight is not None:
                    self._retire(inflight)   # synchronous: bounded depth
        else:
            for req in reqs:
                self._retry_or_fail(req, err)

    def _retry_or_fail(self, req: FFTRequest, err: Exception) -> None:
        retryable = getattr(err, "retryable", True)
        if retryable and req.retries_left > 0 and not self.queue.closed \
                and not req.expired():
            req.retries_left -= 1
            req.attempts += 1
            self.metrics.on_retry()
            timer = threading.Timer(self._backoff_s(req), self._requeue,
                                    args=(req,))
            timer.daemon = True
            timer.start()
            return
        if isinstance(err, RequestTimeout):
            self._fail(req, err, timeout=True)
        elif isinstance(err, ServeError):
            self._fail(req, err)
        else:
            self._fail(req, ServeError(
                f"engine error: {type(err).__name__}: {err}"))

    def _backoff_s(self, req: FFTRequest) -> float:
        """Jittered exponential backoff: doubles per attempt up to the cap,
        scaled by a deterministic per-(request, attempt) factor in
        [0.5, 1.0) so retry storms decorrelate reproducibly."""
        base = self.config.backoff_base_ms * (2 ** max(0, req.attempts - 1))
        jitter = random.Random((req.rid << 8) ^ req.attempts).uniform(0.5, 1.0)
        return min(base, self.config.backoff_max_ms) * jitter / 1e3

    def _requeue(self, req: FFTRequest) -> None:
        if not self.queue.requeue(req):
            self._fail(req, ServeError(
                f"request {req.rid} dropped: service stopped before its "
                f"retry could run"))

    def _record_demotion(self, problem: Problem, backend: str) -> None:
        """Persist an opened quarantine to wisdom (best-effort) so warm
        sessions skip the known-bad pick outright."""
        self.metrics.on_demotion()
        if self.wisdom is None:
            return
        try:
            self.wisdom.record_demotion(problem, backend)
            self.wisdom.save()
        except Exception as e:       # persistence must never kill serving
            self._worker_errors.append(e)

    # --- plan + staging ----------------------------------------------------
    def _cost_model_cm(self):
        """Scoped install of the config's fitted coefficient table (no-op
        without one): request-time plans and fallback-chain rankings both
        run under the per-device fit instead of the hand-written defaults."""
        from contextlib import nullcontext

        if not self.config.costmodel:
            return nullcontext()
        from ..core.costmodel import model_for_device, use_model

        if self._cost_model is None:
            self._cost_model = model_for_device(self.session.device_kind,
                                                self.config.costmodel)
        return use_model(self._cost_model)

    def _plan_candidate(self, problem: Problem) -> Candidate:
        if self.config.backend is not None:
            return Candidate(self.config.backend)
        rigor = PlanRigor(self.config.rigor)
        cache = self.session.plan_cache
        key = PlanCache.plan_key(self.session.device_kind, problem, rigor,
                                 scope="serve")
        with self._cost_model_cm():
            plan, _ = cache.plan(
                key, lambda: make_plan(problem, rigor, wisdom=self.wisdom))
        if plan is None:
            raise ServeError(f"NULL plan for {problem.signature()} "
                             f"(wisdom miss under wisdom_only rigor)")
        return plan.candidate

    def _plan_chain(self, problem: Problem) -> list[Candidate]:
        """The ordered candidates this problem may be served with: the
        planner's pick first, then — when fallback is on — every other
        feasible candidate by modeled cost, ``xla`` guaranteed present."""
        top = self._plan_candidate(problem)
        if not self.config.fallback or self.config.backend is not None:
            # pinned backends never fall back: a per-library bench must fail
            # honestly rather than quietly serve another library's numbers
            return [top]
        ckey = problem.signature()
        with self._chains_lock:
            rest = self._chains.get(ckey)
        if rest is None:
            with self._cost_model_cm():
                rest = fallback_chain(problem)
            with self._chains_lock:
                self._chains[ckey] = rest
        return [top] + [c for c in rest if c.key() != top.key()]

    def _executable(self, batch: Batch, bucket: int
                    ) -> tuple[Candidate, Any]:
        """The AOT-compiled, donated executable for this plan at the bucket
        batch size — built once per (plan, bucket) via the shared
        single-flight PlanCache.  Walks the fallback chain: a candidate
        whose build fails (or that is quarantined / wisdom-demoted) demotes
        to the next, and the terminal candidate is tried regardless."""
        import jax
        from ..core.clients.jax_fft import forward_fn

        problem = Problem(batch.extents, batch.kind, batch.precision,
                          batch=bucket)
        chain = self._plan_chain(problem)
        demoted = (self.wisdom.demoted(problem)
                   if self.wisdom is not None else frozenset())
        last_err: Optional[Exception] = None
        for i, cand in enumerate(chain):
            terminal = i == len(chain) - 1
            is_xla = cand.backend == "xla" and not cand.axes
            bkey = breaker_key(cand.backend, problem)
            if not terminal and not is_xla:
                if cand.backend in demoted or not self.breaker.allows(bkey):
                    continue     # quarantined: skip without a fresh build
            key = PlanCache.executable_key(self.session.device_kind, problem,
                                           cand, "serve_forward")

            def build(cand=cand):
                self._apply_faults("build", cand.backend, batch)
                # Donation only pays off when XLA can alias input to
                # output — c2c transforms, where shapes and dtypes match.
                # For r2c the real input can never back the complex output,
                # and donating it just emits a warning per compile.
                donate = (0,) if problem.complex_input else ()
                fn = jax.jit(forward_fn(problem, cand),
                             donate_argnums=donate)
                spec = jax.ShapeDtypeStruct((bucket, *batch.extents),
                                            problem.input_dtype.name)
                return fn.lower(spec).compile()

            try:
                compiled, _, _ = self.session.plan_cache.executable(key, build)
            except Exception as e:
                last_err = e
                state = self.breaker.record_failure(bkey)
                if state == CircuitBreaker.OPEN and not is_xla:
                    self._record_demotion(problem, cand.backend)
                else:
                    self.metrics.on_demotion()
                continue
            return cand, compiled
        if last_err is not None:
            raise last_err
        raise ServeError(
            f"no live plan candidate for {problem.signature()}: every "
            f"backend in the fallback chain is quarantined")

    def _stage(self, batch: Batch, bucket: int) -> np.ndarray:
        """Copy request payloads into one of two alternating host staging
        buffers (double buffering: buffer k-1 may still be uploading while
        we fill buffer k)."""
        problem = Problem(batch.extents, batch.kind, batch.precision)
        skey = (batch.key, bucket)
        with self._staging_lock:
            bufs = self._staging.get(skey)
            if bufs is None:
                shape = (bucket, *batch.extents)
                bufs = [np.zeros(shape, dtype=problem.input_dtype)
                        for _ in range(2)]
                self._staging[skey] = bufs
                self._staging_flip[skey] = 0
            flip = self._staging_flip[skey]
            self._staging_flip[skey] = 1 - flip
        buf = bufs[flip]
        r0 = 0
        for req in batch.requests:
            buf[r0:r0 + req.rows] = req.payload
            r0 += req.rows
        return buf

    # --- bookkeeping -------------------------------------------------------
    def _fail(self, req: FFTRequest, err: ServeError,
              timeout: bool = False) -> None:
        req._complete(error=err)
        self.metrics.on_error(timeout=timeout)
        self._record(req, success=False, error=str(err))

    def _record(self, req: FFTRequest, success: bool,
                error: str = "") -> None:
        if not self.config.record_requests:
            return
        try:
            device = self.session.device_kind
        except Exception:
            device = "?"
        row = Row(library="ServeFFT", device=device,
                  extents=format_extents(req.extents),
                  rank=len(req.extents),
                  extent_class=classify(req.extents),
                  precision=req.precision, kind=req.kind,
                  rigor=self.config.rigor, run=req.rid, op="serve_request",
                  time_ms=req.latency_ms if success else 0.0,
                  bytes=req.signal_bytes, success=success, error=error)
        with self._rows_lock:
            self._rows.append(row)

    def rows(self) -> list[Row]:
        """Per-request result rows (op ``serve_request``; failed requests
        carry their error) — feed them to a ResultSet for the shared
        percentile aggregation."""
        with self._rows_lock:
            return list(self._rows)

    def result_set(self):
        from ..core.results import columns_for
        from ..core.suite import ResultSet

        return ResultSet(self.rows(), columns_for(False),
                         plan_stats=self.session.plan_cache.stats)

    def report(self) -> dict:
        """Metrics snapshot: the shared plan cache's counters, the
        quarantine (circuit breaker) states, worker errors, and — when a
        FaultPlan is attached — the injected-fault accounting."""
        snap = self.metrics.snapshot(
            plan_stats=self.session.plan_cache.stats,
            quarantine=self.breaker.snapshot())
        snap["worker_errors"] = [f"{type(e).__name__}: {e}"
                                 for e in self._worker_errors]
        if self.fault_plan is not None:
            snap["faults"] = self.fault_plan.snapshot()
        return snap
