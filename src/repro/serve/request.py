"""Serving requests and their completion futures.

A request is one FFT job in flight through the service: the problem
coordinates (extents / kind / precision — the same axes a SuiteSpec sweeps),
the host payload, and the three observability timestamps the latency report
is built from:

    t_enqueue   submit() accepted the request into the bounded queue
    t_dispatch  a worker pulled it into a (possibly coalesced) batch
    t_complete  its result (or error) was published to the future

``latency_ms = t_complete - t_enqueue`` is the number the p50/p95/p99
columns summarize; ``queue_ms = t_dispatch - t_enqueue`` separates queueing
delay from device time.

The future is a plain ``threading.Event`` wrapper (no asyncio: the engine
loop and the submitters are threads), completed exactly once — with a
result, or with a :class:`ServeError` that ``result()`` re-raises.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..core.client import KINDS, PRECISIONS, Problem


class ServeError(RuntimeError):
    """A request failed inside the service (engine error or timeout).
    The failure is recorded as a clean error result row — the worker loop
    itself never dies with the request.  ``retryable`` marks whether the
    engine may re-enqueue the request (with backoff) instead of failing it;
    engine errors default to retryable, deadline/backpressure failures
    don't (retrying an expired request only wastes a worker's time)."""

    retryable = True


class RequestTimeout(ServeError):
    """The request's deadline passed before its result was produced."""

    retryable = False


class QueueFull(ServeError):
    """Backpressure: the bounded request queue rejected a non-blocking
    submit (or a blocking one timed out waiting for space)."""

    retryable = False


_req_ids = itertools.count()


@dataclass
class FFTRequest:
    """One in-flight FFT job (forward transform of ``payload``)."""

    payload: np.ndarray                 # (*extents) or (b, *extents)
    extents: tuple[int, ...]
    kind: str = "Outplace_Complex"
    precision: str = "float"
    rows: int = 1                       # batch rows this request occupies
    rid: int = field(default_factory=lambda: next(_req_ids))
    deadline: Optional[float] = None    # perf_counter() deadline, if any
    # --- observability timestamps (perf_counter seconds) -------------------
    t_enqueue: float = 0.0
    t_dispatch: float = 0.0
    t_complete: float = 0.0
    # --- completion --------------------------------------------------------
    _event: threading.Event = field(default_factory=threading.Event)
    _result: Optional[np.ndarray] = None
    _error: Optional[ServeError] = None
    coalesced: int = 0                  # batch size this request rode in
    # --- fault tolerance ----------------------------------------------------
    retries_left: int = 0               # re-enqueues the engine may still do
    attempts: int = 0                   # dispatch attempts consumed so far

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown kind {self.kind!r}; known: {KINDS}")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"unknown precision {self.precision!r}; known: {PRECISIONS}")

    # --- identity ----------------------------------------------------------
    @property
    def plan_key(self) -> tuple:
        """Requests sharing this key run the same plan — the coalescer may
        stack them on the batch axis of one kernel launch."""
        return (self.extents, self.kind, self.precision)

    def problem(self, batch: Optional[int] = None) -> Problem:
        return Problem(self.extents, self.kind, self.precision,
                       batch=batch if batch is not None else self.rows)

    @property
    def signal_bytes(self) -> int:
        return self.problem().signal_bytes

    def expired(self, now: Optional[float] = None) -> bool:
        if self.deadline is None:
            return False
        return (time.perf_counter() if now is None else now) > self.deadline

    # --- future protocol ---------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def ok(self) -> bool:
        return self.done() and self._error is None

    @property
    def error(self) -> Optional[ServeError]:
        return self._error

    @property
    def latency_ms(self) -> float:
        return (self.t_complete - self.t_enqueue) * 1e3

    @property
    def queue_ms(self) -> float:
        return (self.t_dispatch - self.t_enqueue) * 1e3

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        """Block until complete; raise the request's error if it failed."""
        if not self._event.wait(timeout):
            raise RequestTimeout(
                f"request {self.rid} not complete after {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def _complete(self, result: Optional[np.ndarray] = None,
                  error: Optional[ServeError] = None) -> None:
        """Publish the outcome (exactly once; later calls are ignored so a
        late device result cannot clobber a timeout already reported)."""
        if self._event.is_set():
            return
        self._result = result
        self._error = error
        self.t_complete = time.perf_counter()
        self._event.set()


def make_request(payload: np.ndarray, kind: str = "Outplace_Complex",
                 precision: Optional[str] = None, rank: Optional[int] = None,
                 timeout_ms: Optional[float] = None,
                 retries: int = 0) -> FFTRequest:
    """Build a request from a host array.

    ``rank`` splits the leading axes into batch rows vs. transform extents
    (default: the whole shape is one transform, rows=1).  ``precision`` is
    inferred from the dtype when omitted.  ``retries`` seeds
    ``retries_left`` (the service overrides it with its configured policy
    at submit time unless the request already carries a budget).
    """
    payload = np.asarray(payload)
    if not (np.issubdtype(payload.dtype, np.floating)
            or np.issubdtype(payload.dtype, np.complexfloating)):
        raise ValueError(f"payload dtype {payload.dtype} is not a float or "
                         f"complex FFT input")
    shape = tuple(int(s) for s in payload.shape)
    if rank is None:
        rank = len(shape)
    if not 1 <= rank <= len(shape):
        raise ValueError(f"rank {rank} out of range for shape {shape}")
    extents = shape[len(shape) - rank:]
    rows = 1
    for s in shape[:len(shape) - rank]:
        rows *= s
    if precision is None:
        precision = ("double" if payload.dtype in (np.float64, np.complex128)
                     else "float")
    deadline = (time.perf_counter() + timeout_ms / 1e3
                if timeout_ms is not None else None)
    return FFTRequest(payload=payload.reshape(rows, *extents),
                      extents=extents, kind=kind, precision=precision,
                      rows=rows, deadline=deadline, retries_left=retries)
