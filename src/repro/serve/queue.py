"""Bounded request queue with backpressure (the service's intake).

A plain condition-variable FIFO, sized by ``maxsize``: when the queue is
full, ``put`` either blocks until a worker drains space (the default — the
open-loop replay driver leans on this so an over-driven service degrades to
queueing delay, not unbounded memory) or raises :class:`QueueFull`
immediately / after a timeout for callers that prefer load shedding.

Beyond FIFO ``get``, the coalescer needs one extra primitive:
``take_matching(key)`` — remove every queued request sharing a plan key, in
arrival order, up to a row budget.  Keeping it here (under the same lock)
means the coalescer never sees a torn view of the queue.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Optional

from .request import FFTRequest, QueueFull


class RequestQueue:
    def __init__(self, maxsize: int = 1024):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self._q: deque[FFTRequest] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._not_full = threading.Condition(self._lock)
        self._closed = False

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)

    # --- producer side -----------------------------------------------------
    def put(self, req: FFTRequest, block: bool = True,
            timeout: Optional[float] = None) -> None:
        """Enqueue; stamps ``t_enqueue`` on success.  Raises
        :class:`QueueFull` when non-blocking (or the timeout expires) and
        the bound is hit — the backpressure signal."""
        with self._not_full:
            if self._closed:
                raise QueueFull("queue is closed")
            if len(self._q) >= self.maxsize:
                if not block:
                    raise QueueFull(
                        f"queue full: {len(self._q)}/{self.maxsize} requests "
                        f"pending (raise ServeConfig.max_queue, or back off "
                        f"the producer)")
                deadline = (time.perf_counter() + timeout
                            if timeout is not None else None)
                while len(self._q) >= self.maxsize and not self._closed:
                    remaining = (deadline - time.perf_counter()
                                 if deadline is not None else None)
                    if remaining is not None and remaining <= 0:
                        raise QueueFull(
                            f"queue still full after waiting {timeout}s: "
                            f"{len(self._q)}/{self.maxsize} requests pending")
                    self._not_full.wait(remaining)
                if self._closed:
                    raise QueueFull("queue is closed")
            req.t_enqueue = time.perf_counter()
            self._q.append(req)
            self._not_empty.notify()

    def put_many(self, reqs: list[FFTRequest], block: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Enqueue a batch of requests under one lock acquisition with one
        consumer wakeup — the producer-side analogue of coalescing (a
        per-request ``put`` pays a lock + notify + GIL handoff each time).
        All-or-nothing: raises :class:`QueueFull` before enqueuing anything
        if the whole batch cannot fit."""
        if not reqs:
            return
        with self._not_full:
            if self._closed:
                raise QueueFull("queue is closed")
            if len(self._q) + len(reqs) > self.maxsize:
                if not block:
                    raise QueueFull(
                        f"queue cannot take {len(reqs)} more requests "
                        f"({len(self._q)}/{self.maxsize} pending)")
                deadline = (time.perf_counter() + timeout
                            if timeout is not None else None)
                while len(self._q) + len(reqs) > self.maxsize \
                        and not self._closed:
                    remaining = (deadline - time.perf_counter()
                                 if deadline is not None else None)
                    if remaining is not None and remaining <= 0:
                        raise QueueFull(
                            f"queue cannot take {len(reqs)} more requests "
                            f"after waiting {timeout}s "
                            f"({len(self._q)}/{self.maxsize} pending)")
                    self._not_full.wait(remaining)
                if self._closed:
                    raise QueueFull("queue is closed")
            now = time.perf_counter()
            for req in reqs:
                req.t_enqueue = now
                self._q.append(req)
            self._not_empty.notify()

    def requeue(self, req: FFTRequest) -> bool:
        """Re-admit a request the engine is retrying.  Deliberately ignores
        ``maxsize`` — a retry blocking behind fresh intake would deadlock
        the backoff timer thread — but respects ``closed`` (returns False;
        the caller fails the request cleanly).  Re-entered at the FRONT:
        the request's original arrival predates everything queued now."""
        with self._lock:
            if self._closed:
                return False
            self._q.appendleft(req)
            self._not_empty.notify()
            return True

    # --- consumer side -----------------------------------------------------
    def get(self, timeout: Optional[float] = None) -> Optional[FFTRequest]:
        """Pop the oldest request; ``None`` on timeout or when the queue is
        closed and drained (the worker's shutdown signal)."""
        with self._not_empty:
            deadline = (time.perf_counter() + timeout
                        if timeout is not None else None)
            while not self._q:
                if self._closed:
                    return None
                remaining = (deadline - time.perf_counter()
                             if deadline is not None else None)
                if remaining is not None and remaining <= 0:
                    return None
                self._not_empty.wait(remaining)
            req = self._q.popleft()
            self._not_full.notify()
            return req

    def take_matching(self, key: tuple, max_rows: int) -> list[FFTRequest]:
        """Remove queued requests whose ``plan_key`` equals ``key``, oldest
        first, stopping before a request that would push the summed batch
        rows past ``max_rows``.  Used by the coalescer to top up a batch."""
        out: list[FFTRequest] = []
        rows = 0
        with self._lock:
            kept: deque[FFTRequest] = deque()
            while self._q:
                req = self._q.popleft()
                if req.plan_key == key and rows + req.rows <= max_rows:
                    out.append(req)
                    rows += req.rows
                else:
                    kept.append(req)
            self._q = kept
            if out:
                self._not_full.notify_all()
        return out

    # --- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Stop accepting new work; blocked getters drain what remains and
        then receive ``None``."""
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
            self._not_full.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed
