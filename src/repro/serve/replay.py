"""Traffic replay: a seeded, Zipf-distributed request mix at a configurable
arrival rate — the serving analogue of a SuiteSpec.

gearshifft (and the offline tables) measure one problem at a time on a
quiet device; a service sees a *mix*.  :class:`TrafficSpec` describes that
mix declaratively, with the same round-trip discipline as SuiteSpec:

* the mix is the cross product shapes x kinds x precisions, ranked in
  declaration order and weighted by a Zipf law ``P(rank k) ∝ k^-s`` — a
  handful of hot shapes dominating a long tail, which is what production
  FFT traffic (and LM serving traffic) looks like;
* arrivals follow a seeded Poisson process at ``rate_hz`` (exponential
  inter-arrival gaps); ``rate_hz = 0`` degenerates to a burst — every
  request submitted as fast as the queue accepts, the closed-loop mode the
  coalescing benchmark uses;
* everything is seeded: the same spec replays the same request sequence,
  so tail-latency numbers are comparable across PRs.

``replay()`` drives a running :class:`~repro.serve.engine.FFTService` with
the spec and returns a :class:`ReplayReport` carrying the service metrics
snapshot (p50/p95/p99, sustained GiB/s, coalesce + cache counters) plus
per-mix-entry breakdowns.

``chaos_replay()`` is the fault-tolerance variant: the spec carries a
seeded :class:`~repro.serve.faults.FaultPlan` (``faults=``), the replay
runs under injection, and the :class:`ChaosReport` grades the outcome —
delivered-success rate over the *non-poisoned* requests (a poisoned
request is one an unbounded error rule targets; nothing can save it),
tail-latency inflation against an optional clean baseline, and zero-wedge
invariants.  CI's chaos-smoke step is just this with fixed seeds.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, fields
from typing import Iterator, Optional

import numpy as np

from ..core.client import KINDS, PRECISIONS, Problem
from ..core.extents import format_extents, parse_extents
from .request import FFTRequest


@dataclass(frozen=True)
class TrafficSpec:
    """One serving workload: what arrives, how often, in what proportions."""

    extents: tuple[tuple[int, ...], ...] = ((1024,), (4096,), (256, 256))
    kinds: tuple[str, ...] = ("Outplace_Complex",)
    precisions: tuple[str, ...] = ("float",)
    requests: int = 256          # total requests to replay
    rate_hz: float = 0.0         # Poisson arrival rate; 0 = closed-loop burst
    zipf_s: float = 1.1          # mix skew: P(rank k) ∝ k^-s
    batch: int = 1               # rows per request
    seed: int = 2017
    timeout_ms: Optional[float] = None   # per-request deadline
    faults: tuple = ()           # FaultRule dicts: chaos injection schedule

    def __post_init__(self):
        norm = object.__setattr__
        norm(self, "extents", tuple(
            parse_extents(e) if isinstance(e, str) else tuple(int(v) for v in e)
            for e in self.extents))
        norm(self, "kinds", tuple(self.kinds))
        norm(self, "precisions", tuple(self.precisions))
        # validate + normalize fault rules to plain dicts (JSON-ready, same
        # round-trip discipline as the rest of the spec)
        from .faults import FaultRule
        norm(self, "faults", tuple(
            (r if isinstance(r, FaultRule)
             else FaultRule.from_dict(dict(r))).to_dict()
            for r in self.faults))
        if not self.extents:
            raise ValueError("traffic spec needs at least one extent")
        bad = set(self.kinds) - set(KINDS)
        if bad:
            raise ValueError(f"unknown kind(s) {sorted(bad)}; known: {KINDS}")
        bad = set(self.precisions) - set(PRECISIONS)
        if bad:
            raise ValueError(f"unknown precision(s) {sorted(bad)}; "
                             f"known: {PRECISIONS}")
        if self.requests < 1:
            raise ValueError(f"requests must be >= 1, got {self.requests}")
        if self.rate_hz < 0 or self.zipf_s < 0 or self.batch < 1:
            raise ValueError(f"bad traffic parameters: rate_hz={self.rate_hz}"
                             f" zipf_s={self.zipf_s} batch={self.batch}")

    # --- the mix ------------------------------------------------------------
    def mix(self) -> list[tuple[tuple[int, ...], str, str]]:
        """The ranked (extents, kind, precision) entries, hottest first —
        declaration order is popularity order."""
        return [(e, k, p) for e in self.extents
                for k in self.kinds for p in self.precisions]

    def weights(self) -> np.ndarray:
        """Zipf weights over :meth:`mix`, normalized."""
        n = len(self.mix())
        w = np.arange(1, n + 1, dtype=np.float64) ** -self.zipf_s
        return w / w.sum()

    def schedule(self) -> Iterator[tuple[float, tuple[int, ...], str, str]]:
        """The deterministic replay tape: ``(t_arrival_s, extents, kind,
        precision)`` per request.  Arrival gaps are exponential at
        ``rate_hz`` (all zero for a burst)."""
        rng = np.random.default_rng(self.seed)
        mix = self.mix()
        w = self.weights()
        t = 0.0
        for _ in range(self.requests):
            if self.rate_hz > 0:
                t += float(rng.exponential(1.0 / self.rate_hz))
            idx = int(rng.choice(len(mix), p=w))
            yield t, *mix[idx]

    def fault_plan(self):
        """The spec's injection schedule as a live (counter-carrying)
        FaultPlan — build a fresh one per replay so nth-call windows start
        from zero."""
        from .faults import FaultPlan
        return FaultPlan(self.faults, seed=self.seed)

    # --- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        d = {"extents": [format_extents(e) for e in self.extents],
             "kinds": list(self.kinds), "precisions": list(self.precisions),
             "requests": self.requests, "rate_hz": self.rate_hz,
             "zipf_s": self.zipf_s, "batch": self.batch, "seed": self.seed}
        if self.timeout_ms is not None:
            d["timeout_ms"] = self.timeout_ms
        if self.faults:
            d["faults"] = [dict(r) for r in self.faults]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TrafficSpec":
        d = dict(d)
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown TrafficSpec key(s) {sorted(unknown)}; "
                             f"known: {', '.join(sorted(known))}")
        return cls(**d)


def _payloads(spec: TrafficSpec) -> dict:
    """One pre-generated host payload per mix entry (generating fresh noise
    per request would bottleneck the replay loop, not the service)."""
    rng = np.random.default_rng(spec.seed + 1)
    out = {}
    for ext, kind, prec in spec.mix():
        problem = Problem(ext, kind, prec, batch=spec.batch)
        shape = (spec.batch, *ext)
        x = rng.standard_normal(shape).astype(problem.real_dtype)
        if problem.complex_input:
            x = (x + 1j * rng.standard_normal(shape)).astype(
                problem.input_dtype)
        out[(ext, kind, prec)] = x
    return out


@dataclass
class ReplayReport:
    """What a replay measured: the service metrics snapshot + breakdowns."""

    traffic: dict                 # the TrafficSpec, as plain data
    service: dict                 # ServiceMetrics.snapshot()
    wall_s: float
    per_mix: list[dict] = field(default_factory=list)
    requests: list[FFTRequest] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {"traffic": self.traffic, "service": self.service,
                "wall_s": self.wall_s, "per_mix": self.per_mix}


def replay(service, spec: TrafficSpec,
           wait_timeout_s: float = 120.0) -> ReplayReport:
    """Drive a *running* service with the spec's request tape.

    Open-loop when ``rate_hz > 0``: each request is submitted at its
    scheduled arrival time (sleeping between arrivals), so queueing delay
    under overload shows up in the latency percentiles instead of being
    absorbed by the driver.  Burst mode otherwise.
    """
    from ..core.results import percentile_summary

    payloads = _payloads(spec)
    submitted: list[FFTRequest] = []
    t0 = time.perf_counter()
    for t_arr, ext, kind, prec in spec.schedule():
        if spec.rate_hz > 0:
            now = time.perf_counter() - t0
            if t_arr > now:
                time.sleep(t_arr - now)
        req = service.submit(payloads[(ext, kind, prec)], kind=kind,
                             precision=prec,
                             rank=len(ext),
                             timeout_ms=spec.timeout_ms)
        submitted.append(req)
    for req in submitted:
        try:
            req.result(timeout=wait_timeout_s)
        except Exception:
            pass   # failures are recorded on the request / in the metrics
    wall = time.perf_counter() - t0

    per_mix = []
    by_key: dict[tuple, list[FFTRequest]] = {}
    for req in submitted:
        by_key.setdefault(req.plan_key, []).append(req)
    for (ext, kind, prec) in spec.mix():
        reqs = by_key.get((ext, kind, prec))
        if not reqs:
            continue
        lats = [r.latency_ms for r in reqs if r.ok]
        entry = {"extents": format_extents(ext), "kind": kind,
                 "precision": prec, "requests": len(reqs),
                 "failed": sum(1 for r in reqs if not r.ok)}
        if lats:
            entry["latency_ms"] = {"mean": float(np.mean(lats)),
                                   **percentile_summary(lats)}
        per_mix.append(entry)
    return ReplayReport(traffic=spec.to_dict(), service=service.report(),
                        wall_s=wall, per_mix=per_mix, requests=submitted)


@dataclass
class ChaosReport:
    """A graded chaos replay: the ordinary replay report plus the
    fault-tolerance verdict.

    ``clean_success_rate`` is the number the acceptance gate watches: of
    the requests *no injected fault dooms outright* (see
    :meth:`FaultPlan.is_poison`), what fraction still delivered a result —
    through fallback, retry, bisection, or watchdog recovery.  ``violations``
    is empty when every invariant held; each entry is a human-readable
    sentence naming the broken one.
    """

    replay: ReplayReport
    faults: dict                     # FaultPlan.snapshot() after the run
    total: int = 0
    poisoned: int = 0                # requests no recovery could save
    clean_ok: int = 0                # non-poisoned requests that succeeded
    success_rate: float = 0.0        # over all requests
    clean_success_rate: float = 0.0  # over non-poisoned requests
    violations: list[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        return {**self.replay.to_dict(), "faults": self.faults,
                "total": self.total, "poisoned": self.poisoned,
                "clean_ok": self.clean_ok,
                "success_rate": self.success_rate,
                "clean_success_rate": self.clean_success_rate,
                "violations": list(self.violations), "ok": self.ok}


def chaos_replay(service, spec: TrafficSpec, wait_timeout_s: float = 120.0,
                 min_clean_success: float = 1.0,
                 baseline_p99_ms: Optional[float] = None,
                 max_p99_inflation: float = 50.0) -> ChaosReport:
    """Replay ``spec`` under its fault schedule and grade the recovery.

    The spec's ``faults`` become the service's live FaultPlan (unless the
    service already carries one — e.g. rid-pinned poison rules built after
    request creation).  Invariants checked:

    * ``clean_success_rate >= min_clean_success`` — every request the fault
      schedule didn't doom outright must still be served;
    * no wedged workers, and no worker error that isn't an injected kill
      (the engine must degrade, not die);
    * optionally, delivered p99 latency stays within ``max_p99_inflation``×
      a fault-free ``baseline_p99_ms`` (off unless a baseline is given).
    """
    plan = service.fault_plan
    if plan is None or (not plan and spec.faults):
        plan = spec.fault_plan()
        service.fault_plan = plan
    rep = replay(service, spec, wait_timeout_s=wait_timeout_s)

    total = len(rep.requests)
    poisoned = clean_ok = ok_all = 0
    for req in rep.requests:
        doomed = plan is not None and plan.is_poison(req.extents, req.kind,
                                                     rid=req.rid)
        if req.ok:
            ok_all += 1
        if doomed:
            poisoned += 1
        elif req.ok:
            clean_ok += 1
    clean_total = total - poisoned
    success_rate = ok_all / total if total else 0.0
    clean_rate = clean_ok / clean_total if clean_total else 1.0

    violations: list[str] = []
    snap = rep.service
    if clean_rate < min_clean_success:
        violations.append(
            f"clean success rate {clean_rate:.3f} below required "
            f"{min_clean_success:.3f} ({clean_ok}/{clean_total} non-poisoned "
            f"requests delivered)")
    if snap.get("wedged", 0):
        violations.append(f"{snap['wedged']} worker(s) wedged")
    stray = [e for e in snap.get("worker_errors", ())
             if not e.startswith("WorkerKilled")]
    if stray:
        violations.append(f"unexpected worker error(s): {stray}")
    if baseline_p99_ms is not None and "latency_ms" in snap:
        p99 = snap["latency_ms"]["p99"]
        if p99 > baseline_p99_ms * max_p99_inflation:
            violations.append(
                f"p99 {p99:.1f} ms exceeds {max_p99_inflation:.0f}x the "
                f"fault-free baseline ({baseline_p99_ms:.1f} ms)")

    return ChaosReport(replay=rep,
                       faults=plan.snapshot() if plan is not None else {},
                       total=total, poisoned=poisoned, clean_ok=clean_ok,
                       success_rate=success_rate,
                       clean_success_rate=clean_rate, violations=violations)
