"""Per-request observability: latency percentiles, throughput, counters.

One :class:`ServiceMetrics` instance per service, fed under a lock from the
worker threads.  ``snapshot()`` renders the serving report:

* latency (enqueue→complete) and queue-wait (enqueue→dispatch) p50/p95/p99
  — shared quantile math with the result tables
  (:func:`repro.core.results.percentile_summary`);
* sustained GiB/s at the algorithmic minimum of one HBM read + one write
  per request signal (the same convention ``tools/bench_compare.py`` uses,
  so serving numbers compare against the offline trajectory);
* coalescing counters: batches launched vs. requests served — a coalesce
  rate of ``1 - batches/requests`` — plus padded rows (bucket slack);
* failure counters, each its own column: engine errors, deadline timeouts,
  backpressure sheds, retries (and how many of them ultimately succeeded);
* robustness counters: plan demotions (fallback-chain hops past a failed
  backend), batch bisections, injected faults, watchdog worker restarts,
  wedged workers at shutdown;
* when a plan cache / circuit breaker is attached, its hit/miss totals and
  the per-(backend, problem-class) quarantine states.
"""

from __future__ import annotations

import statistics
import threading
import time
from typing import Optional

from ..core.results import percentile_summary

#: Latency samples kept for the percentile estimate; beyond this the
#: recorder keeps a uniform random reservoir so a week-long service does
#: not grow memory with traffic.
MAX_SAMPLES = 100_000


class ServiceMetrics:
    def __init__(self, max_samples: int = MAX_SAMPLES):
        self._lock = threading.Lock()
        self._max_samples = max_samples
        self._latencies_ms: list[float] = []
        self._queue_ms: list[float] = []
        self._seen = 0                    # total samples offered
        self._rng_state = 0x9E3779B97F4A7C15
        self.submitted = 0
        self.completed = 0
        self.errors = 0                   # engine errors (non-timeout)
        self.timeouts = 0
        self.sheds = 0                    # QueueFull rejections at submit
        self.retries = 0                  # re-enqueues after a failure
        self.retry_successes = 0          # completions that needed >=1 retry
        self.demotions = 0                # fallback hops past a bad backend
        self.bisections = 0               # failed-batch splits
        self.faults_injected = 0          # chaos: FaultPlan rules fired
        self.worker_restarts = 0          # watchdog thread replacements
        self.wedged = 0                   # workers alive past stop() joins
        self.batches = 0
        self.batched_requests = 0         # requests served in size>1 batches
        self.padded_rows = 0              # bucket slack rows computed
        self.bytes_moved = 0              # 2 * signal bytes per completion
        self.t_start = time.perf_counter()
        self.t_last = self.t_start

    # --- tiny deterministic splitmix for reservoir sampling ----------------
    def _rand(self, n: int) -> int:
        self._rng_state = (self._rng_state + 0x9E3779B97F4A7C15) % (1 << 64)
        z = self._rng_state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) % (1 << 64)
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) % (1 << 64)
        return (z ^ (z >> 31)) % n

    def _keep(self, store: list[float], v: float) -> None:
        if len(store) < self._max_samples:
            store.append(v)
        else:                             # reservoir: uniform over history
            i = self._rand(self._seen)
            if i < self._max_samples:
                store[i] = v

    # --- feed --------------------------------------------------------------
    def on_submit(self, n: int = 1) -> None:
        with self._lock:
            self.submitted += n

    def on_batch(self, n_requests: int, rows: int, padded_rows: int) -> None:
        with self._lock:
            self.batches += 1
            if n_requests > 1:
                self.batched_requests += n_requests
            self.padded_rows += padded_rows

    def on_complete(self, latency_ms: float, queue_ms: float,
                    nbytes: int, retried: bool = False) -> None:
        with self._lock:
            self.completed += 1
            self._seen += 1
            self._keep(self._latencies_ms, latency_ms)
            self._keep(self._queue_ms, queue_ms)
            self.bytes_moved += 2 * nbytes   # one read + one write
            if retried:
                self.retry_successes += 1
            self.t_last = time.perf_counter()

    def on_error(self, timeout: bool = False) -> None:
        with self._lock:
            if timeout:
                self.timeouts += 1
            else:
                self.errors += 1

    def on_shed(self, n: int = 1) -> None:
        with self._lock:
            self.sheds += n

    def on_retry(self) -> None:
        with self._lock:
            self.retries += 1

    def on_demotion(self, n: int = 1) -> None:
        with self._lock:
            self.demotions += n

    def on_bisect(self) -> None:
        with self._lock:
            self.bisections += 1

    def on_fault(self, n: int = 1) -> None:
        with self._lock:
            self.faults_injected += n

    def on_worker_restart(self) -> None:
        with self._lock:
            self.worker_restarts += 1

    def on_wedge(self, n: int = 1) -> None:
        with self._lock:
            self.wedged += n

    # --- report ------------------------------------------------------------
    def snapshot(self, plan_stats=None, quarantine=None) -> dict:
        """The serving report, as plain data (JSON-ready)."""
        with self._lock:
            lat = list(self._latencies_ms)
            qms = list(self._queue_ms)
            elapsed = max(self.t_last - self.t_start, 1e-9)
            out = {
                "requests": self.submitted,
                "completed": self.completed,
                "errors": self.errors,
                "timeouts": self.timeouts,
                "sheds": self.sheds,
                "retries": self.retries,
                "retry_successes": self.retry_successes,
                "demotions": self.demotions,
                "bisections": self.bisections,
                "faults_injected": self.faults_injected,
                "worker_restarts": self.worker_restarts,
                "wedged": self.wedged,
                "batches": self.batches,
                "batched_requests": self.batched_requests,
                "padded_rows": self.padded_rows,
                "coalesce_rate": (1.0 - self.batches / self.completed
                                  if self.completed else 0.0),
                "elapsed_s": elapsed,
                "rps": self.completed / elapsed,
                "gib_per_s": self.bytes_moved / elapsed / 2**30,
            }
        if lat:
            out["latency_ms"] = {"mean": statistics.fmean(lat),
                                 **percentile_summary(lat)}
            out["queue_ms"] = {"mean": statistics.fmean(qms),
                               **percentile_summary(qms)}
        if plan_stats is not None:
            out["plan_cache"] = plan_stats.as_dict()
        if quarantine is not None:
            out["quarantine"] = quarantine
        return out
