"""Request coalescing: same-plan requests become one batched kernel launch.

The fused kernels are batch-tiled already (``tile_b`` is the knob), so n
requests for the same (extents, kind, precision) stack on the batch axis of
ONE compiled executable and slice their results back out — n dispatches
collapse into one, which is where the serving throughput win comes from.

Policy: pull the oldest request, then top the batch up with every queued
request sharing its plan key; if the batch still has row budget and the
coalesce window is open, linger — wait up to ``window_ms`` from the *first*
request's dequeue for stragglers to arrive.  A zero window (or
``max_rows=1``) degrades to strict one-request-per-launch FIFO, which is
the serial baseline the benchmark compares against.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

from .queue import RequestQueue
from .request import FFTRequest


@dataclass
class Batch:
    """One coalesced kernel launch: same-plan requests, summed batch rows."""

    key: tuple                           # shared plan key
    requests: list[FFTRequest] = field(default_factory=list)

    @property
    def rows(self) -> int:
        return sum(r.rows for r in self.requests)

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    @property
    def extents(self) -> tuple[int, ...]:
        return self.key[0]

    @property
    def kind(self) -> str:
        return self.key[1]

    @property
    def precision(self) -> str:
        return self.key[2]


class Coalescer:
    """Builds batches from a :class:`RequestQueue`.

    ``next_batch`` polls once (up to ``poll_ms``) and returns ``None`` when
    no request arrived — the caller decides whether that means "retire
    in-flight work" or "queue closed, exit" (see the worker loop in
    :mod:`repro.serve.engine`).
    """

    def __init__(self, queue: RequestQueue, window_ms: float = 2.0,
                 max_rows: int = 32):
        if max_rows < 1:
            raise ValueError(f"max_rows must be >= 1, got {max_rows}")
        self.queue = queue
        self.window_ms = max(0.0, float(window_ms))
        self.max_rows = int(max_rows)

    def _top_up(self, batch: Batch) -> None:
        room = self.max_rows - batch.rows
        if room > 0:
            batch.requests.extend(
                self.queue.take_matching(batch.key, room))

    def next_batch(self, poll_ms: float = 50.0) -> Optional[Batch]:
        first = self.queue.get(timeout=poll_ms / 1e3)
        if first is None:
            return None
        batch = Batch(key=first.plan_key, requests=[first])
        self._top_up(batch)
        if self.window_ms > 0 and batch.rows < self.max_rows:
            # linger: give stragglers the rest of the window to coalesce.
            # Sleep in short slices so a filled batch leaves early.
            deadline = time.perf_counter() + self.window_ms / 1e3
            while batch.rows < self.max_rows:
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    break
                time.sleep(min(remaining, 0.0005))
                self._top_up(batch)
        return batch
