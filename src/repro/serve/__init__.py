"""FFT serving layer: request coalescing, traffic replay, tail latency.

The offline suite answers "how fast is one FFT on a quiet device"; this
package answers the serving question — what latency distribution does a
*mix* of FFT shapes see under load, and how much does coalescing
same-plan requests into one batched launch buy.

Entry points:

* :class:`FFTService` / :class:`ServeConfig` — the engine: bounded queue,
  coalescer, double-buffered worker loop over a shared Session, plus the
  fault-tolerance machinery (fallback chains, retries, batch bisection,
  watchdog).
* :class:`TrafficSpec` / :func:`replay` — seeded Zipf mixed-shape traffic
  at a configurable arrival rate.
* :class:`FaultPlan` / :func:`chaos_replay` — deterministic fault
  injection and the graded recovery replay CI's chaos-smoke step runs.
* ``benchmarks/table_serve.py`` and ``tools/bench_compare.py --serve`` —
  the reporting surfaces.
"""

from .request import (FFTRequest, QueueFull, RequestTimeout, ServeError,
                      make_request)
from .queue import RequestQueue
from .coalescer import Batch, Coalescer
from .metrics import ServiceMetrics
from .faults import (FaultInjected, FaultPlan, FaultRule, WorkerKilled,
                     faulty_build)
from .engine import FFTService, ServeConfig, WorkerWedged
from .replay import (ChaosReport, ReplayReport, TrafficSpec, chaos_replay,
                     replay)

__all__ = [
    "Batch", "ChaosReport", "Coalescer", "FFTRequest", "FFTService",
    "FaultInjected", "FaultPlan", "FaultRule", "QueueFull", "ReplayReport",
    "RequestQueue", "RequestTimeout", "ServeConfig", "ServeError",
    "ServiceMetrics", "TrafficSpec", "WorkerKilled", "WorkerWedged",
    "chaos_replay", "faulty_build", "make_request", "replay",
]
