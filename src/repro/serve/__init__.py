"""FFT serving layer: request coalescing, traffic replay, tail latency.

The offline suite answers "how fast is one FFT on a quiet device"; this
package answers the serving question — what latency distribution does a
*mix* of FFT shapes see under load, and how much does coalescing
same-plan requests into one batched launch buy.

Entry points:

* :class:`FFTService` / :class:`ServeConfig` — the engine: bounded queue,
  coalescer, double-buffered worker loop over a shared Session.
* :class:`TrafficSpec` / :func:`replay` — seeded Zipf mixed-shape traffic
  at a configurable arrival rate.
* ``benchmarks/table_serve.py`` and ``tools/bench_compare.py --serve`` —
  the reporting surfaces.
"""

from .request import (FFTRequest, QueueFull, RequestTimeout, ServeError,
                      make_request)
from .queue import RequestQueue
from .coalescer import Batch, Coalescer
from .metrics import ServiceMetrics
from .engine import FFTService, ServeConfig
from .replay import ReplayReport, TrafficSpec, replay

__all__ = [
    "Batch", "Coalescer", "FFTRequest", "FFTService", "QueueFull",
    "ReplayReport", "RequestQueue", "RequestTimeout", "ServeConfig",
    "ServeError", "ServiceMetrics", "TrafficSpec", "make_request", "replay",
]
