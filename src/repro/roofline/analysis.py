"""Roofline assembly: dry-run JSONs -> the three-term table (§Roofline).

Terms (seconds, per chip — the dry-run artifacts are per-device SPMD
modules, so parsed quantities are already per chip):

  compute    = dot_flops / PEAK_FLOPS            (loop-aware HLO dots)
  memory     = dot_bytes / HBM_BW                (dot operand+output traffic;
               upper bound on HBM movement — fusion keeps some tiles in VMEM)
  collective = collective_bytes / ICI_BW         (loop-aware, per-device)

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per step over the whole
job, divided by chips for the per-chip "useful" flops; the ratio against
compiled dot flops exposes remat/dispatch waste.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

from repro.configs.base import SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"16x16": 256, "2x16x16": 512}


def active_params(cfg) -> tuple[float, float]:
    """(total_params, active_params) excluding embeddings (6ND convention)."""
    d = cfg.d_model
    kind = cfg.block_kind

    def attn_p():
        if cfg.kv_lora_rank:
            hd = cfg.qk_nope_dim + cfg.qk_rope_dim
            return (d * cfg.n_heads * hd + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                    + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                    + cfg.n_heads * cfg.v_head_dim * d)
        return (d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim
                + cfg.n_heads * cfg.head_dim * d)

    def mlp_p(dff):
        return (3 if cfg.mlp_gated else 2) * d * dff

    total = active = 0.0
    if kind in ("gqa", "gemma", "musicgen"):
        per = attn_p() + mlp_p(cfg.d_ff)
        total = active = cfg.n_layers * per
    elif kind == "gqa_moe":
        ex = 3 * d * cfg.d_ff_expert
        per_t = attn_p() + cfg.n_experts * ex
        per_a = attn_p() + cfg.top_k * ex
        total, active = cfg.n_layers * per_t, cfg.n_layers * per_a
    elif kind == "mla_moe":
        ex = 3 * d * cfg.d_ff_expert
        shared = 3 * d * cfg.d_ff_expert * max(cfg.n_shared_experts, 1)
        nd_ = cfg.first_dense_layers
        nm = cfg.n_layers - nd_
        total = nd_ * (attn_p() + mlp_p(cfg.d_ff_dense)) + \
            nm * (attn_p() + cfg.n_experts * ex + shared)
        active = nd_ * (attn_p() + mlp_p(cfg.d_ff_dense)) + \
            nm * (attn_p() + cfg.top_k * ex + shared)
    elif kind == "vlm":
        per = attn_p() + mlp_p(cfg.d_ff)
        n_cross = cfg.n_layers // cfg.cross_every
        total = active = cfg.n_layers * per  # cross ~ self in param count
    elif kind == "xlstm":
        di = 2 * d
        per_m = 2 * d * di + 3 * di * di + di * d + 2 * di
        per_s = 4 * d * d + 4 * d * (d // cfg.n_heads) + 2 * d * int(d * 4 / 3)
        total = active = (cfg.n_layers // 2) * (per_m + per_s)
    elif kind == "hymba":
        di = cfg.d_inner
        mamba = 2 * d * di + di * (2 * cfg.ssm_state) + di * max(1, d // 16) * 2 + di * d
        per = attn_p() + mamba + mlp_p(cfg.d_ff)
        total = active = cfg.n_layers * per
    return total, active


def model_flops(arch: str, shape: str) -> float:
    """6*N_active*D for train (fwd+bwd); 2*N_active*D for inference steps."""
    cfg = get_config(arch)
    sp = SHAPES[shape]
    _, act = active_params(cfg)
    if sp.mode == "train":
        tokens = sp.global_batch * sp.seq_len
        return 6.0 * act * tokens
    if sp.mode == "prefill":
        tokens = sp.global_batch * sp.seq_len
        return 2.0 * act * tokens
    tokens = sp.global_batch  # one new token per sequence
    return 2.0 * act * tokens


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = "-"
    model_flops: float = 0.0
    hlo_flops: float = 0.0
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0
    compile_s: float = 0.0

    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def row_from_record(rec: dict) -> RooflineRow:
    row = RooflineRow(rec["arch"], rec["shape"], rec["mesh"],
                      str(rec["status"]))
    if rec["status"] != "ok":
        return row
    chips = CHIPS[rec["mesh"]]
    row.compute_s = rec["flops_per_device"] / PEAK_FLOPS
    row.memory_s = rec["dot_bytes_per_device"] / HBM_BW
    row.collective_s = rec["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": row.compute_s, "memory": row.memory_s,
             "collective": row.collective_s}
    row.dominant = max(terms, key=terms.get)
    row.model_flops = model_flops(rec["arch"], rec["shape"])
    row.hlo_flops = rec["flops_per_device"] * chips
    row.useful_ratio = row.model_flops / row.hlo_flops if row.hlo_flops else 0.0
    # fraction of ideal: time at peak for MODEL flops / bound step time
    ideal = row.model_flops / chips / PEAK_FLOPS
    bt = row.bound_time()
    row.roofline_fraction = ideal / bt if bt else 0.0
    row.compile_s = rec.get("compile_s", 0.0)
    return row


def load_rows(dryrun_dir: str, mesh: str | None = "16x16") -> list[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if mesh is not None and rec.get("mesh") != mesh:
            continue
        rows.append(row_from_record(rec))
    return rows


def markdown_table(rows: list[RooflineRow]) -> str:
    hdr = ("| arch | shape | status | compute (ms) | memory (ms) | "
           "collective (ms) | dominant | useful (6ND/HLO) | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.status != "ok":
            lines.append(f"| {r.arch} | {r.shape} | {r.status} | - | - | - | - | - | - |")
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | ok | {r.compute_s*1e3:.1f} | "
            f"{r.memory_s*1e3:.1f} | {r.collective_s*1e3:.1f} | "
            f"**{r.dominant}** | {r.useful_ratio:.2f} | "
            f"{r.roofline_fraction:.1%} |")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load_rows(args.dir, args.mesh)
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
