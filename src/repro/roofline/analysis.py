"""Roofline assembly: dry-run JSONs -> the three-term table (§Roofline).

Terms (seconds, per chip — the dry-run artifacts are per-device SPMD
modules, so parsed quantities are already per chip):

  compute    = dot_flops / PEAK_FLOPS            (loop-aware HLO dots)
  memory     = dot_bytes / HBM_BW                (dot operand+output traffic;
               upper bound on HBM movement — fusion keeps some tiles in VMEM)
  collective = collective_bytes / ICI_BW         (loop-aware, per-device)

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per step over the whole
job, divided by chips for the per-chip "useful" flops; the ratio against
compiled dot flops exposes remat/dispatch waste.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import math
import os
from dataclasses import dataclass

from repro.configs.base import SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"16x16": 256, "2x16x16": 512}

#: Per-device (peak FLOP/s, HBM bytes/s) envelopes for the FFT roofline,
#: keyed by a lowercase prefix of ``jax.Device.device_kind``.  The ``cpu``
#: entry is a deliberately conservative host envelope (one core's FMA
#: throughput / dual-channel DRAM) so interpret-mode CI containers still
#: produce *finite, comparable* fractions; absolute cpu fractions are not
#: meaningful across hosts, their trajectory on one host is.
DEVICE_PEAKS = {
    "cpu": (5.0e10, 2.0e10),
    "tpu v5 lite": (PEAK_FLOPS, HBM_BW),
    "tpu v5e": (PEAK_FLOPS, HBM_BW),
    "tpu v4": (275e12, 1228e9),
    "tpu v6": (918e12, 1640e9),
}


def device_peaks(device_kind: str | None) -> tuple[float, float]:
    """(peak FLOP/s, HBM bytes/s) for a jax ``device_kind`` string, by
    longest lowercase-prefix match; unknown kinds fall back to the cpu
    envelope (finite fractions beat a KeyError in a report path)."""
    dk = (device_kind or "").lower()
    best = None
    for prefix, peaks in DEVICE_PEAKS.items():
        if dk.startswith(prefix) and (best is None or len(prefix) > best[0]):
            best = (len(prefix), peaks)
    return best[1] if best else DEVICE_PEAKS["cpu"]


def fft_model_flops(extents, batch: int = 1) -> float:
    """Modeled FFT flops: the standard 5·N·log2(N) op count over the full
    nd problem (log2 factors over the axes sum, so the total-N form covers
    any rank) times the batch."""
    n = 1
    for e in extents:
        n *= int(e)
    if n <= 1:
        return 0.0
    return 5.0 * batch * n * math.log2(n)


def fft_roofline_frac(time_ms: float, flops: float, bytes_moved: float,
                      device_kind: str | None) -> float:
    """Achieved fraction of the modeled roofline for one measured FFT.

    ``ideal = max(flops/peak_flops, bytes/hbm_bw)`` — whichever wall the
    problem hits first — over the measured time.  Always finite for a
    positive measurement: a non-finite or non-positive bytes model (an
    infeasible-candidate sentinel leaking through) contributes zero to the
    ideal rather than poisoning the column.
    """
    if not time_ms or time_ms <= 0.0:
        return 0.0
    peak_flops, hbm_bw = device_peaks(device_kind)
    terms = [0.0]
    if flops and flops > 0 and flops != float("inf"):
        terms.append(flops / peak_flops)
    if bytes_moved and bytes_moved > 0 and bytes_moved != float("inf"):
        terms.append(bytes_moved / hbm_bw)
    return max(terms) / (time_ms * 1e-3)


def active_params(cfg) -> tuple[float, float]:
    """(total_params, active_params) excluding embeddings (6ND convention)."""
    d = cfg.d_model
    kind = cfg.block_kind

    def attn_p():
        if cfg.kv_lora_rank:
            hd = cfg.qk_nope_dim + cfg.qk_rope_dim
            return (d * cfg.n_heads * hd + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                    + cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
                    + cfg.n_heads * cfg.v_head_dim * d)
        return (d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim
                + cfg.n_heads * cfg.head_dim * d)

    def mlp_p(dff):
        return (3 if cfg.mlp_gated else 2) * d * dff

    total = active = 0.0
    if kind in ("gqa", "gemma", "musicgen"):
        per = attn_p() + mlp_p(cfg.d_ff)
        total = active = cfg.n_layers * per
    elif kind == "gqa_moe":
        ex = 3 * d * cfg.d_ff_expert
        per_t = attn_p() + cfg.n_experts * ex
        per_a = attn_p() + cfg.top_k * ex
        total, active = cfg.n_layers * per_t, cfg.n_layers * per_a
    elif kind == "mla_moe":
        ex = 3 * d * cfg.d_ff_expert
        shared = 3 * d * cfg.d_ff_expert * max(cfg.n_shared_experts, 1)
        nd_ = cfg.first_dense_layers
        nm = cfg.n_layers - nd_
        total = nd_ * (attn_p() + mlp_p(cfg.d_ff_dense)) + \
            nm * (attn_p() + cfg.n_experts * ex + shared)
        active = nd_ * (attn_p() + mlp_p(cfg.d_ff_dense)) + \
            nm * (attn_p() + cfg.top_k * ex + shared)
    elif kind == "vlm":
        def cross_attn_p():
            # mirrors models.attention.init_cross_attention with
            # d_kv_in == d_model: q/out over d, k/v from the image embeds
            return (d * cfg.n_heads * cfg.head_dim
                    + 2 * d * cfg.n_kv_heads * cfg.head_dim
                    + cfg.n_heads * cfg.head_dim * d)
        # every cross_every-th decoder layer is cross-attention (the model
        # builds n_layers//cross_every units of (cross_every-1) self + 1
        # cross); count both layer kinds explicitly instead of assuming
        # cross ~ self
        n_cross = cfg.n_layers // cfg.cross_every if cfg.cross_every else 0
        n_self = cfg.n_layers - n_cross
        per_self = attn_p() + mlp_p(cfg.d_ff)
        per_cross = cross_attn_p() + mlp_p(cfg.d_ff)
        total = active = n_self * per_self + n_cross * per_cross
    elif kind == "xlstm":
        di = 2 * d
        per_m = 2 * d * di + 3 * di * di + di * d + 2 * di
        per_s = 4 * d * d + 4 * d * (d // cfg.n_heads) + 2 * d * int(d * 4 / 3)
        total = active = (cfg.n_layers // 2) * (per_m + per_s)
    elif kind == "hymba":
        di = cfg.d_inner
        mamba = 2 * d * di + di * (2 * cfg.ssm_state) + di * max(1, d // 16) * 2 + di * d
        per = attn_p() + mamba + mlp_p(cfg.d_ff)
        total = active = cfg.n_layers * per
    return total, active


def model_flops(arch: str, shape: str) -> float:
    """6*N_active*D for train (fwd+bwd); 2*N_active*D for inference steps."""
    cfg = get_config(arch)
    sp = SHAPES[shape]
    _, act = active_params(cfg)
    if sp.mode == "train":
        tokens = sp.global_batch * sp.seq_len
        return 6.0 * act * tokens
    if sp.mode == "prefill":
        tokens = sp.global_batch * sp.seq_len
        return 2.0 * act * tokens
    tokens = sp.global_batch  # one new token per sequence
    return 2.0 * act * tokens


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = "-"
    model_flops: float = 0.0
    hlo_flops: float = 0.0
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0
    compile_s: float = 0.0

    def bound_time(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def row_from_record(rec: dict) -> RooflineRow:
    row = RooflineRow(rec["arch"], rec["shape"], rec["mesh"],
                      str(rec["status"]))
    if rec["status"] != "ok":
        return row
    chips = CHIPS.get(rec["mesh"])
    if chips is None:
        # an unfamiliar dry-run mesh must not abort the whole table — emit
        # a skipped row so the rest of the grid still renders
        row.status = f"skipped: unknown mesh {rec['mesh']}"
        return row
    row.compute_s = rec["flops_per_device"] / PEAK_FLOPS
    row.memory_s = rec["dot_bytes_per_device"] / HBM_BW
    row.collective_s = rec["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": row.compute_s, "memory": row.memory_s,
             "collective": row.collective_s}
    row.dominant = max(terms, key=terms.get)
    row.model_flops = model_flops(rec["arch"], rec["shape"])
    row.hlo_flops = rec["flops_per_device"] * chips
    row.useful_ratio = row.model_flops / row.hlo_flops if row.hlo_flops else 0.0
    # fraction of ideal: time at peak for MODEL flops / bound step time
    ideal = row.model_flops / chips / PEAK_FLOPS
    bt = row.bound_time()
    row.roofline_fraction = ideal / bt if bt else 0.0
    row.compile_s = rec.get("compile_s", 0.0)
    return row


def load_rows(dryrun_dir: str, mesh: str | None = "16x16") -> list[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh is not None and rec.get("mesh") != mesh:
            continue
        rows.append(row_from_record(rec))
    return rows


def markdown_table(rows: list[RooflineRow]) -> str:
    hdr = ("| arch | shape | status | compute (ms) | memory (ms) | "
           "collective (ms) | dominant | useful (6ND/HLO) | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.status != "ok":
            lines.append(f"| {r.arch} | {r.shape} | {r.status} | - | - | - | - | - | - |")
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | ok | {r.compute_s*1e3:.1f} | "
            f"{r.memory_s*1e3:.1f} | {r.collective_s*1e3:.1f} | "
            f"**{r.dominant}** | {r.useful_ratio:.2f} | "
            f"{r.roofline_fraction:.1%} |")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load_rows(args.dir, args.mesh)
    print(markdown_table(rows))


if __name__ == "__main__":
    main()
