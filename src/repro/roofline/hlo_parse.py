"""Loop-aware HLO analysis.

``compiled.cost_analysis()`` counts a while-loop body exactly ONCE regardless
of trip count (verified empirically — a scan of 16 matmuls reports the flops
of one).  Scan-over-layers models would therefore under-report flops and
collective bytes by ~n_layers.  This module re-derives both from the compiled
HLO text, trip-count aware:

1. split the module into computations and build a per-computation symbol
   table (%name -> shape) from defining lines + header params;
2. per computation, collect dot ops (flops from output shape x contracted
   dims of the lhs, bytes from operand/output shapes) and collective ops
   (output bytes);
3. build the call graph (while bodies, fusions, calls, conditionals); while
   trip counts come from the printed ``known_trip_count`` backend config
   (fallback: the s32 constant in the condition computation);
4. propagate multipliers from ENTRY; total = sum(comp x multiplier).

Dot flops cover >95% of transformer compute; elementwise flops are ignored
(documented in EXPERIMENTS.md §Roofline method).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\]")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*([a-z0-9]+)\[([0-9,]*)\]")
_DOT_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+dot\(%([\w\.\-]+),\s*%([\w\.\-]+)\),\s*"
    r"(?:.*?lhs_contracting_dims=\{([0-9,]*)\})?")
_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+"
    r"(" + "|".join(c + r"(?:-start)?" for c in _COLLECTIVES) + r")\(")
_WHILE_RE = re.compile(r"\swhile\(")
_WHILE_COND = re.compile(r"condition=%?([\w\.\-]+)")
_WHILE_BODY = re.compile(r"body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALL_RE = re.compile(r"(?:calls=|to_apply=)%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _dims_prod(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _dims_prod(dims) * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: dict[str, float] = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict[str, int] = field(default_factory=lambda: defaultdict(int))
    whiles: list[tuple[str, str, int | None]] = field(default_factory=list)
    calls: list[str] = field(default_factory=list)
    consts: list[int] = field(default_factory=list)


def _split_computations(text: str):
    comps = []
    cur_name, cur_lines, is_entry, header = None, [], False, ""
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line and ("->" in line or line.startswith("ENTRY")):
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)", line.strip())
            if m:
                cur_name = m.group(2)
                is_entry = bool(m.group(1))
                header = line
                cur_lines = []
                continue
        if line.startswith("}"):
            if cur_name is not None:
                comps.append((cur_name, is_entry, header, cur_lines))
            cur_name, is_entry = None, False
            continue
        if cur_name is not None:
            cur_lines.append(line)
    return comps


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    for name, is_entry, header, lines in _split_computations(text):
        c = Computation(name, is_entry)
        # symbol table: defining lines + header params
        sym: dict[str, tuple[str, str]] = {}
        for pname, dt, dims in _PARAM_RE.findall(header):
            sym[pname] = (dt, dims)
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                sym[dm.group(1)] = (dm.group(2), dm.group(3))
        for line in lines:
            m = _DOT_RE.search(line)
            if m:
                odt, odims, lhs_name, rhs_name, cdims = m.groups()
                out_elems = _dims_prod(odims)
                k = 1
                lhs = sym.get(lhs_name)
                if lhs is not None and cdims is not None:
                    ld = lhs[1].split(",") if lhs[1] else []
                    for ci in (cdims.split(",") if cdims else []):
                        i = int(ci)
                        if i < len(ld):
                            k *= int(ld[i])
                c.dot_flops += 2.0 * out_elems * k
                ob = _shape_bytes(odt, odims)
                for nm in (lhs_name, rhs_name):
                    s = sym.get(nm)
                    if s is not None:
                        ob += _shape_bytes(*s)
                c.dot_bytes += ob
            mc = _COLL_RE.search(line)
            if mc:
                tup, dt, dims, op = mc.groups()
                kind = op.replace("-start", "")
                size = (sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tup))
                        if tup is not None else _shape_bytes(dt, dims))
                c.coll_bytes[kind] += size
                c.coll_counts[kind] += 1
            if _WHILE_RE.search(line):
                cond = _WHILE_COND.search(line)
                body = _WHILE_BODY.search(line)
                trip = _TRIP_RE.search(line)
                if cond and body:
                    c.whiles.append((cond.group(1), body.group(1),
                                     int(trip.group(1)) if trip else None))
            for mcall in _CALL_RE.finditer(line):
                c.calls.append(mcall.group(1))
            mb = _BRANCH_RE.search(line)
            if mb:
                for nm in mb.group(1).split(","):
                    c.calls.append(nm.strip().lstrip("%"))
            for mk in _CONST_RE.finditer(line):
                c.consts.append(int(mk.group(1)))
        comps[name] = c
    return comps


def _trip_count(comps, cond_name: str, printed: int | None) -> int:
    if printed is not None:
        return printed
    cond = comps.get(cond_name)
    if cond is None or not cond.consts:
        return 1
    return max(cond.consts)


def multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    mult: dict[str, float] = defaultdict(float)
    entries = [c for c in comps.values() if c.is_entry] or list(comps.values())[-1:]
    stack = [(entries[0].name, 1.0)]
    while stack:
        name, m = stack.pop()
        mult[name] += m
        c = comps.get(name)
        if c is None:
            continue
        for cond, body, printed in c.whiles:
            trips = _trip_count(comps, cond, printed)
            stack.append((body, m * trips))
            stack.append((cond, m * (trips + 1)))
        for callee in c.calls:
            if callee in comps:
                stack.append((callee, m))
    return dict(mult)


def analyze(text: str) -> dict:
    """Loop-aware totals from compiled (per-device SPMD) HLO text."""
    comps = parse_module(text)
    mult = multipliers(comps)
    flops = bytes_ = 0.0
    coll: dict[str, float] = defaultdict(float)
    counts: dict[str, float] = defaultdict(float)
    for name, c in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        flops += c.dot_flops * m
        bytes_ += c.dot_bytes * m
        for k, v in c.coll_bytes.items():
            coll[k] += v * m
        for k, v in c.coll_counts.items():
            counts[k] += v * m
    return {
        "dot_flops": flops,
        "dot_bytes": bytes_,
        "collective_bytes": dict(coll),
        "collective_total": sum(coll.values()),
        "collective_counts": dict(counts),
        "n_computations": len(comps),
    }
