"""Deterministic, resumable synthetic token pipeline.

Fault-tolerance contract (DESIGN.md §6): batch content is a pure function of
(seed, step), so a restarted job resumes mid-epoch by just setting the step —
no iterator state to checkpoint, no skipped/duplicated batches, and the
stream is identical for any data-parallel topology (elastic restarts resume
byte-identically on a different mesh).

The generator synthesizes structured sequences (Zipf unigrams + a Markov
chain over a small state machine) so cross-entropy actually *decreases*
during the example trainings — pure-uniform tokens would hide optimizer bugs.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    n_codebooks: int = 0           # musicgen-style multi-codebook streams


class SyntheticTokens:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed Markov transition table: each token prefers a small successor set
        self._succ = base.integers(0, v, (min(v, 4096), 4))

    def batch(self, step: int) -> dict:
        """Batch for a given step — pure function of (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        shape = (b, s, cfg.n_codebooks) if cfg.n_codebooks else (b, s)
        # Zipf-ish marginal via exponential rank sampling
        ranks = rng.exponential(scale=cfg.vocab_size / 8, size=shape)
        tokens = np.minimum(ranks, cfg.vocab_size - 1).astype(np.int64)
        # overlay Markov structure along the sequence axis
        m = self._succ.shape[0]
        pick = rng.integers(0, 4, shape)
        flat = tokens.reshape(-1, *shape[2:]) if cfg.n_codebooks else tokens
        if cfg.n_codebooks:
            for q in range(cfg.n_codebooks):
                t = tokens[..., q]
                t[:, 1:] = np.where(rng.random((b, s - 1)) < 0.7,
                                    self._succ[t[:, :-1] % m, pick[:, 1:, q]] % cfg.vocab_size,
                                    t[:, 1:])
        else:
            tokens[:, 1:] = np.where(rng.random((b, s - 1)) < 0.7,
                                     self._succ[tokens[:, :-1] % m, pick[:, 1:]] % cfg.vocab_size,
                                     tokens[:, 1:])
        return {"tokens": jnp.asarray(tokens, jnp.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
