"""The gearshifft client protocol — paper Table 1, verbatim.

Every benchmarked thing (an FFT backend, or an LM train/serve step) is a
*client* exposing exactly these operations, each timed separately by the
runner:

    constructor/destructor   allocate / destroy
    get_alloc_size / get_transfer_size / get_plan_size
    init_forward / init_inverse          (planning + compilation)
    execute_forward / execute_inverse    (the measured hot op)
    upload / download                    (host <-> device transfer)

The paper realizes this as a compile-time C++ template interface (static
polymorphism); the JAX analogue is per-problem jit specialization — each
(client x precision x transform x extents) owns its own compiled executable,
so the hot loop dispatches nothing.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Any

import numpy as np


# The paper's four transform kinds (memory mode x data type)
KINDS = ("Inplace_Real", "Inplace_Complex", "Outplace_Real", "Outplace_Complex")
PRECISIONS = ("float", "double")


@dataclass(frozen=True)
class Problem:
    """One node of the benchmark tree: a fully specified FFT problem."""

    extents: tuple[int, ...]          # e.g. (128, 128, 128)
    kind: str = "Outplace_Real"       # one of KINDS
    precision: str = "float"          # 'float' | 'double'
    batch: int = 1

    def __post_init__(self):
        assert self.kind in KINDS, self.kind
        assert self.precision in PRECISIONS, self.precision

    @property
    def rank(self) -> int:
        return len(self.extents)

    @property
    def inplace(self) -> bool:
        return self.kind.startswith("Inplace")

    @property
    def complex_input(self) -> bool:
        return self.kind.endswith("Complex")

    @property
    def real_dtype(self) -> np.dtype:
        return np.dtype(np.float32 if self.precision == "float" else np.float64)

    @property
    def input_dtype(self) -> np.dtype:
        if self.complex_input:
            return np.dtype(np.complex64 if self.precision == "float" else np.complex128)
        return self.real_dtype

    @property
    def n_elems(self) -> int:
        out = self.batch
        for v in self.extents:
            out *= v
        return out

    @property
    def signal_bytes(self) -> int:
        return self.n_elems * self.input_dtype.itemsize

    def signature(self) -> str:
        from .extents import format_extents
        return f"{format_extents(self.extents)}/{self.precision}/{self.kind}/b{self.batch}"


class Context:
    """Library/device context: created once per benchmark binary run and
    timed separately (paper §2.2).  Subclasses do device discovery and
    library-global init (e.g. loading wisdom)."""

    title = "default"

    def __init__(self, options: dict[str, Any] | None = None):
        self.options = dict(options or {})

    def create(self) -> None:  # timed once
        import jax
        self.device = jax.devices()[0]
        self.device_kind = self.device.device_kind

    def destroy(self) -> None:
        pass


class FFTClient(abc.ABC):
    """Table-1 interface. The runner drives exactly this sequence per run:

    upload -> init_forward -> execute_forward -> [init_inverse ->
    execute_inverse] -> download, wrapped by allocate/destroy, all timed.
    """

    title = "abstract"

    def __init__(self, problem: Problem, context: Context):
        self.problem = problem
        self.context = context

    # --- memory -----------------------------------------------------------
    @abc.abstractmethod
    def allocate(self) -> None: ...

    @abc.abstractmethod
    def destroy(self) -> None: ...

    def get_alloc_size(self) -> int:
        """Bytes of device signal buffers held."""
        return 0

    def get_transfer_size(self) -> int:
        """Bytes moved per upload/download."""
        return self.problem.signal_bytes

    def get_plan_size(self) -> int:
        """Bytes attributable to the plan (work areas, executable)."""
        return 0

    # --- planning ---------------------------------------------------------
    @abc.abstractmethod
    def init_forward(self) -> None: ...

    @abc.abstractmethod
    def init_inverse(self) -> None: ...

    # --- execution --------------------------------------------------------
    @abc.abstractmethod
    def execute_forward(self) -> None: ...

    @abc.abstractmethod
    def execute_inverse(self) -> None: ...

    # --- transfer ---------------------------------------------------------
    @abc.abstractmethod
    def upload(self, host_data: np.ndarray) -> None: ...

    @abc.abstractmethod
    def download(self) -> np.ndarray: ...
