"""Declarative op schedules + the generic Runner (engine layer 1).

gearshifft's measurement loop (paper §2.2, Fig. 1) is a fixed sequence of
individually timed client operations.  Instead of hardcoding that sequence in
the benchmark driver, an :class:`OpSchedule` declares it as data — a tuple of
:class:`OpStep` rows naming the client method, what the step consumes
(``needs_input``) and produces (``captures_output``), and which client
accessor attributes bytes to the step's result row.

The :class:`Runner` drives any client through its schedule with the paper's
exact timing semantics:

* every step is wrapped in its own :class:`~repro.core.timer.Timer`;
* ``total`` spans the first step through the last;
* warmup runs execute fully but are never recorded;
* byte attributions are queried once per counted run, after the last step
  (matching the original post-run accounting);
* per-op plan-cache events (``hit``/``miss``) are collected from the
  client's ``cache_events`` dict when present.

Non-FFT workloads (LM train/serve steps, distributed transforms) declare
their own schedules and run through the *same* timed path — a client class
opts in by exposing a ``schedule`` attribute.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .timer import Timer


@dataclass(frozen=True)
class OpStep:
    """One timed operation of a schedule.

    ``method`` names the client attribute to call; ``bytes_method`` names the
    client accessor whose return value is recorded as the step's byte count.
    """

    name: str
    method: str
    needs_input: bool = False       # call with the run's host input
    captures_output: bool = False   # return value becomes the run output
    bytes_method: str | None = None


@dataclass(frozen=True)
class OpSchedule:
    """An ordered, named sequence of timed steps."""

    name: str
    steps: tuple[OpStep, ...]

    @property
    def op_names(self) -> tuple[str, ...]:
        """Row op values emitted per run — every step plus ``total``."""
        return tuple(s.name for s in self.steps) + ("total",)


#: The paper's Table-1 sequence, verbatim (allocate .. destroy).
FFT_SCHEDULE = OpSchedule("fft", (
    OpStep("allocate", "allocate", bytes_method="get_alloc_size"),
    OpStep("init_forward", "init_forward", bytes_method="get_plan_size"),
    OpStep("upload", "upload", needs_input=True,
           bytes_method="get_transfer_size"),
    OpStep("execute_forward", "execute_forward"),
    OpStep("init_inverse", "init_inverse", bytes_method="get_plan_size"),
    OpStep("execute_inverse", "execute_inverse"),
    OpStep("download", "download", captures_output=True,
           bytes_method="get_transfer_size"),
    OpStep("destroy", "destroy"),
))


@dataclass
class RunRecord:
    """Measurements of one run.  ``warmup`` records (negative run index) are
    produced only when a warmup run performed a cold plan-cache compile —
    planning cost is a first-class measurement (paper Figs. 4-5) and must
    not vanish just because the cache was populated before run 0."""

    run: int
    times: dict[str, float]            # op name (incl. 'total') -> ms
    nbytes: dict[str, int] = field(default_factory=dict)
    cache: dict[str, str] = field(default_factory=dict)  # op -> 'hit'|'miss'
    warmup: bool = False


@dataclass
class Runner:
    """Drives a fresh client through ``schedule`` for warmups + repetitions.

    ``make_client`` is called once per run (the paper constructs/destroys the
    client every run so allocation and planning stay measured quantities).
    Exceptions propagate to the caller — continue-on-failure policy lives one
    layer up, in the suite driver — but rows already handed to ``on_record``
    are kept, exactly like the original incremental writer.
    """

    schedule: OpSchedule
    warmups: int
    repetitions: int

    def run(self, make_client: Callable[[], Any], host_input: Any = None,
            on_record: Optional[Callable[[RunRecord], None]] = None,
            ) -> tuple[list[RunRecord], Any]:
        records: list[RunRecord] = []
        output: Any = None
        for run in range(-self.warmups, self.repetitions):
            client = make_client()
            times: dict[str, float] = {}
            t_total = Timer().start()
            for step in self.schedule.steps:
                fn = getattr(client, step.method)
                with Timer() as t:
                    ret = fn(host_input) if step.needs_input else fn()
                times[step.name] = t.time_ms
                if step.captures_output:
                    output = ret
            times["total"] = t_total.stop()
            nbytes = {s.name: getattr(client, s.bytes_method)()
                      for s in self.schedule.steps if s.bytes_method}
            cache = dict(getattr(client, "cache_events", ()) or {})
            if run >= 0:
                rec = RunRecord(run, times, nbytes, cache)
                records.append(rec)
                if on_record is not None:
                    on_record(rec)
            elif on_record is not None and "miss" in cache.values():
                # warmup runs are not recorded — EXCEPT the ops that paid a
                # cold compile, so planning cost stays a measured quantity
                on_record(RunRecord(run, times, nbytes, cache, warmup=True))
        return records, output
