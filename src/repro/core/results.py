"""Standardized result output (paper design goal: 'standardized output
format for downstream statistical analysis').

One CSV row per (benchmark configuration, run, operation) — the layout the
paper's R analysis scripts consume: identification columns first, then the
measurement.  ``result.csv`` is the default sink, like gearshifft.
"""

from __future__ import annotations

import csv
import io
import os
import statistics
from dataclasses import dataclass, field


COLUMNS = [
    "library", "device", "extents", "rank", "extent_class", "precision",
    "kind", "rigor", "run", "op", "time_ms", "bytes", "success", "error",
]


@dataclass
class Row:
    library: str
    device: str
    extents: str
    rank: int
    extent_class: str
    precision: str
    kind: str
    rigor: str
    run: int
    op: str
    time_ms: float
    bytes: int = 0
    success: bool = True
    error: str = ""

    def as_list(self):
        return [getattr(self, c) for c in COLUMNS]


@dataclass
class ResultWriter:
    path: str = "result.csv"
    rows: list[Row] = field(default_factory=list)

    def add(self, row: Row) -> None:
        self.rows.append(row)

    def save(self) -> str:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(self.path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(COLUMNS)
            for r in self.rows:
                w.writerow(r.as_list())
        return self.path

    def to_csv_string(self) -> str:
        buf = io.StringIO()
        w = csv.writer(buf)
        w.writerow(COLUMNS)
        for r in self.rows:
            w.writerow(r.as_list())
        return buf.getvalue()

    # --- aggregation for the paper-style figures ---------------------------
    def aggregate(self, op: str | None = None):
        """mean/stdev per (library, extents, precision, kind, rigor, op)."""
        groups: dict[tuple, list[float]] = {}
        for r in self.rows:
            if not r.success or (op is not None and r.op != op):
                continue
            key = (r.library, r.extents, r.precision, r.kind, r.rigor, r.op)
            groups.setdefault(key, []).append(r.time_ms)
        out = []
        for key, vals in sorted(groups.items()):
            mean = statistics.fmean(vals)
            sd = statistics.stdev(vals) if len(vals) > 1 else 0.0
            out.append((*key, mean, sd, len(vals)))
        return out
