"""Standardized result output (paper design goal: 'standardized output
format for downstream statistical analysis') — engine layer 3b.

One row per (benchmark configuration, run, operation) — the layout the
paper's R analysis scripts consume: identification columns first, then the
measurement.  Rows flow through a :class:`ResultSink`:

* :class:`ResultWriter` — the original buffer-everything writer (kept for
  in-memory aggregation by the table scripts and tests);
* :class:`CsvSink` — streaming CSV, each row flushed as it is produced, so
  long suites never hold the result set in memory and a killed run keeps
  everything measured so far;
* :class:`JsonlSink` — streaming JSON-lines with native types (bools and
  numbers survive), the machine-friendly format for downstream analysis.

``result.csv`` is the default sink, like gearshifft.  The ``plan_cache``
column exists only when the plan/executable cache is enabled — with the
cache off, the schema is byte-for-byte the original column order.
"""

from __future__ import annotations

import csv
import io
import json
import os
from dataclasses import dataclass, field

from .compare import PERCENTILES, percentile  # noqa: F401  (re-exported)
from .compare import aggregate_result_rows as _aggregate_named

COLUMNS = [
    "library", "device", "extents", "rank", "extent_class", "precision",
    "kind", "rigor", "run", "op", "time_ms", "bytes", "success", "error",
]

#: Extra column emitted when the plan/executable cache is enabled.
PLAN_CACHE_COLUMN = "plan_cache"

#: Extra column emitted when a wisdom store is attached: where the plan
#: came from (``estimate``/``measure``/``patient``/``wisdom``/
#: ``wisdom_near``/``fallback``) — the provenance that makes interpolated
#: ``wisdom_near`` picks auditable in downstream analysis.
PLAN_SOURCE_COLUMN = "plan_source"


def columns_for(plan_cache: bool, plan_source: bool = False) -> list[str]:
    """Result schema: seed columns, plus cold/warm cache accounting when the
    plan cache is on, plus plan provenance when wisdom is attached."""
    cols = list(COLUMNS)
    if plan_cache:
        cols.append(PLAN_CACHE_COLUMN)
    if plan_source:
        cols.append(PLAN_SOURCE_COLUMN)
    return cols


@dataclass
class Row:
    library: str
    device: str
    extents: str
    rank: int
    extent_class: str
    precision: str
    kind: str
    rigor: str
    run: int
    op: str
    time_ms: float
    bytes: int = 0
    success: bool = True
    error: str = ""
    plan_cache: str = ""   # ''|'hit'|'miss' (column present only when caching)
    plan_source: str = ""  # Plan.source (column present only with wisdom)

    def as_list(self, columns: list[str] = COLUMNS):
        return [getattr(self, c) for c in columns]

    def as_dict(self, columns: list[str] = COLUMNS):
        return {c: getattr(self, c) for c in columns}


class ResultSink:
    """Row consumer interface: ``add`` rows, ``save`` to finalize.

    Sinks track row/failure counts so drivers can report without re-reading
    what was written.
    """

    def __init__(self, path: str, columns: list[str] | None = None):
        self.path = path
        self.columns = list(columns) if columns is not None else list(COLUMNS)
        self.n_rows = 0
        self.n_failures = 0

    def add(self, row: Row) -> None:
        self.n_rows += 1
        if not row.success:
            self.n_failures += 1
        self._write(row)

    def _write(self, row: Row) -> None:
        raise NotImplementedError

    def save(self) -> str:
        """Finalize (close handles / write buffered rows); returns the path."""
        return self.path

    # alias so sinks work in with-statement style call sites
    def close(self) -> str:
        return self.save()

    def _open(self):
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        return open(self.path, "w", newline="")


class CsvSink(ResultSink):
    """Streaming CSV: header on first row, every row flushed immediately."""

    def __init__(self, path: str, columns: list[str] | None = None):
        super().__init__(path, columns)
        self._fh = None
        self._csv = None

    def _write(self, row: Row) -> None:
        if self._fh is None:
            self._fh = self._open()
            self._csv = csv.writer(self._fh)
            self._csv.writerow(self.columns)
        self._csv.writerow(row.as_list(self.columns))
        self._fh.flush()

    def save(self) -> str:
        if self._fh is None:       # no rows: still leave a valid header-only file
            self._fh = self._open()
            csv.writer(self._fh).writerow(self.columns)
        self._fh.close()
        self._fh = self._csv = None
        return self.path


class JsonlSink(ResultSink):
    """Streaming JSON-lines: one object per row, same column order as CSV."""

    def __init__(self, path: str, columns: list[str] | None = None):
        super().__init__(path, columns)
        self._fh = None

    def _write(self, row: Row) -> None:
        if self._fh is None:
            self._fh = self._open()
        self._fh.write(json.dumps(row.as_dict(self.columns)) + "\n")
        self._fh.flush()

    def save(self) -> str:
        if self._fh is None:
            self._fh = self._open()
        self._fh.close()
        self._fh = None
        return self.path


def rows_to_csv(rows, columns) -> str:
    """Header + every row as one CSV string (shared by the buffered writer
    and :class:`repro.core.suite.ResultSet`)."""
    buf = io.StringIO()
    w = csv.writer(buf)
    w.writerow(columns)
    for r in rows:
        w.writerow(r.as_list(columns))
    return buf.getvalue()


def save_csv(path: str, rows, columns) -> str:
    """Write ``rows_to_csv`` to ``path``, creating parent dirs."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w", newline="") as f:
        f.write(rows_to_csv(rows, columns))
    return path


# The tail-latency quantiles (PERCENTILES) and the percentile helper are
# re-exported from the shared comparison core (repro.core.compare), which
# owns the one grouping/stat implementation every surface consumes.


def percentile_summary(vals, quantiles=PERCENTILES) -> dict:
    """``{"p50": ..., "p95": ..., "p99": ...}`` over ``vals`` (ms)."""
    return {f"p{q:g}": percentile(vals, q) for q in quantiles}


def aggregate_rows(rows, op: str | None = None, percentiles: bool = False):
    """mean/stdev per (library, extents, precision, kind, rigor, op) over the
    successful rows — the aggregation the paper-style figures consume.

    With ``percentiles=True`` each tuple gains p50/p95/p99 columns between
    stdev and the count — ``(*key, mean, sd, p50, p95, p99, n)`` — the
    tail-latency view the serving reporter consumes.  The default layout
    (``(*key, mean, sd, n)``) is unchanged so existing consumers keep
    unpacking 9-tuples.

    Thin tuple adapter over the shared comparison core
    (:func:`repro.core.compare.aggregate_result_rows`), which
    :class:`ResultWriter`, :class:`repro.core.suite.ResultSet`, and the
    ``benchmarks/table_*`` reporters all consume.
    """
    return [a.as_tuple()
            for a in _aggregate_named(rows, op, percentiles=percentiles)]


def open_sink(path: str, fmt: str | None = None,
              columns: list[str] | None = None) -> ResultSink:
    """Sink factory: explicit ``fmt`` ('csv'|'jsonl') or by file extension."""
    if fmt is None:
        fmt = "jsonl" if path.endswith((".jsonl", ".ndjson")) else "csv"
    if fmt == "jsonl":
        return JsonlSink(path, columns)
    if fmt == "csv":
        return CsvSink(path, columns)
    raise ValueError(f"unknown sink format {fmt!r}")


@dataclass
class ResultWriter(ResultSink):
    """Buffer-everything sink: keeps rows in memory for aggregation
    (paper-style figures) and writes the whole CSV on :meth:`save`."""

    path: str = "result.csv"
    rows: list[Row] = field(default_factory=list)
    columns: list[str] = field(default_factory=lambda: list(COLUMNS))

    def __post_init__(self):
        self.n_rows = 0
        self.n_failures = 0

    def add(self, row: Row) -> None:
        self.n_rows += 1
        if not row.success:
            self.n_failures += 1
        self.rows.append(row)

    def save(self) -> str:
        return save_csv(self.path, self.rows, self.columns)

    def to_csv_string(self) -> str:
        return rows_to_csv(self.rows, self.columns)

    # --- aggregation for the paper-style figures ---------------------------
    def aggregate(self, op: str | None = None):
        """mean/stdev per (library, extents, precision, kind, rigor, op)."""
        return aggregate_rows(self.rows, op)
