"""Declarative suite descriptions + the Session facade (engine layer 4).

gearshifft drives every library binary from one configuration surface
(extents files + CLI flags) so cross-library comparisons stay reproducible.
This module is that surface for the whole engine:

* :class:`SuiteSpec` — a frozen, serializable description of one benchmark
  run: which clients, which extents (explicit lists *and* generator-backed
  sweep classes ``powerof2``/``radix357``/``oddshape``), kinds, precisions,
  batch, planner rigor, warmups/repetitions, plan-cache policy, wisdom path,
  and the result sink.  Round-trips to TOML (the ``-f extents_file``
  analogue) and JSON, so any run can be saved, replayed, and diffed.
* :class:`Session` — owns the Context lifecycle, device discovery, wisdom
  loading, the (shareable) plan cache, and result sinks.
  ``Session.run(spec)`` returns a :class:`ResultSet`.
* :class:`ResultSet` — the materialized rows of a run plus the
  aggregation/query helpers the table scripts consume.

The CLI (:mod:`repro.core.cli`) is a thin argparse→SuiteSpec adapter, every
``benchmarks/table_*.py`` is a spec run through ``run_suite``, and
programmatic users construct specs directly — one run description behind all
three surfaces.
"""

from __future__ import annotations

import importlib
import json
import os
import statistics
from contextlib import nullcontext
from dataclasses import dataclass, fields
from typing import Any, Iterable, Iterator, Optional, Sequence

from .benchmark import BenchmarkConfig, run_nodes
from .client import KINDS, PRECISIONS, Context
from .extents import SWEEP_CLASSES, format_extents, parse_extents, sweep_extents
from .plan import PlanCache, PlanCacheStats, PlanRigor
from .registry import get_client
from .results import (ResultSink, Row, aggregate_rows, columns_for,
                      open_sink, percentile_summary, rows_to_csv, save_csv)
from .tree import BenchNode, build_tree, select
from .wisdom import Wisdom


# ---------------------------------------------------------------------------
# sweep specs — generator-backed extent classes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SweepSpec:
    """One generator-backed extent sweep (paper Fig. 7 extent classes).

    ``extent_class`` is one of ``powerof2`` (requires ``min_exp``/``max_exp``),
    ``radix357`` (optional ``count``/``start``) or ``oddshape`` (optional
    ``count``); ``rank`` repeats the size along 1..3 dimensions.
    """

    extent_class: str
    rank: int = 1
    min_exp: Optional[int] = None
    max_exp: Optional[int] = None
    count: Optional[int] = None
    start: Optional[int] = None

    def __post_init__(self):
        # validate eagerly: a bad sweep must fail at spec-build time
        self.extents()

    def extents(self) -> list[tuple[int, ...]]:
        params = {k: getattr(self, k)
                  for k in ("min_exp", "max_exp", "count", "start")
                  if getattr(self, k) is not None}
        return sweep_extents(self.extent_class, self.rank, **params)

    def to_dict(self) -> dict:
        d = {"class": self.extent_class, "rank": self.rank}
        for k in ("min_exp", "max_exp", "count", "start"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SweepSpec":
        d = dict(d)
        extent_class = d.pop("class", None) or d.pop("extent_class", None)
        if extent_class is None:
            raise ValueError(f"sweep entry missing 'class': {d}")
        known = {"rank", "min_exp", "max_exp", "count", "start"}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown sweep key(s) {sorted(unknown)}; "
                             f"allowed: class, {', '.join(sorted(known))}")
        return cls(extent_class=extent_class, **d)


def _as_extent(v) -> tuple[int, ...]:
    if isinstance(v, str):
        return parse_extents(v)
    if isinstance(v, int):
        return (v,)
    return parse_extents(format_extents(tuple(int(x) for x in v)))


# ---------------------------------------------------------------------------
# the suite spec
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SuiteSpec:
    """A complete, serializable description of one benchmark run.

    Every field has a TOML/JSON representation; :meth:`to_toml` /
    :meth:`from_toml` (and the JSON twins) round-trip to an equal spec, so
    ``--dump-config`` → ``--config`` replays any CLI invocation exactly.
    """

    clients: tuple[str, ...] = ("XlaFFT",)
    load: tuple[str, ...] = ()                  # extra client modules
    extents: tuple[tuple[int, ...], ...] = ()   # explicit extents
    sweeps: tuple[SweepSpec, ...] = ()          # generator-backed extents
    kinds: tuple[str, ...] = KINDS
    precisions: tuple[str, ...] = ("float",)
    batch: int = 1
    device_counts: tuple[int, ...] = ()         # multi-device scaling axis
    select: Optional[str] = None                # '-r' wildcard pattern
    rigor: str = "estimate"
    warmups: int = 1
    repetitions: int = 3
    error_bound: float = 1e-5
    seed: int = 2017
    plan_cache: bool = True
    wisdom: Optional[str] = None                # wisdom JSON path
    costmodel: Optional[str] = None             # fitted coefficient table path
    output: Optional[str] = "result.csv"        # None = in-memory only
    format: Optional[str] = None                # 'csv' | 'jsonl' | by extension
    verbose: bool = False

    def __post_init__(self):
        norm = object.__setattr__
        norm(self, "clients", tuple(str(c) for c in self.clients))
        norm(self, "load", tuple(str(m) for m in self.load))
        norm(self, "extents", tuple(_as_extent(e) for e in self.extents))
        norm(self, "sweeps", tuple(
            s if isinstance(s, SweepSpec) else SweepSpec.from_dict(s)
            for s in self.sweeps))
        norm(self, "kinds", tuple(self.kinds))
        norm(self, "precisions", tuple(self.precisions))
        norm(self, "device_counts", tuple(int(n) for n in self.device_counts))
        if any(n < 1 for n in self.device_counts):
            raise ValueError(f"device_counts must be >= 1, "
                             f"got {self.device_counts}")
        if isinstance(self.rigor, PlanRigor):
            norm(self, "rigor", self.rigor.value)
        bad = set(self.kinds) - set(KINDS)
        if bad:
            raise ValueError(f"unknown kind(s) {sorted(bad)}; known: {KINDS}")
        bad = set(self.precisions) - set(PRECISIONS)
        if bad:
            raise ValueError(
                f"unknown precision(s) {sorted(bad)}; known: {PRECISIONS}")
        if self.rigor not in {r.value for r in PlanRigor}:
            raise ValueError(f"unknown rigor {self.rigor!r}; known: "
                             f"{[r.value for r in PlanRigor]}")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if self.warmups < 0 or self.repetitions < 0:
            raise ValueError("warmups/repetitions must be >= 0")
        if self.format is not None and self.format not in ("csv", "jsonl"):
            raise ValueError(f"unknown format {self.format!r}")

    # --- node tree ---------------------------------------------------------
    def resolved_extents(self) -> tuple[tuple[int, ...], ...]:
        """Explicit extents followed by every sweep's expansion, in order."""
        out = list(self.extents)
        for sweep in self.sweeps:
            out.extend(sweep.extents())
        return tuple(out)

    def load_modules(self) -> None:
        """Import the spec's extra client modules (registry side effects)."""
        for mod in self.load:
            importlib.import_module(mod)

    def build_nodes(self) -> list[BenchNode]:
        """Materialize the benchmark tree this spec describes."""
        # built-in clients self-register on import (deferred: spec
        # serialization must work without pulling in jax)
        from .clients import jax_fft, dist_fft, serve_fft  # noqa: F401
        self.load_modules()
        exts = self.resolved_extents()
        if not exts:
            raise ValueError(
                "spec resolves no extents: give 'extents' and/or 'sweeps'")
        nodes = build_tree([get_client(c) for c in self.clients], exts,
                           kinds=self.kinds, precisions=self.precisions,
                           batch=self.batch)
        return select(nodes, self.select)

    def benchmark_config(self) -> BenchmarkConfig:
        return BenchmarkConfig(
            warmups=self.warmups, repetitions=self.repetitions,
            error_bound=self.error_bound, rigor=PlanRigor(self.rigor),
            output=self.output or "result.csv", seed=self.seed)

    # --- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        """Plain-data form: extents as '128x128' strings (the CLI syntax),
        sweeps as a list of tables, ``None`` fields omitted."""
        d: dict[str, Any] = {
            "clients": list(self.clients),
            "extents": [format_extents(e) for e in self.extents],
            "kinds": list(self.kinds),
            "precisions": list(self.precisions),
            "batch": self.batch,
            "rigor": self.rigor,
            "warmups": self.warmups,
            "repetitions": self.repetitions,
            "error_bound": self.error_bound,
            "seed": self.seed,
            "plan_cache": self.plan_cache,
            "verbose": self.verbose,
        }
        if self.load:
            d["load"] = list(self.load)
        if self.device_counts:
            # the scaling axis a driver (tools/bench_compare.py --devices)
            # fans out over — one subprocess per count, since a process's
            # XLA device count is fixed at first jax init.  Omitted when
            # empty so legacy specs round-trip byte-identically.
            d["device_counts"] = list(self.device_counts)
        for k in ("select", "wisdom", "costmodel", "output", "format"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        if self.sweeps:
            d["sweep"] = [s.to_dict() for s in self.sweeps]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SuiteSpec":
        d = dict(d)
        sweeps = d.pop("sweep", None) or d.pop("sweeps", None) or ()
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown SuiteSpec key(s) {sorted(unknown)}; "
                             f"known: {', '.join(sorted(known | {'sweep'}))}")
        return cls(sweeps=tuple(SweepSpec.from_dict(s) if isinstance(s, dict)
                                else s for s in sweeps), **d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "SuiteSpec":
        return cls.from_dict(json.loads(text))

    def to_toml(self) -> str:
        """Emit the spec as TOML (scalar/array keys, then ``[[sweep]]``
        tables).  Hand-rolled writer: the container has no TOML emitter."""
        d = self.to_dict()
        sweeps = d.pop("sweep", [])
        lines = [f"{k} = {_toml_value(v)}" for k, v in d.items()]
        for s in sweeps:
            lines += ["", "[[sweep]]"]
            lines += [f"{k} = {_toml_value(v)}" for k, v in s.items()]
        return "\n".join(lines) + "\n"

    @classmethod
    def from_toml(cls, text: str) -> "SuiteSpec":
        return cls.from_dict(_toml_loads(text))

    def save(self, path: str) -> str:
        """Write the spec to ``path`` (TOML, or JSON for ``.json``)."""
        text = (self.to_json() if path.endswith(".json") else self.to_toml())
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            f.write(text)
        return path

    @classmethod
    def from_file(cls, path: str) -> "SuiteSpec":
        with open(path) as f:
            text = f.read()
        if path.endswith(".json"):
            return cls.from_json(text)
        return cls.from_toml(text)


def _toml_value(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, (int, float)):
        return repr(v)
    if isinstance(v, str):
        return json.dumps(v)   # JSON string escaping is valid TOML
    if isinstance(v, (list, tuple)):
        return "[" + ", ".join(_toml_value(x) for x in v) + "]"
    raise TypeError(f"cannot serialize {type(v).__name__} to TOML: {v!r}")


def _toml_loads(text: str) -> dict:
    try:
        import tomllib
    except ImportError:                           # Python 3.10: use tomli
        try:
            import tomli as tomllib
        except ImportError as e:
            raise RuntimeError(
                "reading TOML specs needs Python >= 3.11 (tomllib) or the "
                "tomli package; use a .json spec instead") from e
    return tomllib.loads(text)


# ---------------------------------------------------------------------------
# result sets
# ---------------------------------------------------------------------------
class ResultSet:
    """The materialized rows of one suite run + query/aggregation helpers
    (moved here from ``ResultWriter``, which remains a plain sink)."""

    def __init__(self, rows: Iterable[Row], columns: Sequence[str],
                 path: Optional[str] = None,
                 plan_stats: Optional[PlanCacheStats] = None):
        self.rows: list[Row] = list(rows)
        self.columns = list(columns)
        self.path = path              # file the run streamed to, if any
        self.plan_stats = plan_stats  # PlanCacheStats when caching was on

    # --- container protocol ------------------------------------------------
    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self) -> Iterator[Row]:
        return iter(self.rows)

    @property
    def n_rows(self) -> int:
        return len(self.rows)

    @property
    def n_failures(self) -> int:
        return sum(1 for r in self.rows if not r.success)

    # --- queries -----------------------------------------------------------
    def query(self, **eq) -> list[Row]:
        """Rows whose attributes equal every given keyword, e.g.
        ``rs.query(op='execute_forward', library='XlaFFT')``."""
        return [r for r in self.rows
                if all(getattr(r, k) == v for k, v in eq.items())]

    def failures(self) -> list[Row]:
        return [r for r in self.rows if not r.success]

    def aggregate(self, op: Optional[str] = None, percentiles: bool = False):
        """mean/stdev per (library, extents, precision, kind, rigor, op);
        ``percentiles=True`` adds p50/p95/p99 columns (see
        :func:`repro.core.results.aggregate_rows`)."""
        return aggregate_rows(self.rows, op, percentiles=percentiles)

    def aggregate_named(self, op: Optional[str] = None,
                        percentiles: bool = False):
        """Same grouping as :meth:`aggregate` but through the shared
        comparison core directly: a list of
        :class:`repro.core.compare.AggRow` with *named* fields
        (``a.library``, ``a.mean``, ``a.p99``, ...) — what the
        ``benchmarks/table_*`` reporters consume instead of unpacking
        positional tuples."""
        from .compare import aggregate_result_rows
        return aggregate_result_rows(self.rows, op, percentiles=percentiles)

    def summary(self, latency_op: str = "execute_forward") -> dict:
        """Planner-cost overview (paper Figs. 4-5) without grepping CSV rows:
        row/failure counts, aggregate planning time (the init ops carry
        planning + compilation), its cold-compile share, and the plan-cache
        hit/miss totals — per-row markers plus the session-level stats.

        When any successful ``latency_op`` rows exist (``execute_forward``
        by default; pass ``"serve_request"`` for service replays) the
        summary also carries their tail-latency view — mean + p50/p95/p99
        over every matching row."""
        init_ops = ("init_forward", "init_inverse")
        plan_rows = [r for r in self.rows if r.op in init_ops]
        events = [r.plan_cache for r in plan_rows if r.plan_cache]
        total = sum(r.time_ms for r in plan_rows)
        if events:
            cold = sum(r.time_ms for r in plan_rows if r.plan_cache == "miss")
        else:
            # no hit/miss markers = plan cache off: every init op re-plans
            # and re-compiles, so the whole planning time is cold
            cold = total
        out = {
            "rows": self.n_rows,
            "failures": self.n_failures,
            "plan_time_ms": total,
            "plan_time_cold_ms": cold,
            "plan_cache_hits": sum(1 for e in events if e == "hit"),
            "plan_cache_misses": sum(1 for e in events if e == "miss"),
        }
        lat = [r.time_ms for r in self.rows
               if r.success and r.op == latency_op]
        if lat:
            out["latency_ms"] = {"op": latency_op, "n": len(lat),
                                 "mean": statistics.fmean(lat),
                                 **percentile_summary(lat)}
        if self.plan_stats is not None:
            out["plan_cache"] = self.plan_stats.as_dict()
        return out

    # --- export ------------------------------------------------------------
    def to_csv_string(self) -> str:
        return rows_to_csv(self.rows, self.columns)

    def save(self, path: str) -> str:
        save_csv(path, self.rows, self.columns)
        self.path = path
        return path

    @classmethod
    def concat(cls, results: Sequence["ResultSet"]) -> "ResultSet":
        """Merge runs that share a schema into one result set."""
        if not results:
            return cls([], columns_for(False))
        cols = results[0].columns
        for r in results[1:]:
            if r.columns != cols:
                raise ValueError("cannot concat ResultSets with different "
                                 f"columns: {cols} vs {r.columns}")
        return cls([row for r in results for row in r.rows], cols,
                   path=results[0].path)


class _CollectorSink(ResultSink):
    """In-memory sink feeding a ResultSet."""

    def __init__(self, columns):
        super().__init__(path="", columns=columns)
        self.rows: list[Row] = []

    def _write(self, row: Row) -> None:
        self.rows.append(row)


class _TeeSink(ResultSink):
    """Forward every row to several sinks (memory + streaming file)."""

    def __init__(self, sinks: Sequence[ResultSink]):
        super().__init__(path="", columns=sinks[0].columns)
        self.sinks = list(sinks)

    def add(self, row: Row) -> None:
        self.n_rows += 1
        if not row.success:
            self.n_failures += 1
        for s in self.sinks:
            s.add(row)

    def save(self) -> str:
        for s in self.sinks:
            s.save()
        return self.path


# ---------------------------------------------------------------------------
# the session facade
# ---------------------------------------------------------------------------
class Session:
    """Owns everything a run needs besides its description: the Context
    lifecycle, device discovery, wisdom, the plan/executable cache, and the
    result sinks.  Reusing one Session across several ``run`` calls shares
    the plan cache, so repeated specs dispatch warm executables.
    """

    def __init__(self, context: Optional[Context] = None,
                 plan_cache: Optional[PlanCache] = None,
                 wisdom: Optional[Wisdom] = None):
        self.context = context if context is not None else Context()
        self._plan_cache = plan_cache
        self._wisdom = wisdom
        self._device_kind: Optional[str] = None

    @property
    def device_kind(self) -> str:
        """Discovered JAX device kind — the key wisdom stores are written
        under by ``python -m repro.core.wisdom``."""
        if self._device_kind is None:
            import jax
            self._device_kind = jax.devices()[0].device_kind
        return self._device_kind

    @property
    def plan_cache(self) -> PlanCache:
        """The session-lifetime plan cache (created on first use)."""
        if self._plan_cache is None:
            self._plan_cache = PlanCache()
        return self._plan_cache

    def _resolve_wisdom(self, spec: SuiteSpec) -> Optional[Wisdom]:
        if self._wisdom is not None:
            return self._wisdom
        if spec.wisdom:
            return Wisdom(spec.wisdom, device_kind=self.device_kind)
        return None

    def run(self, spec: SuiteSpec,
            nodes: Optional[Sequence[BenchNode]] = None) -> ResultSet:
        """Execute the spec; returns the materialized :class:`ResultSet`.

        ``nodes`` overrides the spec's own tree (the CLI pre-builds it to
        report empty selections before any device work happens).
        """
        if nodes is None:
            nodes = spec.build_nodes()
        else:
            spec.load_modules()
        cache = self.plan_cache if spec.plan_cache else None
        wisdom = self._resolve_wisdom(spec)
        columns = columns_for(cache is not None,
                              plan_source=wisdom is not None)
        collector = _CollectorSink(columns)
        sinks: list[ResultSink] = [collector]
        if spec.output:
            sinks.append(open_sink(spec.output, fmt=spec.format,
                                   columns=columns))
        writer = _TeeSink(sinks)
        # a fitted coefficient table, when the spec names one, becomes the
        # active cost model for the whole run: ESTIMATE picks, MEASURE
        # candidate orderings, and fallback chains all re-rank under it
        if spec.costmodel:
            from .costmodel import model_for_device, use_model
            model_cm = use_model(model_for_device(self.device_kind,
                                                  spec.costmodel))
        else:
            model_cm = nullcontext()
        with model_cm:
            run_nodes(nodes, context=self.context,
                      config=spec.benchmark_config(), writer=writer,
                      plan_cache=cache, wisdom=wisdom, verbose=spec.verbose)
        writer.save()
        if wisdom is not None and spec.rigor in (PlanRigor.MEASURE.value,
                                                 PlanRigor.PATIENT.value):
            # persist tuned selections: a warm Session (or a later process
            # pointing at the same wisdom file) skips the candidate sweep
            wisdom.save()
        return ResultSet(collector.rows, columns,
                         path=spec.output if spec.output else None,
                         plan_stats=cache.stats if cache else None)


def run_suite(spec: SuiteSpec, session: Optional[Session] = None) -> ResultSet:
    """One-shot convenience: run ``spec`` in a fresh (or given) Session."""
    return (session if session is not None else Session()).run(spec)


# ---------------------------------------------------------------------------
# support matrix
# ---------------------------------------------------------------------------
#: Power-of-two probe extents per rank used to answer "does this backend
#: support rank r at all?" — pow2 so every pow2-only backend registers its
#: ranks; extent-dependent caps (VMEM budgets, smoothness) still apply to
#: individual problems via ``plan.backend_supports``.
SUPPORT_PROBE_EXTENTS = {1: (16,), 2: (8, 16), 3: (4, 4, 8)}


def support_matrix(kinds: Sequence[str] = KINDS,
                   precisions: Sequence[str] = PRECISIONS,
                   probes: Optional[dict] = None) -> list[dict]:
    """The backend x kind x rank x precision feasibility table.

    One row per cell, ``{"backend", "kind", "precision", "rank", "extents",
    "supported"}`` — the single source of truth behind the README's
    support-matrix section and the conformance matrix's cell enumeration
    (``tests/test_conformance.py`` sweeps exactly the supported cells).
    """
    from .client import Problem
    from .plan import BACKENDS, backend_supports

    probes = dict(SUPPORT_PROBE_EXTENTS if probes is None else probes)
    rows = []
    for backend in BACKENDS:
        for rank, extents in sorted(probes.items()):
            for kind in kinds:
                for precision in precisions:
                    problem = Problem(tuple(extents), kind, precision)
                    rows.append({
                        "backend": backend, "kind": kind,
                        "precision": precision, "rank": rank,
                        "extents": tuple(extents),
                        "supported": backend_supports(backend, problem),
                    })
    return rows


def dist_support_matrix(device_counts: Sequence[int] = (2, 4, 8),
                        kinds: Sequence[str] = KINDS,
                        probes: Optional[dict] = None) -> list[dict]:
    """The distributed-decomposition x kind x rank x device-count table —
    the device-count column of the README support matrix.

    Mesh shapes per backend follow the planner's enumeration: ``dist1d`` and
    ``slab`` flatten the P devices, ``pencil`` uses the most balanced
    (Pr, Pc) factorization.
    """
    from .client import Problem
    from .plan import DIST_BACKENDS, _pencil_mesh_shapes, dist_supports

    probes = dict(SUPPORT_PROBE_EXTENTS if probes is None else probes)
    rows = []
    for backend in DIST_BACKENDS:
        for devices in device_counts:
            for rank, extents in sorted(probes.items()):
                for kind in kinds:
                    if backend == "pencil":
                        shapes = _pencil_mesh_shapes(devices) or [(devices,)]
                        mesh_shape = shapes[0]
                    else:
                        mesh_shape = (devices,)
                    problem = Problem(tuple(extents), kind, "float")
                    rows.append({
                        "backend": backend, "kind": kind, "rank": rank,
                        "devices": devices, "extents": tuple(extents),
                        "supported": dist_supports(backend, problem,
                                                   mesh_shape),
                    })
    return rows


__all__ = ["SweepSpec", "SuiteSpec", "ResultSet", "Session", "run_suite",
           "SWEEP_CLASSES", "SUPPORT_PROBE_EXTENTS", "support_matrix",
           "dist_support_matrix"]
