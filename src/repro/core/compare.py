"""Shared comparison core for the perf trajectory (paper §2: *reproducible,
unbiased* comparison).

Every surface that reads or writes ``BENCH_*.json`` trajectory documents —
``tools/bench_compare.py`` (the writer), ``tools/bench_diff.py`` (the
regression gate), the ``benchmarks/table_*`` reporters, and
``ResultSet.aggregate`` — goes through this module instead of carrying a
private copy of the grouping/stat/alignment logic.  It is deliberately
stdlib-only (no jax, no numpy) so the diff gate stays a sub-second tool.

Three layers:

* **documents** — :func:`make_meta` stamps a schema-versioned provenance
  header (schema, git sha, device kind, jax version, reps);
  :func:`load_bench` reads + validates a doc and *normalizes* rows so
  schema-1 documents (BENCH_PR3..PR7: no ``kind``/``precision``/``mode``
  fields, ``devices`` only on distributed rows) align against schema-2
  ones;
* **alignment** — :func:`row_key` / :func:`align_rows` pair rows across
  two runs by ``(mode, backend, extent, kind, precision, rank, devices)``;
* **verdicts** — :func:`diff_docs` applies noise-aware thresholds (pooled
  standard error from the per-row ``sd_ms``/``n`` columns, plus a
  configurable min-effect floor so 1-rep smoke runs never flap on jitter)
  and :func:`markdown_report` / :func:`fig7_report` render the delta
  report and the gearshifft-style Fig. 7 living table.

The statistics helpers at the bottom (:class:`AggStats`,
:func:`aggregate_result_rows`) are the one mean/stdev/percentile core the
suite-result aggregation (``repro.core.results.aggregate_rows``) and the
benchmark tables consume.
"""

from __future__ import annotations

import json
import math
import os
import statistics
import subprocess
from dataclasses import dataclass, field

#: Version stamped into ``meta["schema"]`` by :func:`make_meta`.  Schema 1
#: (every committed BENCH_PR*.json before the comparison core existed) has
#: no ``schema`` field at all; the loader back-fills its defaults.
SCHEMA_VERSION = 2

#: Fields a grid row is normalized to carry (schema-1 defaults) — the
#: bench grid has always been the forward c64 float transform.
GRID_ROW_DEFAULTS = {
    "mode": "grid",
    "kind": "Outplace_Complex",
    "precision": "float",
    "devices": 1,
}

#: The cross-run alignment key (issue: backend, extents, kind, precision,
#: rank, device_count — plus ``mode`` so serve/chaos rows never collide
#: with grid rows).
ALIGN_KEY = ("mode", "backend", "extent", "kind", "precision", "rank",
             "devices")

#: Per-mode comparison metric: (row field, lower_is_better).
METRICS = {
    "grid": ("time_ms", True),
    "serve_replay": ("p50_ms", True),
    "serve_burst": ("speedup", False),
    "chaos_fallback": ("clean_success_rate", False),
    "chaos_kill": ("clean_success_rate", False),
}


class BenchFormatError(ValueError):
    """A BENCH document failed structural validation."""


# ---------------------------------------------------------------------------
# documents
# ---------------------------------------------------------------------------
def git_sha(cwd: str | None = None) -> str | None:
    """Current commit sha for provenance stamping; None outside a repo."""
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=cwd,
                             capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def make_meta(**fields) -> dict:
    """Schema-versioned provenance header for a BENCH document.

    Callers pass the run facts (``device_kind``, ``platform``, ``jax``,
    ``reps``, ...); this stamps ``schema`` and the current ``git_sha`` so
    every trajectory point records exactly which tree produced it.
    """
    meta = {"schema": SCHEMA_VERSION, "git_sha": git_sha()}
    meta.update(fields)
    return meta


def normalize_row(rec: dict) -> dict:
    """A defensive copy of one result row with schema-1 gaps back-filled
    so alignment keys exist for every document vintage."""
    row = dict(rec)
    row.setdefault("mode", "grid")
    if row["mode"] == "grid":
        for k, v in GRID_ROW_DEFAULTS.items():
            row.setdefault(k, v)
        if "rank" not in row and "extent" in row:
            row["rank"] = len(str(row["extent"]).split("x"))
    else:
        # serve/chaos rows: no extent grid; backend may be absent (chaos)
        row.setdefault("backend", row["mode"])
        row.setdefault("extent", "")
        row.setdefault("kind", "")
        row.setdefault("precision", "")
        row.setdefault("rank", 0)
        row.setdefault("devices", 1)
    row.setdefault("ok", False)
    return row


@dataclass
class BenchDoc:
    """One loaded + normalized BENCH_*.json trajectory document."""

    path: str
    meta: dict
    rows: list[dict]

    @property
    def schema(self) -> int:
        return int(self.meta.get("schema", 1))

    @property
    def git_sha(self) -> str | None:
        return self.meta.get("git_sha")

    @property
    def label(self) -> str:
        return os.path.basename(self.path) or self.path

    def ok_rows(self) -> list[dict]:
        return [r for r in self.rows if r.get("ok")]


_REQUIRED_META = ("device_kind", "platform")


def load_bench(path: str) -> BenchDoc:
    """Load + validate one BENCH document; raises :class:`BenchFormatError`
    with the offending path on malformed input."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except json.JSONDecodeError as e:
        raise BenchFormatError(f"{path}: not valid JSON ({e})") from e
    if not isinstance(doc, dict):
        raise BenchFormatError(f"{path}: top level must be an object")
    meta = doc.get("meta")
    results = doc.get("results")
    if not isinstance(meta, dict):
        raise BenchFormatError(f"{path}: missing 'meta' object")
    if not isinstance(results, list):
        raise BenchFormatError(f"{path}: missing 'results' list")
    missing = [k for k in _REQUIRED_META if k not in meta]
    if missing:
        raise BenchFormatError(f"{path}: meta missing {missing}")
    schema = meta.get("schema", 1)
    if not isinstance(schema, int) or schema < 1:
        raise BenchFormatError(f"{path}: bad meta.schema {schema!r}")
    if schema > SCHEMA_VERSION:
        raise BenchFormatError(
            f"{path}: schema {schema} is newer than supported "
            f"{SCHEMA_VERSION}; upgrade the comparison core")
    rows = []
    for i, rec in enumerate(results):
        if not isinstance(rec, dict):
            raise BenchFormatError(f"{path}: results[{i}] is not an object")
        row = normalize_row(rec)
        if row["mode"] == "grid" and "backend" not in row:
            raise BenchFormatError(f"{path}: results[{i}] has no backend")
        rows.append(row)
    return BenchDoc(path=path, meta=meta, rows=rows)


# ---------------------------------------------------------------------------
# alignment
# ---------------------------------------------------------------------------
def row_key(row: dict) -> tuple:
    """The cross-run identity of one row (see :data:`ALIGN_KEY`)."""
    return tuple(row.get(k) for k in ALIGN_KEY)


def format_key(key: tuple) -> str:
    mode, backend, extent, kind, precision, rank, devices = key
    bits = [backend]
    if extent:
        bits.append(str(extent))
    if mode != "grid":
        bits.insert(0, mode)
    if kind and kind != GRID_ROW_DEFAULTS["kind"]:
        bits.append(kind)
    if precision and precision != GRID_ROW_DEFAULTS["precision"]:
        bits.append(precision)
    if devices and devices != 1:
        bits.append(f"{devices}dev")
    return "/".join(bits)


def align_rows(a_rows: list[dict], b_rows: list[dict]
               ) -> list[tuple[tuple, dict | None, dict | None]]:
    """Pair rows of two runs by :func:`row_key`.

    Order: every key of the baseline run first (in file order), then keys
    only the candidate run has.  Duplicate keys within one run keep the
    first occurrence (and are surfaced by the diff as a doc warning).
    """
    a_by = {}
    for r in a_rows:
        a_by.setdefault(row_key(r), r)
    b_by = {}
    for r in b_rows:
        b_by.setdefault(row_key(r), r)
    out = []
    for r in a_rows:
        k = row_key(r)
        if a_by.get(k) is not r:
            continue                       # duplicate key: first wins
        out.append((k, r, b_by.get(k)))
    for r in b_rows:
        k = row_key(r)
        if k not in a_by and b_by.get(k) is r:
            out.append((k, None, r))
    return out


# ---------------------------------------------------------------------------
# noise-aware verdicts
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Thresholds:
    """When is a delta a *regression* rather than noise?

    A slowdown must clear **every** gate:

    * ``sigma``  — |Δ| > sigma × pooled standard error, where the pooled
      error is ``sqrt(sd_a²/n_a + sd_b²/n_b)`` from the per-row
      ``sd_ms``/``n`` columns (Welch).  Rows without spread data (n ≤ 1 —
      the 1-rep smoke grid — or schema-1 docs) contribute zero, so the
      floors below are the only gate there;
    * ``min_rel`` — |Δ| / baseline ≥ min_rel (the min-effect floor);
    * ``min_abs_ms`` — |Δ| ≥ min_abs_ms, so micro-rows never flap on
      scheduler jitter.
    """

    sigma: float = 3.0
    min_rel: float = 0.10
    min_abs_ms: float = 0.05

    #: Human tag for the report header.
    name: str = "default"


#: Smoke-grade preset: 1 rep, interpret-mode kernels, possibly a different
#: host than the committed baseline — only order-of-magnitude slowdowns
#: (or feasibility regressions, which ignore thresholds entirely) gate.
SMOKE_THRESHOLDS = Thresholds(sigma=3.0, min_rel=4.0, min_abs_ms=1.0,
                              name="smoke")

VERDICTS = ("regression", "improvement", "unchanged", "added", "removed")


@dataclass
class DiffRow:
    key: tuple
    verdict: str                  # one of VERDICTS
    detail: str = ""
    metric: str = ""
    a_value: float | None = None
    b_value: float | None = None
    delta_rel: float | None = None   # (b - a) / a, sign of the raw delta
    stderr: float | None = None      # pooled standard error (metric units)

    @property
    def name(self) -> str:
        return format_key(self.key)


@dataclass
class DiffResult:
    baseline: BenchDoc
    candidate: BenchDoc
    thresholds: Thresholds
    rows: list[DiffRow] = field(default_factory=list)
    warnings: list[str] = field(default_factory=list)

    def count(self, verdict: str) -> int:
        return sum(1 for r in self.rows if r.verdict == verdict)

    @property
    def regressions(self) -> list[DiffRow]:
        return [r for r in self.rows if r.verdict == "regression"]

    @property
    def has_regression(self) -> bool:
        return bool(self.regressions)


def _spread(row: dict) -> tuple[float, int]:
    """(sd, n) of the row's comparison metric; (0, 1) when unknown."""
    n = int(row.get("n", row.get("reps", 1)) or 1)
    sd = float(row.get("sd_ms", 0.0) or 0.0)
    return sd, max(n, 1)


def pooled_stderr(row_a: dict, row_b: dict) -> float:
    """Welch pooled standard error of the difference of two row means."""
    sd_a, n_a = _spread(row_a)
    sd_b, n_b = _spread(row_b)
    return math.sqrt(sd_a ** 2 / n_a + sd_b ** 2 / n_b)


def compare_pair(key: tuple, row_a: dict | None, row_b: dict | None,
                 th: Thresholds) -> DiffRow:
    """Noise-aware verdict for one aligned pair (either side may be None)."""
    if row_a is None:
        return DiffRow(key, "added", detail="no baseline row")
    if row_b is None:
        return DiffRow(key, "removed", detail="row missing from candidate")
    ok_a, ok_b = bool(row_a.get("ok")), bool(row_b.get("ok"))
    if ok_a and not ok_b:
        return DiffRow(key, "regression",
                       detail="feasibility lost: "
                              f"{row_b.get('error', 'not ok')}")
    if not ok_a and ok_b:
        return DiffRow(key, "improvement", detail="now feasible")
    if not ok_a and not ok_b:
        return DiffRow(key, "unchanged", detail="infeasible in both runs")
    metric, lower_better = METRICS.get(key[0], ("time_ms", True))
    va, vb = row_a.get(metric), row_b.get(metric)
    if va is None or vb is None:
        return DiffRow(key, "unchanged", metric=metric,
                       detail=f"metric {metric} missing")
    va, vb = float(va), float(vb)
    delta = vb - va
    worse = delta if lower_better else -delta
    stderr = pooled_stderr(row_a, row_b)
    rel = (delta / va if va
           else 0.0 if delta == 0 else math.copysign(math.inf, delta))
    row = DiffRow(key, "unchanged", metric=metric, a_value=va, b_value=vb,
                  delta_rel=rel, stderr=stderr)
    gate = max(th.min_abs_ms, th.sigma * stderr, th.min_rel * abs(va))
    if worse > gate:
        row.verdict = "regression"
        row.detail = (f"{metric} {'+' if delta >= 0 else ''}{rel:.0%} "
                      f"exceeds gate")
    elif -worse > gate:
        row.verdict = "improvement"
    else:
        row.detail = "within noise"
    return row


def diff_docs(baseline: BenchDoc, candidate: BenchDoc,
              thresholds: Thresholds = Thresholds()) -> DiffResult:
    """Align two trajectory documents and classify every paired row."""
    res = DiffResult(baseline, candidate, thresholds)
    for doc in (baseline, candidate):
        seen, dups = set(), set()
        for r in doc.rows:
            k = row_key(r)
            (dups if k in seen else seen).add(k)
        for k in sorted(dups):
            res.warnings.append(
                f"{doc.label}: duplicate row key {format_key(k)} "
                "(first occurrence used)")
    if baseline.meta.get("device_kind") != candidate.meta.get("device_kind"):
        res.warnings.append(
            "device kinds differ "
            f"({baseline.meta.get('device_kind')!r} vs "
            f"{candidate.meta.get('device_kind')!r}): absolute times are "
            "not comparable; rely on feasibility + large relative deltas")
    for key, ra, rb in align_rows(baseline.rows, candidate.rows):
        res.rows.append(compare_pair(key, ra, rb, thresholds))
    return res


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------
def _meta_line(doc: BenchDoc) -> str:
    sha = (doc.git_sha or "?")[:12]
    m = doc.meta
    reps = m.get("reps", "?")
    return (f"`{doc.label}` — schema {doc.schema}, git `{sha}`, "
            f"device {m.get('device_kind', '?')} "
            f"({m.get('platform', '?')}), jax {m.get('jax', '?')}, "
            f"reps {reps}")


def _fmt(v: float | None) -> str:
    return "-" if v is None else f"{v:.3f}"


def markdown_report(res: DiffResult) -> str:
    """The bench_diff delta report: provenance, per-row verdicts, summary."""
    th = res.thresholds
    lines = [
        "# bench_diff report",
        "",
        f"- baseline:  {_meta_line(res.baseline)}",
        f"- candidate: {_meta_line(res.candidate)}",
        f"- thresholds: `{th.name}` (sigma={th.sigma:g}, "
        f"min_rel={th.min_rel:.0%}, min_abs={th.min_abs_ms:g} ms)",
        "",
    ]
    for w in res.warnings:
        lines.append(f"> **warning:** {w}")
    if res.warnings:
        lines.append("")
    lines += [
        "| row | metric | baseline | candidate | Δ | noise (±σ) | verdict |",
        "|---|---|---|---|---|---|---|",
    ]
    order = {v: i for i, v in enumerate(VERDICTS)}
    for r in sorted(res.rows, key=lambda r: (order[r.verdict], r.name)):
        delta = ("-" if r.delta_rel is None
                 else f"{'+' if r.delta_rel >= 0 else ''}{r.delta_rel:.1%}")
        noise = "-" if not r.stderr else f"{r.stderr:.3f}"
        verdict = (f"**{r.verdict}**" if r.verdict == "regression"
                   else r.verdict)
        note = f" ({r.detail})" if r.detail and r.verdict != "unchanged" else ""
        lines.append(f"| {r.name} | {r.metric or '-'} | {_fmt(r.a_value)} | "
                     f"{_fmt(r.b_value)} | {delta} | {noise} | "
                     f"{verdict}{note} |")
    n_reg = res.count("regression")
    lines += [
        "",
        f"**{n_reg} regression(s)**, {res.count('improvement')} "
        f"improvement(s), {res.count('unchanged')} unchanged, "
        f"{res.count('added')} added, {res.count('removed')} removed "
        f"over {len(res.rows)} aligned rows.",
        "",
        ("VERDICT: FAIL — candidate regresses the baseline." if n_reg
         else "VERDICT: PASS — no regression against the baseline."),
    ]
    return "\n".join(lines) + "\n"


#: Paper extent-class display order for the Fig. 7 table.
_CLASS_ORDER = {"powerof2": 0, "radix357": 1, "oddshape": 2}


def fig7_report(doc: BenchDoc) -> str:
    """The repo's living gearshifft Fig. 7: support matrix × extent class ×
    achieved fraction of the roofline.

    One row per (backend, devices), one column per (extent class, rank);
    each cell is the best ``roofline_frac`` the backend achieved over that
    class (achieved fraction of the hardware's modeled peak), ``·`` where
    every grid point was infeasible, blank where none was attempted.
    """
    grid = [r for r in doc.rows if r["mode"] == "grid"]
    cols = sorted({(r.get("class", "?"), r["rank"]) for r in grid},
                  key=lambda c: (_CLASS_ORDER.get(c[0], 9), c[1]))
    backends = sorted({(r["backend"], r["devices"]) for r in grid})
    cells: dict[tuple, dict[tuple, list]] = {}
    for r in grid:
        col = (r.get("class", "?"), r["rank"])
        cells.setdefault((r["backend"], r["devices"]), {}) \
             .setdefault(col, []).append(r)
    m = doc.meta
    lines = [
        "# Fig. 7 — achieved fraction of roofline by backend × extent class",
        "",
        f"- source: {_meta_line(doc)}",
        "- cell = best achieved fraction of the modeled roofline "
        "(`roofline_frac`: ideal time at the device's peak FLOP/s and "
        "HBM bandwidth over measured time); `·` = infeasible, blank = "
        "not attempted.",
        "",
        "| backend | " + " | ".join(f"{c}/{r}d" for c, r in cols) + " |",
        "|" + "---|" * (len(cols) + 1),
    ]
    for backend, devices in backends:
        name = backend if devices == 1 else f"{backend} @{devices}dev"
        row = [name]
        for col in cols:
            rs = cells.get((backend, devices), {}).get(col)
            if not rs:
                row.append("")
                continue
            fracs = [r["roofline_frac"] for r in rs
                     if r.get("ok") and isinstance(
                         r.get("roofline_frac"), (int, float))
                     and math.isfinite(r["roofline_frac"])]
            if fracs:
                row.append(f"{max(fracs):.1%}")
            elif any(r.get("ok") for r in rs):
                row.append("?")        # ran, but no roofline data (schema 1)
            else:
                row.append("·")
        lines.append("| " + " | ".join(row) + " |")
    n_ok = sum(1 for r in grid if r.get("ok"))
    lines += ["", f"{n_ok}/{len(grid)} grid points feasible."]
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# suite-result aggregation (the core ResultSet / table_* consume)
# ---------------------------------------------------------------------------
#: Tail-latency quantiles shared with ``repro.core.results``.
PERCENTILES = (50, 95, 99)


def percentile(vals, q: float) -> float:
    """q-th percentile (0..100), linear interpolation between closest
    ranks — matches ``numpy.percentile``'s default method."""
    if not vals:
        raise ValueError("percentile of empty sequence")
    s = sorted(vals)
    if len(s) == 1:
        return float(s[0])
    pos = (len(s) - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    return float(s[lo] + (s[hi] - s[lo]) * (pos - lo))


@dataclass(frozen=True)
class AggStats:
    """mean/sd/n (+ optional percentiles) of one measurement group."""

    mean: float
    sd: float
    n: int
    best: float
    percentiles: tuple[float, ...] = ()

    @classmethod
    def of(cls, vals, with_percentiles: bool = False) -> "AggStats":
        return cls(
            mean=statistics.fmean(vals),
            sd=statistics.stdev(vals) if len(vals) > 1 else 0.0,
            n=len(vals),
            best=min(vals),
            percentiles=(tuple(percentile(vals, q) for q in PERCENTILES)
                         if with_percentiles else ()),
        )


@dataclass(frozen=True)
class AggRow:
    """One aggregated suite-result group with *named* fields — what the
    ``benchmarks/table_*`` reporters consume instead of unpacking
    positional tuples."""

    library: str
    extents: str
    precision: str
    kind: str
    rigor: str
    op: str
    stats: AggStats

    @property
    def mean(self) -> float:
        return self.stats.mean

    @property
    def sd(self) -> float:
        return self.stats.sd

    @property
    def n(self) -> int:
        return self.stats.n

    @property
    def p50(self) -> float:
        return self.stats.percentiles[0]

    @property
    def p95(self) -> float:
        return self.stats.percentiles[1]

    @property
    def p99(self) -> float:
        return self.stats.percentiles[2]

    def as_tuple(self) -> tuple:
        """The legacy positional layout of ``results.aggregate_rows``."""
        key = (self.library, self.extents, self.precision, self.kind,
               self.rigor, self.op)
        if self.stats.percentiles:
            return (*key, self.mean, self.sd, *self.stats.percentiles, self.n)
        return (*key, self.mean, self.sd, self.n)


def aggregate_result_rows(rows, op: str | None = None,
                          percentiles: bool = False) -> list[AggRow]:
    """Group successful suite-result rows by (library, extents, precision,
    kind, rigor, op) → :class:`AggStats`.  The single grouping/stat core
    behind ``results.aggregate_rows``, ``ResultSet.aggregate``, and every
    ``benchmarks/table_*`` reporter."""
    groups: dict[tuple, list[float]] = {}
    for r in rows:
        if not r.success or (op is not None and r.op != op):
            continue
        key = (r.library, r.extents, r.precision, r.kind, r.rigor, r.op)
        groups.setdefault(key, []).append(r.time_ms)
    return [AggRow(*key, AggStats.of(vals, with_percentiles=percentiles))
            for key, vals in sorted(groups.items())]
