"""Backend quarantine: a circuit breaker over (backend, problem-class) pairs.

Split out of ``plan.py`` (which re-exports everything here, so existing
``from repro.core.plan import CircuitBreaker`` imports keep working): the
breaker is pure fault-tolerance state with no dependency on the candidate
space or the cost model, and the serve engine imports it on its own.
"""

from __future__ import annotations

import threading
import time
from typing import Callable

from .client import Problem
from .extents import classify


def problem_class(problem: Problem) -> str:
    """The quarantine granularity: a backend that fails for one oddshape
    rank-2 problem is suspect for every oddshape rank-2 problem, but a
    powerof2 rank-1 success says nothing about either."""
    return f"{classify(problem.extents)}|r{problem.rank}"


def breaker_key(backend: str, problem: Problem) -> str:
    return f"{backend}|{problem_class(problem)}"


class CircuitBreaker:
    """Quarantine for (backend, problem-class) pairs that keep failing.

    Classic three-state breaker, keyed by :func:`breaker_key`:

      closed     pair is healthy; every attempt allowed
      open       ``threshold`` consecutive failures seen — attempts denied
                 until ``cooldown_s`` elapses
      half_open  cooldown elapsed; exactly ONE probe attempt is allowed
                 through.  Success re-closes the breaker, failure re-opens
                 it (and restarts the cooldown).  If the probe never
                 resolves (its thread died), a fresh probe is allowed after
                 another cooldown, so a lost probe can't wedge the pair
                 open forever.

    Thread-safe: all transitions happen under one lock, and the totals
    (``failures``/``successes``) are exact counts of the record calls —
    the invariant the threaded hammer test pins.  ``clock`` is injectable
    for deterministic tests.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        if threshold < 1:
            raise ValueError(f"breaker threshold must be >= 1: {threshold}")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._entries: dict[str, dict] = {}

    def _entry(self, key: str) -> dict:
        e = self._entries.get(key)
        if e is None:
            e = self._entries[key] = {
                "state": self.CLOSED, "consecutive": 0, "failures": 0,
                "successes": 0, "opens": 0, "opened_at": 0.0,
                "probe_at": None}
        return e

    def allows(self, key: str) -> bool:
        """May the caller *attempt* this pair right now?  Claims the
        half-open probe slot when it grants one — call only when about to
        actually try (use :meth:`available` for side-effect-free checks)."""
        now = self._clock()
        with self._lock:
            e = self._entry(key)
            if e["state"] == self.CLOSED:
                return True
            if e["state"] == self.OPEN:
                if now - e["opened_at"] < self.cooldown_s:
                    return False
                e["state"] = self.HALF_OPEN
                e["probe_at"] = now
                return True       # the cooldown-expiry probe
            # HALF_OPEN: one outstanding probe at a time
            if e["probe_at"] is not None \
                    and now - e["probe_at"] < self.cooldown_s:
                return False
            e["probe_at"] = now   # previous probe was lost; allow another
            return True

    def available(self, key: str) -> bool:
        """Side-effect-free: would an attempt plausibly be allowed?"""
        with self._lock:
            e = self._entries.get(key)
            if e is None or e["state"] != self.OPEN:
                return True
            return self._clock() - e["opened_at"] >= self.cooldown_s

    def record_failure(self, key: str) -> str:
        """Count a failure; returns the pair's new state (``'open'`` means
        this failure tripped — or re-tripped — the quarantine)."""
        with self._lock:
            e = self._entry(key)
            e["failures"] += 1
            e["consecutive"] += 1
            if e["state"] == self.HALF_OPEN \
                    or e["consecutive"] >= self.threshold:
                if e["state"] != self.OPEN:
                    e["opens"] += 1
                e["state"] = self.OPEN
                e["opened_at"] = self._clock()
                e["probe_at"] = None
            return e["state"]

    def record_success(self, key: str) -> str:
        with self._lock:
            e = self._entry(key)
            e["successes"] += 1
            e["consecutive"] = 0
            e["state"] = self.CLOSED
            e["probe_at"] = None
            return e["state"]

    def state(self, key: str) -> str:
        with self._lock:
            e = self._entries.get(key)
            return e["state"] if e else self.CLOSED

    def snapshot(self) -> dict:
        with self._lock:
            return {k: {"state": e["state"], "failures": e["failures"],
                        "successes": e["successes"], "opens": e["opens"]}
                    for k, e in self._entries.items()}
