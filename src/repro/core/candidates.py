"""The planner's candidate space: backends, feasibility, and enumeration.

Split out of ``plan.py`` (which re-exports everything here).  This module
holds the *structural* half of planning — what a backend can run, which
(backend, knob) combinations exist for a problem — while the *quantitative*
half (how many HBM passes each choice costs) lives in
:mod:`repro.core.costmodel`.  The two layers meet only where enumeration
prunes by modeled cost: those call sites import the **active** cost model
lazily, so a fitted per-device coefficient table installed via
``costmodel.set_active_model`` steers candidate pruning and ranking without
any caller changing.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

from .client import Problem
from .extents import (_factors_only, next_pow2 as _next_pow2, next_smooth)


@dataclass(frozen=True)
class Candidate:
    """One point in the planner's search space.

    A candidate is either *homogeneous* (one backend applied per axis, or a
    whole-transform backend from :data:`FUSED_ND`) or — when ``axes`` is
    non-empty — a **per-axis assignment**: ``axes[i]`` transforms
    ``extents[i]`` (outermost first), each with its own backend and knobs.
    Per-axis candidates carry the placeholder backend ``'nd'``.

    Distributed candidates (:data:`DIST_BACKENDS`) additionally carry the
    **mesh shape** they decompose over — ``('slab', mesh=(4,))`` renders as
    ``slab[4]``, ``('pencil', mesh=(2, 4))`` as ``pencil[2x4]`` — because a
    selection tuned for one device count is meaningless for another, in
    plan-cache keys and in wisdom alike.
    """

    backend: str          # 'xla' | 'stockham' | ... | 'slab' | 'nd'
    options: tuple[tuple[str, Any], ...] = ()
    axes: tuple["Candidate", ...] = ()   # per-axis assignment (ND-native)
    mesh: tuple[int, ...] = ()           # device-mesh shape (distributed)

    def opts(self) -> dict[str, Any]:
        return dict(self.options)

    def per_axis(self, rank: int) -> tuple["Candidate", ...]:
        """The axis-by-axis assignment this candidate denotes: its explicit
        ``axes``, or the same (backend, knobs) replicated across ``rank``."""
        if self.axes:
            if len(self.axes) != rank:
                raise ValueError(
                    f"candidate assigns {len(self.axes)} axes to a rank-"
                    f"{rank} problem: {self.key()}")
            return self.axes
        return (Candidate(self.backend, self.options),) * rank

    def key(self) -> str:
        if self.axes:
            return "nd[" + ";".join(a.key() for a in self.axes) + "]"
        base = self.backend
        if self.mesh:
            base += "[" + "x".join(str(s) for s in self.mesh) + "]"
        o = ",".join(f"{k}={v}" for k, v in self.options)
        return f"{base}({o})" if o else base


def _pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def _smooth(n: int) -> bool:
    return n >= 1 and _factors_only(n, (2, 3, 5, 7, 11, 13))


def _smooth7(n: int) -> bool:
    """2^a*3^b*5^c*7^d — the extents the mixed-radix Stockham kernel
    factors (paper's powerof2 + radix357 classes; shares the extent
    classifier's ``_factors_only``)."""
    return n >= 1 and _factors_only(n, (2, 3, 5, 7))


#: Feasibility caps for the fused kernel paths (see the kernel modules).
FOURSTEP_PALLAS_MAX_N = 128 * 128        # one fused four-step kernel pass
STOCKHAM_PALLAS_MAX_N = 1 << 20          # ops.MAX_N: single-kernel hard cap
STOCKHAM_PALLAS_VMEM_N = 1 << 15         # fits a useful batch tile in VMEM
SIXSTEP_MIN_N, SIXSTEP_MAX_N = 4, 1 << 24
FFT2_PALLAS_MAX_ELEMS = 1 << 18          # fft2 ops.MAX_ELEMS: hard cap
FFT2_PALLAS_VMEM_ELEMS = 1 << 16         # n1*n2 tile fits the VMEM budget
#: Largest chirp-Z length whose padded transform (next_pow2(2n-1)) still
#: fits the six-step composition's SIXSTEP_MAX_N = 2^24.
CHIRPZ_PALLAS_MAX_N = 1 << 23

#: Whole-transform backends: one engine call covers every axis, so the
#: separable path's swapaxes traffic never happens.
FUSED_ND = ("xla", "fft2_pallas")

#: Every backend the planner knows, in enumeration (preference-tie) order.
BACKENDS = ("xla", "stockham", "fourstep", "dft", "fourstep_pallas",
            "stockham_pallas", "sixstep", "fft2_pallas", "chirpz_pallas",
            "bluestein")

#: Mesh-sharded decompositions (fft/distributed.py) — enumerated only when
#: an active mesh is installed (launch.mesh.set_active_mesh), and kept out
#: of :data:`BACKENDS` so single-device planning and the conformance
#: support matrix are byte-identical without one.
DIST_BACKENDS = ("dist1d", "slab", "pencil")

#: all_to_alls per decomposition in the default TRANSPOSED-output layout.
DIST_A2A_COUNT = {"dist1d": 2, "slab": 1, "pencil": 2}
#: extra all_to_alls for natural-order output.
DIST_NATURAL_EXTRA = {"dist1d": 1, "slab": 1, "pencil": 2}


def axis_feasible(backend: str, n: int) -> bool:
    """Can ``backend`` transform one batched axis of extent ``n``?  This is
    the engine-level contract: the length the cfft actually receives — n//2
    for the packed r2c innermost axis of an EVEN real extent, the full
    length for an odd one, see ``axis_engine_n``.  The chirp backends are
    the any-length catch-all, so odd-length real kinds explicitly route to
    the full-complex chirp path rather than a meaningless packed half."""
    if backend in ("xla", "bluestein"):
        return True
    if backend == "stockham":
        return _pow2(n)
    if backend == "fourstep":
        return _smooth(n)
    if backend == "dft":
        return n <= 128
    if backend == "fourstep_pallas":
        return _kernel_factorable(n)
    if backend == "stockham_pallas":
        return _smooth7(n) and n <= STOCKHAM_PALLAS_MAX_N
    if backend == "chirpz_pallas":
        # any length whose padded pow2 transform the fused engines cover
        return 1 <= n <= CHIRPZ_PALLAS_MAX_N
    if backend == "sixstep":
        # the engine falls back to the fused Stockham kernel below
        # SIXSTEP_MIN_N (packed-real halves can land there)
        return _pow2(n) and n <= SIXSTEP_MAX_N and n >= 2
    return False


def axis_engine_n(problem: Problem, axis: int) -> int:
    """Extent the 1-D engine actually transforms along ``axis``.

    Real kinds take the packed half-length path on the innermost axis (the
    cfft runs at n//2 for even n; odd lengths pay the full complex
    transform), so feasibility and the cost model must look at that length,
    not the nominal extent."""
    n = problem.extents[axis]
    if problem.complex_input or axis < problem.rank - 1:
        return n
    return n // 2 if n % 2 == 0 and n > 1 else n


def fft2_feasible(problem: Problem) -> bool:
    """The fused rank-2 kernel holds the whole n1 x n2 tile in VMEM."""
    exts = problem.extents
    return (len(exts) == 2 and all(_pow2(v) for v in exts)
            and exts[0] * exts[1] <= FFT2_PALLAS_MAX_ELEMS
            and (problem.complex_input or exts[-1] % 2 == 0))


def backend_supports(backend: str, problem: Problem) -> bool:
    """Single source of truth for the support matrix: candidates(), the
    conformance matrix, and the README table all consult this."""
    if backend == "fft2_pallas":
        return fft2_feasible(problem)
    if backend == "xla":
        return True
    if backend == "sixstep":
        # offered only where the six-step composition is the real algorithm
        if not all(_pow2(v) and SIXSTEP_MIN_N <= v <= SIXSTEP_MAX_N
                   for v in problem.extents):
            return False
    return all(axis_feasible(backend, axis_engine_n(problem, i))
               for i in range(problem.rank))


# ---------------------------------------------------------------------------
# Distributed candidates: slab / pencil / dist1d over the active mesh
# ---------------------------------------------------------------------------
def _mesh_devices(mesh) -> int:
    """Device count of a mesh (or mesh-shaped stand-in with ``.size``)."""
    return int(mesh.size)


def dist_supports(backend: str, problem: Problem,
                  mesh_shape: Sequence[int]) -> bool:
    """Can ``backend`` decompose ``problem`` over a mesh of ``mesh_shape``?

    Distribution is complex-kinds-only: the packed r2c half-spectrum extents
    (n//2, n//2+1) break the tiled all_to_all divisibility that every
    rotation depends on.  ``dist1d`` additionally needs batch == 1 — its
    matrix view consumes the whole axis.
    """
    if not problem.complex_input:
        return False
    from repro.fft import distributed as dist

    shape = tuple(int(s) for s in mesh_shape)
    p = 1
    for s in shape:
        p *= s
    if p < 2:
        return False   # one device: decomposition is pure overhead
    if backend == "dist1d":
        return (problem.rank == 1 and problem.batch == 1
                and dist.can_shard_1d(problem.extents[0], p))
    if backend == "slab":
        return (len(shape) == 1 and problem.rank in (2, 3)
                and dist.slab_divisible(problem.extents, p))
    if backend == "pencil":
        return (len(shape) == 2 and problem.rank == 3
                and dist.pencil_divisible(problem.extents, *shape))
    return False


def _pencil_mesh_shapes(p: int, patient: bool = False) -> list[tuple[int, int]]:
    """(Pr, Pc) factorizations of ``p``: the most balanced one by default,
    widened to (at most four) alternates under PATIENT."""
    shapes = [(pr, p // pr) for pr in range(2, int(p ** 0.5) + 1)
              if p % pr == 0]
    shapes.sort(key=lambda s: s[1] - s[0])
    if not patient:
        return shapes[:1]
    out = list(shapes)
    out += [(pc, pr) for pr, pc in shapes if pr != pc]
    return out[:4]


def dist_local_lengths(problem: Problem, cand: Candidate
                       ) -> list[tuple[int, float]]:
    """The local sub-transform lengths a distributed candidate runs per
    shard, each with the swapaxes passes its position costs (+2 when the
    transform axis is not innermost in the local block, like the separable
    single-device path; 0 for the innermost axis)."""
    p = 1
    for s in cand.mesh:
        p *= s
    if cand.backend == "dist1d":
        from repro.fft.distributed import _choose_1d_factors

        n1, n2 = _choose_1d_factors(problem.extents[0], p)
        return [(n1, 2.0), (n2, 0.0)]
    # slab / pencil transform every global axis at its full extent locally
    return [(n, 0.0 if i == problem.rank - 1 else 2.0)
            for i, n in enumerate(problem.extents)]


def _dist_candidates(problem: Problem, mesh, patient: bool
                     ) -> list[Candidate]:
    """Sharded decompositions feasible for ``problem`` over ``mesh``.

    PATIENT widens with the decomposition x local-engine cross: alternate
    pencil mesh factorizations, and each feasible local engine forced via
    the ``local`` knob (the distributed analogue of the kernel tile
    sweeps)."""
    from .costmodel import dist_local_engine, hbm_passes

    p = _mesh_devices(mesh)
    if p < 2:
        return []
    out: list[Candidate] = []
    if dist_supports("dist1d", problem, (p,)):
        out.append(Candidate("dist1d", mesh=(p,)))
    if dist_supports("slab", problem, (p,)):
        out.append(Candidate("slab", mesh=(p,)))
    for shape in _pencil_mesh_shapes(p, patient):
        if dist_supports("pencil", problem, shape):
            out.append(Candidate("pencil", mesh=shape))
    if patient:
        extra = []
        for c in out:
            lengths = [n for n, _ in dist_local_lengths(problem, c)]
            default = {dist_local_engine(n) for n in lengths}
            locals_ = [b for b in BACKENDS
                       if b not in FUSED_ND and b not in default
                       and all(axis_feasible(b, n) for n in lengths)
                       and all(hbm_passes(b, n) != float("inf")
                               for n in lengths)]
            locals_.sort(key=lambda b: sum(hbm_passes(b, n) for n in lengths))
            extra += [Candidate(c.backend, (("local", b),), mesh=c.mesh)
                      for b in locals_[:2]]
        out += extra
    return out


def candidates(problem: Problem, patient: bool = False,
               mesh=None) -> list[Candidate]:
    """Enumerate feasible (backend, knob) combinations for a problem.

    The space is ND-native: besides homogeneous candidates (one backend for
    every axis) it holds the whole-transform backends (``xla``, and the
    fused rank-2 ``fft2_pallas`` kernel) and **per-axis assignments**
    (``Candidate.axes``) mixing backends across axes, pruned by the
    bytes-moved model.  ``patient=True`` widens the space with the fused
    kernels' tunable knobs — batch tiles, the (mixed-)radix schedule, the
    six-step n1*n2 split, the fft2 radix, the chirp-Z padded-engine choice
    — the FFTW_PATIENT analogue of searching algorithm *and* implementation
    parameters.

    ``mesh`` gates the distributed decompositions: ``None`` consults the
    active mesh (``launch.mesh.get_active_mesh``), which is itself None
    unless a launcher installed one — so single-process planning never
    offers a multi-device plan.
    """
    exts = problem.extents
    out: list[Candidate] = [Candidate("xla")]
    # every backend — the chirp catch-alls included — goes through
    # backend_supports, which evaluates feasibility at the ENGINE length:
    # odd-length real kinds route to the full-complex chirp path (engine
    # length n, not the even-only packed n//2) and caps apply there
    for b in BACKENDS[1:]:
        if backend_supports(b, problem):
            out.append(Candidate(b))
    if problem.rank >= 2:
        out += _mixed_candidates(problem, limit=12 if patient else 6)
    if mesh is None:
        from repro.launch.mesh import get_active_mesh

        mesh = get_active_mesh()
    if mesh is not None:
        out += _dist_candidates(problem, mesh, patient)
    if patient:
        extra = []
        for c in out:
            if c.options or c.axes:
                continue
            if c.backend == "fourstep_pallas":
                for tb in (4, 8, 16):
                    extra.append(Candidate("fourstep_pallas", (("tile_b", tb),)))
            elif c.backend == "stockham_pallas":
                for tb in (4, 16):
                    for radix in (4, 8):
                        extra.append(Candidate(
                            "stockham_pallas",
                            (("radix", radix), ("tile_b", tb))))
            elif c.backend == "sixstep":
                for n1 in _sixstep_splits(exts[-1]):
                    extra.append(Candidate("sixstep", (("split_n1", n1),)))
                extra.append(Candidate("sixstep", (("tile_b", 16),)))
            elif c.backend == "chirpz_pallas":
                # a forced engine applies to EVERY axis the separable path
                # transforms, so gate each knob on every axis's engine
                # length (_sixstep_splits rule: only emit knobs the engine
                # actually honors, never ones that raise at build time)
                eng_ns = [axis_engine_n(problem, i)
                          for i in range(problem.rank)]
                engines = []
                if all(next_smooth(2 * v - 1) <= STOCKHAM_PALLAS_MAX_N
                       for v in eng_ns):
                    engines.append("stockham_pallas")  # smooth-m padding
                if all(SIXSTEP_MIN_N <= _next_pow2(2 * v - 1)
                       <= SIXSTEP_MAX_N for v in eng_ns):
                    engines.append("sixstep")
                for eng in engines:
                    extra.append(Candidate("chirpz_pallas",
                                           (("engine", eng),)))
                extra.append(Candidate("chirpz_pallas", (("tile_b", 16),)))
            elif c.backend == "fft2_pallas":
                for tb in (2, 8):
                    for radix in (4, 8):
                        extra.append(Candidate(
                            "fft2_pallas",
                            (("radix", radix), ("tile_b", tb))))
        out += extra
    return out


def _mixed_candidates(problem: Problem, limit: int) -> list[Candidate]:
    """Per-axis backend assignments, pruned by the bytes-moved model.

    For each axis, rank the separable backends by modeled engine passes at
    that axis's (packed) extent and keep the best two; the cross product —
    minus homogeneous assignments, which are already enumerated — is then
    re-ranked by the full ND model and truncated to ``limit``.  This is how
    the planner expresses e.g. 'dft on the tiny outer axis, fused Stockham
    on the long inner one' without sweeping every combination."""
    import itertools

    from .costmodel import estimate_bytes_moved, hbm_passes

    per_axis: list[list[str]] = []
    for i in range(problem.rank):
        n_eng = axis_engine_n(problem, i)
        feas = [b for b in BACKENDS
                if b not in FUSED_ND and axis_feasible(b, n_eng)]
        feas.sort(key=lambda b: hbm_passes(b, n_eng))
        per_axis.append(feas[:2])
    scored = []
    for combo in itertools.product(*per_axis):
        if len(set(combo)) == 1:
            continue  # homogeneous: already in the candidate list
        cand = Candidate("nd", axes=tuple(Candidate(b) for b in combo))
        cost = estimate_bytes_moved(problem, cand)
        if cost != float("inf"):
            scored.append((cost, cand))
    scored.sort(key=lambda t: t[0])
    return [cand for _, cand in scored[:limit]]


def _sixstep_splits(n: int) -> list[int]:
    """Alternative n = n1*n2 residual splits for the PATIENT sweep: the
    balanced split and a residual-heavy one, besides the default.  Both
    sixstep.choose_split constraints apply — n1 <= 2^10 (the residual
    VMEM cap) and n2 <= 2^14 — so every emitted knob is one the engine
    actually honors rather than silently replacing with the default."""
    if not _pow2(n) or n < SIXSTEP_MIN_N:
        return []
    k = n.bit_length() - 1
    default_k1 = k - min(14, k - 1)
    opts = {max(1, k // 2), max(1, min(10, k - 1))} - {default_k1}
    return sorted(1 << k1 for k1 in opts
                  if 1 <= k1 <= 10 and k - k1 <= 14)


def _kernel_factorable(n: int) -> bool:
    """n = n1*n2 with both <= 128 (single fused fft4step kernel pass)."""
    if n > FOURSTEP_PALLAS_MAX_N:
        return False
    for n1 in range(min(128, n), 0, -1):
        if n % n1 == 0 and n // n1 <= 128:
            return True
    return False
