"""Plans, plan rigors, and the planner/autotuner.

fftw's planner concept (paper §2.1) mapped to JAX:

  plan          = (backend, factorization/tile knobs) + the AOT-compiled
                  executable for one Problem
  FFTW_ESTIMATE = static heuristic over the candidate space (no timing)
  FFTW_MEASURE  = compile + time every candidate, keep the fastest
  FFTW_PATIENT  = MEASURE over a widened space (kernel tile shapes too)
  FFTW_WISDOM_ONLY = look up a persisted choice; None plan if absent

Planning *time* is a first-class measurement (paper Figs. 4-5: MEASURE costs
3-4 orders of magnitude more than ESTIMATE and can exceed the transform time
by far) — the planner therefore reports plan_time_ms with every plan.
"""

from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from .client import Problem


class PlanRigor(enum.Enum):
    ESTIMATE = "estimate"
    MEASURE = "measure"
    PATIENT = "patient"
    WISDOM_ONLY = "wisdom_only"


@dataclass(frozen=True)
class Candidate:
    """One point in the planner's search space."""

    backend: str                      # 'xla' | 'fourstep' | 'stockham' | 'bluestein' | 'dft'
    options: tuple[tuple[str, Any], ...] = ()

    def opts(self) -> dict[str, Any]:
        return dict(self.options)

    def key(self) -> str:
        o = ",".join(f"{k}={v}" for k, v in self.options)
        return f"{self.backend}({o})" if o else self.backend


@dataclass
class Plan:
    problem: Problem
    candidate: Candidate
    rigor: PlanRigor
    plan_time_ms: float = 0.0
    measured_ms: dict[str, float] = field(default_factory=dict)  # per-candidate timings


def _pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def _smooth(n: int) -> bool:
    for p in (2, 3, 5, 7, 11, 13):
        while n % p == 0:
            n //= p
    return n == 1


def candidates(problem: Problem, patient: bool = False) -> list[Candidate]:
    """Enumerate feasible (backend, knob) combinations for a problem.

    Backends transform the innermost extent; outer extents are batched via
    nd-application, so feasibility is decided per-axis (all axes must be
    supported by the backend).
    """
    exts = problem.extents
    out: list[Candidate] = [Candidate("xla")]
    if all(_pow2(v) for v in exts):
        out.append(Candidate("stockham"))
    if all(_smooth(v) for v in exts):
        out.append(Candidate("fourstep"))
    if all(v <= 128 for v in exts):
        out.append(Candidate("dft"))
    if all(_kernel_factorable(v) for v in exts):
        out.append(Candidate("fourstep_pallas"))
    out.append(Candidate("bluestein"))  # always feasible
    if patient:
        extra = []
        for c in out:
            if c.backend == "fourstep_pallas":
                for tb in (4, 8, 16):
                    extra.append(Candidate("fourstep_pallas", (("tile_b", tb),)))
        out += extra
    return out


def _kernel_factorable(n: int) -> bool:
    """n = n1*n2 with both <= 128 (single fused fft4step kernel pass)."""
    if n > 128 * 128:
        return False
    for n1 in range(min(128, n), 0, -1):
        if n % n1 == 0 and n // n1 <= 128:
            return True
    return False


def estimate_choice(problem: Problem) -> Candidate:
    """The ESTIMATE heuristic: a static cost model.

    Mirrors fftw's 'probably sub-optimal but instant' behavior: prefer the
    vendor path (XLA HLO) for large/smooth problems, the matmul paths for
    small ones, bluestein only when nothing else fits.
    """
    cands = {c.backend: c for c in candidates(problem)}
    n_inner = problem.extents[-1]
    if "dft" in cands and n_inner <= 128 and problem.rank == 1:
        return cands["dft"]
    if "xla" in cands:
        return cands["xla"]
    return cands["bluestein"]


def measure_plan(problem: Problem, build: Callable[[Candidate], Callable],
                 cands: Sequence[Candidate], reps: int = 3) -> tuple[Candidate, dict[str, float]]:
    """MEASURE: compile + run each candidate, return fastest + timing table."""
    import jax

    timings: dict[str, float] = {}
    rng = np.random.default_rng(0)
    x = rng.standard_normal((problem.batch, *problem.extents)).astype(problem.real_dtype)
    if problem.complex_input:
        x = x.astype(problem.input_dtype)
    xd = jax.device_put(x)
    for cand in cands:
        try:
            fn = build(cand)
            fn(xd)  # compile + warmup
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(xd))
                best = min(best, (time.perf_counter() - t0) * 1e3)
            timings[cand.key()] = best
        except Exception as e:  # infeasible candidate: record, keep going
            timings[cand.key()] = float("nan")
    feasible = {k: v for k, v in timings.items() if v == v}
    if not feasible:
        raise RuntimeError(f"no feasible plan for {problem.signature()}")
    best_key = min(feasible, key=feasible.get)
    best_cand = next(c for c in cands if c.key() == best_key)
    return best_cand, timings


def make_plan(problem: Problem, rigor: PlanRigor,
              build: Callable[[Candidate], Callable] | None = None,
              wisdom=None) -> Plan | None:
    """The planner. Returns None for WISDOM_ONLY misses (fftw NULL plan)."""
    t0 = time.perf_counter()
    if rigor is PlanRigor.WISDOM_ONLY:
        if wisdom is None:
            return None
        cand = wisdom.lookup(problem)
        if cand is None:
            return None
        return Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3)

    if rigor is PlanRigor.ESTIMATE or build is None:
        cand, timings = estimate_choice(problem), {}
    else:
        cands = candidates(problem, patient=(rigor is PlanRigor.PATIENT))
        cand, timings = measure_plan(problem, build, cands)
    plan = Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3, timings)
    if wisdom is not None and rigor in (PlanRigor.MEASURE, PlanRigor.PATIENT):
        wisdom.record(problem, cand)
    return plan
