"""Plans, plan rigors, and the planner/autotuner.

fftw's planner concept (paper §2.1) mapped to JAX:

  plan          = (backend, factorization/tile knobs) + the AOT-compiled
                  executable for one Problem
  FFTW_ESTIMATE = static heuristic over the candidate space (no timing)
  FFTW_MEASURE  = compile + time every candidate, keep the fastest
  FFTW_PATIENT  = MEASURE over a widened space (kernel tile shapes too)
  FFTW_WISDOM_ONLY = look up a persisted choice; None plan if absent

Planning *time* is a first-class measurement (paper Figs. 4-5: MEASURE costs
3-4 orders of magnitude more than ESTIMATE and can exceed the transform time
by far) — the planner therefore reports plan_time_ms with every plan.

This module is the planning *driver* plus the compatibility façade over the
split-out layers — every historical ``from repro.core.plan import ...``
keeps resolving:

  :mod:`repro.core.candidates`  the search space: Candidate, feasibility
                                predicates, backend registries, caps,
                                candidate enumeration
  :mod:`repro.core.costmodel`   the fittable bytes-moved model: CostModel,
                                per-device coefficient tables, hbm_passes /
                                estimate_bytes_moved / estimate_choice
  :mod:`repro.core.breaker`     the (backend, problem-class) circuit breaker

The cost functions re-exported here delegate to the **active** cost model
(:func:`repro.core.costmodel.get_active_model`): installing a fitted
per-device table re-ranks ESTIMATE picks, fallback chains, and the serve
engine's chain memoization without any caller changing.
"""

from __future__ import annotations

import enum
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from .client import Problem

# --- compatibility façade: the split-out planning layers -------------------
from .candidates import (  # noqa: F401  (re-exported public surface)
    BACKENDS, CHIRPZ_PALLAS_MAX_N, Candidate, DIST_A2A_COUNT, DIST_BACKENDS,
    DIST_NATURAL_EXTRA, FFT2_PALLAS_MAX_ELEMS, FFT2_PALLAS_VMEM_ELEMS,
    FOURSTEP_PALLAS_MAX_N, FUSED_ND, SIXSTEP_MAX_N, SIXSTEP_MIN_N,
    STOCKHAM_PALLAS_MAX_N, STOCKHAM_PALLAS_VMEM_N, _dist_candidates,
    _kernel_factorable, _mesh_devices, _mixed_candidates, _pencil_mesh_shapes,
    _pow2, _sixstep_splits, _smooth, _smooth7, axis_engine_n, axis_feasible,
    backend_supports, candidates, dist_local_lengths, dist_supports,
    fft2_feasible)
from .costmodel import (  # noqa: F401
    DIST_A2A_LATENCY_BYTES, DIST_LINK_COST, CostCoefficients, CostModel,
    Infeasible, _axis_elems, dist_local_engine, estimate_bytes_moved,
    estimate_choice, get_active_model, hbm_passes, set_active_model,
    use_model)
from .breaker import (  # noqa: F401
    CircuitBreaker, breaker_key, problem_class)


class PlanRigor(enum.Enum):
    ESTIMATE = "estimate"
    MEASURE = "measure"
    PATIENT = "patient"
    WISDOM_ONLY = "wisdom_only"


@dataclass
class Plan:
    problem: Problem
    candidate: Candidate
    rigor: PlanRigor
    plan_time_ms: float = 0.0
    measured_ms: dict[str, float] = field(default_factory=dict)  # per-candidate timings
    fallbacks: tuple[str, ...] = ()   # candidate keys demoted before this one
    #: Where the selection came from — 'estimate' | 'measure' | 'patient' |
    #: 'wisdom' (exact persisted hit) | 'wisdom_near' (nearest-neighbor
    #: interpolated warm start) | 'fallback' (chain walk after demotions).
    #: Result rows surface this so interpolated picks stay distinguishable.
    source: str = ""


# ---------------------------------------------------------------------------
# Plan/executable cache (engine layer 2)
# ---------------------------------------------------------------------------
@dataclass
class PlanCacheStats:
    """Cold/warm accounting: misses pay the measured compile (cold) cost,
    hits dispatch the memoized executable (warm)."""

    hits: int = 0
    misses: int = 0
    cold_ms: float = 0.0   # total time spent building on misses

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "cold_ms": self.cold_ms}


class PlanCache:
    """Memoizes lowered/compiled executables and measured plan selections.

    Keys include the *device kind* (an executable compiled for one device
    kind must never serve another), the problem signature (extents,
    precision, kind, batch), the candidate (backend + knobs), and the
    transform direction.  Without the cache every repetition re-lowers and
    re-compiles (the honest per-run planning measurement of paper Figs. 4-5);
    with it, the first run to need an executable — possibly a warmup, whose
    cold-compile ops are then emitted with a negative run index — pays the
    measured cold compile, and warm repetitions reuse the executable.  Both
    quantities stay measured, and result rows carry a ``plan_cache``
    hit/miss marker so they remain distinguishable downstream.

    Lookups are **concurrency-safe**: the maps are guarded by a lock and
    builds are single-flight — when several serving workers race on the same
    cold key, exactly one runs ``build`` while the rest wait on its in-flight
    marker and then take the hit path, so ``misses`` always equals the number
    of distinct keys built and ``hits + misses`` the number of lookups (the
    invariant the threaded hammer test pins).
    """

    def __init__(self) -> None:
        self._execs: dict[str, Any] = {}
        self._plans: dict[str, Any] = {}
        self._lock = threading.Lock()
        self._inflight: dict[str, threading.Event] = {}
        self.stats = PlanCacheStats()

    def _single_flight(self, table: dict, kind: str, key: str,
                       build: Callable[[], Any],
                       count_stats: bool) -> tuple[Any, str, float]:
        """One builder per (kind, key); racing threads wait and read the
        published value.  The lock is dropped while ``build`` runs (compiles
        can take seconds) and re-taken to publish.  ``count_stats`` keeps the
        hit/miss accounting an executable-cache quantity, as before."""
        flight_key = f"{kind}|{key}"
        while True:
            with self._lock:
                if key in table:
                    if count_stats:
                        self.stats.hits += 1
                    return table[key], "hit", 0.0
                ev = self._inflight.get(flight_key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[flight_key] = ev
                    break           # we are the builder
            ev.wait()               # another thread is building this key
        t0 = time.perf_counter()
        try:
            built = build()
            ms = (time.perf_counter() - t0) * 1e3
            with self._lock:
                table[key] = built
                if count_stats:
                    self.stats.misses += 1
                    self.stats.cold_ms += ms
            return built, "miss", ms
        finally:
            with self._lock:
                self._inflight.pop(flight_key, None)
            ev.set()

    # --- keys -------------------------------------------------------------
    @staticmethod
    def executable_key(device_kind: str, problem: Problem,
                       candidate: "Candidate | str", direction: str) -> str:
        ck = candidate.key() if isinstance(candidate, Candidate) else str(candidate)
        return f"exec|{device_kind}|{problem.signature()}|{ck}|{direction}"

    @staticmethod
    def plan_key(device_kind: str, problem: Problem, rigor: "PlanRigor",
                 scope: str = "") -> str:
        return f"plan|{device_kind}|{problem.signature()}|{rigor.value}|{scope}"

    # --- lookups ----------------------------------------------------------
    def executable(self, key: str, build: Callable[[], Any]
                   ) -> tuple[Any, str, float]:
        """Return ``(executable, 'hit'|'miss', elapsed_ms)``.

        ``build`` runs only on a miss; its wall time is the measured cold
        compile cost.
        """
        return self._single_flight(self._execs, "exec", key, build,
                                   count_stats=True)

    def plan(self, key: str, make: Callable[[], Any]) -> tuple[Any, str]:
        """Memoized plan selection (candidate sweeps run at most once per
        key — a MEASURE sweep over repeated repetitions stops re-compiling
        every candidate).  ``None`` results (wisdom misses) are cached too:
        a deterministic miss stays a miss."""
        plan, event, _ = self._single_flight(self._plans, "plan", key, make,
                                             count_stats=False)
        return plan, event

    def __len__(self) -> int:
        with self._lock:
            return len(self._execs)


def cached_build(plan_cache: "PlanCache | None", events: dict, op_name: str,
                 key: str, build: Callable[[], Any]):
    """Memoize-or-build an executable, recording the hit/miss event for the
    result rows.  With no cache attached this is just ``build()`` — the
    per-run recompile measurement."""
    if plan_cache is None:
        return build()
    compiled, event, _ = plan_cache.executable(key, build)
    events[op_name] = event
    return compiled


def executable_bytes(compiled) -> int:
    """Bytes attributable to a compiled executable (plan size analogue)."""
    try:
        ma = compiled.memory_analysis()
        return int(getattr(ma, "temp_size_in_bytes", 0) +
                   getattr(ma, "generated_code_size_in_bytes", 0))
    except Exception:
        return 0


def fallback_chain(problem: Problem, patient: bool = False,
                   mesh=None) -> list[Candidate]:
    """The ordered degradation path: ESTIMATE's pick first (its dft pin for
    tiny rank-1 problems included), then every other feasible candidate by
    ascending modeled cost, with a plain ``xla`` candidate guaranteed
    present — the always-feasible terminal fallback.  Pure ordering under
    the *active* cost model — a fitted per-device table re-ranks the chain
    for every walker: the walkers (:func:`make_plan`'s fault-tolerant mode,
    the serve engine) apply wisdom-demotion and circuit-breaker filtering
    at try time."""
    cands = candidates(problem, patient=patient, mesh=mesh)
    scored = [(estimate_bytes_moved(problem, c), i, c)
              for i, c in enumerate(cands)]
    ranked = [c for cost, _, c in sorted(scored, key=lambda t: t[:2])
              if cost != float("inf")]
    top = estimate_choice(problem)
    chain = [top] + [c for c in ranked if c.key() != top.key()]
    if not any(c.backend == "xla" and not c.axes for c in chain):
        chain.append(Candidate("xla"))
    return chain


def probe_finite(fn: Callable, problem: Problem) -> None:
    """Cheap output-finiteness probe: push one all-ones batch through a
    freshly built executable and reject it on any non-finite output — the
    'compiles fine, computes garbage' failure mode a build error misses."""
    x = np.ones((problem.batch, *problem.extents), dtype=problem.real_dtype)
    if problem.complex_input:
        x = x.astype(problem.input_dtype)
    out = np.asarray(fn(x))
    if not np.isfinite(out).all():
        raise RuntimeError(
            f"finiteness probe failed for {problem.signature()}: "
            f"executable produced non-finite output")


def measure_plan(problem: Problem, build: Callable[[Candidate], Callable],
                 cands: Sequence[Candidate], reps: int = 3) -> tuple[Candidate, dict[str, float]]:
    """MEASURE: compile + run each candidate, return fastest + timing table."""
    import jax

    timings: dict[str, float] = {}
    rng = np.random.default_rng(0)
    x = rng.standard_normal((problem.batch, *problem.extents)).astype(problem.real_dtype)
    if problem.complex_input:
        x = x.astype(problem.input_dtype)
    xd = jax.device_put(x)
    for cand in cands:
        try:
            fn = build(cand)
            fn(xd)  # compile + warmup
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(xd))
                best = min(best, (time.perf_counter() - t0) * 1e3)
            timings[cand.key()] = best
        except Exception as e:  # infeasible candidate: record, keep going
            timings[cand.key()] = float("nan")
    feasible = {k: v for k, v in timings.items() if v == v}
    if not feasible:
        raise RuntimeError(f"no feasible plan for {problem.signature()}")
    best_key = min(feasible, key=feasible.get)
    best_cand = next(c for c in cands if c.key() == best_key)
    return best_cand, timings


def _demoted_backends(wisdom, problem: Problem) -> frozenset:
    """Backends wisdom has quarantined for this problem-class (empty when
    the wisdom store is absent or predates demotion records)."""
    if wisdom is None:
        return frozenset()
    demoted = getattr(wisdom, "demoted", None)
    return demoted(problem) if callable(demoted) else frozenset()


def _near_lookup(wisdom, problem: Problem, demoted: frozenset):
    """Nearest-neighbor wisdom consultation (schema v3): a candidate tuned
    for the closest same-feasibility-class shape, or None.  Duck-typed so
    pre-v3 stores (and stand-ins without ``lookup_near``) just miss."""
    near = getattr(wisdom, "lookup_near", None)
    if near is None:
        return None
    hit = near(problem)
    if hit is None:
        return None
    cand, _neighbor = hit
    if cand.backend in demoted and cand.backend != "xla":
        return None
    return cand


def _fallback_plan(problem: Problem, rigor: PlanRigor,
                   build: Callable[[Candidate], Callable], wisdom,
                   breaker: CircuitBreaker, probe: bool, t0: float,
                   demoted: frozenset) -> Plan:
    """Fault-tolerant planning: walk the cost-ordered fallback chain,
    demoting past candidates that fail at build (or at the optional
    finiteness probe), with circuit-breaker bookkeeping per (backend,
    problem-class) pair.  A demotion that OPENS the breaker is persisted to
    wisdom so warm sessions skip the known-bad pick outright.  The terminal
    candidate — by construction a plain ``xla`` is always in the chain —
    is tried regardless of quarantine state."""
    chain = fallback_chain(problem, patient=(rigor is PlanRigor.PATIENT))
    fallbacks: list[str] = []
    last_err: Exception | None = None
    for i, cand in enumerate(chain):
        terminal = i == len(chain) - 1
        is_xla = cand.backend == "xla" and not cand.axes
        if not terminal and not is_xla:
            if cand.backend in demoted:
                fallbacks.append(cand.key())
                continue
            if not breaker.allows(breaker_key(cand.backend, problem)):
                fallbacks.append(cand.key())
                continue
        try:
            fn = build(cand)
            if probe:
                probe_finite(fn, problem)
        except Exception as e:
            last_err = e
            state = breaker.record_failure(breaker_key(cand.backend, problem))
            if wisdom is not None and not is_xla \
                    and state == CircuitBreaker.OPEN:
                wisdom.record_demotion(problem, cand.backend)
            fallbacks.append(cand.key())
            continue
        breaker.record_success(breaker_key(cand.backend, problem))
        return Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3,
                    fallbacks=tuple(fallbacks),
                    source="fallback" if fallbacks else "estimate")
    raise RuntimeError(
        f"no feasible plan for {problem.signature()}: all {len(chain)} "
        f"candidates failed (last: {type(last_err).__name__}: {last_err})")


def make_plan(problem: Problem, rigor: PlanRigor,
              build: Callable[[Candidate], Callable] | None = None,
              wisdom=None, breaker: CircuitBreaker | None = None,
              probe: bool = False, near: bool = True) -> Plan | None:
    """The planner. Returns None for WISDOM_ONLY misses (fftw NULL plan).

    MEASURE/PATIENT consult wisdom first, fftw-style: a persisted selection
    for this (device, problem) short-circuits the candidate sweep entirely,
    so a warm Session (or a second process sharing the wisdom file) plans in
    microseconds instead of re-compiling every candidate.  On an exact miss
    a schema-v3 wisdom store is consulted for a **nearest-neighbor** warm
    start (``Wisdom.lookup_near``): the selection tuned for the closest
    shape in the same backend-feasibility class, returned with plan source
    ``'wisdom_near'`` so results stay honest.  ``near=False`` disables the
    interpolated path — the pregeneration tools use it so every swept shape
    gets a real sweep rather than inheriting its neighbor's pick.

    Fault tolerance: with both ``build`` and ``breaker`` supplied, planning
    walks the :func:`fallback_chain` instead — each candidate is actually
    built (and optionally finiteness-probed with ``probe=True``) before it
    is returned, failures demote to the next candidate by modeled cost, and
    the (backend, problem-class) pair is quarantined in the breaker; see
    :func:`_fallback_plan`.  Without a breaker, behavior is unchanged except
    that wisdom-recorded demotions steer the ESTIMATE pick away from
    known-bad backends.
    """
    t0 = time.perf_counter()
    if rigor is PlanRigor.WISDOM_ONLY:
        if wisdom is None:
            return None
        cand = wisdom.lookup(problem)
        if cand is not None:
            return Plan(problem, cand, rigor,
                        (time.perf_counter() - t0) * 1e3, source="wisdom")
        if near:
            cand = _near_lookup(wisdom, problem,
                                _demoted_backends(wisdom, problem))
            if cand is not None:
                return Plan(problem, cand, rigor,
                            (time.perf_counter() - t0) * 1e3,
                            source="wisdom_near")
        return None

    demoted = _demoted_backends(wisdom, problem)
    if wisdom is not None and rigor in (PlanRigor.MEASURE, PlanRigor.PATIENT):
        cand = wisdom.lookup(problem)
        if cand is not None and cand.backend not in demoted:
            # tuned knobs persisted by an earlier sweep
            return Plan(problem, cand, rigor,
                        (time.perf_counter() - t0) * 1e3, source="wisdom")
        if cand is None and near:
            # nearest-neighbor warm start: MEASURE-grade pick without the
            # sweep — the selection tuned for the closest same-class shape
            cand = _near_lookup(wisdom, problem, demoted)
            if cand is not None:
                return Plan(problem, cand, rigor,
                            (time.perf_counter() - t0) * 1e3,
                            source="wisdom_near")

    if build is not None and breaker is not None:
        return _fallback_plan(problem, rigor, build, wisdom, breaker, probe,
                              t0, demoted)

    if rigor is PlanRigor.ESTIMATE or build is None:
        cand, timings = estimate_choice(problem), {}
        if cand.backend in demoted and cand.backend != "xla":
            # warm session: skip the known-bad pick without a live breaker
            for c in fallback_chain(problem):
                if c.backend == "xla" or c.backend not in demoted:
                    cand = c
                    break
    else:
        cands = candidates(problem, patient=(rigor is PlanRigor.PATIENT))
        if demoted:
            cands = [c for c in cands
                     if c.backend == "xla" or c.backend not in demoted]
        cand, timings = measure_plan(problem, build, cands)
    plan = Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3,
                timings, source=rigor.value if timings else "estimate")
    # persist only selections a sweep actually timed: a build-less
    # MEASURE/PATIENT call falls back to the untimed ESTIMATE pick, and
    # recording that would let the wisdom-first short-circuit lock it in
    # forever as if it had been measured
    if wisdom is not None and timings \
            and rigor in (PlanRigor.MEASURE, PlanRigor.PATIENT):
        wisdom.record(problem, cand,
                      measured_ms=timings.get(cand.key()),
                      rigor=rigor.value)
    return plan
