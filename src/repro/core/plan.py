"""Plans, plan rigors, and the planner/autotuner.

fftw's planner concept (paper §2.1) mapped to JAX:

  plan          = (backend, factorization/tile knobs) + the AOT-compiled
                  executable for one Problem
  FFTW_ESTIMATE = static heuristic over the candidate space (no timing)
  FFTW_MEASURE  = compile + time every candidate, keep the fastest
  FFTW_PATIENT  = MEASURE over a widened space (kernel tile shapes too)
  FFTW_WISDOM_ONLY = look up a persisted choice; None plan if absent

Planning *time* is a first-class measurement (paper Figs. 4-5: MEASURE costs
3-4 orders of magnitude more than ESTIMATE and can exceed the transform time
by far) — the planner therefore reports plan_time_ms with every plan.
"""

from __future__ import annotations

import enum
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from .client import Problem
from .extents import (_factors_only, classify, next_pow2 as _next_pow2,
                      next_smooth)


class PlanRigor(enum.Enum):
    ESTIMATE = "estimate"
    MEASURE = "measure"
    PATIENT = "patient"
    WISDOM_ONLY = "wisdom_only"


@dataclass(frozen=True)
class Candidate:
    """One point in the planner's search space.

    A candidate is either *homogeneous* (one backend applied per axis, or a
    whole-transform backend from :data:`FUSED_ND`) or — when ``axes`` is
    non-empty — a **per-axis assignment**: ``axes[i]`` transforms
    ``extents[i]`` (outermost first), each with its own backend and knobs.
    Per-axis candidates carry the placeholder backend ``'nd'``.

    Distributed candidates (:data:`DIST_BACKENDS`) additionally carry the
    **mesh shape** they decompose over — ``('slab', mesh=(4,))`` renders as
    ``slab[4]``, ``('pencil', mesh=(2, 4))`` as ``pencil[2x4]`` — because a
    selection tuned for one device count is meaningless for another, in
    plan-cache keys and in wisdom alike.
    """

    backend: str          # 'xla' | 'stockham' | ... | 'slab' | 'nd'
    options: tuple[tuple[str, Any], ...] = ()
    axes: tuple["Candidate", ...] = ()   # per-axis assignment (ND-native)
    mesh: tuple[int, ...] = ()           # device-mesh shape (distributed)

    def opts(self) -> dict[str, Any]:
        return dict(self.options)

    def per_axis(self, rank: int) -> tuple["Candidate", ...]:
        """The axis-by-axis assignment this candidate denotes: its explicit
        ``axes``, or the same (backend, knobs) replicated across ``rank``."""
        if self.axes:
            if len(self.axes) != rank:
                raise ValueError(
                    f"candidate assigns {len(self.axes)} axes to a rank-"
                    f"{rank} problem: {self.key()}")
            return self.axes
        return (Candidate(self.backend, self.options),) * rank

    def key(self) -> str:
        if self.axes:
            return "nd[" + ";".join(a.key() for a in self.axes) + "]"
        base = self.backend
        if self.mesh:
            base += "[" + "x".join(str(s) for s in self.mesh) + "]"
        o = ",".join(f"{k}={v}" for k, v in self.options)
        return f"{base}({o})" if o else base


@dataclass
class Plan:
    problem: Problem
    candidate: Candidate
    rigor: PlanRigor
    plan_time_ms: float = 0.0
    measured_ms: dict[str, float] = field(default_factory=dict)  # per-candidate timings
    fallbacks: tuple[str, ...] = ()   # candidate keys demoted before this one


# ---------------------------------------------------------------------------
# Backend quarantine: circuit breaker over (backend, problem-class) pairs
# ---------------------------------------------------------------------------
def problem_class(problem: Problem) -> str:
    """The quarantine granularity: a backend that fails for one oddshape
    rank-2 problem is suspect for every oddshape rank-2 problem, but a
    powerof2 rank-1 success says nothing about either."""
    return f"{classify(problem.extents)}|r{problem.rank}"


def breaker_key(backend: str, problem: Problem) -> str:
    return f"{backend}|{problem_class(problem)}"


class CircuitBreaker:
    """Quarantine for (backend, problem-class) pairs that keep failing.

    Classic three-state breaker, keyed by :func:`breaker_key`:

      closed     pair is healthy; every attempt allowed
      open       ``threshold`` consecutive failures seen — attempts denied
                 until ``cooldown_s`` elapses
      half_open  cooldown elapsed; exactly ONE probe attempt is allowed
                 through.  Success re-closes the breaker, failure re-opens
                 it (and restarts the cooldown).  If the probe never
                 resolves (its thread died), a fresh probe is allowed after
                 another cooldown, so a lost probe can't wedge the pair
                 open forever.

    Thread-safe: all transitions happen under one lock, and the totals
    (``failures``/``successes``) are exact counts of the record calls —
    the invariant the threaded hammer test pins.  ``clock`` is injectable
    for deterministic tests.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        if threshold < 1:
            raise ValueError(f"breaker threshold must be >= 1: {threshold}")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._entries: dict[str, dict] = {}

    def _entry(self, key: str) -> dict:
        e = self._entries.get(key)
        if e is None:
            e = self._entries[key] = {
                "state": self.CLOSED, "consecutive": 0, "failures": 0,
                "successes": 0, "opens": 0, "opened_at": 0.0,
                "probe_at": None}
        return e

    def allows(self, key: str) -> bool:
        """May the caller *attempt* this pair right now?  Claims the
        half-open probe slot when it grants one — call only when about to
        actually try (use :meth:`available` for side-effect-free checks)."""
        now = self._clock()
        with self._lock:
            e = self._entry(key)
            if e["state"] == self.CLOSED:
                return True
            if e["state"] == self.OPEN:
                if now - e["opened_at"] < self.cooldown_s:
                    return False
                e["state"] = self.HALF_OPEN
                e["probe_at"] = now
                return True       # the cooldown-expiry probe
            # HALF_OPEN: one outstanding probe at a time
            if e["probe_at"] is not None \
                    and now - e["probe_at"] < self.cooldown_s:
                return False
            e["probe_at"] = now   # previous probe was lost; allow another
            return True

    def available(self, key: str) -> bool:
        """Side-effect-free: would an attempt plausibly be allowed?"""
        with self._lock:
            e = self._entries.get(key)
            if e is None or e["state"] != self.OPEN:
                return True
            return self._clock() - e["opened_at"] >= self.cooldown_s

    def record_failure(self, key: str) -> str:
        """Count a failure; returns the pair's new state (``'open'`` means
        this failure tripped — or re-tripped — the quarantine)."""
        with self._lock:
            e = self._entry(key)
            e["failures"] += 1
            e["consecutive"] += 1
            if e["state"] == self.HALF_OPEN \
                    or e["consecutive"] >= self.threshold:
                if e["state"] != self.OPEN:
                    e["opens"] += 1
                e["state"] = self.OPEN
                e["opened_at"] = self._clock()
                e["probe_at"] = None
            return e["state"]

    def record_success(self, key: str) -> str:
        with self._lock:
            e = self._entry(key)
            e["successes"] += 1
            e["consecutive"] = 0
            e["state"] = self.CLOSED
            e["probe_at"] = None
            return e["state"]

    def state(self, key: str) -> str:
        with self._lock:
            e = self._entries.get(key)
            return e["state"] if e else self.CLOSED

    def snapshot(self) -> dict:
        with self._lock:
            return {k: {"state": e["state"], "failures": e["failures"],
                        "successes": e["successes"], "opens": e["opens"]}
                    for k, e in self._entries.items()}


# ---------------------------------------------------------------------------
# Plan/executable cache (engine layer 2)
# ---------------------------------------------------------------------------
@dataclass
class PlanCacheStats:
    """Cold/warm accounting: misses pay the measured compile (cold) cost,
    hits dispatch the memoized executable (warm)."""

    hits: int = 0
    misses: int = 0
    cold_ms: float = 0.0   # total time spent building on misses

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "cold_ms": self.cold_ms}


class PlanCache:
    """Memoizes lowered/compiled executables and measured plan selections.

    Keys include the *device kind* (an executable compiled for one device
    kind must never serve another), the problem signature (extents,
    precision, kind, batch), the candidate (backend + knobs), and the
    transform direction.  Without the cache every repetition re-lowers and
    re-compiles (the honest per-run planning measurement of paper Figs. 4-5);
    with it, the first run to need an executable — possibly a warmup, whose
    cold-compile ops are then emitted with a negative run index — pays the
    measured cold compile, and warm repetitions reuse the executable.  Both
    quantities stay measured, and result rows carry a ``plan_cache``
    hit/miss marker so they remain distinguishable downstream.

    Lookups are **concurrency-safe**: the maps are guarded by a lock and
    builds are single-flight — when several serving workers race on the same
    cold key, exactly one runs ``build`` while the rest wait on its in-flight
    marker and then take the hit path, so ``misses`` always equals the number
    of distinct keys built and ``hits + misses`` the number of lookups (the
    invariant the threaded hammer test pins).
    """

    def __init__(self) -> None:
        self._execs: dict[str, Any] = {}
        self._plans: dict[str, Any] = {}
        self._lock = threading.Lock()
        self._inflight: dict[str, threading.Event] = {}
        self.stats = PlanCacheStats()

    def _single_flight(self, table: dict, kind: str, key: str,
                       build: Callable[[], Any],
                       count_stats: bool) -> tuple[Any, str, float]:
        """One builder per (kind, key); racing threads wait and read the
        published value.  The lock is dropped while ``build`` runs (compiles
        can take seconds) and re-taken to publish.  ``count_stats`` keeps the
        hit/miss accounting an executable-cache quantity, as before."""
        flight_key = f"{kind}|{key}"
        while True:
            with self._lock:
                if key in table:
                    if count_stats:
                        self.stats.hits += 1
                    return table[key], "hit", 0.0
                ev = self._inflight.get(flight_key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight[flight_key] = ev
                    break           # we are the builder
            ev.wait()               # another thread is building this key
        t0 = time.perf_counter()
        try:
            built = build()
            ms = (time.perf_counter() - t0) * 1e3
            with self._lock:
                table[key] = built
                if count_stats:
                    self.stats.misses += 1
                    self.stats.cold_ms += ms
            return built, "miss", ms
        finally:
            with self._lock:
                self._inflight.pop(flight_key, None)
            ev.set()

    # --- keys -------------------------------------------------------------
    @staticmethod
    def executable_key(device_kind: str, problem: Problem,
                       candidate: "Candidate | str", direction: str) -> str:
        ck = candidate.key() if isinstance(candidate, Candidate) else str(candidate)
        return f"exec|{device_kind}|{problem.signature()}|{ck}|{direction}"

    @staticmethod
    def plan_key(device_kind: str, problem: Problem, rigor: "PlanRigor",
                 scope: str = "") -> str:
        return f"plan|{device_kind}|{problem.signature()}|{rigor.value}|{scope}"

    # --- lookups ----------------------------------------------------------
    def executable(self, key: str, build: Callable[[], Any]
                   ) -> tuple[Any, str, float]:
        """Return ``(executable, 'hit'|'miss', elapsed_ms)``.

        ``build`` runs only on a miss; its wall time is the measured cold
        compile cost.
        """
        return self._single_flight(self._execs, "exec", key, build,
                                   count_stats=True)

    def plan(self, key: str, make: Callable[[], Any]) -> tuple[Any, str]:
        """Memoized plan selection (candidate sweeps run at most once per
        key — a MEASURE sweep over repeated repetitions stops re-compiling
        every candidate).  ``None`` results (wisdom misses) are cached too:
        a deterministic miss stays a miss."""
        plan, event, _ = self._single_flight(self._plans, "plan", key, make,
                                             count_stats=False)
        return plan, event

    def __len__(self) -> int:
        with self._lock:
            return len(self._execs)


def cached_build(plan_cache: "PlanCache | None", events: dict, op_name: str,
                 key: str, build: Callable[[], Any]):
    """Memoize-or-build an executable, recording the hit/miss event for the
    result rows.  With no cache attached this is just ``build()`` — the
    per-run recompile measurement."""
    if plan_cache is None:
        return build()
    compiled, event, _ = plan_cache.executable(key, build)
    events[op_name] = event
    return compiled


def executable_bytes(compiled) -> int:
    """Bytes attributable to a compiled executable (plan size analogue)."""
    try:
        ma = compiled.memory_analysis()
        return int(getattr(ma, "temp_size_in_bytes", 0) +
                   getattr(ma, "generated_code_size_in_bytes", 0))
    except Exception:
        return 0


def _pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def _smooth(n: int) -> bool:
    return n >= 1 and _factors_only(n, (2, 3, 5, 7, 11, 13))


def _smooth7(n: int) -> bool:
    """2^a*3^b*5^c*7^d — the extents the mixed-radix Stockham kernel
    factors (paper's powerof2 + radix357 classes; shares the extent
    classifier's ``_factors_only``)."""
    return n >= 1 and _factors_only(n, (2, 3, 5, 7))


#: Feasibility caps for the fused kernel paths (see the kernel modules).
FOURSTEP_PALLAS_MAX_N = 128 * 128        # one fused four-step kernel pass
STOCKHAM_PALLAS_MAX_N = 1 << 20          # ops.MAX_N: single-kernel hard cap
STOCKHAM_PALLAS_VMEM_N = 1 << 15         # fits a useful batch tile in VMEM
SIXSTEP_MIN_N, SIXSTEP_MAX_N = 4, 1 << 24
FFT2_PALLAS_MAX_ELEMS = 1 << 18          # fft2 ops.MAX_ELEMS: hard cap
FFT2_PALLAS_VMEM_ELEMS = 1 << 16         # n1*n2 tile fits the VMEM budget
#: Largest chirp-Z length whose padded transform (next_pow2(2n-1)) still
#: fits the six-step composition's SIXSTEP_MAX_N = 2^24.
CHIRPZ_PALLAS_MAX_N = 1 << 23

#: Whole-transform backends: one engine call covers every axis, so the
#: separable path's swapaxes traffic never happens.
FUSED_ND = ("xla", "fft2_pallas")

#: Every backend the planner knows, in enumeration (preference-tie) order.
BACKENDS = ("xla", "stockham", "fourstep", "dft", "fourstep_pallas",
            "stockham_pallas", "sixstep", "fft2_pallas", "chirpz_pallas",
            "bluestein")

#: Mesh-sharded decompositions (fft/distributed.py) — enumerated only when
#: an active mesh is installed (launch.mesh.set_active_mesh), and kept out
#: of :data:`BACKENDS` so single-device planning and the conformance
#: support matrix are byte-identical without one.
DIST_BACKENDS = ("dist1d", "slab", "pencil")

#: Interconnect cost of one all-to-all'd byte relative to one HBM byte —
#: ICI/NVLink-class fabrics move bytes at a small single-digit multiple of
#: HBM cost; this single coefficient is what lets ESTIMATE rank "one
#: device, one HBM touch" against "P devices, two all-to-alls" honestly.
DIST_LINK_COST = 4.0
#: Fixed per-collective charge (latency, layout fix-ups) expressed in
#: equivalent HBM bytes — keeps tiny transforms from sharding: below ~1 MiB
#: the collective's constant cost dwarfs any compute win.
DIST_A2A_LATENCY_BYTES = float(1 << 20)
#: all_to_alls per decomposition in the default TRANSPOSED-output layout.
DIST_A2A_COUNT = {"dist1d": 2, "slab": 1, "pencil": 2}
#: extra all_to_alls for natural-order output.
DIST_NATURAL_EXTRA = {"dist1d": 1, "slab": 1, "pencil": 2}


def axis_feasible(backend: str, n: int) -> bool:
    """Can ``backend`` transform one batched axis of extent ``n``?  This is
    the engine-level contract: the length the cfft actually receives — n//2
    for the packed r2c innermost axis of an EVEN real extent, the full
    length for an odd one, see ``axis_engine_n``.  The chirp backends are
    the any-length catch-all, so odd-length real kinds explicitly route to
    the full-complex chirp path rather than a meaningless packed half."""
    if backend in ("xla", "bluestein"):
        return True
    if backend == "stockham":
        return _pow2(n)
    if backend == "fourstep":
        return _smooth(n)
    if backend == "dft":
        return n <= 128
    if backend == "fourstep_pallas":
        return _kernel_factorable(n)
    if backend == "stockham_pallas":
        return _smooth7(n) and n <= STOCKHAM_PALLAS_MAX_N
    if backend == "chirpz_pallas":
        # any length whose padded pow2 transform the fused engines cover
        return 1 <= n <= CHIRPZ_PALLAS_MAX_N
    if backend == "sixstep":
        # the engine falls back to the fused Stockham kernel below
        # SIXSTEP_MIN_N (packed-real halves can land there)
        return _pow2(n) and n <= SIXSTEP_MAX_N and n >= 2
    return False


def axis_engine_n(problem: Problem, axis: int) -> int:
    """Extent the 1-D engine actually transforms along ``axis``.

    Real kinds take the packed half-length path on the innermost axis (the
    cfft runs at n//2 for even n; odd lengths pay the full complex
    transform), so feasibility and the cost model must look at that length,
    not the nominal extent."""
    n = problem.extents[axis]
    if problem.complex_input or axis < problem.rank - 1:
        return n
    return n // 2 if n % 2 == 0 and n > 1 else n


def fft2_feasible(problem: Problem) -> bool:
    """The fused rank-2 kernel holds the whole n1 x n2 tile in VMEM."""
    exts = problem.extents
    return (len(exts) == 2 and all(_pow2(v) for v in exts)
            and exts[0] * exts[1] <= FFT2_PALLAS_MAX_ELEMS
            and (problem.complex_input or exts[-1] % 2 == 0))


def backend_supports(backend: str, problem: Problem) -> bool:
    """Single source of truth for the support matrix: candidates(), the
    conformance matrix, and the README table all consult this."""
    if backend == "fft2_pallas":
        return fft2_feasible(problem)
    if backend == "xla":
        return True
    if backend == "sixstep":
        # offered only where the six-step composition is the real algorithm
        if not all(_pow2(v) and SIXSTEP_MIN_N <= v <= SIXSTEP_MAX_N
                   for v in problem.extents):
            return False
    return all(axis_feasible(backend, axis_engine_n(problem, i))
               for i in range(problem.rank))


# ---------------------------------------------------------------------------
# Distributed candidates: slab / pencil / dist1d over the active mesh
# ---------------------------------------------------------------------------
def _mesh_devices(mesh) -> int:
    """Device count of a mesh (or mesh-shaped stand-in with ``.size``)."""
    return int(mesh.size)


def dist_supports(backend: str, problem: Problem,
                  mesh_shape: Sequence[int]) -> bool:
    """Can ``backend`` decompose ``problem`` over a mesh of ``mesh_shape``?

    Distribution is complex-kinds-only: the packed r2c half-spectrum extents
    (n//2, n//2+1) break the tiled all_to_all divisibility that every
    rotation depends on.  ``dist1d`` additionally needs batch == 1 — its
    matrix view consumes the whole axis.
    """
    if not problem.complex_input:
        return False
    from repro.fft import distributed as dist

    shape = tuple(int(s) for s in mesh_shape)
    p = 1
    for s in shape:
        p *= s
    if p < 2:
        return False   # one device: decomposition is pure overhead
    if backend == "dist1d":
        return (problem.rank == 1 and problem.batch == 1
                and dist.can_shard_1d(problem.extents[0], p))
    if backend == "slab":
        return (len(shape) == 1 and problem.rank in (2, 3)
                and dist.slab_divisible(problem.extents, p))
    if backend == "pencil":
        return (len(shape) == 2 and problem.rank == 3
                and dist.pencil_divisible(problem.extents, *shape))
    return False


def _pencil_mesh_shapes(p: int, patient: bool = False) -> list[tuple[int, int]]:
    """(Pr, Pc) factorizations of ``p``: the most balanced one by default,
    widened to (at most four) alternates under PATIENT."""
    shapes = [(pr, p // pr) for pr in range(2, int(p ** 0.5) + 1)
              if p % pr == 0]
    shapes.sort(key=lambda s: s[1] - s[0])
    if not patient:
        return shapes[:1]
    out = list(shapes)
    out += [(pc, pr) for pr, pc in shapes if pr != pc]
    return out[:4]


def dist_local_lengths(problem: Problem, cand: Candidate
                       ) -> list[tuple[int, float]]:
    """The local sub-transform lengths a distributed candidate runs per
    shard, each with the swapaxes passes its position costs (+2 when the
    transform axis is not innermost in the local block, like the separable
    single-device path; 0 for the innermost axis)."""
    p = 1
    for s in cand.mesh:
        p *= s
    if cand.backend == "dist1d":
        from repro.fft.distributed import _choose_1d_factors

        n1, n2 = _choose_1d_factors(problem.extents[0], p)
        return [(n1, 2.0), (n2, 0.0)]
    # slab / pencil transform every global axis at its full extent locally
    return [(n, 0.0 if i == problem.rank - 1 else 2.0)
            for i, n in enumerate(problem.extents)]


def dist_local_engine(n: int) -> str:
    """The separable backend a distributed plan runs locally at length
    ``n`` when no explicit ``local`` knob forces one: fewest modeled HBM
    passes, ties to the earlier (more conservative) BACKENDS entry."""
    best, best_p = "fourstep", float("inf")
    for b in BACKENDS:
        if b in FUSED_ND:
            continue
        if axis_feasible(b, n):
            passes = hbm_passes(b, n)
            if passes < best_p:
                best, best_p = b, passes
    return best


def _dist_candidates(problem: Problem, mesh, patient: bool
                     ) -> list[Candidate]:
    """Sharded decompositions feasible for ``problem`` over ``mesh``.

    PATIENT widens with the decomposition x local-engine cross: alternate
    pencil mesh factorizations, and each feasible local engine forced via
    the ``local`` knob (the distributed analogue of the kernel tile
    sweeps)."""
    p = _mesh_devices(mesh)
    if p < 2:
        return []
    out: list[Candidate] = []
    if dist_supports("dist1d", problem, (p,)):
        out.append(Candidate("dist1d", mesh=(p,)))
    if dist_supports("slab", problem, (p,)):
        out.append(Candidate("slab", mesh=(p,)))
    for shape in _pencil_mesh_shapes(p, patient):
        if dist_supports("pencil", problem, shape):
            out.append(Candidate("pencil", mesh=shape))
    if patient:
        extra = []
        for c in out:
            lengths = [n for n, _ in dist_local_lengths(problem, c)]
            default = {dist_local_engine(n) for n in lengths}
            locals_ = [b for b in BACKENDS
                       if b not in FUSED_ND and b not in default
                       and all(axis_feasible(b, n) for n in lengths)
                       and all(hbm_passes(b, n) != float("inf")
                               for n in lengths)]
            locals_.sort(key=lambda b: sum(hbm_passes(b, n) for n in lengths))
            extra += [Candidate(c.backend, (("local", b),), mesh=c.mesh)
                      for b in locals_[:2]]
        out += extra
    return out


def candidates(problem: Problem, patient: bool = False,
               mesh=None) -> list[Candidate]:
    """Enumerate feasible (backend, knob) combinations for a problem.

    The space is ND-native: besides homogeneous candidates (one backend for
    every axis) it holds the whole-transform backends (``xla``, and the
    fused rank-2 ``fft2_pallas`` kernel) and **per-axis assignments**
    (``Candidate.axes``) mixing backends across axes, pruned by the
    bytes-moved model.  ``patient=True`` widens the space with the fused
    kernels' tunable knobs — batch tiles, the (mixed-)radix schedule, the
    six-step n1*n2 split, the fft2 radix, the chirp-Z padded-engine choice
    — the FFTW_PATIENT analogue of searching algorithm *and* implementation
    parameters.

    ``mesh`` gates the distributed decompositions: ``None`` consults the
    active mesh (``launch.mesh.get_active_mesh``), which is itself None
    unless a launcher installed one — so single-process planning never
    offers a multi-device plan.
    """
    exts = problem.extents
    out: list[Candidate] = [Candidate("xla")]
    # every backend — the chirp catch-alls included — goes through
    # backend_supports, which evaluates feasibility at the ENGINE length:
    # odd-length real kinds route to the full-complex chirp path (engine
    # length n, not the even-only packed n//2) and caps apply there
    for b in BACKENDS[1:]:
        if backend_supports(b, problem):
            out.append(Candidate(b))
    if problem.rank >= 2:
        out += _mixed_candidates(problem, limit=12 if patient else 6)
    if mesh is None:
        from repro.launch.mesh import get_active_mesh

        mesh = get_active_mesh()
    if mesh is not None:
        out += _dist_candidates(problem, mesh, patient)
    if patient:
        extra = []
        for c in out:
            if c.options or c.axes:
                continue
            if c.backend == "fourstep_pallas":
                for tb in (4, 8, 16):
                    extra.append(Candidate("fourstep_pallas", (("tile_b", tb),)))
            elif c.backend == "stockham_pallas":
                for tb in (4, 16):
                    for radix in (4, 8):
                        extra.append(Candidate(
                            "stockham_pallas",
                            (("radix", radix), ("tile_b", tb))))
            elif c.backend == "sixstep":
                for n1 in _sixstep_splits(exts[-1]):
                    extra.append(Candidate("sixstep", (("split_n1", n1),)))
                extra.append(Candidate("sixstep", (("tile_b", 16),)))
            elif c.backend == "chirpz_pallas":
                # a forced engine applies to EVERY axis the separable path
                # transforms, so gate each knob on every axis's engine
                # length (_sixstep_splits rule: only emit knobs the engine
                # actually honors, never ones that raise at build time)
                eng_ns = [axis_engine_n(problem, i)
                          for i in range(problem.rank)]
                engines = []
                if all(next_smooth(2 * v - 1) <= STOCKHAM_PALLAS_MAX_N
                       for v in eng_ns):
                    engines.append("stockham_pallas")  # smooth-m padding
                if all(SIXSTEP_MIN_N <= _next_pow2(2 * v - 1)
                       <= SIXSTEP_MAX_N for v in eng_ns):
                    engines.append("sixstep")
                for eng in engines:
                    extra.append(Candidate("chirpz_pallas",
                                           (("engine", eng),)))
                extra.append(Candidate("chirpz_pallas", (("tile_b", 16),)))
            elif c.backend == "fft2_pallas":
                for tb in (2, 8):
                    for radix in (4, 8):
                        extra.append(Candidate(
                            "fft2_pallas",
                            (("radix", radix), ("tile_b", tb))))
        out += extra
    return out


def _mixed_candidates(problem: Problem, limit: int) -> list[Candidate]:
    """Per-axis backend assignments, pruned by the bytes-moved model.

    For each axis, rank the separable backends by modeled engine passes at
    that axis's (packed) extent and keep the best two; the cross product —
    minus homogeneous assignments, which are already enumerated — is then
    re-ranked by the full ND model and truncated to ``limit``.  This is how
    the planner expresses e.g. 'dft on the tiny outer axis, fused Stockham
    on the long inner one' without sweeping every combination."""
    import itertools

    per_axis: list[list[str]] = []
    for i in range(problem.rank):
        n_eng = axis_engine_n(problem, i)
        feas = [b for b in BACKENDS
                if b not in FUSED_ND and axis_feasible(b, n_eng)]
        feas.sort(key=lambda b: hbm_passes(b, n_eng))
        per_axis.append(feas[:2])
    scored = []
    for combo in itertools.product(*per_axis):
        if len(set(combo)) == 1:
            continue  # homogeneous: already in the candidate list
        cand = Candidate("nd", axes=tuple(Candidate(b) for b in combo))
        cost = estimate_bytes_moved(problem, cand)
        if cost != float("inf"):
            scored.append((cost, cand))
    scored.sort(key=lambda t: t[0])
    return [cand for _, cand in scored[:limit]]


def _sixstep_splits(n: int) -> list[int]:
    """Alternative n = n1*n2 residual splits for the PATIENT sweep: the
    balanced split and a residual-heavy one, besides the default.  Both
    sixstep.choose_split constraints apply — n1 <= 2^10 (the residual
    VMEM cap) and n2 <= 2^14 — so every emitted knob is one the engine
    actually honors rather than silently replacing with the default."""
    if not _pow2(n) or n < SIXSTEP_MIN_N:
        return []
    k = n.bit_length() - 1
    default_k1 = k - min(14, k - 1)
    opts = {max(1, k // 2), max(1, min(10, k - 1))} - {default_k1}
    return sorted(1 << k1 for k1 in opts
                  if 1 <= k1 <= 10 and k - k1 <= 14)


def _kernel_factorable(n: int) -> bool:
    """n = n1*n2 with both <= 128 (single fused fft4step kernel pass)."""
    if n > FOURSTEP_PALLAS_MAX_N:
        return False
    for n1 in range(min(128, n), 0, -1):
        if n % n1 == 0 and n // n1 <= 128:
            return True
    return False


# ---------------------------------------------------------------------------
# ESTIMATE cost model: modeled HBM traffic per backend
# ---------------------------------------------------------------------------
def hbm_passes(backend: str, n: int) -> float:
    """Modeled HBM round-trips of the whole signal for one length-n
    transform (the quantity that dominates above the paper's ~1 MiB
    boundary).  ``inf`` marks an infeasible / VMEM-overflowing choice.

    The fused kernels are the reason this model exists: stockham_pallas and
    fourstep_pallas read and write the signal exactly once, the six-step
    composition a small constant (2 kernel passes + 3 transposes), while
    the staged jnp Stockham pays one pass per radix-2 stage.
    """
    inf = float("inf")
    if backend == "xla":
        if _smooth7(n):
            return 2.0  # vendor path: multi-stage but heavily fused
        # non-smooth lengths send the vendor library down its own chirp
        # fallback: ~3 fused transforms at the padded pow2 length
        return 6.0 * (_next_pow2(2 * n - 1) / n)
    if backend == "stockham":
        if not _pow2(n):
            return inf
        return float(max(1, n.bit_length() - 1))   # one pass per stage
    if backend == "fourstep":
        if not _smooth(n):
            return inf
        levels = 1
        m = n
        while m > 128:
            m = -(-m // 128)
            levels += 1
        return 2.0 * levels
    if backend == "dft":
        return 1.0 if n <= 128 else inf
    if backend == "fourstep_pallas":
        return 1.0 if _kernel_factorable(n) else inf
    if backend == "stockham_pallas":
        # any 7-smooth length is one mixed-radix kernel pass; beyond the
        # VMEM tile budget the kernel can't hold a batch row
        return 1.0 if _smooth7(n) and n <= STOCKHAM_PALLAS_VMEM_N else inf
    if backend == "sixstep":
        if _pow2(n) and SIXSTEP_MIN_N <= n <= SIXSTEP_MAX_N:
            return 5.0  # 2 fused kernel passes + 3 transpose passes
        return inf
    if backend == "chirpz_pallas":
        if not 1 <= n <= CHIRPZ_PALLAS_MAX_N:
            return inf
        # two fused padded transforms + chirp mul, filter mul, final chirp;
        # the filter spectrum is host-cached so no third transform runs.
        # The mixed-radix kernel convolves at the smallest 7-SMOOTH
        # m >= 2n-1 (often ~2x tighter than pow2); sixstep needs pow2.
        ms = next_smooth(2 * n - 1)
        if ms <= STOCKHAM_PALLAS_VMEM_N:
            return 5.0 * (ms / n)                 # 2*1 engine passes + 3
        return 13.0 * (_next_pow2(2 * n - 1) / n)  # 2*5 sixstep passes + 3
    if backend == "bluestein":
        m = 1
        while m < 2 * n - 1:
            m *= 2
        # 3 staged Stockham transforms of padded length m, + chirp setup
        return (3.0 * max(1, m.bit_length() - 1) + 2.0) * (m / n)
    return inf


def _axis_elems(problem: Problem, axis: int) -> int:
    """Complex elements the transform carries while working on ``axis``.

    Complex kinds move the whole signal on every axis.  Real kinds run the
    innermost axis packed at half the elements (even n) and every outer
    axis on the half-spectrum — n_last//2 + 1 bins along the last axis —
    which is the traffic halving the paper's Fig. 8a measures."""
    if problem.complex_input:
        return problem.n_elems
    n_last = problem.extents[-1]
    rows = problem.n_elems // n_last
    if axis == problem.rank - 1:
        return rows * (n_last // 2) if n_last % 2 == 0 else problem.n_elems
    return rows * (n_last // 2 + 1)


def estimate_bytes_moved(problem: Problem, cand: Candidate) -> float:
    """Modeled HBM bytes for the full nd transform under ``cand``.

    Whole-transform backends (:data:`FUSED_ND`) move the signal their fixed
    number of passes with **no** transpose traffic.  Separable assignments
    charge, per axis: the engine's ``hbm_passes`` at the extent the engine
    actually sees (packed half-length on a real innermost axis), *plus* the
    two swapaxes passes ``nd._apply_last`` really performs for every
    non-innermost axis — zero for the innermost one.  Each pass reads and
    writes the live elements once (see :func:`_axis_elems` for the r2c
    half-spectrum sizes).  ``inf`` marks an infeasible assignment.

    Distributed candidates (:data:`DIST_BACKENDS`) model the **per-device**
    cost — what bounds wall time when every device works in parallel: the
    local per-axis engine passes on the 1/P-sized shard, plus the
    interconnect term — each all_to_all moves the device's whole block once,
    charged at :data:`DIST_LINK_COST` HBM-equivalent bytes per byte plus the
    fixed :data:`DIST_A2A_LATENCY_BYTES` per collective.  That latency floor
    is why small transforms never shard and the single-/multi-device
    crossover sits where it does.
    """
    complex_itemsize = 16 if problem.precision == "double" else 8
    if cand.backend in DIST_BACKENDS:
        p = 1
        for s in cand.mesh:
            p *= s
        if not dist_supports(cand.backend, problem, cand.mesh):
            return float("inf")
        opts = cand.opts()
        forced = opts.get("local")
        passes = 0.0
        for n_g, swaps in dist_local_lengths(problem, cand):
            b = forced or dist_local_engine(n_g)
            hp = hbm_passes(b, n_g)
            if hp == float("inf") or not axis_feasible(b, n_g):
                return float("inf")
            passes += hp + swaps
        if cand.backend == "dist1d":
            passes += 1.0   # the per-shard twiddle multiply
        dev_bytes = (problem.n_elems / p) * complex_itemsize
        n_a2a = DIST_A2A_COUNT[cand.backend]
        if opts.get("natural"):
            n_a2a += DIST_NATURAL_EXTRA[cand.backend]
        return (passes * 2.0 * dev_bytes
                + n_a2a * (dev_bytes * DIST_LINK_COST
                           + DIST_A2A_LATENCY_BYTES))
    if cand.backend in FUSED_ND:
        elems = _axis_elems(problem, problem.rank - 1)
        if cand.backend == "xla":
            # vendor path: 2 fused passes on smooth extents; a non-smooth
            # axis drags the whole transform into its chirp fallback
            passes = max(hbm_passes("xla", axis_engine_n(problem, i))
                         for i in range(problem.rank))
        else:              # fft2_pallas: one read + one write of the tile
            # the VMEM budget binds the tile the kernel actually holds:
            # real kinds run packed, so the inner extent halves (even n)
            tile_elems = (problem.extents[0] *
                          axis_engine_n(problem, problem.rank - 1))
            feasible = (fft2_feasible(problem)
                        and tile_elems <= FFT2_PALLAS_VMEM_ELEMS)
            passes = 1.0 if feasible else float("inf")
        return passes * 2.0 * elems * complex_itemsize
    total = 0.0
    for axis, ax_cand in enumerate(cand.per_axis(problem.rank)):
        passes = hbm_passes(ax_cand.backend, axis_engine_n(problem, axis))
        if axis != problem.rank - 1:
            passes += 2.0   # swapaxes in + out around the engine call
        total += passes * 2.0 * _axis_elems(problem, axis) * complex_itemsize
    return total


def estimate_choice(problem: Problem) -> Candidate:
    """The ESTIMATE heuristic: a static bytes-moved cost model.

    Mirrors fftw's 'probably sub-optimal but instant' behavior: tiny rank-1
    problems go straight to the single-matmul dft kernel (launch overhead
    dominates traffic there); everything else takes the feasible candidate
    that moves the fewest modeled HBM bytes (ties keep the earlier, more
    conservative entry — the vendor path is enumerated first, per-axis
    mixed assignments last).
    """
    cands = candidates(problem)
    by_backend = {c.backend: c for c in cands}
    n_inner = problem.extents[-1]
    if "dft" in by_backend and n_inner <= 128 and problem.rank == 1:
        return by_backend["dft"]
    best, best_cost = None, float("inf")
    for c in cands:
        cost = estimate_bytes_moved(problem, c)
        if cost < best_cost:
            best, best_cost = c, cost
    if best is not None:
        return best
    return by_backend.get("xla", by_backend["bluestein"])


def fallback_chain(problem: Problem, patient: bool = False,
                   mesh=None) -> list[Candidate]:
    """The ordered degradation path: ESTIMATE's pick first (its dft pin for
    tiny rank-1 problems included), then every other feasible candidate by
    ascending modeled cost, with a plain ``xla`` candidate guaranteed
    present — the always-feasible terminal fallback.  Pure ordering: the
    walkers (:func:`make_plan`'s fault-tolerant mode, the serve engine)
    apply wisdom-demotion and circuit-breaker filtering at try time."""
    cands = candidates(problem, patient=patient, mesh=mesh)
    scored = [(estimate_bytes_moved(problem, c), i, c)
              for i, c in enumerate(cands)]
    ranked = [c for cost, _, c in sorted(scored, key=lambda t: t[:2])
              if cost != float("inf")]
    top = estimate_choice(problem)
    chain = [top] + [c for c in ranked if c.key() != top.key()]
    if not any(c.backend == "xla" and not c.axes for c in chain):
        chain.append(Candidate("xla"))
    return chain


def probe_finite(fn: Callable, problem: Problem) -> None:
    """Cheap output-finiteness probe: push one all-ones batch through a
    freshly built executable and reject it on any non-finite output — the
    'compiles fine, computes garbage' failure mode a build error misses."""
    x = np.ones((problem.batch, *problem.extents), dtype=problem.real_dtype)
    if problem.complex_input:
        x = x.astype(problem.input_dtype)
    out = np.asarray(fn(x))
    if not np.isfinite(out).all():
        raise RuntimeError(
            f"finiteness probe failed for {problem.signature()}: "
            f"executable produced non-finite output")


def measure_plan(problem: Problem, build: Callable[[Candidate], Callable],
                 cands: Sequence[Candidate], reps: int = 3) -> tuple[Candidate, dict[str, float]]:
    """MEASURE: compile + run each candidate, return fastest + timing table."""
    import jax

    timings: dict[str, float] = {}
    rng = np.random.default_rng(0)
    x = rng.standard_normal((problem.batch, *problem.extents)).astype(problem.real_dtype)
    if problem.complex_input:
        x = x.astype(problem.input_dtype)
    xd = jax.device_put(x)
    for cand in cands:
        try:
            fn = build(cand)
            fn(xd)  # compile + warmup
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(xd))
                best = min(best, (time.perf_counter() - t0) * 1e3)
            timings[cand.key()] = best
        except Exception as e:  # infeasible candidate: record, keep going
            timings[cand.key()] = float("nan")
    feasible = {k: v for k, v in timings.items() if v == v}
    if not feasible:
        raise RuntimeError(f"no feasible plan for {problem.signature()}")
    best_key = min(feasible, key=feasible.get)
    best_cand = next(c for c in cands if c.key() == best_key)
    return best_cand, timings


def _demoted_backends(wisdom, problem: Problem) -> frozenset:
    """Backends wisdom has quarantined for this problem-class (empty when
    the wisdom store is absent or predates demotion records)."""
    if wisdom is None:
        return frozenset()
    demoted = getattr(wisdom, "demoted", None)
    return demoted(problem) if callable(demoted) else frozenset()


def _fallback_plan(problem: Problem, rigor: PlanRigor,
                   build: Callable[[Candidate], Callable], wisdom,
                   breaker: CircuitBreaker, probe: bool, t0: float,
                   demoted: frozenset) -> Plan:
    """Fault-tolerant planning: walk the cost-ordered fallback chain,
    demoting past candidates that fail at build (or at the optional
    finiteness probe), with circuit-breaker bookkeeping per (backend,
    problem-class) pair.  A demotion that OPENS the breaker is persisted to
    wisdom so warm sessions skip the known-bad pick outright.  The terminal
    candidate — by construction a plain ``xla`` is always in the chain —
    is tried regardless of quarantine state."""
    chain = fallback_chain(problem, patient=(rigor is PlanRigor.PATIENT))
    fallbacks: list[str] = []
    last_err: Exception | None = None
    for i, cand in enumerate(chain):
        terminal = i == len(chain) - 1
        is_xla = cand.backend == "xla" and not cand.axes
        if not terminal and not is_xla:
            if cand.backend in demoted:
                fallbacks.append(cand.key())
                continue
            if not breaker.allows(breaker_key(cand.backend, problem)):
                fallbacks.append(cand.key())
                continue
        try:
            fn = build(cand)
            if probe:
                probe_finite(fn, problem)
        except Exception as e:
            last_err = e
            state = breaker.record_failure(breaker_key(cand.backend, problem))
            if wisdom is not None and not is_xla \
                    and state == CircuitBreaker.OPEN:
                wisdom.record_demotion(problem, cand.backend)
            fallbacks.append(cand.key())
            continue
        breaker.record_success(breaker_key(cand.backend, problem))
        return Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3,
                    fallbacks=tuple(fallbacks))
    raise RuntimeError(
        f"no feasible plan for {problem.signature()}: all {len(chain)} "
        f"candidates failed (last: {type(last_err).__name__}: {last_err})")


def make_plan(problem: Problem, rigor: PlanRigor,
              build: Callable[[Candidate], Callable] | None = None,
              wisdom=None, breaker: CircuitBreaker | None = None,
              probe: bool = False) -> Plan | None:
    """The planner. Returns None for WISDOM_ONLY misses (fftw NULL plan).

    MEASURE/PATIENT consult wisdom first, fftw-style: a persisted selection
    for this (device, problem) short-circuits the candidate sweep entirely,
    so a warm Session (or a second process sharing the wisdom file) plans in
    microseconds instead of re-compiling every candidate.

    Fault tolerance: with both ``build`` and ``breaker`` supplied, planning
    walks the :func:`fallback_chain` instead — each candidate is actually
    built (and optionally finiteness-probed with ``probe=True``) before it
    is returned, failures demote to the next candidate by modeled cost, and
    the (backend, problem-class) pair is quarantined in the breaker; see
    :func:`_fallback_plan`.  Without a breaker, behavior is unchanged except
    that wisdom-recorded demotions steer the ESTIMATE pick away from
    known-bad backends.
    """
    t0 = time.perf_counter()
    if rigor is PlanRigor.WISDOM_ONLY:
        if wisdom is None:
            return None
        cand = wisdom.lookup(problem)
        if cand is None:
            return None
        return Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3)

    demoted = _demoted_backends(wisdom, problem)
    if wisdom is not None and rigor in (PlanRigor.MEASURE, PlanRigor.PATIENT):
        cand = wisdom.lookup(problem)
        if cand is not None and cand.backend not in demoted:
            # tuned knobs persisted by an earlier sweep
            return Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3)

    if build is not None and breaker is not None:
        return _fallback_plan(problem, rigor, build, wisdom, breaker, probe,
                              t0, demoted)

    if rigor is PlanRigor.ESTIMATE or build is None:
        cand, timings = estimate_choice(problem), {}
        if cand.backend in demoted and cand.backend != "xla":
            # warm session: skip the known-bad pick without a live breaker
            for c in fallback_chain(problem):
                if c.backend == "xla" or c.backend not in demoted:
                    cand = c
                    break
    else:
        cands = candidates(problem, patient=(rigor is PlanRigor.PATIENT))
        if demoted:
            cands = [c for c in cands
                     if c.backend == "xla" or c.backend not in demoted]
        cand, timings = measure_plan(problem, build, cands)
    plan = Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3, timings)
    # persist only selections a sweep actually timed: a build-less
    # MEASURE/PATIENT call falls back to the untimed ESTIMATE pick, and
    # recording that would let the wisdom-first short-circuit lock it in
    # forever as if it had been measured
    if wisdom is not None and timings \
            and rigor in (PlanRigor.MEASURE, PlanRigor.PATIENT):
        wisdom.record(problem, cand)
    return plan
