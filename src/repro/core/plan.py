"""Plans, plan rigors, and the planner/autotuner.

fftw's planner concept (paper §2.1) mapped to JAX:

  plan          = (backend, factorization/tile knobs) + the AOT-compiled
                  executable for one Problem
  FFTW_ESTIMATE = static heuristic over the candidate space (no timing)
  FFTW_MEASURE  = compile + time every candidate, keep the fastest
  FFTW_PATIENT  = MEASURE over a widened space (kernel tile shapes too)
  FFTW_WISDOM_ONLY = look up a persisted choice; None plan if absent

Planning *time* is a first-class measurement (paper Figs. 4-5: MEASURE costs
3-4 orders of magnitude more than ESTIMATE and can exceed the transform time
by far) — the planner therefore reports plan_time_ms with every plan.
"""

from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import numpy as np

from .client import Problem


class PlanRigor(enum.Enum):
    ESTIMATE = "estimate"
    MEASURE = "measure"
    PATIENT = "patient"
    WISDOM_ONLY = "wisdom_only"


@dataclass(frozen=True)
class Candidate:
    """One point in the planner's search space."""

    backend: str                      # 'xla' | 'fourstep' | 'stockham' | 'bluestein' | 'dft'
    options: tuple[tuple[str, Any], ...] = ()

    def opts(self) -> dict[str, Any]:
        return dict(self.options)

    def key(self) -> str:
        o = ",".join(f"{k}={v}" for k, v in self.options)
        return f"{self.backend}({o})" if o else self.backend


@dataclass
class Plan:
    problem: Problem
    candidate: Candidate
    rigor: PlanRigor
    plan_time_ms: float = 0.0
    measured_ms: dict[str, float] = field(default_factory=dict)  # per-candidate timings


# ---------------------------------------------------------------------------
# Plan/executable cache (engine layer 2)
# ---------------------------------------------------------------------------
@dataclass
class PlanCacheStats:
    """Cold/warm accounting: misses pay the measured compile (cold) cost,
    hits dispatch the memoized executable (warm)."""

    hits: int = 0
    misses: int = 0
    cold_ms: float = 0.0   # total time spent building on misses

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "cold_ms": self.cold_ms}


class PlanCache:
    """Memoizes lowered/compiled executables and measured plan selections.

    Keys include the *device kind* (an executable compiled for one device
    kind must never serve another), the problem signature (extents,
    precision, kind, batch), the candidate (backend + knobs), and the
    transform direction.  Without the cache every repetition re-lowers and
    re-compiles (the honest per-run planning measurement of paper Figs. 4-5);
    with it, the first run to need an executable — possibly a warmup, whose
    cold-compile ops are then emitted with a negative run index — pays the
    measured cold compile, and warm repetitions reuse the executable.  Both
    quantities stay measured, and result rows carry a ``plan_cache``
    hit/miss marker so they remain distinguishable downstream.
    """

    def __init__(self) -> None:
        self._execs: dict[str, Any] = {}
        self._plans: dict[str, Any] = {}
        self.stats = PlanCacheStats()

    # --- keys -------------------------------------------------------------
    @staticmethod
    def executable_key(device_kind: str, problem: Problem,
                       candidate: "Candidate | str", direction: str) -> str:
        ck = candidate.key() if isinstance(candidate, Candidate) else str(candidate)
        return f"exec|{device_kind}|{problem.signature()}|{ck}|{direction}"

    @staticmethod
    def plan_key(device_kind: str, problem: Problem, rigor: "PlanRigor",
                 scope: str = "") -> str:
        return f"plan|{device_kind}|{problem.signature()}|{rigor.value}|{scope}"

    # --- lookups ----------------------------------------------------------
    def executable(self, key: str, build: Callable[[], Any]
                   ) -> tuple[Any, str, float]:
        """Return ``(executable, 'hit'|'miss', elapsed_ms)``.

        ``build`` runs only on a miss; its wall time is the measured cold
        compile cost.
        """
        if key in self._execs:
            self.stats.hits += 1
            return self._execs[key], "hit", 0.0
        t0 = time.perf_counter()
        compiled = build()
        ms = (time.perf_counter() - t0) * 1e3
        self._execs[key] = compiled
        self.stats.misses += 1
        self.stats.cold_ms += ms
        return compiled, "miss", ms

    def plan(self, key: str, make: Callable[[], Any]) -> tuple[Any, str]:
        """Memoized plan selection (candidate sweeps run at most once per
        key — a MEASURE sweep over repeated repetitions stops re-compiling
        every candidate).  ``None`` results (wisdom misses) are cached too:
        a deterministic miss stays a miss."""
        if key in self._plans:
            return self._plans[key], "hit"
        plan = make()
        self._plans[key] = plan
        return plan, "miss"

    def __len__(self) -> int:
        return len(self._execs)


def cached_build(plan_cache: "PlanCache | None", events: dict, op_name: str,
                 key: str, build: Callable[[], Any]):
    """Memoize-or-build an executable, recording the hit/miss event for the
    result rows.  With no cache attached this is just ``build()`` — the
    per-run recompile measurement."""
    if plan_cache is None:
        return build()
    compiled, event, _ = plan_cache.executable(key, build)
    events[op_name] = event
    return compiled


def executable_bytes(compiled) -> int:
    """Bytes attributable to a compiled executable (plan size analogue)."""
    try:
        ma = compiled.memory_analysis()
        return int(getattr(ma, "temp_size_in_bytes", 0) +
                   getattr(ma, "generated_code_size_in_bytes", 0))
    except Exception:
        return 0


def _pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def _smooth(n: int) -> bool:
    for p in (2, 3, 5, 7, 11, 13):
        while n % p == 0:
            n //= p
    return n == 1


def candidates(problem: Problem, patient: bool = False) -> list[Candidate]:
    """Enumerate feasible (backend, knob) combinations for a problem.

    Backends transform the innermost extent; outer extents are batched via
    nd-application, so feasibility is decided per-axis (all axes must be
    supported by the backend).
    """
    exts = problem.extents
    out: list[Candidate] = [Candidate("xla")]
    if all(_pow2(v) for v in exts):
        out.append(Candidate("stockham"))
    if all(_smooth(v) for v in exts):
        out.append(Candidate("fourstep"))
    if all(v <= 128 for v in exts):
        out.append(Candidate("dft"))
    if all(_kernel_factorable(v) for v in exts):
        out.append(Candidate("fourstep_pallas"))
    out.append(Candidate("bluestein"))  # always feasible
    if patient:
        extra = []
        for c in out:
            if c.backend == "fourstep_pallas":
                for tb in (4, 8, 16):
                    extra.append(Candidate("fourstep_pallas", (("tile_b", tb),)))
        out += extra
    return out


def _kernel_factorable(n: int) -> bool:
    """n = n1*n2 with both <= 128 (single fused fft4step kernel pass)."""
    if n > 128 * 128:
        return False
    for n1 in range(min(128, n), 0, -1):
        if n % n1 == 0 and n // n1 <= 128:
            return True
    return False


def estimate_choice(problem: Problem) -> Candidate:
    """The ESTIMATE heuristic: a static cost model.

    Mirrors fftw's 'probably sub-optimal but instant' behavior: prefer the
    vendor path (XLA HLO) for large/smooth problems, the matmul paths for
    small ones, bluestein only when nothing else fits.
    """
    cands = {c.backend: c for c in candidates(problem)}
    n_inner = problem.extents[-1]
    if "dft" in cands and n_inner <= 128 and problem.rank == 1:
        return cands["dft"]
    if "xla" in cands:
        return cands["xla"]
    return cands["bluestein"]


def measure_plan(problem: Problem, build: Callable[[Candidate], Callable],
                 cands: Sequence[Candidate], reps: int = 3) -> tuple[Candidate, dict[str, float]]:
    """MEASURE: compile + run each candidate, return fastest + timing table."""
    import jax

    timings: dict[str, float] = {}
    rng = np.random.default_rng(0)
    x = rng.standard_normal((problem.batch, *problem.extents)).astype(problem.real_dtype)
    if problem.complex_input:
        x = x.astype(problem.input_dtype)
    xd = jax.device_put(x)
    for cand in cands:
        try:
            fn = build(cand)
            fn(xd)  # compile + warmup
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(xd))
                best = min(best, (time.perf_counter() - t0) * 1e3)
            timings[cand.key()] = best
        except Exception as e:  # infeasible candidate: record, keep going
            timings[cand.key()] = float("nan")
    feasible = {k: v for k, v in timings.items() if v == v}
    if not feasible:
        raise RuntimeError(f"no feasible plan for {problem.signature()}")
    best_key = min(feasible, key=feasible.get)
    best_cand = next(c for c in cands if c.key() == best_key)
    return best_cand, timings


def make_plan(problem: Problem, rigor: PlanRigor,
              build: Callable[[Candidate], Callable] | None = None,
              wisdom=None) -> Plan | None:
    """The planner. Returns None for WISDOM_ONLY misses (fftw NULL plan)."""
    t0 = time.perf_counter()
    if rigor is PlanRigor.WISDOM_ONLY:
        if wisdom is None:
            return None
        cand = wisdom.lookup(problem)
        if cand is None:
            return None
        return Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3)

    if rigor is PlanRigor.ESTIMATE or build is None:
        cand, timings = estimate_choice(problem), {}
    else:
        cands = candidates(problem, patient=(rigor is PlanRigor.PATIENT))
        cand, timings = measure_plan(problem, build, cands)
    plan = Plan(problem, cand, rigor, (time.perf_counter() - t0) * 1e3, timings)
    if wisdom is not None and rigor in (PlanRigor.MEASURE, PlanRigor.PATIENT):
        wisdom.record(problem, cand)
    return plan
