"""Timer harness.

The paper measures every client operation with its own timer object (CPU
chrono timers for fftw/clFFT, CUDA events for cuFFT) and quantifies the
timer-object overhead (paper Fig. 2, 'below 2%').  Our device analogue is a
host monotonic timer around ``block_until_ready`` — the JAX equivalent of an
event-synchronized device timing.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class Timer:
    """Start/stop timer accumulating one measurement in milliseconds."""

    time_ms: float = float("nan")
    _t0: float = field(default=0.0, repr=False)

    def start(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def stop(self) -> float:
        self.time_ms = (time.perf_counter() - self._t0) * 1e3
        return self.time_ms

    def __enter__(self) -> "Timer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def timed(fn, *args, **kwargs):
    """Run fn, blocking on JAX outputs; return (result, milliseconds)."""
    t = Timer().start()
    out = fn(*args, **kwargs)
    _block(out)
    return out, t.stop()


def _block(out) -> None:
    import jax
    for leaf in jax.tree_util.tree_leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
