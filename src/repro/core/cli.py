"""gearshifft-style CLI — a thin adapter from argparse to :class:`SuiteSpec`.

    python -m repro.core.cli -e 128x128 1024 -r '*/float/*/Inplace_Real' \
        --client XlaFFT --rigor measure -o result.csv

reproduces `gearshifft_clfft -e 128x128 1024 -r */float/*/Inplace_Real -d cpu`.
One process can host several "library binaries" (clients); selecting a single
client mimics the per-library executables gearshifft builds.

Every invocation is parsed into one serializable
:class:`repro.core.suite.SuiteSpec` and executed by a
:class:`repro.core.suite.Session` — the same path the benchmark tables and
programmatic users take.  Two flags expose the spec itself:

* ``--config suite.toml`` loads a spec file (TOML, or JSON by extension) —
  gearshifft's ``-f extents_file`` analogue; any explicitly passed CLI flag
  overrides the file's value.
* ``--dump-config [path|-]`` emits the fully resolved spec of this
  invocation (TOML, or JSON for ``*.json``) and exits without running, so
  any CLI run can be saved, replayed with ``--config``, and diffed.

Clients come from the registry (populated by ``repro.core.clients.*`` at
import; extra modules can be pulled in with ``--load pkg.mod`` or the spec's
``load`` list), results stream through a CSV or JSONL sink (chosen by
``--format`` or the output extension), and the plan/executable cache is on by
default — disable it with ``--no-plan-cache`` to restore the paper's per-run
recompile measurement and the original CSV schema.
"""

from __future__ import annotations

import argparse
import importlib
from typing import Sequence

from .client import KINDS, PRECISIONS
from .plan import PlanRigor
from .registry import client_names
from .suite import Session, SuiteSpec
from .clients import jax_fft, dist_fft, serve_fft  # noqa: F401  (populate the registry)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="repro-bench", description=__doc__)
    p.add_argument("-e", "--extents", nargs="+", default=["32x32x32"],
                   help="extents specs like 128x128 or 1024")
    p.add_argument("-r", "--run", default=None,
                   help="wildcard selection title/precision/extents/kind")
    p.add_argument("--client", nargs="+", default=["XlaFFT"],
                   choices=client_names(), help="client 'binaries' to run")
    p.add_argument("--load", nargs="*", default=[], metavar="MODULE",
                   help="extra modules to import (register more clients)")
    p.add_argument("--kinds", nargs="+", default=list(KINDS), choices=KINDS)
    p.add_argument("--precisions", nargs="+", default=["float"], choices=PRECISIONS)
    p.add_argument("--rigor", default="estimate",
                   choices=[r.value for r in PlanRigor])
    p.add_argument("--warmups", type=int, default=1)
    p.add_argument("--reps", type=int, default=3)
    p.add_argument("--error-bound", type=float, default=1e-5)
    p.add_argument("--wisdom", default=None, help="wisdom JSON path")
    p.add_argument("--no-plan-cache", action="store_true",
                   help="re-compile every run (paper-faithful planning cost; "
                        "restores the original CSV schema)")
    p.add_argument("-o", "--output", default="result.csv")
    p.add_argument("--format", default=None, choices=["csv", "jsonl"],
                   help="result sink format (default: by output extension)")
    p.add_argument("-b", "--batch", type=int, default=1)
    p.add_argument("-v", "--verbose", action="store_true")
    p.add_argument("--config", default=None, metavar="SPEC",
                   help="load a SuiteSpec file (.toml/.json); explicitly "
                        "passed flags override its values")
    p.add_argument("--dump-config", nargs="?", const="-", default=None,
                   metavar="PATH",
                   help="emit the resolved spec (TOML, or JSON for *.json; "
                        "'-' = stdout) and exit without running")
    return p


#: argparse dest -> SuiteSpec field (``no_plan_cache`` is handled separately
#: because its sense is inverted).
_ARG_TO_FIELD = {
    "extents": "extents", "run": "select", "client": "clients",
    "load": "load", "kinds": "kinds", "precisions": "precisions",
    "batch": "batch", "rigor": "rigor", "warmups": "warmups",
    "reps": "repetitions", "error_bound": "error_bound", "wisdom": "wisdom",
    "output": "output", "format": "format", "verbose": "verbose",
}


def spec_from_args(args: argparse.Namespace,
                   only: set[str] | None = None,
                   base: SuiteSpec | None = None) -> SuiteSpec:
    """Map parsed args onto a SuiteSpec.

    With ``base`` (a ``--config`` spec), only the arg dests named in
    ``only`` — the flags the user explicitly passed — override the file.
    """
    vals = {}
    for arg, fld in _ARG_TO_FIELD.items():
        if only is not None and arg not in only:
            continue
        vals[fld] = getattr(args, arg)
    if only is None or "no_plan_cache" in only:
        vals["plan_cache"] = not args.no_plan_cache
    if base is not None:
        from dataclasses import replace
        return replace(base, **vals)
    return SuiteSpec(**vals)


def _explicit_args(argv: Sequence[str] | None) -> set[str]:
    """Dests of the flags actually present on the command line (parsed with
    all defaults suppressed, so absent flags leave no attribute)."""
    p = build_parser()
    for a in p._actions:
        a.default = argparse.SUPPRESS
    ns, _ = p.parse_known_args(argv)
    return set(vars(ns))


def main(argv: Sequence[str] | None = None) -> int:
    # --load/--config run before the main parse so the clients they register
    # appear in --client choices
    pre = argparse.ArgumentParser(add_help=False)
    pre.add_argument("--load", nargs="*", default=[])
    pre.add_argument("--config", default=None)
    known, _ = pre.parse_known_args(argv)
    for mod in known.load:
        importlib.import_module(mod)
    base = None
    if known.config:
        base = SuiteSpec.from_file(known.config)
        base.load_modules()

    args = build_parser().parse_args(argv)
    if base is not None:
        spec = spec_from_args(args, only=_explicit_args(argv), base=base)
    else:
        spec = spec_from_args(args)

    if args.dump_config is not None:
        if args.dump_config == "-":
            print(spec.to_toml(), end="")
        else:
            spec.save(args.dump_config)
            print(f"wrote spec to {args.dump_config}")
        return 0

    nodes = spec.build_nodes()
    if not nodes:
        print("no benchmarks selected")
        return 1
    result = Session().run(spec, nodes=nodes)
    print(f"wrote {result.n_rows} rows to {result.path}; "
          f"{result.n_failures} failures")
    summ = result.summary()
    print(f"plan time: {summ['plan_time_ms']:.0f} ms total "
          f"({summ['plan_time_cold_ms']:.0f} ms cold compile)")
    if result.plan_stats is not None:
        s = result.plan_stats
        print(f"plan cache: {s.hits} hits, {s.misses} misses, "
              f"cold compile {s.cold_ms:.0f} ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
