"""gearshifft-style CLI.

    python -m repro.core.cli -e 128x128 1024 -r '*/float/*/Inplace_Real' \
        --client XlaFFT --rigor measure -o result.csv

reproduces `gearshifft_clfft -e 128x128 1024 -r */float/*/Inplace_Real -d cpu`.
One process can host several "library binaries" (clients); selecting a single
client mimics the per-library executables gearshifft builds.
"""

from __future__ import annotations

import argparse
from typing import Sequence

from .benchmark import Benchmark, BenchmarkConfig
from .client import KINDS, PRECISIONS, Context
from .extents import parse_extents
from .plan import PlanRigor
from .tree import build_tree, select
from .wisdom import Wisdom
from .clients import jax_fft as jf

CLIENTS = {
    "XlaFFT": jf.XlaFFTClient,
    "Stockham": jf.StockhamClient,
    "FourStep": jf.FourStepClient,
    "FourStepPallas": jf.FourStepPallasClient,
    "Bluestein": jf.BluesteinClient,
    "Planned": jf.PlannedClient,
}


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="repro-bench", description=__doc__)
    p.add_argument("-e", "--extents", nargs="+", default=["32x32x32"],
                   help="extents specs like 128x128 or 1024")
    p.add_argument("-r", "--run", default=None,
                   help="wildcard selection title/precision/extents/kind")
    p.add_argument("--client", nargs="+", default=["XlaFFT"],
                   choices=sorted(CLIENTS), help="client 'binaries' to run")
    p.add_argument("--kinds", nargs="+", default=list(KINDS), choices=KINDS)
    p.add_argument("--precisions", nargs="+", default=["float"], choices=PRECISIONS)
    p.add_argument("--rigor", default="estimate",
                   choices=[r.value for r in PlanRigor])
    p.add_argument("--warmups", type=int, default=1)
    p.add_argument("--reps", type=int, default=3)
    p.add_argument("--error-bound", type=float, default=1e-5)
    p.add_argument("--wisdom", default=None, help="wisdom JSON path")
    p.add_argument("-o", "--output", default="result.csv")
    p.add_argument("-b", "--batch", type=int, default=1)
    p.add_argument("-v", "--verbose", action="store_true")
    return p


def main(argv: Sequence[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    extents = [parse_extents(e) for e in args.extents]
    nodes = build_tree([CLIENTS[c] for c in args.client], extents,
                       kinds=args.kinds, precisions=args.precisions,
                       batch=args.batch)
    nodes = select(nodes, args.run)
    if not nodes:
        print("no benchmarks selected")
        return 1
    cfg = BenchmarkConfig(warmups=args.warmups, repetitions=args.reps,
                          error_bound=args.error_bound,
                          rigor=PlanRigor(args.rigor), output=args.output)
    wisdom = Wisdom(args.wisdom) if args.wisdom else None
    bench = Benchmark(Context(), cfg)
    writer = bench.run_nodes(nodes, wisdom=wisdom, verbose=args.verbose)
    path = writer.save()
    n_fail = sum(1 for r in writer.rows if not r.success)
    print(f"wrote {len(writer.rows)} rows to {path}; {n_fail} failures")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
