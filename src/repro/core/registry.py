"""Decorator-based client registry (engine layer 3a).

gearshifft builds one binary per FFT library; our analogue is one registered
client class per backend "binary".  The registry replaces the hardcoded
``CLIENTS`` dict the CLI used to carry: any module — ``repro.core.clients.*``
or an out-of-tree ``benchmarks/*`` table — registers its clients with

    @register_client()
    class MyClient: ...

and the CLI discovers them by name.  Re-registering the *same* class under
the same name is a no-op (modules may be imported twice); registering a
*different* class under a taken name is rejected loudly.
"""

from __future__ import annotations

from typing import Callable, Type

_REGISTRY: dict[str, Type] = {}


def register_client(name: str | None = None) -> Callable[[Type], Type]:
    """Class decorator: ``@register_client()`` or ``@register_client("Name")``.

    The registered name defaults to the class's ``title`` attribute (falling
    back to ``__name__``).
    """

    def deco(cls: Type) -> Type:
        key = name or getattr(cls, "title", None) or cls.__name__
        existing = _REGISTRY.get(key)
        if existing is not None and existing is not cls:
            raise ValueError(
                f"client name {key!r} already registered by "
                f"{existing.__module__}.{existing.__qualname__}")
        _REGISTRY[key] = cls
        return cls

    return deco


def get_client(name: str) -> Type:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY)) or "<none>"
        raise KeyError(f"unknown client {name!r}; registered: {known}") from None


def client_names() -> list[str]:
    return sorted(_REGISTRY)


def registered_clients() -> dict[str, Type]:
    return dict(_REGISTRY)
