"""The benchmark tree + wildcard run selection.

gearshifft materializes every (client / precision / kind / extents) combination
as a node in a Boost-UTF test tree and selects nodes with patterns like

    -r '*/float/*/Inplace_Real'        (title / precision / extents / kind)

We reproduce the same four-level path layout and fnmatch-style wildcards.
"""

from __future__ import annotations

import fnmatch
from dataclasses import dataclass
from typing import Iterable, Sequence, Type

from .client import KINDS, PRECISIONS, Problem
from .extents import classify, format_extents


@dataclass(frozen=True)
class BenchNode:
    """One leaf: a client class bound to a fully specified problem."""

    client_cls: Type
    problem: Problem

    @property
    def path(self) -> str:
        p = self.problem
        return "/".join([self.client_cls.title, p.precision,
                         format_extents(p.extents), p.kind])

    @property
    def extent_class(self) -> str:
        return classify(self.problem.extents)


def build_tree(client_classes: Sequence[Type],
               extents_list: Iterable[tuple[int, ...]],
               kinds: Sequence[str] = KINDS,
               precisions: Sequence[str] = PRECISIONS,
               batch: int = 1) -> list[BenchNode]:
    nodes = []
    for cls in client_classes:
        for prec in precisions:
            for ext in extents_list:
                for kind in kinds:
                    nodes.append(BenchNode(cls, Problem(tuple(ext), kind, prec, batch)))
    return nodes


def select(nodes: Sequence[BenchNode], pattern: str | None) -> list[BenchNode]:
    """Filter by a '/'-separated wildcard pattern (missing levels = '*')."""
    if not pattern:
        return list(nodes)
    parts = pattern.split("/")
    parts += ["*"] * (4 - len(parts))
    out = []
    for node in nodes:
        levels = node.path.split("/")
        if all(fnmatch.fnmatch(lv, pat) for lv, pat in zip(levels, parts)):
            out.append(node)
    return out
