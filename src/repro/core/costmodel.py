"""The planner's cost model as a first-class, *fittable* layer.

ESTIMATE ranks candidates by modeled HBM traffic (bytes moved).  Before this
module existed the model's constants — per-backend pass counts, the chirp
padding overheads, the interconnect link cost — were literals buried in
``plan.py``: hand-written guesses.  Here they live in a
:class:`CostCoefficients` table, versioned and loadable per **device kind**,
so ``tools/fit_costmodel.py`` can regress them from measured BENCH_*.json +
wisdom data and a Session can install the fitted table for its device.

Layering:

* :data:`DEFAULT_COEFFICIENTS` reproduces the historical hand-written
  values **bit-for-bit** — with it installed (the default), every golden
  ESTIMATE pick and dist-cost crossover is byte-identical to the
  pre-refactor planner.
* A module-level *active* model (:func:`get_active_model` /
  :func:`set_active_model` / :func:`use_model`) is what the compatibility
  functions ``hbm_passes`` / ``estimate_bytes_moved`` / ``estimate_choice``
  delegate to; ``plan.fallback_chain`` and the serve engine's chain
  memoization therefore consult fitted rankings the moment a fitted table
  is installed, with no caller changes.
* Infeasible assignments get a typed :class:`Infeasible` verdict from
  :meth:`CostModel.estimate` (``float()`` of it is still ``inf``, so the
  numeric ``estimate_bytes_moved`` contract is unchanged).
"""

from __future__ import annotations

import json
import warnings
from contextlib import contextmanager
from dataclasses import dataclass, fields, replace
from typing import Optional

from .client import Problem
from .candidates import (BACKENDS, CHIRPZ_PALLAS_MAX_N, Candidate,
                         DIST_A2A_COUNT, DIST_BACKENDS, DIST_NATURAL_EXTRA,
                         FUSED_ND, FFT2_PALLAS_VMEM_ELEMS,
                         SIXSTEP_MAX_N, SIXSTEP_MIN_N,
                         STOCKHAM_PALLAS_VMEM_N, _kernel_factorable, _pow2,
                         _smooth, _smooth7, axis_engine_n, axis_feasible,
                         candidates, dist_local_lengths, dist_supports,
                         fft2_feasible)
from .extents import next_pow2 as _next_pow2, next_smooth

#: Schema stamped into coefficient-table files; loaders reject newer ones.
COSTMODEL_SCHEMA_VERSION = 1

#: Interconnect cost of one all-to-all'd byte relative to one HBM byte —
#: ICI/NVLink-class fabrics move bytes at a small single-digit multiple of
#: HBM cost; this single coefficient is what lets ESTIMATE rank "one
#: device, one HBM touch" against "P devices, two all-to-alls" honestly.
DIST_LINK_COST = 4.0
#: Fixed per-collective charge (latency, layout fix-ups) expressed in
#: equivalent HBM bytes — keeps tiny transforms from sharding: below ~1 MiB
#: the collective's constant cost dwarfs any compute win.
DIST_A2A_LATENCY_BYTES = float(1 << 20)


@dataclass(frozen=True)
class Infeasible:
    """Typed infeasibility verdict from :meth:`CostModel.estimate`.

    Falsy, and ``float()`` of it is ``inf`` — so numeric callers keep their
    sentinel while reporting callers (bench_compare's roofline) can tell
    *why* a row had no modeled traffic instead of silently papering over it.
    """

    reason: str = ""

    def __bool__(self) -> bool:
        return False

    def __float__(self) -> float:
        return float("inf")


@dataclass(frozen=True)
class CostCoefficients:
    """Every fittable constant of the bytes-moved model, with the
    historical hand-written values as defaults.

    Pass counts are HBM round-trips of the live signal; the chirp/bluestein
    entries are multiplied by their padding ratio (m/n) at evaluation time,
    so fitting them rescales the *overhead*, not the structure.
    """

    # vendor path: multi-stage but heavily fused on smooth extents; a
    # non-smooth length takes the library's own chirp fallback
    xla_smooth_passes: float = 2.0
    xla_chirp_passes: float = 6.0
    # one staged jnp pass per radix-2 stage
    stockham_stage_passes: float = 1.0
    # per recursion level of the cache-blocked four-step
    fourstep_level_passes: float = 2.0
    # single-matmul DFT: one fused touch
    dft_passes: float = 1.0
    # fused kernels: read + write the signal exactly once
    fourstep_pallas_passes: float = 1.0
    stockham_pallas_passes: float = 1.0
    # 2 fused kernel passes + 3 transpose passes
    sixstep_passes: float = 5.0
    # chirp-Z: 2 padded engine passes + chirp/filter/final muls, charged at
    # the padded length (x m/n) — smooth-m kernel vs pow2 six-step engine
    chirpz_smooth_passes: float = 5.0
    chirpz_pow2_passes: float = 13.0
    # staged-Stockham Bluestein: 3 padded transforms + chirp setup
    bluestein_stage_passes: float = 3.0
    bluestein_setup_passes: float = 2.0
    # swapaxes in + out around every non-innermost separable engine call
    transpose_passes: float = 2.0
    # interconnect: per-byte link cost + per-collective latency floor
    dist_link_cost: float = DIST_LINK_COST
    dist_a2a_latency_bytes: float = DIST_A2A_LATENCY_BYTES
    # dist1d's extra per-shard twiddle multiply
    dist1d_twiddle_passes: float = 1.0
    # latency-floor heuristic: rank-1 problems at or below this inner
    # engine length go straight to the single-matmul dft kernel
    dft_pin_max_n: int = 128

    def to_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}

    @classmethod
    def from_dict(cls, d: dict) -> "CostCoefficients":
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            warnings.warn(f"ignoring unknown cost coefficients: {unknown}")
        kw = {k: v for k, v in d.items() if k in known}
        if "dft_pin_max_n" in kw:
            kw["dft_pin_max_n"] = int(kw["dft_pin_max_n"])
        return cls(**{k: (float(v) if k != "dft_pin_max_n" else v)
                      for k, v in kw.items()})


DEFAULT_COEFFICIENTS = CostCoefficients()

#: Which coefficients a measured row for each backend calibrates — the
#: fitter scales these together so structural ratios inside a backend
#: (e.g. chirp smooth vs pow2 overhead) are preserved.
BACKEND_COEFFS = {
    "xla": ("xla_smooth_passes", "xla_chirp_passes"),
    "stockham": ("stockham_stage_passes",),
    "fourstep": ("fourstep_level_passes",),
    "dft": ("dft_passes",),
    "fourstep_pallas": ("fourstep_pallas_passes",),
    "stockham_pallas": ("stockham_pallas_passes",),
    "sixstep": ("sixstep_passes",),
    "chirpz_pallas": ("chirpz_smooth_passes", "chirpz_pow2_passes"),
    "bluestein": ("bluestein_stage_passes", "bluestein_setup_passes"),
}


class CostModel:
    """Bytes-moved model over one :class:`CostCoefficients` table.

    ``device_kind`` labels which device the coefficients were fitted for
    (``"default"`` = the hand-written table); ``source`` records provenance
    for reports.
    """

    def __init__(self, coeffs: CostCoefficients = DEFAULT_COEFFICIENTS,
                 device_kind: str = "default",
                 source: str = "hand-written defaults"):
        self.coeffs = coeffs
        self.device_kind = device_kind
        self.source = source

    def __repr__(self) -> str:
        return f"CostModel({self.device_kind!r}, source={self.source!r})"

    def scaled(self, backend_scales: dict[str, float],
               device_kind: str = "", source: str = "") -> "CostModel":
        """A new model with each backend's coefficients (see
        :data:`BACKEND_COEFFS`) multiplied by its fitted scale."""
        updates: dict[str, float] = {}
        for backend, scale in backend_scales.items():
            for name in BACKEND_COEFFS.get(backend, ()):
                updates[name] = getattr(self.coeffs, name) * float(scale)
        return CostModel(replace(self.coeffs, **updates),
                         device_kind or self.device_kind,
                         source or self.source)

    # --- per-axis engine passes -------------------------------------------
    def hbm_passes(self, backend: str, n: int) -> float:
        """Modeled HBM round-trips of the whole signal for one length-n
        transform (the quantity that dominates above the paper's ~1 MiB
        boundary).  ``inf`` marks an infeasible / VMEM-overflowing choice.

        The fused kernels are the reason this model exists: stockham_pallas
        and fourstep_pallas read and write the signal exactly once, the
        six-step composition a small constant (2 kernel passes + 3
        transposes), while the staged jnp Stockham pays one pass per
        radix-2 stage.
        """
        c = self.coeffs
        inf = float("inf")
        if backend == "xla":
            if _smooth7(n):
                return c.xla_smooth_passes  # vendor path: heavily fused
            # non-smooth lengths send the vendor library down its own chirp
            # fallback: ~3 fused transforms at the padded pow2 length
            return c.xla_chirp_passes * (_next_pow2(2 * n - 1) / n)
        if backend == "stockham":
            if not _pow2(n):
                return inf
            # one pass per stage
            return c.stockham_stage_passes * float(max(1, n.bit_length() - 1))
        if backend == "fourstep":
            if not _smooth(n):
                return inf
            levels = 1
            m = n
            while m > 128:
                m = -(-m // 128)
                levels += 1
            return c.fourstep_level_passes * levels
        if backend == "dft":
            return c.dft_passes if n <= 128 else inf
        if backend == "fourstep_pallas":
            return c.fourstep_pallas_passes if _kernel_factorable(n) else inf
        if backend == "stockham_pallas":
            # any 7-smooth length is one mixed-radix kernel pass; beyond the
            # VMEM tile budget the kernel can't hold a batch row
            if _smooth7(n) and n <= STOCKHAM_PALLAS_VMEM_N:
                return c.stockham_pallas_passes
            return inf
        if backend == "sixstep":
            if _pow2(n) and SIXSTEP_MIN_N <= n <= SIXSTEP_MAX_N:
                return c.sixstep_passes  # 2 fused kernel passes + 3 transposes
            return inf
        if backend == "chirpz_pallas":
            if not 1 <= n <= CHIRPZ_PALLAS_MAX_N:
                return inf
            # two fused padded transforms + chirp mul, filter mul, final
            # chirp; the filter spectrum is host-cached so no third
            # transform runs.  The mixed-radix kernel convolves at the
            # smallest 7-SMOOTH m >= 2n-1 (often ~2x tighter than pow2);
            # sixstep needs pow2.
            ms = next_smooth(2 * n - 1)
            if ms <= STOCKHAM_PALLAS_VMEM_N:
                return c.chirpz_smooth_passes * (ms / n)
            return c.chirpz_pow2_passes * (_next_pow2(2 * n - 1) / n)
        if backend == "bluestein":
            m = 1
            while m < 2 * n - 1:
                m *= 2
            # 3 staged Stockham transforms of padded length m, + chirp setup
            return (c.bluestein_stage_passes * max(1, m.bit_length() - 1)
                    + c.bluestein_setup_passes) * (m / n)
        return inf

    # --- live elements per axis -------------------------------------------
    @staticmethod
    def axis_elems(problem: Problem, axis: int) -> int:
        """Complex elements the transform carries while working on ``axis``.

        Complex kinds move the whole signal on every axis.  Real kinds run
        the innermost axis packed at half the elements (even n) and every
        outer axis on the half-spectrum — n_last//2 + 1 bins along the last
        axis — which is the traffic halving the paper's Fig. 8a measures."""
        if problem.complex_input:
            return problem.n_elems
        n_last = problem.extents[-1]
        rows = problem.n_elems // n_last
        if axis == problem.rank - 1:
            return rows * (n_last // 2) if n_last % 2 == 0 else problem.n_elems
        return rows * (n_last // 2 + 1)

    # --- full-transform estimate ------------------------------------------
    def estimate(self, problem: Problem,
                 cand: Candidate) -> "float | Infeasible":
        """Modeled HBM bytes for the full nd transform under ``cand``, or a
        typed :class:`Infeasible` verdict.

        Whole-transform backends (``FUSED_ND``) move the signal their fixed
        number of passes with **no** transpose traffic.  Separable
        assignments charge, per axis: the engine's :meth:`hbm_passes` at the
        extent the engine actually sees (packed half-length on a real
        innermost axis), *plus* the two swapaxes passes ``nd._apply_last``
        really performs for every non-innermost axis — zero for the
        innermost one.  Each pass reads and writes the live elements once
        (see :meth:`axis_elems` for the r2c half-spectrum sizes).

        Distributed candidates (``DIST_BACKENDS``) model the **per-device**
        cost — what bounds wall time when every device works in parallel:
        the local per-axis engine passes on the 1/P-sized shard, plus the
        interconnect term — each all_to_all moves the device's whole block
        once, charged at ``dist_link_cost`` HBM-equivalent bytes per byte
        plus the fixed ``dist_a2a_latency_bytes`` per collective.  That
        latency floor is why small transforms never shard and the
        single-/multi-device crossover sits where it does.
        """
        c = self.coeffs
        complex_itemsize = 16 if problem.precision == "double" else 8
        if cand.backend in DIST_BACKENDS:
            p = 1
            for s in cand.mesh:
                p *= s
            if not dist_supports(cand.backend, problem, cand.mesh):
                return Infeasible(
                    f"{cand.key()} cannot decompose "
                    f"{problem.signature()} over mesh {cand.mesh}")
            opts = cand.opts()
            forced = opts.get("local")
            passes = 0.0
            for n_g, swaps in dist_local_lengths(problem, cand):
                b = forced or self.dist_local_engine(n_g)
                hp = self.hbm_passes(b, n_g)
                if hp == float("inf") or not axis_feasible(b, n_g):
                    return Infeasible(
                        f"local engine {b} infeasible at n={n_g}")
                passes += hp + swaps
            if cand.backend == "dist1d":
                passes += c.dist1d_twiddle_passes  # per-shard twiddle mul
            dev_bytes = (problem.n_elems / p) * complex_itemsize
            n_a2a = DIST_A2A_COUNT[cand.backend]
            if opts.get("natural"):
                n_a2a += DIST_NATURAL_EXTRA[cand.backend]
            return (passes * 2.0 * dev_bytes
                    + n_a2a * (dev_bytes * c.dist_link_cost
                               + c.dist_a2a_latency_bytes))
        if cand.backend in FUSED_ND:
            elems = self.axis_elems(problem, problem.rank - 1)
            if cand.backend == "xla":
                # vendor path: 2 fused passes on smooth extents; a
                # non-smooth axis drags the whole transform into its chirp
                # fallback
                passes = max(self.hbm_passes("xla", axis_engine_n(problem, i))
                             for i in range(problem.rank))
            else:          # fft2_pallas: one read + one write of the tile
                # the VMEM budget binds the tile the kernel actually holds:
                # real kinds run packed, so the inner extent halves (even n)
                tile_elems = (problem.extents[0] *
                              axis_engine_n(problem, problem.rank - 1))
                if not (fft2_feasible(problem)
                        and tile_elems <= FFT2_PALLAS_VMEM_ELEMS):
                    return Infeasible(
                        f"fft2_pallas tile of {tile_elems} elems exceeds "
                        f"the VMEM budget for {problem.signature()}")
                passes = 1.0
            return passes * 2.0 * elems * complex_itemsize
        total = 0.0
        for axis, ax_cand in enumerate(cand.per_axis(problem.rank)):
            n_eng = axis_engine_n(problem, axis)
            passes = self.hbm_passes(ax_cand.backend, n_eng)
            if passes == float("inf"):
                return Infeasible(
                    f"{ax_cand.backend} infeasible at engine length "
                    f"{n_eng} (axis {axis} of {problem.signature()})")
            if axis != problem.rank - 1:
                passes += c.transpose_passes  # swapaxes in + out
            total += (passes * 2.0 * self.axis_elems(problem, axis)
                      * complex_itemsize)
        return total

    def estimate_bytes_moved(self, problem: Problem,
                             cand: Candidate) -> float:
        """Numeric view of :meth:`estimate` — infeasible is ``inf``."""
        return float(self.estimate(problem, cand))

    # --- rankings ---------------------------------------------------------
    def dist_local_engine(self, n: int) -> str:
        """The separable backend a distributed plan runs locally at length
        ``n`` when no explicit ``local`` knob forces one: fewest modeled
        HBM passes, ties to the earlier (more conservative) BACKENDS
        entry."""
        best, best_p = "fourstep", float("inf")
        for b in BACKENDS:
            if b in FUSED_ND:
                continue
            if axis_feasible(b, n):
                passes = self.hbm_passes(b, n)
                if passes < best_p:
                    best, best_p = b, passes
        return best

    def estimate_choice(self, problem: Problem) -> Candidate:
        """The ESTIMATE heuristic: a static bytes-moved cost model.

        Mirrors fftw's 'probably sub-optimal but instant' behavior: tiny
        rank-1 problems go straight to the single-matmul dft kernel (launch
        overhead dominates traffic there); everything else takes the
        feasible candidate that moves the fewest modeled HBM bytes (ties
        keep the earlier, more conservative entry — the vendor path is
        enumerated first, per-axis mixed assignments last).
        """
        cands = candidates(problem)
        by_backend = {c.backend: c for c in cands}
        n_inner = problem.extents[-1]
        if "dft" in by_backend and n_inner <= self.coeffs.dft_pin_max_n \
                and problem.rank == 1:
            return by_backend["dft"]
        best, best_cost = None, float("inf")
        for c in cands:
            cost = self.estimate_bytes_moved(problem, c)
            if cost < best_cost:
                best, best_cost = c, cost
        if best is not None:
            return best
        return by_backend.get("xla", by_backend["bluestein"])


#: The golden hand-written model: installed by default, pinned by the
#: planner's golden ESTIMATE tests.
DEFAULT_MODEL = CostModel()

_active_model: CostModel = DEFAULT_MODEL


def get_active_model() -> CostModel:
    """The model every compatibility function (and therefore the planner,
    ``fallback_chain``, and the serve engine's chain memoization) consults."""
    return _active_model


def set_active_model(model: Optional[CostModel]) -> CostModel:
    """Install ``model`` (None restores the default); returns the previous
    active model so callers can restore it."""
    global _active_model
    prev = _active_model
    _active_model = model if model is not None else DEFAULT_MODEL
    return prev


@contextmanager
def use_model(model: Optional[CostModel]):
    """Scoped :func:`set_active_model` — a Session installs its fitted
    per-device table for the duration of a run and restores on exit."""
    prev = set_active_model(model)
    try:
        yield get_active_model()
    finally:
        set_active_model(prev)


# --- compatibility surface (what plan.py re-exports) -----------------------
def hbm_passes(backend: str, n: int) -> float:
    return get_active_model().hbm_passes(backend, n)


def estimate_bytes_moved(problem: Problem, cand: Candidate) -> float:
    return get_active_model().estimate_bytes_moved(problem, cand)


def estimate_choice(problem: Problem) -> Candidate:
    return get_active_model().estimate_choice(problem)


def dist_local_engine(n: int) -> str:
    return get_active_model().dist_local_engine(n)


def _axis_elems(problem: Problem, axis: int) -> int:
    return CostModel.axis_elems(problem, axis)


# ---------------------------------------------------------------------------
# Versioned per-device-kind coefficient tables
# ---------------------------------------------------------------------------
def load_tables(path: str) -> dict[str, CostModel]:
    """Load a fitted coefficient-table file: ``{"schema": 1, "tables":
    {device_kind: {coeff: value}}, ...meta}``.  Raises on a newer schema —
    a stale reader must not silently misinterpret fitted numbers."""
    with open(path) as f:
        doc = json.load(f)
    schema = doc.get("schema")
    if schema != COSTMODEL_SCHEMA_VERSION:
        raise ValueError(
            f"cost-model table {path} has schema {schema!r}; this reader "
            f"understands v{COSTMODEL_SCHEMA_VERSION}")
    source = doc.get("generated_by", path)
    return {kind: CostModel(CostCoefficients.from_dict(tbl), kind,
                            source=f"{source} [{kind}]")
            for kind, tbl in doc.get("tables", {}).items()}


def save_tables(path: str, models: dict[str, CostModel],
                meta: Optional[dict] = None) -> None:
    doc = {"schema": COSTMODEL_SCHEMA_VERSION, **(meta or {}),
           "tables": {kind: m.coeffs.to_dict()
                      for kind, m in sorted(models.items())}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def model_for_device(device_kind: str,
                     tables: "dict[str, CostModel] | str") -> CostModel:
    """Pick the table for ``device_kind`` — exact match first, then a
    case-insensitive prefix match (``"NVIDIA H100"`` finds a ``"nvidia"``
    table), then ``"default"``, else the hand-written model."""
    if isinstance(tables, str):
        tables = load_tables(tables)
    if device_kind in tables:
        return tables[device_kind]
    dk = device_kind.lower()
    for kind, model in sorted(tables.items()):
        k = kind.lower()
        if k != "default" and (dk.startswith(k) or k.startswith(dk)):
            return model
    return tables.get("default", DEFAULT_MODEL)


# ---------------------------------------------------------------------------
# Rank-correlation metric shared by the fitter, CI, and tests
# ---------------------------------------------------------------------------
def spearman(xs, ys) -> float:
    """Spearman rank correlation (ties get average ranks); nan for < 2
    points or zero variance.  Stdlib-only on purpose — the fitter must run
    in a bare CI container."""
    n = len(xs)
    if n != len(ys):
        raise ValueError(f"length mismatch: {n} vs {len(ys)}")
    if n < 2:
        return float("nan")

    def ranks(vals):
        order = sorted(range(n), key=lambda i: vals[i])
        r = [0.0] * n
        i = 0
        while i < n:
            j = i
            while j + 1 < n and vals[order[j + 1]] == vals[order[i]]:
                j += 1
            avg = (i + j) / 2.0 + 1.0
            for k in range(i, j + 1):
                r[order[k]] = avg
            i = j + 1
        return r

    rx, ry = ranks(list(xs)), ranks(list(ys))
    mx = sum(rx) / n
    my = sum(ry) / n
    cov = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    vx = sum((a - mx) ** 2 for a in rx)
    vy = sum((b - my) ** 2 for b in ry)
    if vx == 0.0 or vy == 0.0:
        return float("nan")
    return cov / (vx * vy) ** 0.5
