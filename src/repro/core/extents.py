"""Extents handling: parsing, classification and the paper's extent classes.

gearshifft names its extent classes powerof2 / radix357 / oddshape (Fig. 7);
we reproduce the same taxonomy and the '-e 128x128 1024' CLI syntax.
"""

from __future__ import annotations

import math
from typing import Iterator, Sequence


def parse_extents(spec: str) -> tuple[int, ...]:
    """'128x128x128' -> (128, 128, 128); '1024' -> (1024,)."""
    try:
        ext = tuple(int(p) for p in spec.lower().split("x"))
    except ValueError as e:
        raise ValueError(f"bad extents spec {spec!r}") from e
    if not ext or any(v < 1 for v in ext) or len(ext) > 3:
        raise ValueError(f"bad extents spec {spec!r} (rank 1..3, positive)")
    return ext


def format_extents(ext: Sequence[int]) -> str:
    return "x".join(str(v) for v in ext)


def total_elems(ext: Sequence[int]) -> int:
    return math.prod(ext)


def _factors_only(n: int, primes: Sequence[int]) -> bool:
    for p in primes:
        while n % p == 0:
            n //= p
    return n == 1


def next_pow2(v: int) -> int:
    """Smallest power of two >= v (shared by the planner's cost model and
    the pow2-padded convolution engines)."""
    m = 1
    while m < v:
        m *= 2
    return m


def next_smooth(v: int, primes: Sequence[int] = (2, 3, 5, 7)) -> int:
    """Smallest integer >= v whose prime factors all lie in ``primes`` —
    the padding helper for engines that accept any smooth length (the
    mixed-radix Stockham kernel): a chirp-Z convolution at 7-smooth m
    instead of next_pow2 can shrink the padded work by nearly 2x."""
    v = max(1, v)
    while not _factors_only(v, primes):
        v += 1
    return v


def classify(ext: Sequence[int]) -> str:
    """Paper extent classes: powerof2 | radix357 | oddshape."""
    if all(v & (v - 1) == 0 for v in ext):
        return "powerof2"
    if all(_factors_only(v, (2, 3, 5, 7)) for v in ext):
        return "radix357"
    return "oddshape"


def powerof2_extents(rank: int, min_exp: int, max_exp: int) -> Iterator[tuple[int, ...]]:
    for e in range(min_exp, max_exp + 1):
        yield (2 ** e,) * rank


def radix357_extents(rank: int, count: int = 8, start: int = 3) -> Iterator[tuple[int, ...]]:
    """Sizes of the form 2^a * 3^b * 5^c * 7^d that are not powers of two.

    Scans upward one at a time: powers of 3 alone make the sequence
    infinite, so this always terminates.  (The previous ``v // 8`` skip for
    v >= 32 could step over every remaining smooth number and loop forever,
    e.g. ``start=96``.)
    """
    emitted, v = 0, start
    while emitted < count:
        if _factors_only(v, (2, 3, 5, 7)) and (v & (v - 1)):
            yield (v,) * rank
            emitted += 1
        v += 1


def oddshape_extents(rank: int, count: int = 6) -> Iterator[tuple[int, ...]]:
    """Powers of 19 and friends (the paper's power-of-19 oddshape runs)."""
    base = [19, 19 * 19, 19 ** 3, 11 ** 3, 13 ** 3, 17 ** 3, 23 ** 3, 19 ** 4]
    for v in base[:count]:
        yield (v,) * rank


#: Generator-backed sweep classes a SuiteSpec can name instead of listing
#: extents explicitly — the paper's three extent classes (Fig. 7).
SWEEP_CLASSES = ("powerof2", "radix357", "oddshape")

_SWEEP_PARAMS = {
    "powerof2": {"min_exp", "max_exp"},
    "radix357": {"count", "start"},
    "oddshape": {"count"},
}


def sweep_extents(extent_class: str, rank: int, **params) -> list[tuple[int, ...]]:
    """Expand a named sweep class into concrete extents.

    ``powerof2`` requires ``min_exp``/``max_exp``; ``radix357`` accepts
    ``count``/``start``; ``oddshape`` accepts ``count``.  Unknown classes and
    unknown/missing parameters raise ``ValueError`` so a bad spec file fails
    before any benchmark runs.
    """
    if extent_class not in SWEEP_CLASSES:
        raise ValueError(f"unknown sweep class {extent_class!r}; "
                         f"known: {', '.join(SWEEP_CLASSES)}")
    if rank < 1 or rank > 3:
        raise ValueError(f"sweep rank must be 1..3, got {rank}")
    extra = set(params) - _SWEEP_PARAMS[extent_class]
    if extra:
        raise ValueError(f"sweep class {extent_class!r} does not accept "
                         f"{sorted(extra)}; allowed: "
                         f"{sorted(_SWEEP_PARAMS[extent_class])}")
    if extent_class == "powerof2":
        missing = {"min_exp", "max_exp"} - set(params)
        if missing:
            raise ValueError(f"powerof2 sweep requires {sorted(missing)}")
        return list(powerof2_extents(rank, params["min_exp"], params["max_exp"]))
    if extent_class == "radix357":
        return list(radix357_extents(rank, **params))
    return list(oddshape_extents(rank, **params))
