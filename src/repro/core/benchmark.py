"""The benchmark suite driver — gearshifft's measurement core (paper §2.2,
Fig. 1), layered over the generic Runner.

Per selected tree node:  context create (timed once per suite) ->
Runner drives the node's OpSchedule (default: the paper's Table-1 sequence
allocate -> init_forward -> upload -> execute_forward -> init_inverse ->
execute_inverse -> download -> destroy) for warmups + repetitions, each
operation individually timed; 'total' spans the whole run.
After the last run the output is validated: by default the round-trip is
compared against the input (err = sample standard deviation of
(input - roundtrip); err > eps marks the node failed), or by the client
class's own ``check`` hook for non-FFT workloads.  A failed node never
aborts the suite — it is recorded and the suite CONTINUES (paper behavior).
"""

from __future__ import annotations

import traceback
import warnings
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from .client import Context, Problem
from .plan import PlanCache, PlanRigor
from .results import ResultSink, ResultWriter, Row, columns_for
from .schedule import FFT_SCHEDULE, Runner
from .timer import Timer
from .tree import BenchNode
from .wisdom import Wisdom

# compile-time constants in gearshifft's cmake; options here
DEFAULT_ERROR_BOUND = 1e-5
DEFAULT_WARMUPS = 2
DEFAULT_REPS = 10

OPS = FFT_SCHEDULE.op_names   # ("allocate", ..., "destroy", "total")


class NoRunsError(RuntimeError):
    """Raised when a node produced no output to validate (repetitions=0 or
    the schedule never captured a download)."""


@dataclass
class BenchmarkConfig:
    warmups: int = DEFAULT_WARMUPS
    repetitions: int = DEFAULT_REPS
    error_bound: float = DEFAULT_ERROR_BOUND
    rigor: PlanRigor = PlanRigor.ESTIMATE
    output: str = "result.csv"
    seed: int = 2017  # year of the paper


def make_input(problem: Problem, seed: int) -> np.ndarray:
    """The paper fills buffers with a see-saw function on [0, 1)."""
    n = problem.n_elems
    saw = (np.arange(n, dtype=np.float64) % 512) / 512.0
    x = saw.reshape(problem.batch, *problem.extents).astype(problem.real_dtype)
    if problem.complex_input:
        x = x.astype(problem.input_dtype)
    return x


def roundtrip_error(x: np.ndarray, y: np.ndarray) -> float:
    """epsilon = sample standard deviation of (input - roundtrip) (paper §2.2)."""
    d = (x.astype(np.complex128) - y.astype(np.complex128)).ravel()
    n = d.size
    if n < 2:
        return float(np.abs(d).max(initial=0.0))
    mean = d.mean()
    return float(np.sqrt(np.sum(np.abs(d - mean) ** 2) / (n - 1)))


def run_node(node: BenchNode, *, context: Context, config: BenchmarkConfig,
             writer: ResultSink, plan_cache: Optional[PlanCache] = None,
             wisdom: Optional[Wisdom] = None, verbose: bool = False) -> None:
    """Drive one tree node through its schedule; record rows, never raise
    (a failed config is a recorded failure, paper continue-on-error policy)."""
    p = node.problem
    cfg = config
    base = dict(library=node.client_cls.title,
                device=getattr(context, "device_kind", "?"),
                extents="x".join(map(str, p.extents)), rank=p.rank,
                extent_class=node.extent_class, precision=p.precision,
                kind=p.kind, rigor=cfg.rigor.value)
    schedule = getattr(node.client_cls, "schedule", None) or FFT_SCHEDULE
    make_host = getattr(node.client_cls, "make_host_input", None)
    host_in = (make_host(p, cfg.seed) if make_host is not None
               else make_input(p, cfg.seed))
    runner = Runner(schedule, cfg.warmups, cfg.repetitions)

    # `on_record` fires after each run with the run's client still live in
    # `holder` — how every row of the run learns its plan's provenance
    # (exact wisdom hit vs interpolated wisdom_near vs real sweep)
    holder: dict = {}

    def emit(rec):
        # a warmup record carries only its cold-compile ops (negative
        # run index marks it as outside the counted repetitions)
        ops = (tuple(op for op, ev in rec.cache.items() if ev == "miss")
               if rec.warmup else schedule.op_names)
        source = getattr(holder.get("client"), "plan_source", "")
        for op in ops:
            writer.add(Row(**base, run=rec.run, op=op,
                           time_ms=rec.times[op],
                           bytes=rec.nbytes.get(op, 0),
                           plan_cache=rec.cache.get(op, ""),
                           plan_source=source))

    def make_client():
        holder["client"] = node.client_cls(p, context, rigor=cfg.rigor,
                                           wisdom=wisdom,
                                           plan_cache=plan_cache)
        return holder["client"]

    try:
        _, last_out = runner.run(make_client, host_in, on_record=emit)
        # validate AFTER the last run (paper: validated once at the end);
        # warmup-only output is not a measured result — don't bless it
        if cfg.repetitions <= 0 or last_out is None:
            raise NoRunsError(
                "no runs executed (repetitions=0 or download never ran)")
        check = getattr(node.client_cls, "check", None)
        if check is not None:
            ok, msg = check(p, host_in, last_out, cfg.error_bound)
            detail = msg or "ok"
        else:
            err = roundtrip_error(host_in, last_out.reshape(host_in.shape))
            ok = err <= cfg.error_bound
            msg = "" if ok else f"roundtrip_err={err:.3e}"
            detail = f"err={err:.2e}"
        writer.add(Row(**base, run=cfg.repetitions, op="validate",
                       time_ms=0.0, bytes=0, success=bool(ok),
                       error="" if ok else msg))
        if verbose:
            print(f"[{'ok' if ok else 'FAIL'}] {node.path} {detail}")
    except NoRunsError as e:
        # repetitions=0 / missing download: a clear report, not a
        # misleading AttributeError from validating a None output
        writer.add(Row(**base, run=0, op="validate", time_ms=0.0,
                       bytes=0, success=False, error=str(e)))
        if verbose:
            print(f"[SKIP] {node.path}: {e}")
    except Exception as e:  # failed config: record, continue with next node
        writer.add(Row(**base, run=0, op="validate", time_ms=0.0,
                       bytes=0, success=False,
                       error=f"{type(e).__name__}: {e}"))
        if verbose:
            print(f"[FAIL] {node.path}: {e}")
            traceback.print_exc()


def run_nodes(nodes: Sequence[BenchNode], *, context: Context,
              config: BenchmarkConfig, writer: ResultSink,
              plan_cache: Optional[PlanCache] = None,
              wisdom: Optional[Wisdom] = None,
              verbose: bool = False) -> ResultSink:
    """The suite loop: timed context create, every node, context destroy.

    This is the function both entry points share — ``Session.run`` (the
    supported API, see :mod:`repro.core.suite`) and the deprecated
    :meth:`Benchmark.run_nodes` shim.
    """
    with Timer() as t_ctx:
        context.create()
    writer.add(Row("context", getattr(context, "device_kind", "?"),
                   "-", 0, "-", "-", "-", "-", 0, "create_context",
                   t_ctx.time_ms))
    for node in nodes:
        run_node(node, context=context, config=config, writer=writer,
                 plan_cache=plan_cache, wisdom=wisdom, verbose=verbose)
    context.destroy()
    return writer


@dataclass
class Benchmark:
    """Deprecated suite driver — use :class:`repro.core.suite.Session` with a
    :class:`repro.core.suite.SuiteSpec` instead.

    Kept as a thin shim over :func:`run_nodes` so existing callers and tests
    keep passing.  ``plan_cache`` (optional) memoizes compiled executables
    across runs and adds a ``plan_cache`` hit/miss column to every row; with
    it left ``None`` the per-run recompile behavior and the original CSV
    schema are preserved exactly.
    """

    context: Context
    config: BenchmarkConfig = field(default_factory=BenchmarkConfig)
    writer: Optional[ResultSink] = None
    plan_cache: Optional[PlanCache] = None

    def __post_init__(self):
        if self.writer is None:
            self.writer = ResultWriter(
                self.config.output,
                columns=columns_for(self.plan_cache is not None))

    def run_nodes(self, nodes: Sequence[BenchNode],
                  wisdom: Optional[Wisdom] = None,
                  verbose: bool = False) -> ResultSink:
        warnings.warn(
            "Benchmark.run_nodes is deprecated; build a SuiteSpec and run it "
            "through repro.core.suite.Session (or run_suite)",
            DeprecationWarning, stacklevel=2)
        return run_nodes(nodes, context=self.context, config=self.config,
                         writer=self.writer, plan_cache=self.plan_cache,
                         wisdom=wisdom, verbose=verbose)
