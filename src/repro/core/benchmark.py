"""The benchmark runner — gearshifft's measurement core (paper §2.2, Fig. 1).

Per selected tree node:  context create (timed once per suite) ->
for each run in (warmups + repetitions):
    allocate -> init_forward -> upload -> execute_forward
    -> init_inverse -> execute_inverse -> download -> destroy
each operation individually timed; 'total' spans allocate..destroy.
After the last run the round-trip output is validated against the input:
err = sample standard deviation of (input - roundtrip); err > eps marks the
node failed and the suite CONTINUES with the next node (paper behavior).
"""

from __future__ import annotations

import traceback
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .client import Context, Problem
from .plan import PlanRigor
from .results import ResultWriter, Row
from .timer import Timer
from .tree import BenchNode

# compile-time constants in gearshifft's cmake; options here
DEFAULT_ERROR_BOUND = 1e-5
DEFAULT_WARMUPS = 2
DEFAULT_REPS = 10

OPS = ("allocate", "init_forward", "upload", "execute_forward",
       "init_inverse", "execute_inverse", "download", "destroy", "total")


@dataclass
class BenchmarkConfig:
    warmups: int = DEFAULT_WARMUPS
    repetitions: int = DEFAULT_REPS
    error_bound: float = DEFAULT_ERROR_BOUND
    rigor: PlanRigor = PlanRigor.ESTIMATE
    output: str = "result.csv"
    seed: int = 2017  # year of the paper


def make_input(problem: Problem, seed: int) -> np.ndarray:
    """The paper fills buffers with a see-saw function on [0, 1)."""
    n = problem.n_elems
    saw = (np.arange(n, dtype=np.float64) % 512) / 512.0
    x = saw.reshape(problem.batch, *problem.extents).astype(problem.real_dtype)
    if problem.complex_input:
        x = x.astype(problem.input_dtype)
    return x


def roundtrip_error(x: np.ndarray, y: np.ndarray) -> float:
    """epsilon = sample standard deviation of (input - roundtrip) (paper §2.2)."""
    d = (x.astype(np.complex128) - y.astype(np.complex128)).ravel()
    n = d.size
    if n < 2:
        return float(np.abs(d).max(initial=0.0))
    mean = d.mean()
    return float(np.sqrt(np.sum(np.abs(d - mean) ** 2) / (n - 1)))


@dataclass
class Benchmark:
    """Suite driver: configure(argv) + run(clients, extents...)."""

    context: Context
    config: BenchmarkConfig = field(default_factory=BenchmarkConfig)
    writer: ResultWriter = None

    def __post_init__(self):
        if self.writer is None:
            self.writer = ResultWriter(self.config.output)

    def run_nodes(self, nodes: Sequence[BenchNode], wisdom=None, verbose: bool = False) -> ResultWriter:
        with Timer() as t_ctx:
            self.context.create()
        self.writer.add(Row("context", getattr(self.context, "device_kind", "?"),
                            "-", 0, "-", "-", "-", "-", 0, "create_context",
                            t_ctx.time_ms))
        for node in nodes:
            self._run_node(node, wisdom, verbose)
        self.context.destroy()
        return self.writer

    # ------------------------------------------------------------------
    def _run_node(self, node: BenchNode, wisdom, verbose: bool) -> None:
        p = node.problem
        cfg = self.config
        base = dict(library=node.client_cls.title,
                    device=getattr(self.context, "device_kind", "?"),
                    extents="x".join(map(str, p.extents)), rank=p.rank,
                    extent_class=node.extent_class, precision=p.precision,
                    kind=p.kind, rigor=cfg.rigor.value)
        host_in = make_input(p, cfg.seed)
        last_out = None
        try:
            for run in range(-cfg.warmups, cfg.repetitions):
                client = node.client_cls(p, self.context, rigor=cfg.rigor, wisdom=wisdom)
                times: dict[str, float] = {}
                t_total = Timer().start()
                with Timer() as t:
                    client.allocate()
                times["allocate"] = t.time_ms
                with Timer() as t:
                    client.init_forward()
                times["init_forward"] = t.time_ms
                with Timer() as t:
                    client.upload(host_in)
                times["upload"] = t.time_ms
                with Timer() as t:
                    client.execute_forward()
                times["execute_forward"] = t.time_ms
                with Timer() as t:
                    client.init_inverse()
                times["init_inverse"] = t.time_ms
                with Timer() as t:
                    client.execute_inverse()
                times["execute_inverse"] = t.time_ms
                with Timer() as t:
                    last_out = client.download()
                times["download"] = t.time_ms
                with Timer() as t:
                    client.destroy()
                times["destroy"] = t.time_ms
                times["total"] = t_total.stop()
                if run >= 0:  # warmup runs are not recorded
                    nbytes = {"upload": client.get_transfer_size(),
                              "download": client.get_transfer_size(),
                              "allocate": client.get_alloc_size(),
                              "init_forward": client.get_plan_size(),
                              "init_inverse": client.get_plan_size()}
                    for op in OPS:
                        self.writer.add(Row(**base, run=run, op=op,
                                            time_ms=times[op],
                                            bytes=nbytes.get(op, 0)))
            # validate AFTER the last run (paper: validated once at the end)
            err = roundtrip_error(host_in, last_out.reshape(host_in.shape))
            ok = err <= cfg.error_bound
            self.writer.add(Row(**base, run=cfg.repetitions, op="validate",
                                time_ms=0.0, bytes=0, success=bool(ok),
                                error="" if ok else f"roundtrip_err={err:.3e}"))
            if verbose:
                print(f"[{'ok' if ok else 'FAIL'}] {node.path} err={err:.2e}")
        except Exception as e:  # failed config: record, continue with next node
            self.writer.add(Row(**base, run=0, op="validate", time_ms=0.0,
                                bytes=0, success=False,
                                error=f"{type(e).__name__}: {e}"))
            if verbose:
                print(f"[FAIL] {node.path}: {e}")
                traceback.print_exc()
