"""Wisdom: persisted planner decisions (fftw's wisdom files, §2.1).

A wisdom store maps a problem signature (extents/precision/kind/batch +
device kind) to the winning Candidate from a MEASURE/PATIENT run.  Stored as
JSON next to the results so WISDOM_ONLY runs are reproducible.

Schema v3 grows each record with the *provenance the cost-model fitter
consumes* — the winner's measured time and the rigor that produced the
knobs — and the store with **nearest-neighbor interpolation**
(:meth:`Wisdom.lookup_near`): an exact miss falls back to the selection
tuned for the closest shape in the same backend-feasibility class, so
unseen shapes get a MEASURE-grade warm start instead of a cold PATIENT
sweep.  v1 (pre-versioning) and v2 files still load unchanged.

Offline pre-generation lives in ``tools/pregen_wisdom.py`` (the
``fftwf-wisdom`` analogue, paper §3.3); the :func:`generate`/:func:`main`
entry points here are deprecated shims kept for callers of the old
``python -m repro.core.wisdom`` interface.
"""

from __future__ import annotations

import json
import math
import os
import tempfile
import threading
import warnings
from typing import Optional

from .client import Problem
from .candidates import (BACKENDS, Candidate, backend_supports)
from .costmodel import estimate_bytes_moved
from .extents import classify, parse_extents
from .breaker import problem_class

# re-exported for compatibility: historical imports got these via wisdom
from .plan import PlanRigor  # noqa: F401


DEFAULT_PATH = os.path.expanduser("~/.cache/repro/wisdom.json")

#: Schema version stamped into every record this writer produces.  Loaders
#: keep records at or below their own version (missing ``v`` = version 1,
#: the pre-versioning layout) and skip-and-warn on anything newer or
#: malformed — a future writer sharing the file must never crash this one.
#: v2 added per-axis/mesh candidate fields; v3 adds ``measured_ms`` +
#: ``rigor`` provenance (consumed by tools/fit_costmodel.py) and the
#: nearest-neighbor ``lookup_near`` read path.
WISDOM_SCHEMA_VERSION = 3

#: Store key holding backend demotions (known-bad picks), not a selection:
#: ``{f"{device_kind}|{problem_class}": [backend, ...]}``.
_DEMOTED_KEY = "__demoted__"

#: Candidate knobs that encode a *shape-specific* tuning decision — a
#: nearest-neighbor warm start must drop them when the extents differ
#: (``split_n1`` names an n1*n2 factorization of the neighbor's length;
#: ``engine`` is gated on the neighbor's padded chirp length).  Batch
#: tiles and radix schedules transfer across nearby shapes.
_SHAPE_KNOBS = frozenset({"split_n1", "engine"})


def _candidate_to_record(cand: Candidate) -> dict:
    rec = {"v": WISDOM_SCHEMA_VERSION, "backend": cand.backend,
           "options": [list(kv) for kv in cand.options]}
    if cand.axes:   # per-axis ND assignment: recurse (old records omit it)
        rec["axes"] = [_candidate_to_record(a) for a in cand.axes]
    if cand.mesh:   # distributed: mesh shape is part of the selection
        rec["mesh"] = list(cand.mesh)
    return rec


def _candidate_from_record(rec: dict) -> Candidate:
    # .get defaults keep every legacy record (no axes/mesh field) loading
    return Candidate(rec["backend"],
                     tuple((k, v) for k, v in rec["options"]),
                     tuple(_candidate_from_record(a)
                           for a in rec.get("axes", ())),
                     tuple(int(s) for s in rec.get("mesh", ())))


def _strip_shape_knobs(cand: Candidate) -> Candidate:
    """A copy of ``cand`` without the shape-specific knobs (recursively for
    per-axis assignments) — what a neighbor's tuning legitimately transfers."""
    opts = tuple(kv for kv in cand.options if kv[0] not in _SHAPE_KNOBS)
    axes = tuple(_strip_shape_knobs(a) for a in cand.axes)
    return Candidate(cand.backend, opts, axes, cand.mesh)


def _feasibility_class(problem: Problem) -> frozenset:
    """The set of backends that support ``problem`` — interpolation never
    crosses this boundary: a neighbor whose support set differs (a cap or
    packing rule flips somewhere between the two shapes) is no neighbor."""
    return frozenset(b for b in BACKENDS if backend_supports(b, problem))


class Wisdom:
    """In-memory map is guarded by a lock so serving workers can look up,
    record, and save concurrently; the *file* side was already safe (atomic
    mkstemp + os.replace writes with merge-on-save, below)."""

    def __init__(self, path: str = DEFAULT_PATH, device_kind: str = ""):
        self.path = path
        self.device_kind = device_kind
        self._lock = threading.RLock()
        self._store: dict[str, dict] = self._read_disk()

    def _read_disk(self) -> dict:
        """Best-effort load: a missing file is an empty store, and so is a
        corrupt/truncated one (warn, don't crash) — a concurrent session
        must never take the whole benchmark down.  Individual entries are
        validated too: an unparseable record or one written by a future
        schema version is skipped with a warning rather than poisoning the
        load (see :data:`WISDOM_SCHEMA_VERSION`)."""
        try:
            with open(self.path) as f:
                store = json.load(f)
            if not isinstance(store, dict):
                raise ValueError(f"wisdom root is {type(store).__name__}")
        except FileNotFoundError:
            return {}
        except (json.JSONDecodeError, OSError, ValueError) as e:
            warnings.warn(f"ignoring unreadable wisdom at {self.path}: {e}")
            return {}
        clean: dict[str, dict] = {}
        for key, rec in store.items():
            why = self._invalid_reason(key, rec)
            if why is None:
                clean[key] = rec
            else:
                warnings.warn(
                    f"skipping wisdom entry {key!r} in {self.path}: {why}")
        return clean

    @staticmethod
    def _invalid_reason(key: str, rec) -> Optional[str]:
        """None for a loadable entry, else a human-readable skip reason."""
        if key == _DEMOTED_KEY:
            if isinstance(rec, dict) and all(
                    isinstance(v, list) and all(isinstance(b, str) for b in v)
                    for v in rec.values()):
                return None
            return "malformed demotion table"
        if not isinstance(rec, dict):
            return f"record is {type(rec).__name__}, not an object"
        v = rec.get("v", 1)
        if not isinstance(v, int) or v < 1:
            return f"bad schema version {v!r}"
        if v > WISDOM_SCHEMA_VERSION:
            return (f"schema version {v} is newer than this reader "
                    f"(v{WISDOM_SCHEMA_VERSION})")
        if not isinstance(rec.get("backend"), str) \
                or not isinstance(rec.get("options"), list):
            return "missing/malformed backend or options"
        ms = rec.get("measured_ms")
        if ms is not None and not isinstance(ms, (int, float)):
            return f"malformed measured_ms {ms!r}"
        try:
            _candidate_from_record(rec)
        except Exception as e:
            return f"unparseable candidate ({type(e).__name__}: {e})"
        return None

    def _key(self, problem: Problem, scope: str = "") -> str:
        """Unscoped keys hold the open planner's (Planned client) choices —
        the original store layout, so existing wisdom files stay valid.  A
        ``scope`` (the pinned client's backend) namespaces per-library
        tuning, mirroring gearshifft's one-wisdom-file-per-binary: a knob
        sweep won by StockhamPallas must not overwrite the open planner's
        cross-backend winner for the same problem."""
        base = f"{self.device_kind}|{problem.signature()}"
        return f"{base}|{scope}" if scope else base

    def _parse_key(self, key: str, scope: str = "") -> Optional[Problem]:
        """Invert :meth:`_key` for entries in this store's device kind and
        ``scope`` namespace; None for any other (or unparseable) key."""
        prefix = f"{self.device_kind}|"
        if not key.startswith(prefix):
            return None
        rest = key[len(prefix):]
        if scope:
            suffix = f"|{scope}"
            if not rest.endswith(suffix):
                return None
            rest = rest[:-len(suffix)]
        if "|" in rest:     # a differently-scoped (or demotion) entry
            return None
        parts = rest.split("/")
        if len(parts) != 4 or not parts[3].startswith("b"):
            return None
        try:
            return Problem(parse_extents(parts[0]), parts[2], parts[1],
                           batch=int(parts[3][1:]))
        except Exception:
            return None

    def lookup(self, problem: Problem, scope: str = "") -> Optional[Candidate]:
        with self._lock:
            rec = self._store.get(self._key(problem, scope))
        if rec is None:
            return None
        return _candidate_from_record(rec)

    def lookup_near(self, problem: Problem, scope: str = ""
                    ) -> Optional[tuple[Candidate, str]]:
        """Nearest-neighbor interpolation over (extent, batch, rank): the
        selection persisted for the closest shape in the same
        backend-feasibility class, with shape-specific knobs stripped.

        Returns ``(candidate, neighbor_key)`` or None.  'Closest' is
        Euclidean distance in log2 space over the per-axis extents and
        batch — the resolution at which transform behavior actually
        changes.  A neighbor never crosses a feasibility boundary: it must
        share the query's rank, extent class, and full backend-support set
        (see :func:`_feasibility_class`), its candidate must itself be
        feasible for the query, and mesh-shaped (distributed) selections
        never transfer — a decomposition tuned for one device count is
        meaningless for another shape on another mesh.
        """
        exts_q = problem.extents
        class_q = classify(exts_q)
        feas_q = None       # computed lazily: most stores miss outright
        best: Optional[tuple[float, str, Candidate]] = None
        with self._lock:
            items = [(k, rec) for k, rec in self._store.items()
                     if k != _DEMOTED_KEY]
        for key, rec in items:
            neighbor = self._parse_key(key, scope)
            if neighbor is None or (neighbor.extents == exts_q
                                    and neighbor.batch == problem.batch):
                continue    # foreign namespace, or the exact key (a miss
                            # here means the caller already tried it)
            if (neighbor.rank != problem.rank
                    or neighbor.kind != problem.kind
                    or neighbor.precision != problem.precision
                    or classify(neighbor.extents) != class_q):
                continue
            if feas_q is None:
                feas_q = _feasibility_class(problem)
            if _feasibility_class(neighbor) != feas_q:
                continue
            try:
                cand = _candidate_from_record(rec)
            except Exception:
                continue
            if cand.mesh:
                continue
            if neighbor.extents != exts_q:
                cand = _strip_shape_knobs(cand)
            if cand.backend != "nd" and cand.backend not in feas_q:
                continue
            if estimate_bytes_moved(problem, cand) == float("inf"):
                continue    # per-axis assignment infeasible at these extents
            d = sum((math.log2(a) - math.log2(b)) ** 2
                    for a, b in zip(exts_q, neighbor.extents))
            d += (math.log2(problem.batch) - math.log2(neighbor.batch)) ** 2
            if best is None or (d, key) < (best[0], best[1]):
                best = (d, key, cand)
        if best is None:
            return None
        return best[2], best[1]

    def record(self, problem: Problem, cand: Candidate, scope: str = "",
               measured_ms: Optional[float] = None,
               rigor: Optional[str] = None) -> None:
        """Persist a selection; ``measured_ms`` (the winner's timed
        best-of-reps) and ``rigor`` record the provenance the cost-model
        fitter trains on.  Both are optional so legacy call sites — and
        selections that were never timed — keep writing valid records."""
        rec = _candidate_to_record(cand)
        if measured_ms is not None and measured_ms == measured_ms:
            rec["measured_ms"] = float(measured_ms)
        if rigor is not None:
            rec["rigor"] = str(rigor)
        with self._lock:
            self._store[self._key(problem, scope)] = rec

    # --- demotions: known-bad (backend, problem-class) pairs --------------
    def _demote_key(self, problem: Problem) -> str:
        return f"{self.device_kind}|{problem_class(problem)}"

    def record_demotion(self, problem: Problem, backend: str) -> None:
        """Persistably quarantine ``backend`` for this problem-class: warm
        sessions (and the planner's ESTIMATE path) skip it outright."""
        with self._lock:
            table = self._store.setdefault(_DEMOTED_KEY, {})
            row = table.setdefault(self._demote_key(problem), [])
            if backend not in row:
                row.append(backend)

    def demoted(self, problem: Problem) -> frozenset:
        with self._lock:
            table = self._store.get(_DEMOTED_KEY, {})
            return frozenset(table.get(self._demote_key(problem), ()))

    def measurements(self) -> list[tuple[Problem, Candidate, float]]:
        """Every v3 record carrying a measured time, parsed — the fitter's
        training rows from this store's device kind (any scope)."""
        out = []
        with self._lock:
            items = list(self._store.items())
        for key, rec in items:
            if key == _DEMOTED_KEY or not isinstance(rec, dict):
                continue
            ms = rec.get("measured_ms")
            if not isinstance(ms, (int, float)):
                continue
            # accept scoped keys too: strip a trailing |scope namespace
            problem = self._parse_key(key)
            if problem is None and key.count("|") >= 2:
                problem = self._parse_key(key[:key.rfind("|")])
            if problem is None:
                continue
            try:
                out.append((problem, _candidate_from_record(rec), float(ms)))
            except Exception:
                continue
        return out

    def save(self) -> None:
        """Atomic, concurrent-tolerant write.

        Merge-on-save: entries another session persisted since our load are
        re-read and kept.  Conflicting selections keep ours (they're newer),
        but **field-wise**: v3 provenance fields (``measured_ms``/``rigor``)
        another session attached to the same key survive a save by a writer
        that didn't set them — concurrent saves union-merge v3 fields.
        The temp file is uniquely named (mkstemp, not a fixed ``.tmp`` two
        racing sessions would clobber), fsync'd, then os.replace'd — readers
        always see a complete JSON document, never a torn write.
        """
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        with self._lock:
            merged = self._read_disk()
            # demotions union across sessions: a pair one session proved bad
            # stays quarantined even when another session saves concurrently
            disk_dem = merged.get(_DEMOTED_KEY, {})
            ours_dem = self._store.get(_DEMOTED_KEY, {})
            union = {k: list(v) for k, v in disk_dem.items()}
            for k, backends in ours_dem.items():
                row = union.setdefault(k, [])
                row += [b for b in backends if b not in row]
            for k, rec in self._store.items():
                if k == _DEMOTED_KEY:
                    continue
                disk_rec = merged.get(k)
                if isinstance(disk_rec, dict) and isinstance(rec, dict) \
                        and disk_rec.get("backend") == rec.get("backend") \
                        and disk_rec.get("options") == rec.get("options"):
                    # same selection: union the provenance fields
                    merged[k] = {**disk_rec, **rec}
                else:
                    merged[k] = rec
            if union:
                merged[_DEMOTED_KEY] = union
            self._store = merged
            snapshot = dict(merged)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".wisdom-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(snapshot, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        with self._lock:
            return len(self._store) - (_DEMOTED_KEY in self._store)


def generate(sizes, path: str = DEFAULT_PATH, rigor: PlanRigor = PlanRigor.PATIENT,
             kinds=("Outplace_Real", "Outplace_Complex"), precisions=("float",)) -> Wisdom:
    """Deprecated: use ``tools/pregen_wisdom.py`` (it sweeps the full
    support matrix, records v3 provenance, and ships checked-in packs)."""
    warnings.warn(
        "repro.core.wisdom.generate is deprecated; use tools/pregen_wisdom.py",
        DeprecationWarning, stacklevel=2)
    import jax
    from .plan import make_plan
    from .clients.jax_fft import build_forward

    wisdom = Wisdom(path, device_kind=jax.devices()[0].device_kind)
    for ext in sizes:
        for kind in kinds:
            for prec in precisions:
                problem = Problem(tuple(ext), kind, prec)
                # near=False: every swept shape gets a real sweep — a
                # pregeneration run must not inherit its neighbor's pick
                make_plan(problem, rigor, build=lambda c: build_forward(problem, c),
                          wisdom=wisdom, near=False)
    wisdom.save()
    return wisdom


def main(argv=None) -> None:
    """Deprecated CLI shim: forwards to ``tools/pregen_wisdom.py``."""
    warnings.warn(
        "python -m repro.core.wisdom is deprecated; "
        "use tools/pregen_wisdom.py", DeprecationWarning, stacklevel=2)
    import argparse

    p = argparse.ArgumentParser(description="pre-generate repro FFT wisdom "
                                "(deprecated: use tools/pregen_wisdom.py)")
    p.add_argument("-o", "--output", default=DEFAULT_PATH)
    p.add_argument("--max-exp", type=int, default=12,
                   help="powers of two up to 2^max_exp (1D) / 2^(max_exp//3*3) (3D)")
    args = p.parse_args(argv)
    sizes = [(2 ** e,) for e in range(1, args.max_exp + 1)]
    sizes += [(2 ** e,) * 3 for e in range(1, args.max_exp // 3 + 1)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        w = generate(sizes, args.output)
    print(f"wrote {len(w)} wisdom entries to {args.output}")


if __name__ == "__main__":
    main()
