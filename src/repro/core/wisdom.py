"""Wisdom: persisted planner decisions (fftw's wisdom files, §2.1).

A wisdom store maps a problem signature (extents/precision/kind/batch +
device kind) to the winning Candidate from a MEASURE/PATIENT run.  Stored as
JSON next to the results so WISDOM_ONLY runs are reproducible; the
``python -m repro.core.wisdom`` entry point mirrors the ``fftwf-wisdom``
pre-generation binary (paper §3.3).
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import warnings
from typing import Optional

from .client import Problem
from .plan import Candidate, PlanRigor, problem_class


DEFAULT_PATH = os.path.expanduser("~/.cache/repro/wisdom.json")

#: Schema version stamped into every record this writer produces.  Loaders
#: keep records at or below their own version (missing ``v`` = version 1,
#: the pre-versioning layout) and skip-and-warn on anything newer or
#: malformed — a future writer sharing the file must never crash this one.
WISDOM_SCHEMA_VERSION = 2

#: Store key holding backend demotions (known-bad picks), not a selection:
#: ``{f"{device_kind}|{problem_class}": [backend, ...]}``.
_DEMOTED_KEY = "__demoted__"


def _candidate_to_record(cand: Candidate) -> dict:
    rec = {"v": WISDOM_SCHEMA_VERSION, "backend": cand.backend,
           "options": [list(kv) for kv in cand.options]}
    if cand.axes:   # per-axis ND assignment: recurse (old records omit it)
        rec["axes"] = [_candidate_to_record(a) for a in cand.axes]
    if cand.mesh:   # distributed: mesh shape is part of the selection
        rec["mesh"] = list(cand.mesh)
    return rec


def _candidate_from_record(rec: dict) -> Candidate:
    # .get defaults keep every legacy record (no axes/mesh field) loading
    return Candidate(rec["backend"],
                     tuple((k, v) for k, v in rec["options"]),
                     tuple(_candidate_from_record(a)
                           for a in rec.get("axes", ())),
                     tuple(int(s) for s in rec.get("mesh", ())))


class Wisdom:
    """In-memory map is guarded by a lock so serving workers can look up,
    record, and save concurrently; the *file* side was already safe (atomic
    mkstemp + os.replace writes with merge-on-save, below)."""

    def __init__(self, path: str = DEFAULT_PATH, device_kind: str = ""):
        self.path = path
        self.device_kind = device_kind
        self._lock = threading.RLock()
        self._store: dict[str, dict] = self._read_disk()

    def _read_disk(self) -> dict:
        """Best-effort load: a missing file is an empty store, and so is a
        corrupt/truncated one (warn, don't crash) — a concurrent session
        must never take the whole benchmark down.  Individual entries are
        validated too: an unparseable record or one written by a future
        schema version is skipped with a warning rather than poisoning the
        load (see :data:`WISDOM_SCHEMA_VERSION`)."""
        try:
            with open(self.path) as f:
                store = json.load(f)
            if not isinstance(store, dict):
                raise ValueError(f"wisdom root is {type(store).__name__}")
        except FileNotFoundError:
            return {}
        except (json.JSONDecodeError, OSError, ValueError) as e:
            warnings.warn(f"ignoring unreadable wisdom at {self.path}: {e}")
            return {}
        clean: dict[str, dict] = {}
        for key, rec in store.items():
            why = self._invalid_reason(key, rec)
            if why is None:
                clean[key] = rec
            else:
                warnings.warn(
                    f"skipping wisdom entry {key!r} in {self.path}: {why}")
        return clean

    @staticmethod
    def _invalid_reason(key: str, rec) -> Optional[str]:
        """None for a loadable entry, else a human-readable skip reason."""
        if key == _DEMOTED_KEY:
            if isinstance(rec, dict) and all(
                    isinstance(v, list) and all(isinstance(b, str) for b in v)
                    for v in rec.values()):
                return None
            return "malformed demotion table"
        if not isinstance(rec, dict):
            return f"record is {type(rec).__name__}, not an object"
        v = rec.get("v", 1)
        if not isinstance(v, int) or v < 1:
            return f"bad schema version {v!r}"
        if v > WISDOM_SCHEMA_VERSION:
            return (f"schema version {v} is newer than this reader "
                    f"(v{WISDOM_SCHEMA_VERSION})")
        if not isinstance(rec.get("backend"), str) \
                or not isinstance(rec.get("options"), list):
            return "missing/malformed backend or options"
        try:
            _candidate_from_record(rec)
        except Exception as e:
            return f"unparseable candidate ({type(e).__name__}: {e})"
        return None

    def _key(self, problem: Problem, scope: str = "") -> str:
        """Unscoped keys hold the open planner's (Planned client) choices —
        the original store layout, so existing wisdom files stay valid.  A
        ``scope`` (the pinned client's backend) namespaces per-library
        tuning, mirroring gearshifft's one-wisdom-file-per-binary: a knob
        sweep won by StockhamPallas must not overwrite the open planner's
        cross-backend winner for the same problem."""
        base = f"{self.device_kind}|{problem.signature()}"
        return f"{base}|{scope}" if scope else base

    def lookup(self, problem: Problem, scope: str = "") -> Optional[Candidate]:
        with self._lock:
            rec = self._store.get(self._key(problem, scope))
        if rec is None:
            return None
        return _candidate_from_record(rec)

    def record(self, problem: Problem, cand: Candidate, scope: str = "") -> None:
        with self._lock:
            self._store[self._key(problem, scope)] = _candidate_to_record(cand)

    # --- demotions: known-bad (backend, problem-class) pairs --------------
    def _demote_key(self, problem: Problem) -> str:
        return f"{self.device_kind}|{problem_class(problem)}"

    def record_demotion(self, problem: Problem, backend: str) -> None:
        """Persistably quarantine ``backend`` for this problem-class: warm
        sessions (and the planner's ESTIMATE path) skip it outright."""
        with self._lock:
            table = self._store.setdefault(_DEMOTED_KEY, {})
            row = table.setdefault(self._demote_key(problem), [])
            if backend not in row:
                row.append(backend)

    def demoted(self, problem: Problem) -> frozenset:
        with self._lock:
            table = self._store.get(_DEMOTED_KEY, {})
            return frozenset(table.get(self._demote_key(problem), ()))

    def save(self) -> None:
        """Atomic, concurrent-tolerant write.

        Merge-on-save: entries another session persisted since our load are
        re-read and kept (our selections win conflicts — they're newer).
        The temp file is uniquely named (mkstemp, not a fixed ``.tmp`` two
        racing sessions would clobber), fsync'd, then os.replace'd — readers
        always see a complete JSON document, never a torn write.
        """
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        with self._lock:
            merged = self._read_disk()
            # demotions union across sessions: a pair one session proved bad
            # stays quarantined even when another session saves concurrently
            disk_dem = merged.get(_DEMOTED_KEY, {})
            ours_dem = self._store.get(_DEMOTED_KEY, {})
            union = {k: list(v) for k, v in disk_dem.items()}
            for k, backends in ours_dem.items():
                row = union.setdefault(k, [])
                row += [b for b in backends if b not in row]
            merged.update(self._store)
            if union:
                merged[_DEMOTED_KEY] = union
            self._store = merged
            snapshot = dict(merged)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".wisdom-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(snapshot, f, indent=1, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def __len__(self) -> int:
        with self._lock:
            return len(self._store) - (_DEMOTED_KEY in self._store)


def generate(sizes, path: str = DEFAULT_PATH, rigor: PlanRigor = PlanRigor.PATIENT,
             kinds=("Outplace_Real", "Outplace_Complex"), precisions=("float",)) -> Wisdom:
    """Pre-plan a canonical size set (the fftwf-wisdom analogue)."""
    import jax
    from .plan import make_plan
    from .clients.jax_fft import build_forward

    wisdom = Wisdom(path, device_kind=jax.devices()[0].device_kind)
    for ext in sizes:
        for kind in kinds:
            for prec in precisions:
                problem = Problem(tuple(ext), kind, prec)
                make_plan(problem, rigor, build=lambda c: build_forward(problem, c),
                          wisdom=wisdom)
    wisdom.save()
    return wisdom


def main() -> None:
    p = argparse.ArgumentParser(description="pre-generate repro FFT wisdom")
    p.add_argument("-o", "--output", default=DEFAULT_PATH)
    p.add_argument("--max-exp", type=int, default=12,
                   help="powers of two up to 2^max_exp (1D) / 2^(max_exp//3*3) (3D)")
    args = p.parse_args()
    sizes = [(2 ** e,) for e in range(1, args.max_exp + 1)]
    sizes += [(2 ** e,) * 3 for e in range(1, args.max_exp // 3 + 1)]
    w = generate(sizes, args.output)
    print(f"wrote {len(w)} wisdom entries to {args.output}")


if __name__ == "__main__":
    main()
