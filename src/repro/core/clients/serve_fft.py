"""ServeFFT: the serving engine driven through the Table-1 timed path.

The other clients measure one transform on a quiet device; this one
measures the *service* — a burst of same-problem requests submitted
through :class:`repro.serve.FFTService` so the timed ``execute_forward``
covers queueing, coalescing into batched launches, and result scatter.
It is the bridge that lets the suite machinery (SuiteSpec trees,
ResultSet aggregation, bench_compare trajectories) benchmark the serving
layer with zero new driver code.

Schedule mapping (serving has no inverse path — forward only):

    allocate         construct + start the service (threads, queue)
    init_forward     warm the plan: one probe request pays any cold
                     plan/compile (hit/miss recorded from the shared cache)
    upload           stage the burst: K copies of the host input
    execute_forward  submit the K-request burst, wait for every result
    download         first result (validation input)
    destroy          drain + stop the service

Context options (all ``serve_``-prefixed): ``serve_burst`` (requests per
measured burst, default 8), ``serve_window_ms``, ``serve_max_batch``,
``serve_workers``, ``serve_inflight``, ``serve_backend`` (pin one
backend, e.g. per-library bench fan-out).
"""

from __future__ import annotations

import numpy as np

from ..client import Context, FFTClient, Problem
from ..plan import PlanCache, PlanRigor
from ..registry import register_client
from ..schedule import OpSchedule, OpStep
from ..wisdom import Wisdom

#: Table-1 minus the inverse steps: a service serves forward transforms.
SERVE_SCHEDULE = OpSchedule("serve", (
    OpStep("allocate", "allocate", bytes_method="get_alloc_size"),
    OpStep("init_forward", "init_forward", bytes_method="get_plan_size"),
    OpStep("upload", "upload", needs_input=True,
           bytes_method="get_transfer_size"),
    OpStep("execute_forward", "execute_forward"),
    OpStep("download", "download", captures_output=True,
           bytes_method="get_transfer_size"),
    OpStep("destroy", "destroy"),
))


@register_client()
class ServeFFTClient(FFTClient):
    title = "ServeFFT"
    schedule = SERVE_SCHEDULE

    def __init__(self, problem: Problem, context: Context,
                 rigor: PlanRigor | None = None, wisdom: Wisdom | None = None,
                 plan_cache: PlanCache | None = None):
        super().__init__(problem, context)
        if problem.inplace:
            # the service always scatters results out of a fresh batch
            # buffer; claiming in-place semantics would be a lie
            raise ValueError("ServeFFT supports out-of-place kinds only")
        opts = context.options
        self.burst = int(opts.get("serve_burst", 8))
        if self.burst < 1:
            raise ValueError(f"serve_burst must be >= 1, got {self.burst}")
        self.rigor = rigor if rigor is not None else PlanRigor.ESTIMATE
        self.wisdom = wisdom
        self.plan_cache = plan_cache
        self.cache_events: dict[str, str] = {}
        from repro.serve import ServeConfig

        self._config = ServeConfig(
            coalesce_window_ms=float(opts.get("serve_window_ms", 2.0)),
            max_batch=max(int(opts.get("serve_max_batch", 32)),
                          problem.batch),
            workers=int(opts.get("serve_workers", 1)),
            inflight=int(opts.get("serve_inflight", 2)),
            rigor=self.rigor.value if isinstance(self.rigor, PlanRigor)
            else str(self.rigor),
            backend=opts.get("serve_backend"),
            record_requests=False)   # the Runner records; don't double-book
        self._service = None
        self._host = None
        self._results: list[np.ndarray] = []

    # --- memory -----------------------------------------------------------
    def allocate(self) -> None:
        from ..suite import Session
        from repro.serve import FFTService

        session = Session(context=self.context, plan_cache=self.plan_cache,
                          wisdom=self.wisdom)
        self._service = FFTService(session=session, config=self._config,
                                   wisdom=self.wisdom).start()

    def destroy(self) -> None:
        if self._service is not None:
            self._service.stop()
            self._service = None
        self._host = None
        self._results = []

    def get_alloc_size(self) -> int:
        # staging + device batch buffer at the coalesced bucket size
        per_row = self.problem.signal_bytes // max(self.problem.batch, 1)
        return 2 * self._config.max_batch * per_row

    def get_transfer_size(self) -> int:
        return self.burst * self.problem.signal_bytes

    # --- planning ---------------------------------------------------------
    def init_forward(self) -> None:
        """Warm the plan + executable with one probe request, so the cold
        compile is attributed here (like every other client) and the timed
        burst measures steady-state serving."""
        stats = self._service.session.plan_cache.stats
        misses0 = stats.misses
        probe = np.zeros((self.problem.batch, *self.problem.extents),
                         dtype=self.problem.input_dtype)
        req = self._service.submit(probe, kind=self.problem.kind,
                                   precision=self.problem.precision,
                                   rank=self.problem.rank)
        req.result(timeout=600)
        self.cache_events["init_forward"] = (
            "miss" if stats.misses > misses0 else "hit")

    def init_inverse(self) -> None:
        raise NotImplementedError("ServeFFT serves forward transforms only")

    # --- execution ---------------------------------------------------------
    def execute_forward(self) -> None:
        reqs = [self._service.submit(self._host, kind=self.problem.kind,
                                     precision=self.problem.precision,
                                     rank=self.problem.rank)
                for _ in range(self.burst)]
        self._results = [np.asarray(r.result(timeout=600)) for r in reqs]

    def execute_inverse(self) -> None:
        raise NotImplementedError("ServeFFT serves forward transforms only")

    # --- transfer ----------------------------------------------------------
    def upload(self, host_data: np.ndarray) -> None:
        self._host = np.asarray(host_data).reshape(
            (self.problem.batch, *self.problem.extents))

    def download(self) -> np.ndarray:
        return self._results[0]

    # --- validation ---------------------------------------------------------
    @classmethod
    def check(cls, problem: Problem, host_in: np.ndarray, out: np.ndarray,
              error_bound: float) -> tuple[bool, str]:
        """Forward-only validation against the numpy reference (there is no
        inverse leg to round-trip through)."""
        x = np.asarray(host_in).reshape((problem.batch, *problem.extents))
        axes = tuple(range(-problem.rank, 0))
        if problem.complex_input:
            ref = np.fft.fftn(x.astype(np.complex128), axes=axes)
        else:
            ref = np.fft.rfftn(x.astype(np.float64), axes=axes)
        got = np.asarray(out).reshape(ref.shape).astype(np.complex128)
        scale = float(np.max(np.abs(ref)) or 1.0)
        err = float(np.max(np.abs(got - ref))) / scale
        # float32 transforms accumulate more rounding than the paper's 1e-5
        # roundtrip bound allows for a one-way spectrum comparison
        bound = max(error_bound, 1e-4 if problem.precision == "float"
                    else 1e-10)
        ok = err <= bound
        return ok, "" if ok else f"forward_err={err:.3e} > {bound:g}"
