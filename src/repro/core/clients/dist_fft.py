"""Distributed-FFT client: the mesh-parallel 1D four-step transform
(repro.fft.distributed) driven through the SAME Table-1 timed path as the
single-device libraries — the FFTW-MPI / cuFFTMp "binary" of the suite.

The forward transform emits the FFTW_MPI_TRANSPOSED_OUT spectrum layout; the
inverse consumes it directly (TRANSPOSED_IN), so the measured round trip is
the production layout-aware path with two all_to_alls per direction and no
reordering pass.  On a single-device host the mesh degenerates to P=1 and
the collectives are identity — the same code path the pod runs.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..client import Context, FFTClient, Problem
from ..plan import PlanCache, PlanRigor, cached_build, executable_bytes
from ..registry import register_client
from ..wisdom import Wisdom
from repro.fft import distributed as dist


@register_client()
class DistFFT1DClient(FFTClient):
    """1D distributed four-step FFT over all visible devices.

    Constraints (recorded as node failures, not suite aborts): rank-1
    complex transforms, batch 1, and n must factor as n1*n2 with the device
    count dividing n1.
    """

    title = "DistFFT1D"

    def __init__(self, problem: Problem, context: Context,
                 rigor: PlanRigor | None = None, wisdom: Wisdom | None = None,
                 plan_cache: PlanCache | None = None):
        super().__init__(problem, context)
        if problem.rank != 1:
            raise ValueError("DistFFT1D supports rank-1 transforms only")
        if not problem.complex_input:
            raise ValueError("DistFFT1D supports complex kinds only")
        if problem.batch != 1:
            raise ValueError("DistFFT1D supports batch=1 only")
        self.plan_cache = plan_cache
        self.cache_events: dict[str, str] = {}
        self._n = problem.extents[0]
        self._mesh = None
        self._sharding = None
        self._buf = None
        self._spec = None
        self._fwd_compiled = self._inv_compiled = None
        self._plan_bytes = 0

    # --- memory -----------------------------------------------------------
    def allocate(self) -> None:
        devices = jax.devices()
        self._mesh = Mesh(np.array(devices), ("data",))
        self._sharding = NamedSharding(self._mesh, P("data"))
        x = jnp.zeros((self._n,), dtype=self.problem.input_dtype.name)
        self._buf = jax.device_put(x, self._sharding)
        self._buf.block_until_ready()

    def destroy(self) -> None:
        for b in (self._buf, self._spec):
            if b is not None:
                try:
                    b.delete()
                except Exception:
                    pass
        self._buf = self._spec = None
        self._fwd_compiled = self._inv_compiled = None

    def get_alloc_size(self) -> int:
        return 2 * self.problem.signal_bytes   # signal + spectrum buffers

    def get_plan_size(self) -> int:
        return self._plan_bytes

    # --- planning ---------------------------------------------------------
    def _n_devices(self) -> int:
        return len(jax.devices())

    def _compile(self, direction: str, build):
        key = PlanCache.executable_key(
            getattr(self.context, "device_kind", "?"), self.problem,
            f"dist_fourstep[p={self._n_devices()}]", direction)
        return cached_build(self.plan_cache, self.cache_events,
                            f"init_{direction}", key, build)

    def init_forward(self) -> None:
        def build():
            fn, _ = dist.make_fft1d(self._mesh, "data", self._n)
            return fn.lower(self._buf).compile()

        self._fwd_compiled = self._compile("forward", build)
        self._plan_bytes = executable_bytes(self._fwd_compiled)

    def init_inverse(self) -> None:
        def build():
            fn, _ = dist.make_ifft1d(self._mesh, "data", self._n)
            # the transposed spectrum has the signal's shape/dtype/sharding
            return fn.lower(self._spec if self._spec is not None
                            else self._buf).compile()

        self._inv_compiled = self._compile("inverse", build)
        self._plan_bytes += executable_bytes(self._inv_compiled)

    # --- execution --------------------------------------------------------
    def execute_forward(self) -> None:
        self._spec = self._fwd_compiled(self._buf)
        self._spec.block_until_ready()

    def execute_inverse(self) -> None:
        self._buf = self._inv_compiled(self._spec)
        self._buf.block_until_ready()

    # --- transfer ---------------------------------------------------------
    def upload(self, host_data: np.ndarray) -> None:
        flat = jnp.asarray(np.asarray(host_data).reshape(-1))
        self._buf = jax.device_put(flat, self._sharding)
        self._buf.block_until_ready()

    def download(self) -> np.ndarray:
        return np.asarray(self._buf)
