"""Distributed-FFT clients: the mesh-parallel transforms
(repro.fft.distributed) driven through the SAME Table-1 timed path as the
single-device libraries — the FFTW-MPI / cuFFTMp "binaries" of the suite.

``DistFFT1D`` runs the distributed four-step; ``DistFFTND`` runs the
planned slab/pencil decompositions, selecting among them (and their local
per-axis engines) with the interconnect-aware cost model in ``plan.py``.

Forward transforms emit the FFTW_MPI_TRANSPOSED_OUT spectrum layout and the
inverse consumes it directly (TRANSPOSED_IN), so the measured round trip is
the production layout-aware path with no reordering pass; pass the context
option ``dist_natural=True`` to buy natural-order spectra for one extra
all_to_all per direction instead.  On a single-device host the mesh
degenerates to P=1 and the collectives are identity — the same code path
the pod runs.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..client import Context, FFTClient, Problem
from ..plan import (Candidate, Plan, PlanCache, PlanRigor, cached_build,
                    dist_local_engine, dist_local_lengths, dist_supports,
                    estimate_bytes_moved, executable_bytes)
from ..registry import register_client
from ..wisdom import Wisdom
from repro.fft import distributed as dist
from repro.launch.mesh import flat_mesh, get_active_mesh, reshaped_mesh


def dist_engines(problem: Problem, cand: Candidate) -> list:
    """One local engine per sub-transform of a distributed candidate: the
    ``local`` knob when the sweep forced one, else the cost model's best
    separable backend at each local length — resolved to callables through
    the same ``_engine`` table every single-device plan uses."""
    from .jax_fft import _engine

    forced = cand.opts().get("local")
    out = []
    for n, _ in dist_local_lengths(problem, cand):
        b = forced or dist_local_engine(n)
        out.append(_engine(Candidate(b)))
    return out


@register_client()
class DistFFT1DClient(FFTClient):
    """1D distributed four-step FFT over all visible devices.

    Constraints (recorded as node failures, not suite aborts): rank-1
    complex transforms, batch 1, and n must factor as n1*n2 with the device
    count dividing n1.
    """

    title = "DistFFT1D"

    def __init__(self, problem: Problem, context: Context,
                 rigor: PlanRigor | None = None, wisdom: Wisdom | None = None,
                 plan_cache: PlanCache | None = None):
        super().__init__(problem, context)
        if problem.rank != 1:
            raise ValueError("DistFFT1D supports rank-1 transforms only")
        if not problem.complex_input:
            raise ValueError("DistFFT1D supports complex kinds only")
        if problem.batch != 1:
            raise ValueError("DistFFT1D supports batch=1 only")
        self.plan_cache = plan_cache
        self.cache_events: dict[str, str] = {}
        self._n = problem.extents[0]
        # natural-order spectra (one extra all_to_all per direction) —
        # both directions honor it, so round trips stay layout-consistent
        self._natural = bool(context.options.get("dist_natural", False))
        self._mesh = None
        self._sharding = None
        self._buf = None
        self._spec = None
        self._fwd_compiled = self._inv_compiled = None
        self._plan_bytes = 0

    # --- memory -----------------------------------------------------------
    def allocate(self) -> None:
        devices = jax.devices()
        self._mesh = Mesh(np.array(devices), ("data",))
        self._sharding = NamedSharding(self._mesh, P("data"))
        x = jnp.zeros((self._n,), dtype=self.problem.input_dtype.name)
        self._buf = jax.device_put(x, self._sharding)
        self._buf.block_until_ready()

    def destroy(self) -> None:
        for b in (self._buf, self._spec):
            if b is not None:
                try:
                    b.delete()
                except Exception:
                    pass
        self._buf = self._spec = None
        self._fwd_compiled = self._inv_compiled = None

    def get_alloc_size(self) -> int:
        return 2 * self.problem.signal_bytes   # signal + spectrum buffers

    def get_plan_size(self) -> int:
        return self._plan_bytes

    # --- planning ---------------------------------------------------------
    def _n_devices(self) -> int:
        return len(jax.devices())

    def _compile(self, direction: str, build):
        nat = ",natural" if self._natural else ""
        key = PlanCache.executable_key(
            getattr(self.context, "device_kind", "?"), self.problem,
            f"dist_fourstep[p={self._n_devices()}{nat}]", direction)
        return cached_build(self.plan_cache, self.cache_events,
                            f"init_{direction}", key, build)

    def _engines(self):
        cand = Candidate("dist1d", mesh=(self._n_devices(),))
        return dist_engines(self.problem, cand)

    def init_forward(self) -> None:
        def build():
            fn, _ = dist.make_fft1d(self._mesh, "data", self._n,
                                    natural=self._natural,
                                    engines=self._engines())
            return fn.lower(self._buf).compile()

        self._fwd_compiled = self._compile("forward", build)
        self._plan_bytes = executable_bytes(self._fwd_compiled)

    def init_inverse(self) -> None:
        def build():
            fn, _ = dist.make_ifft1d(self._mesh, "data", self._n,
                                     natural=self._natural,
                                     engines=self._engines())
            # the spectrum has the signal's shape/dtype/sharding
            return fn.lower(self._spec if self._spec is not None
                            else self._buf).compile()

        self._inv_compiled = self._compile("inverse", build)
        self._plan_bytes += executable_bytes(self._inv_compiled)

    # --- execution --------------------------------------------------------
    def execute_forward(self) -> None:
        self._spec = self._fwd_compiled(self._buf)
        self._spec.block_until_ready()

    def execute_inverse(self) -> None:
        self._buf = self._inv_compiled(self._spec)
        self._buf.block_until_ready()

    # --- transfer ---------------------------------------------------------
    def upload(self, host_data: np.ndarray) -> None:
        flat = jnp.asarray(np.asarray(host_data).reshape(-1))
        self._buf = jax.device_put(flat, self._sharding)
        self._buf.block_until_ready()

    def download(self) -> np.ndarray:
        return np.asarray(self._buf)


@register_client()
class DistFFTNDClient(FFTClient):
    """Planned mesh-parallel ND FFT: slab or pencil decomposition.

    The planner side of the tentpole: candidates come from the distributed
    cost model (``plan.estimate_bytes_moved`` with the interconnect term)
    over the active mesh — or a flat mesh over every visible device when
    none is installed — and MEASURE/PATIENT time the decomposition x
    local-engine space, persisting winners to wisdom under the ``dist``
    scope with their mesh shape.  Constraints: rank-2/3 complex kinds whose
    extents satisfy the decomposition divisibility rules.
    """

    title = "DistFFTND"
    rigor = PlanRigor.ESTIMATE

    def __init__(self, problem: Problem, context: Context,
                 rigor: PlanRigor | None = None, wisdom: Wisdom | None = None,
                 plan_cache: PlanCache | None = None):
        super().__init__(problem, context)
        if problem.rank not in (2, 3):
            raise ValueError("DistFFTND supports rank-2/3 transforms only")
        if not problem.complex_input:
            raise ValueError("DistFFTND supports complex kinds only")
        if rigor is not None:
            self.rigor = rigor
        self.wisdom = wisdom
        self.plan_cache = plan_cache
        self.cache_events: dict[str, str] = {}
        self._natural = bool(context.options.get("dist_natural", False))
        self._forced = context.options.get("dist_backend")  # 'slab'|'pencil'
        self.plan: Plan | None = None
        self._base_mesh = None
        self._mesh = None
        self._in_sharding = None
        self._buf = None
        self._spec = None
        self._fwd_compiled = self._inv_compiled = None
        self._plan_bytes = 0

    # --- planning ---------------------------------------------------------
    def _candidates(self) -> list[Candidate]:
        from ..plan import _dist_candidates

        if self._base_mesh.size < 2:
            # degenerate P=1 mesh: the collectives are identity, the same
            # code path the pod runs — how tier-1 tests cover this client
            return [Candidate("slab", mesh=(1,))]
        patient = self.rigor is PlanRigor.PATIENT
        cands = [c for c in _dist_candidates(self.problem, self._base_mesh,
                                             patient)
                 if c.backend in ("slab", "pencil")]
        if self._forced:
            cands = [c for c in cands if c.backend == self._forced]
        if not cands:
            raise ValueError(
                f"no feasible slab/pencil decomposition of "
                f"{self.problem.extents} over {self._base_mesh.size} devices")
        return cands

    def _make_plan(self) -> Plan:
        import time as _time

        t0 = _time.perf_counter()
        measured = self.rigor in (PlanRigor.MEASURE, PlanRigor.PATIENT)
        if self.wisdom is not None and \
                self.rigor is not PlanRigor.ESTIMATE:
            cand = self.wisdom.lookup(self.problem, scope="dist")
            if cand is not None and cand.backend in ("slab", "pencil") \
                    and dist_supports(cand.backend, self.problem, cand.mesh) \
                    and _mesh_total(cand.mesh) == self._base_mesh.size:
                return Plan(self.problem, cand, self.rigor,
                            (_time.perf_counter() - t0) * 1e3)
        if self.rigor is PlanRigor.WISDOM_ONLY:
            raise RuntimeError("NULL plan (wisdom miss)")
        cands = self._candidates()
        timings: dict[str, float] = {}
        if measured and len(cands) > 1:
            from ..plan import measure_plan

            def build(c):
                fn, mesh, in_spec, _ = self._build_fn(c, "forward")
                sh = NamedSharding(mesh, in_spec)
                return lambda x: fn(jax.device_put(x, sh))

            cand, timings = measure_plan(self.problem, build, cands)
            if self.wisdom is not None:
                self.wisdom.record(self.problem, cand, scope="dist")
        else:
            cand = min(cands,
                       key=lambda c: estimate_bytes_moved(self.problem, c))
        return Plan(self.problem, cand, self.rigor,
                    (_time.perf_counter() - t0) * 1e3, timings)

    def _select(self) -> Candidate:
        if self.plan is not None:
            return self.plan.candidate
        if self.plan_cache is not None:
            pkey = PlanCache.plan_key(
                getattr(self.context, "device_kind", "?"), self.problem,
                self.rigor, scope=f"dist[{self._base_mesh.size}]")
            plan, _ = self.plan_cache.plan(pkey, self._make_plan)
        else:
            plan = self._make_plan()
        self.plan = plan
        return plan.candidate

    def _build_fn(self, cand: Candidate, direction: str):
        """The jit-able sharded transform for one candidate (used both by
        the MEASURE sweep and the final executable build)."""
        mesh = reshaped_mesh(self._base_mesh, cand.mesh)
        engines = dist_engines(self.problem, cand)
        inverse = direction == "inverse"
        if cand.backend == "slab":
            fn, in_spec, out_spec = dist.make_slab_fftnd(
                mesh, "d0", self.problem.extents, inverse=inverse,
                natural=self._natural, engines=engines)
        else:
            fn, in_spec, out_spec = dist.make_pencil_fftnd(
                mesh, "d0", "d1", self.problem.extents, inverse=inverse,
                natural=self._natural, engines=engines)
        return fn, mesh, in_spec, out_spec

    # --- memory -----------------------------------------------------------
    def allocate(self) -> None:
        active = get_active_mesh()
        self._base_mesh = active if active is not None else flat_mesh()
        cand = self._select()
        fn, mesh, in_spec, out_spec = self._build_fn(cand, "forward")
        self._mesh = mesh
        self._in_sharding = NamedSharding(mesh, in_spec)
        x = jnp.zeros((self.problem.batch, *self.problem.extents),
                      dtype=self.problem.input_dtype.name)
        self._buf = jax.device_put(x, self._in_sharding)
        self._buf.block_until_ready()

    def destroy(self) -> None:
        for b in (self._buf, self._spec):
            if b is not None:
                try:
                    b.delete()
                except Exception:
                    pass
        self._buf = self._spec = None
        self._fwd_compiled = self._inv_compiled = None

    def get_alloc_size(self) -> int:
        return 2 * self.problem.signal_bytes   # signal + spectrum buffers

    def get_plan_size(self) -> int:
        return self._plan_bytes

    # --- compile ----------------------------------------------------------
    def _compile(self, direction: str, build):
        nat = ",natural" if self._natural else ""
        cand = self.plan.candidate
        key = PlanCache.executable_key(
            getattr(self.context, "device_kind", "?"), self.problem,
            f"{cand.key()}{nat}", direction)
        return cached_build(self.plan_cache, self.cache_events,
                            f"init_{direction}", key, build)

    def init_forward(self) -> None:
        cand = self._select()

        def build():
            fn, _, _, _ = self._build_fn(cand, "forward")
            return fn.lower(self._buf).compile()

        self._fwd_compiled = self._compile("forward", build)
        self._plan_bytes = executable_bytes(self._fwd_compiled)

    def init_inverse(self) -> None:
        cand = self.plan.candidate

        def build():
            fwd, mesh, _, out_spec = self._build_fn(cand, "forward")
            inv, _, in_spec, _ = self._build_fn(cand, "inverse")
            spec_shape = jax.ShapeDtypeStruct(
                (self.problem.batch, *self.problem.extents),
                self.problem.input_dtype.name,
                sharding=NamedSharding(mesh, out_spec))
            return inv.lower(spec_shape).compile()

        self._inv_compiled = self._compile("inverse", build)
        self._plan_bytes += executable_bytes(self._inv_compiled)

    # --- execution --------------------------------------------------------
    def execute_forward(self) -> None:
        self._spec = self._fwd_compiled(self._buf)
        self._spec.block_until_ready()

    def execute_inverse(self) -> None:
        self._buf = self._inv_compiled(self._spec)
        self._buf.block_until_ready()

    # --- transfer ---------------------------------------------------------
    def upload(self, host_data: np.ndarray) -> None:
        x = jnp.asarray(np.asarray(host_data).reshape(
            (self.problem.batch, *self.problem.extents)))
        self._buf = jax.device_put(x, self._in_sharding)
        self._buf.block_until_ready()

    def download(self) -> np.ndarray:
        return np.asarray(self._buf)


def _mesh_total(shape) -> int:
    out = 1
    for s in shape:
        out *= s
    return out
