"""The JAX FFT clients — the in-repo analogue of the paper's fftw/cuFFT/clFFT
client implementations, one per backend engine.

Backend map (DESIGN.md §2):
  xla              XLA's native FFT HLO ("vendor library", whole-ND)
  stockham         pure-jnp Stockham autosort (radix-2 butterfly baseline)
  fourstep         matmul-DFT four-step (MXU formulation, jnp)
  fourstep_pallas  fused four-step Pallas kernel, n <= 16384 (interpret off-TPU)
  stockham_pallas  fused multi-stage Stockham Pallas kernel: every radix
                   stage on a VMEM-resident batch tile, one HBM touch
                   (knobs: tile_b, radix)
  sixstep          large-N path composing stockham_pallas residual
                   transforms with the fused four-step kernel
                   (knobs: split_n1, tile_b)
  fft2_pallas      fused rank-2 kernel: row stages, in-VMEM transpose,
                   column stages on one resident n1 x n2 tile — the whole
                   2D transform in one HBM touch (knobs: tile_b, radix)
  dft              direct matmul DFT Pallas kernel (tiny extents)
  chirpz_pallas    fused chirp-Z: host-cached chirp + filter spectrum, the
                   two padded pow2 transforms through the fused Pallas
                   engines (knobs: engine, tile_b) — the fast oddshape path
  bluestein        chirp-Z on the staged jnp engine (any size, baseline)

The mixed-radix stockham_pallas kernel covers the paper's radix357 class
(any 2^a*3^b*5^c*7^d length) in a single HBM touch; chirpz_pallas covers
oddshape, so all three Fig. 7 extent classes ride fused kernels.

Plans are ND-native: a candidate may assign a different backend to every
axis (``Candidate.axes``); separable engines are applied per axis through
``nd.fftn``'s minimal-transpose path, while the whole-transform backends
(xla, fft2_pallas) take the fused route.  Real kinds run the packed
half-spectrum path on top of whichever complex backend the planner picked —
per-axis engines through ``nd.rfftn``, fused ones through
``rfft.rfftn_packed``.

A client owns device buffers + AOT-compiled executables for ONE Problem —
the jit-specialization equivalent of gearshifft's compile-time template
instantiation.  By default init_forward/init_inverse re-lower and re-compile
on every run so planning cost stays an honestly measured quantity (paper
Figs. 4/5); with a PlanCache attached, the first run pays the measured cold
compile and warm repetitions reuse the cached executable, with hit/miss
events surfaced per op for the result rows.
"""

from __future__ import annotations

from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from ..client import Context, FFTClient, Problem
from ..plan import (Candidate, Plan, PlanCache, PlanRigor, cached_build,
                    executable_bytes, make_plan)
from ..registry import register_client
from ..wisdom import Wisdom
from repro.fft import bluestein, fourstep, nd, stockham
from repro.fft import rfft as rfft_mod


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _engine(cand: Candidate) -> Callable:
    """Return cfft(x, inverse=False) transforming the LAST axis."""
    b = cand.backend
    if b == "stockham":
        return stockham.fft
    if b == "fourstep":
        return fourstep.fft
    if b == "bluestein":
        return bluestein.fft   # staged jnp chirp-Z baseline
    if b == "chirpz_pallas":
        opts = cand.opts()
        engine = opts.get("engine", "auto")
        tile_b = opts.get("tile_b")
        interp = not _on_tpu()
        return lambda x, inverse=False: bluestein.fft(x, inverse=inverse,
                                                      engine=engine,
                                                      tile_b=tile_b,
                                                      interpret=interp)
    if b == "fourstep_pallas":
        from repro.kernels.fft4step import ops as fs_ops
        tile_b = cand.opts().get("tile_b", 8)
        interp = not _on_tpu()
        return lambda x, inverse=False: fs_ops.fft(x, inverse=inverse,
                                                   tile_b=tile_b, interpret=interp)
    if b == "stockham_pallas":
        from repro.kernels.stockham_pallas import ops as sp_ops
        opts = cand.opts()
        tile_b = opts.get("tile_b")
        radix = opts.get("radix", 8)
        interp = not _on_tpu()
        return lambda x, inverse=False: sp_ops.fft(x, inverse=inverse,
                                                   tile_b=tile_b, radix=radix,
                                                   interpret=interp)
    if b == "sixstep":
        from repro.fft import sixstep
        opts = cand.opts()
        split_n1 = opts.get("split_n1")
        tile_b = opts.get("tile_b")
        interp = not _on_tpu()
        return lambda x, inverse=False: sixstep.fft(x, inverse=inverse,
                                                    n1=split_n1, tile_b=tile_b,
                                                    interpret=interp)
    if b == "dft":
        from repro.kernels.dft_matmul import ops as dft_ops
        interp = not _on_tpu()
        return lambda x, inverse=False: dft_ops.dft(x, inverse=inverse, interpret=interp)
    raise ValueError(f"unknown backend {b!r}")


def _fft2_engine(cand: Candidate) -> Callable:
    """Whole-transform engine cfft2(x, inverse=False) over the LAST TWO
    axes: the fused rank-2 Pallas kernel."""
    from repro.kernels.fft2_pallas import ops as f2_ops
    opts = cand.opts()
    tile_b = opts.get("tile_b")
    radix = opts.get("radix", 8)
    interp = not _on_tpu()
    return lambda x, inverse=False: f2_ops.fft2(x, inverse=inverse,
                                                tile_b=tile_b, radix=radix,
                                                interpret=interp)


def _axis_engines(problem: Problem, cand: Candidate) -> list[Callable]:
    """One separable engine per axis from the (possibly per-axis) plan."""
    return [_engine(c) for c in cand.per_axis(problem.rank)]


def _forward_fn(problem: Problem, cand: Candidate) -> Callable:
    axes = tuple(range(-problem.rank, 0))
    if cand.backend == "xla":
        if problem.complex_input:
            return lambda x: jnp.fft.fftn(x, axes=axes)
        return lambda x: jnp.fft.rfftn(x, axes=axes)
    if cand.backend == "fft2_pallas":
        if problem.rank != 2:   # fail loudly, like every other backend's
            raise ValueError(   # infeasible build — never silent wrong math
                f"fft2_pallas is rank-2 only, got rank {problem.rank}")
        eng2 = _fft2_engine(cand)
        if problem.complex_input:
            return eng2
        return lambda x: rfft_mod.rfftn_packed(x, eng2, rank=2)
    engines = _axis_engines(problem, cand)
    if problem.complex_input:
        return lambda x: nd.fftn(x, engines, axes=axes)
    return lambda x: nd.rfftn(x, engines, axes=axes)


def _inverse_fn(problem: Problem, cand: Candidate) -> Callable:
    axes = tuple(range(-problem.rank, 0))
    if cand.backend == "xla":
        if problem.complex_input:
            return lambda y: jnp.fft.ifftn(y, axes=axes)
        return lambda y: jnp.fft.irfftn(y, s=problem.extents, axes=axes)
    if cand.backend == "fft2_pallas":
        if problem.rank != 2:
            raise ValueError(
                f"fft2_pallas is rank-2 only, got rank {problem.rank}")
        eng2 = _fft2_engine(cand)
        if problem.complex_input:
            return lambda y: eng2(y, inverse=True)
        return lambda y: rfft_mod.irfftn_packed(y, problem.extents, eng2)
    engines = _axis_engines(problem, cand)
    if problem.complex_input:
        return lambda y: nd.fftn(y, engines, axes=axes, inverse=True)
    return lambda y: nd.irfftn(y, problem.extents, engines, axes=axes)


#: Public name for the un-jitted forward builder — the serving engine wraps
#: it with its own jit (donated staging buffer, AOT-compiled per batch
#: bucket) instead of taking build_forward's plain jit.
forward_fn = _forward_fn


def build_forward(problem: Problem, cand: Candidate) -> Callable:
    """jit-compiled forward for planner MEASURE timing."""
    return jax.jit(_forward_fn(problem, cand))


def build_inverse(problem: Problem, cand: Candidate) -> Callable:
    """jit-compiled inverse (the conformance matrix's roundtrip leg)."""
    return jax.jit(_inverse_fn(problem, cand))


class JaxFFTClient(FFTClient):
    """Generic client; subclasses pin ``backend_filter`` to mimic having one
    binary per library (gearshifft_cufft, gearshifft_fftw, ...)."""

    title = "jaxfft"
    backend_filter: str | None = None   # force one backend, like a library binary
    rigor = PlanRigor.ESTIMATE

    def __init__(self, problem: Problem, context: Context,
                 rigor: PlanRigor | None = None, wisdom: Wisdom | None = None,
                 plan_cache: PlanCache | None = None):
        super().__init__(problem, context)
        if rigor is not None:
            self.rigor = rigor
        self.wisdom = wisdom
        self.plan_cache = plan_cache
        self.cache_events: dict[str, str] = {}
        self.plan: Plan | None = None
        self._buf = None
        self._spec = None
        self._fwd = self._inv = None
        self._fwd_compiled = self._inv_compiled = None
        self._plan_bytes = 0

    # --- memory -----------------------------------------------------------
    def allocate(self) -> None:
        x = jnp.zeros((self.problem.batch, *self.problem.extents),
                      dtype=self.problem.input_dtype.name)
        self._buf = jax.device_put(x)
        self._buf.block_until_ready()

    def destroy(self) -> None:
        for b in (self._buf, self._spec):
            if b is not None:
                try:
                    b.delete()
                except Exception:
                    pass
        self._buf = self._spec = None
        self._fwd_compiled = self._inv_compiled = None

    def get_alloc_size(self) -> int:
        n_in = self.problem.signal_bytes
        if self.problem.inplace:
            if self.problem.complex_input:
                return n_in
            # FFTW padded in-place r2c layout: the real array's last axis is
            # padded to 2*(n/2+1) reals so the n/2+1 complex half-spectrum
            # bins fit in place — the padding is part of the allocation
            return self._halfspec_bytes()
        # out-of-place: plus the spectrum buffer
        if self.problem.complex_input:
            return 2 * n_in
        return n_in + self._halfspec_bytes()

    def _halfspec_bytes(self) -> int:
        ext = self.problem.extents
        n_out = self.problem.batch
        for v in ext[:-1]:
            n_out *= v
        n_out *= ext[-1] // 2 + 1
        return n_out * self.problem.input_dtype.itemsize * (2 if not self.problem.complex_input else 1)

    def get_plan_size(self) -> int:
        return self._plan_bytes

    # --- planning ---------------------------------------------------------
    def _make_plan(self) -> Plan | None:
        from ..plan import candidates, measure_plan
        import time as _time

        build = lambda c: build_forward(self.problem, c)
        if self.backend_filter is None:
            return make_plan(self.problem, self.rigor, build=build,
                             wisdom=self.wisdom)
        # library-pinned client: planner searches only this backend's knobs.
        # Wisdom entries are scoped by the backend so per-library tuning
        # persists without clobbering the open planner's choices.
        t0 = _time.perf_counter()
        measured = self.rigor in (PlanRigor.MEASURE, PlanRigor.PATIENT)
        if (measured or self.rigor is PlanRigor.WISDOM_ONLY) \
                and self.wisdom is not None:
            cand = self.wisdom.lookup(self.problem, scope=self.backend_filter)
            if cand is not None and cand.backend == self.backend_filter:
                return Plan(self.problem, cand, self.rigor,
                            (_time.perf_counter() - t0) * 1e3,
                            source="wisdom")
        if self.rigor is PlanRigor.WISDOM_ONLY:
            return None   # fftw NULL plan: no persisted selection, no sweep
        cands = [c for c in candidates(self.problem,
                                       patient=(self.rigor is PlanRigor.PATIENT))
                 if c.backend == self.backend_filter] or [Candidate(self.backend_filter)]
        if measured and len(cands) > 1:
            cand, timings = measure_plan(self.problem, build, cands)
            if self.wisdom is not None:   # persist the tuned knobs
                self.wisdom.record(
                    self.problem, cand, scope=self.backend_filter,
                    measured_ms=timings.get(cand.key()),
                    rigor=self.rigor.value)
        else:
            cand, timings = cands[0], {}
        return Plan(self.problem, cand, self.rigor,
                    (_time.perf_counter() - t0) * 1e3, timings,
                    source=self.rigor.value if timings else "estimate")

    def _select(self) -> Candidate | None:
        if self.plan_cache is not None:
            # memoized selection: MEASURE/PATIENT candidate sweeps (which
            # compile every candidate) run at most once per problem
            pkey = PlanCache.plan_key(self._device_kind(), self.problem,
                                      self.rigor, scope=self.backend_filter or "*")
            plan, _ = self.plan_cache.plan(pkey, self._make_plan)
        else:
            plan = self._make_plan()
        if plan is None:
            return None
        self.plan = plan
        return plan.candidate

    def _device_kind(self) -> str:
        return getattr(self.context, "device_kind", "?")

    @property
    def plan_source(self) -> str:
        """Where this client's plan came from (``Plan.source``) — surfaced
        as the result rows' ``plan_source`` column when wisdom is attached,
        so exact-``wisdom`` hits, interpolated ``wisdom_near`` warm starts,
        and real sweeps stay distinguishable downstream."""
        return self.plan.source if self.plan is not None else ""

    def init_forward(self) -> None:
        cand = self._select()
        if cand is None:
            raise RuntimeError("NULL plan (wisdom miss)")  # fftw semantics

        def build():
            donate = (0,) if self.problem.inplace else ()
            fn = jax.jit(_forward_fn(self.problem, cand), donate_argnums=donate)
            lowered = fn.lower(jax.ShapeDtypeStruct(self._buf.shape, self._buf.dtype))
            return lowered.compile()

        self._fwd_compiled = cached_build(
            self.plan_cache, self.cache_events, "init_forward",
            PlanCache.executable_key(self._device_kind(), self.problem,
                                     cand, "forward"), build)
        self._plan_bytes = _plan_bytes(self._fwd_compiled)

    def init_inverse(self) -> None:
        cand = self.plan.candidate

        def build():
            donate = (0,) if self.problem.inplace else ()
            fn = jax.jit(_inverse_fn(self.problem, cand), donate_argnums=donate)
            spec_shape = jax.eval_shape(_forward_fn(self.problem, cand),
                                        jax.ShapeDtypeStruct((self.problem.batch, *self.problem.extents),
                                                             self.problem.input_dtype.name))
            return fn.lower(spec_shape).compile()

        self._inv_compiled = cached_build(
            self.plan_cache, self.cache_events, "init_inverse",
            PlanCache.executable_key(self._device_kind(), self.problem,
                                     cand, "inverse"), build)
        self._plan_bytes += _plan_bytes(self._inv_compiled)

    # --- execution --------------------------------------------------------
    def execute_forward(self) -> None:
        self._spec = self._fwd_compiled(self._buf)
        if self.problem.inplace:
            self._buf = None  # donated
        self._spec.block_until_ready()

    def execute_inverse(self) -> None:
        self._buf = self._inv_compiled(self._spec)
        if self.problem.inplace:
            self._spec = None
        self._buf.block_until_ready()

    # --- transfer ---------------------------------------------------------
    def upload(self, host_data: np.ndarray) -> None:
        self._buf = jax.device_put(jnp.asarray(host_data))
        self._buf.block_until_ready()

    def download(self) -> np.ndarray:
        return np.asarray(self._buf)


_plan_bytes = executable_bytes


# --- one "binary" per library, as in the paper ------------------------------
@register_client()
class XlaFFTClient(JaxFFTClient):
    title = "XlaFFT"
    backend_filter = "xla"


@register_client()
class StockhamClient(JaxFFTClient):
    title = "Stockham"
    backend_filter = "stockham"


@register_client()
class FourStepClient(JaxFFTClient):
    title = "FourStep"
    backend_filter = "fourstep"


@register_client()
class FourStepPallasClient(JaxFFTClient):
    title = "FourStepPallas"
    backend_filter = "fourstep_pallas"


@register_client()
class StockhamPallasClient(JaxFFTClient):
    title = "StockhamPallas"
    backend_filter = "stockham_pallas"


@register_client()
class SixStepClient(JaxFFTClient):
    title = "SixStep"
    backend_filter = "sixstep"


@register_client()
class Fft2PallasClient(JaxFFTClient):
    title = "Fft2Pallas"
    backend_filter = "fft2_pallas"


@register_client()
class ChirpZPallasClient(JaxFFTClient):
    title = "ChirpZPallas"
    backend_filter = "chirpz_pallas"


@register_client()
class BluesteinClient(JaxFFTClient):
    title = "Bluestein"
    backend_filter = "bluestein"


@register_client()
class PlannedClient(JaxFFTClient):
    """Planner-driven client (rigor decides the backend), fftw-style."""
    title = "Planned"
    backend_filter = None
