"""Fault-tolerance machinery: FaultPlan matching + determinism, the
circuit breaker (with a threaded hammer), planner fallback chains, the
wisdom schema-version/demotion layer, and chaos traffic specs."""

from __future__ import annotations

import json
import threading

import numpy as np
import pytest

from repro.core.client import Problem
from repro.core.plan import (Candidate, CircuitBreaker, PlanRigor,
                             breaker_key, fallback_chain, make_plan,
                             probe_finite, problem_class)
from repro.core.wisdom import WISDOM_SCHEMA_VERSION, Wisdom
from repro.serve import (FaultInjected, FaultPlan, FaultRule, TrafficSpec,
                         faulty_build)


def _hammer(n_threads, fn):
    errors = []
    barrier = threading.Barrier(n_threads)

    def work(i):
        try:
            barrier.wait(timeout=30)
            fn(i)
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
    assert not errors, errors
    assert not any(t.is_alive() for t in threads)


# ---------------------------------------------------------------------------
# FaultRule / FaultPlan
# ---------------------------------------------------------------------------
def test_fault_rule_validation_and_roundtrip():
    rule = FaultRule("compile_error", backend="stockham_pallas",
                     extents=[64], after=1, times=2)
    assert rule.extents == (64,) and rule.site == "build"
    assert FaultRule.from_dict(rule.to_dict()) == rule
    assert "backend" in rule.to_dict() and "kind" not in rule.to_dict()
    with pytest.raises(ValueError, match="unknown fault"):
        FaultRule("segfault")
    with pytest.raises(ValueError, match="bad fault window"):
        FaultRule("execute_error", after=-1)
    with pytest.raises(ValueError, match="unknown FaultRule key"):
        FaultRule.from_dict({"fault": "nan_output", "nope": 1})


def test_fault_plan_nth_call_window_and_sites():
    plan = FaultPlan([
        {"fault": "execute_error", "backend": "xla", "after": 1, "times": 2},
        {"fault": "compile_error"},
    ])
    # site filtering: an execute rule never fires at build, and vice versa
    assert [r.fault for r in plan.check("build", "xla")] == ["compile_error"]
    # nth-call window: skip 1, fire 2, then exhausted
    fired = [bool(plan.check("execute", "xla")) for _ in range(5)]
    assert fired == [False, True, True, False, False]
    # backend mismatch never advances the counter
    assert plan.check("execute", "stockham") == []
    assert plan.injected == 3                      # 1 compile + 2 execute
    snap = plan.snapshot()
    assert snap["rules"][0]["matched"] == 5
    assert snap["rules"][0]["fired"] == 2
    assert FaultPlan.from_dict(plan.to_dict()).to_dict() == plan.to_dict()
    assert plan and not FaultPlan()


def test_fault_plan_rid_pinning_and_extents():
    plan = FaultPlan([{"fault": "nan_output", "rid": 7},
                      {"fault": "execute_error", "extents": [32]}])
    assert plan.check("execute", "xla", (64,), rids=[5, 6]) == []
    assert len(plan.check("execute", "xla", (64,), rids=[6, 7])) == 1
    assert len(plan.check("execute", "xla", (32,), rids=[1])) == 1
    assert len(plan.check("execute", "xla", (32,), rids=[7])) == 2


def test_fault_plan_is_poison_semantics():
    plan = FaultPlan([
        # pinned to one backend: a fallback chain escapes it -> not poison
        {"fault": "compile_error", "backend": "stockham_pallas"},
        # bounded window: retries outlast it -> not poison
        {"fault": "execute_error", "times": 2},
        # rid-pinned unbounded error: that one request is doomed
        {"fault": "nan_output", "rid": 3},
        # stalls never doom anything
        {"fault": "transfer_stall"},
    ])
    assert not plan.is_poison((64,), "Outplace_Complex")
    assert plan.is_poison((64,), "Outplace_Complex", rid=3)
    assert not plan.is_poison((64,), "Outplace_Complex", rid=4)
    # wildcard-backend unbounded error fault dooms every matching request
    doom = FaultPlan([{"fault": "execute_error", "extents": [128]}])
    assert doom.is_poison((128,), "Outplace_Complex")
    assert not doom.is_poison((64,), "Outplace_Complex")


def test_fault_plan_thread_safe_counters():
    plan = FaultPlan([{"fault": "execute_error", "after": 10, "times": 5}])
    n_threads, per_thread = 8, 25

    def work(i):
        for _ in range(per_thread):
            plan.check("execute", "xla")

    _hammer(n_threads, work)
    snap = plan.snapshot()["rules"][0]
    assert snap["matched"] == n_threads * per_thread   # no lost counts
    assert snap["fired"] == 5                          # window stays exact


def test_faulty_build_wraps_planner_build():
    problem = Problem((64,), "Outplace_Complex", "float")
    plan = FaultPlan([{"fault": "compile_error", "backend": "dft"}])
    calls = []

    def build(cand):
        calls.append(cand.backend)
        return lambda x: x

    wrapped = faulty_build(build, plan, problem)
    with pytest.raises(FaultInjected, match="injected compile error"):
        wrapped(Candidate("dft"))
    assert calls == []                       # fault fired before the build
    assert wrapped(Candidate("xla"))(1) == 1
    assert faulty_build(build, None, problem) is build


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------
def _breaker(threshold=3, cooldown=100.0):
    t = [0.0]
    return CircuitBreaker(threshold=threshold, cooldown_s=cooldown,
                          clock=lambda: t[0]), t


def test_breaker_open_halfopen_close_lifecycle():
    b, t = _breaker()
    key = "stockham|powerof2|r1"
    assert b.allows(key) and b.state(key) == CircuitBreaker.CLOSED
    assert b.record_failure(key) == CircuitBreaker.CLOSED
    assert b.record_failure(key) == CircuitBreaker.CLOSED
    assert b.record_failure(key) == CircuitBreaker.OPEN   # threshold hit
    assert not b.allows(key) and not b.available(key)
    t[0] += 99.0
    assert not b.allows(key)                  # cooldown not elapsed
    t[0] += 2.0
    assert b.available(key)
    assert b.allows(key)                      # the half-open probe
    assert not b.allows(key)                  # one probe at a time
    assert b.record_failure(key) == CircuitBreaker.OPEN   # probe failed
    t[0] += 101.0
    assert b.allows(key)
    b.record_success(key)                     # probe succeeded: re-close
    assert b.state(key) == CircuitBreaker.CLOSED
    assert b.allows(key) and b.allows(key)    # closed: unlimited attempts
    snap = b.snapshot()[key]
    assert snap["opens"] == 2 and snap["failures"] == 4


def test_breaker_success_resets_consecutive_count():
    b, _ = _breaker(threshold=3)
    for _ in range(2):
        b.record_failure("k")
    b.record_success("k")
    for _ in range(2):
        assert b.record_failure("k") == CircuitBreaker.CLOSED
    assert b.record_failure("k") == CircuitBreaker.OPEN


def test_breaker_lost_probe_reallowed_after_cooldown():
    b, t = _breaker(threshold=1, cooldown=10.0)
    b.record_failure("k")
    t[0] += 11.0
    assert b.allows("k")          # probe granted... and then its thread dies
    assert not b.allows("k")
    t[0] += 11.0
    assert b.allows("k")          # a lost probe can't wedge the pair


def test_breaker_threaded_hammer_exact_counts_and_single_probe():
    b, t = _breaker(threshold=5, cooldown=1000.0)
    n_threads, per_thread = 8, 50
    keys = [f"b{i}|powerof2|r1" for i in range(3)]

    def work(i):
        rng = np.random.default_rng(i)
        for j in range(per_thread):
            key = keys[int(rng.integers(len(keys)))]
            if j % 3 == 0:
                b.record_success(key)
            else:
                b.record_failure(key)
            b.allows(key)         # race state reads against transitions

    _hammer(n_threads, work)
    snap = b.snapshot()
    total = sum(e["failures"] + e["successes"] for e in snap.values())
    assert total == n_threads * per_thread    # no lost counts under racing
    # force every key open, advance past cooldown: exactly ONE probe each
    for key in keys:
        for _ in range(5):
            b.record_failure(key)
        assert b.state(key) == CircuitBreaker.OPEN
    t[0] += 1001.0
    grants = {key: [] for key in keys}
    lock = threading.Lock()

    def probe(i):
        for key in keys:
            ok = b.allows(key)
            with lock:
                grants[key].append(ok)

    _hammer(n_threads, probe)
    for key in keys:
        assert sum(grants[key]) == 1, f"{key}: {grants[key]}"


# ---------------------------------------------------------------------------
# planner fallback
# ---------------------------------------------------------------------------
def test_fallback_chain_ordering_and_terminal_xla():
    problem = Problem((64,), "Outplace_Complex", "float")
    chain = fallback_chain(problem)
    keys = [c.key() for c in chain]
    assert len(keys) == len(set(keys))            # deduped
    assert chain[0].backend == "dft"              # the tiny-1D estimate pin
    assert any(c.backend == "xla" and not c.axes for c in chain)
    # an oddshape rank-1 problem still terminates in a feasible candidate
    odd = fallback_chain(Problem((97,), "Outplace_Complex", "float"))
    assert any(c.backend == "xla" and not c.axes for c in odd)


def test_probe_finite_rejects_nan_executable():
    problem = Problem((8,), "Outplace_Complex", "float")
    probe_finite(lambda x: np.ones_like(x), problem)     # finite: fine
    with pytest.raises(RuntimeError, match="finiteness probe failed"):
        probe_finite(lambda x: np.full_like(x, np.nan), problem)


def test_make_plan_falls_back_past_injected_compile_errors(tmp_path):
    problem = Problem((64,), "Outplace_Complex", "float")
    wisdom = Wisdom(str(tmp_path / "w.json"), device_kind="cpu")
    breaker = CircuitBreaker(threshold=1, cooldown_s=3600.0)
    top = fallback_chain(problem)[0].backend
    fplan = FaultPlan([{"fault": "compile_error", "backend": top}])
    built = []

    def build(cand):
        built.append(cand.backend)
        return lambda x: x

    plan = make_plan(problem, PlanRigor.ESTIMATE,
                     build=faulty_build(build, fplan, problem),
                     wisdom=wisdom, breaker=breaker)
    assert plan.candidate.backend != top
    assert any(top in key for key in plan.fallbacks)
    assert top not in built                   # the fault pre-empted its build
    # threshold=1: the failure opened the breaker and persisted a demotion
    assert breaker.state(breaker_key(top, problem)) == CircuitBreaker.OPEN
    assert top in wisdom.demoted(problem)
    # a fresh walk now skips the quarantined backend without re-building
    plan2 = make_plan(problem, PlanRigor.ESTIMATE,
                      build=faulty_build(build, fplan, problem),
                      wisdom=wisdom, breaker=breaker)
    assert plan2.candidate.backend != top
    # ...and so does a plain ESTIMATE call steered by wisdom alone
    plan3 = make_plan(problem, PlanRigor.ESTIMATE, wisdom=wisdom)
    assert plan3.candidate.backend != top


def test_make_plan_terminal_xla_survives_total_quarantine():
    problem = Problem((64,), "Outplace_Complex", "float")
    breaker = CircuitBreaker(threshold=1, cooldown_s=3600.0)

    def build(cand):
        if not (cand.backend == "xla" and not cand.axes):
            raise RuntimeError(f"{cand.backend} is down")
        return lambda x: x

    plan = make_plan(problem, PlanRigor.ESTIMATE, build=build,
                     breaker=breaker)
    assert plan.candidate.backend == "xla"
    assert len(plan.fallbacks) >= 1
    # everything failing -> the planner reports, not hangs
    breaker2 = CircuitBreaker(threshold=1, cooldown_s=3600.0)

    def all_down(cand):
        raise RuntimeError("device on fire")

    with pytest.raises(RuntimeError, match="no feasible plan"):
        make_plan(problem, PlanRigor.ESTIMATE, build=all_down,
                  breaker=breaker2)


def test_make_plan_probe_rejects_garbage_output():
    problem = Problem((16,), "Outplace_Complex", "float")
    breaker = CircuitBreaker(threshold=1, cooldown_s=3600.0)
    top = fallback_chain(problem)[0].backend

    def build(cand):
        if cand.backend == top:
            return lambda x: np.full((problem.batch, *problem.extents),
                                     np.nan, dtype=np.complex64)
        return lambda x: np.zeros((problem.batch, *problem.extents),
                                  dtype=np.complex64)

    plan = make_plan(problem, PlanRigor.ESTIMATE, build=build,
                     breaker=breaker, probe=True)
    assert plan.candidate.backend != top      # NaN executable demoted


# ---------------------------------------------------------------------------
# wisdom schema versioning + demotions
# ---------------------------------------------------------------------------
def test_wisdom_skips_corrupt_and_future_entries(tmp_path):
    path = tmp_path / "wisdom.json"
    problem = Problem((64,), "Outplace_Complex", "float")
    w = Wisdom(str(path), device_kind="cpu")
    w.record(problem, Candidate("xla"))
    w.save()
    with open(path) as f:
        store = json.load(f)
    good_key = next(iter(store))
    assert store[good_key]["v"] == WISDOM_SCHEMA_VERSION
    store["future"] = {"v": WISDOM_SCHEMA_VERSION + 1, "backend": "warp",
                       "options": []}
    store["not_a_record"] = "xla"
    store["bad_version"] = {"v": "two", "backend": "xla", "options": []}
    store["unparseable"] = {"v": 1, "backend": "xla", "options": [["k"]]}
    store["__demoted__"] = {"cpu|powerof2|r1": "stockham"}   # not a list
    with open(path, "w") as f:
        json.dump(store, f)
    with pytest.warns(UserWarning) as warned:
        fresh = Wisdom(str(path), device_kind="cpu")
    assert len(warned) == 5
    msgs = "\n".join(str(x.message) for x in warned)
    assert "newer than this reader" in msgs
    assert "malformed demotion table" in msgs
    assert fresh.lookup(problem) is not None      # valid entry survives
    assert len(fresh) == 1
    # a save round-trip writes back only the clean store (merge-on-save
    # re-reads the still-corrupt file, so the same warnings fire again)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("ignore")
        fresh.save()
    reread = Wisdom(str(path), device_kind="cpu")
    assert reread.lookup(problem).backend == "xla"


def test_wisdom_legacy_v1_records_still_load(tmp_path):
    path = tmp_path / "wisdom.json"
    with open(path, "w") as f:                    # pre-versioning layout
        json.dump({"cpu|64-f-oc-b1": {"backend": "xla", "options": []}}, f)
    w = Wisdom(str(path), device_kind="cpu")
    assert len(w) == 1


def test_wisdom_demotions_roundtrip_and_union_merge(tmp_path):
    path = tmp_path / "wisdom.json"
    p1 = Problem((64,), "Outplace_Complex", "float")
    p2 = Problem((64, 64), "Outplace_Complex", "float")
    assert problem_class(p1) != problem_class(p2)
    w1 = Wisdom(str(path), device_kind="cpu")
    w2 = Wisdom(str(path), device_kind="cpu")
    w1.record_demotion(p1, "stockham")
    w2.record_demotion(p2, "fourstep_pallas")
    w1.save()
    w2.save()          # merge-on-save must union, not clobber, w1's table
    fresh = Wisdom(str(path), device_kind="cpu")
    assert fresh.demoted(p1) == {"stockham"}
    assert fresh.demoted(p2) == {"fourstep_pallas"}
    assert fresh.demoted(Problem((97,), "Outplace_Complex", "float")) \
        == frozenset()
    # demotions are bookkeeping, not selections: store length ignores them
    assert len(fresh) == 0


# ---------------------------------------------------------------------------
# chaos traffic specs
# ---------------------------------------------------------------------------
def test_traffic_spec_faults_roundtrip():
    spec = TrafficSpec(extents=((64,),), requests=4,
                       faults=({"fault": "compile_error",
                                "backend": "stockham_pallas"},))
    assert TrafficSpec.from_dict(spec.to_dict()) == spec
    assert spec.fault_plan().rules[0].backend == "stockham_pallas"
    assert "faults" not in TrafficSpec(extents=((64,),)).to_dict()
    with pytest.raises(ValueError, match="unknown fault"):
        TrafficSpec(extents=((64,),), faults=({"fault": "meteor"},))
