"""Widened planner: new fused-kernel backends in the candidate space, the
PATIENT knob sweep, the bytes-moved ESTIMATE model, and wisdom-persisted
PATIENT selections that let a warm Session skip the sweep entirely."""

import json
import math

import pytest

from repro.core.client import Problem
from repro.core.plan import (Candidate, PlanRigor, STOCKHAM_PALLAS_VMEM_N,
                             candidates, estimate_bytes_moved,
                             estimate_choice, hbm_passes, make_plan)
from repro.core.suite import Session, SuiteSpec
from repro.core.wisdom import Wisdom
from repro.core.clients.jax_fft import build_forward


# --------------------------------------------------------------------------
# candidate space
# --------------------------------------------------------------------------
def test_new_backends_offered_for_all_pow2_up_to_2_20():
    for e in range(2, 21):
        backs = {c.backend for c in candidates(Problem((1 << e,)))}
        assert "stockham_pallas" in backs, f"2^{e}"
        assert "sixstep" in backs, f"2^{e}"
    # rank-3 pow2 (per-axis feasibility)
    backs = {c.backend for c in candidates(Problem((16, 16, 16)))}
    assert {"stockham_pallas", "sixstep"} <= backs
    # non-smooth and too-small axes are excluded
    assert "stockham_pallas" not in {
        c.backend for c in candidates(Problem((97,)))}
    assert "sixstep" not in {c.backend for c in candidates(Problem((2,)))}


def test_mixed_radix_and_chirpz_offered_for_nonpow2():
    """The paper's radix357 and oddshape classes are first-class: 7-smooth
    lengths get the mixed-radix fused kernel, everything gets the fused
    chirp-Z (up to its padded six-step cap)."""
    for n in (12, 100, 3072, 18432):          # radix357
        backs = {c.backend for c in candidates(Problem((n,),
                                                       "Outplace_Complex"))}
        assert "stockham_pallas" in backs, n
        assert "chirpz_pallas" in backs, n
    for n in (19, 361, 6859):                 # oddshape
        backs = {c.backend for c in candidates(Problem((n,),
                                                       "Outplace_Complex"))}
        assert "stockham_pallas" not in backs, n
        assert "chirpz_pallas" in backs, n
        assert "bluestein" in backs, n


def test_sixstep_split_knobs_are_honored_by_engine():
    """Every split_n1 the PATIENT sweep emits must be one choose_split
    accepts — a rejected knob silently duplicates the default candidate."""
    from repro.fft.sixstep import choose_split
    for e in (8, 12, 16, 20, 22, 24):
        n = 1 << e
        for c in candidates(Problem((n,)), patient=True):
            if c.backend == "sixstep" and "split_n1" in c.opts():
                n1 = c.opts()["split_n1"]
                assert choose_split(n, n1) == (n1, n // n1), (n, n1)


def test_patient_widens_with_kernel_knobs():
    cands = candidates(Problem((1 << 16,)), patient=True)
    keys = {c.key() for c in cands}
    assert len(cands) >= 10
    knobbed = [c for c in cands if c.options]
    assert len(knobbed) >= 6        # the widened PATIENT space
    assert any(c.backend == "stockham_pallas" and "radix" in c.opts()
               and "tile_b" in c.opts() for c in knobbed)
    assert any(c.backend == "sixstep" and "split_n1" in c.opts()
               for c in knobbed)
    assert any(c.backend == "sixstep" and "tile_b" in c.opts()
               for c in knobbed)
    assert len(keys) == len(cands)  # no duplicate candidates


# --------------------------------------------------------------------------
# bytes-moved ESTIMATE model
# --------------------------------------------------------------------------
def test_hbm_passes_model():
    n = 1 << 12
    assert hbm_passes("stockham_pallas", n) == 1.0      # one HBM touch
    assert hbm_passes("fourstep_pallas", n) == 1.0
    assert hbm_passes("stockham", n) == 12.0            # one pass per stage
    assert hbm_passes("sixstep", n) == 5.0
    # beyond the VMEM tile budget the fused Stockham is not a real option
    assert math.isinf(hbm_passes("stockham_pallas",
                                 STOCKHAM_PALLAS_VMEM_N * 2))
    assert math.isinf(hbm_passes("fourstep_pallas", 1 << 15))
    assert math.isinf(hbm_passes("stockham_pallas", 97))   # not 7-smooth
    # mixed radix: any 7-smooth length is still a single touch
    assert hbm_passes("stockham_pallas", 100) == 1.0
    assert hbm_passes("stockham_pallas", 3072) == 1.0
    assert hbm_passes("stockham_pallas", 18432) == 1.0


def test_hbm_passes_chirpz_model():
    # n=6859 convolves on the mixed-radix kernel at the smallest 7-smooth
    # m >= 2n-1 (13720 = 2^3*5*7^3, tighter than pow2 16384):
    # (2*1 engine passes + 3 pointwise) * m/n
    assert hbm_passes("chirpz_pallas", 6859) == \
        pytest.approx(5.0 * 13720 / 6859)
    # past the VMEM tile budget the padded transforms ride sixstep (5
    # passes each) at the pow2 padding
    n_big = (1 << 15) + 1                 # pow2 m = 2^17
    assert hbm_passes("chirpz_pallas", n_big) == \
        pytest.approx(13.0 * (1 << 17) / n_big)
    assert math.isinf(hbm_passes("chirpz_pallas", (1 << 23) + 1))
    # the vendor path pays its own modeled chirp fallback on non-smooth n
    assert hbm_passes("xla", 1 << 12) == 2.0
    assert hbm_passes("xla", 6859) == pytest.approx(6.0 * (1 << 14) / 6859)
    # ...which the fused chirp undercuts
    assert hbm_passes("chirpz_pallas", 6859) < hbm_passes("xla", 6859)


def test_estimate_bytes_moved_scales():
    # complex kinds: the engine moves the full signal
    p64 = Problem((4096,), "Outplace_Complex")
    one_pass = estimate_bytes_moved(p64, Candidate("stockham_pallas"))
    staged = estimate_bytes_moved(p64, Candidate("stockham"))
    assert one_pass == 2.0 * 4096 * 8        # read + write, c64 bytes
    assert staged == 12 * one_pass           # log2(4096) passes
    # double precision doubles the traffic
    assert estimate_bytes_moved(Problem((4096,), "Outplace_Complex",
                                        precision="double"),
                                Candidate("stockham_pallas")) == 2 * one_pass
    # real kinds ride the packed half-length path: half the traffic (and
    # one fewer stage for the staged backend, which runs at n/2)
    real = estimate_bytes_moved(Problem((4096,), "Outplace_Real"),
                                Candidate("stockham_pallas"))
    assert real == one_pass / 2


def test_estimate_choice_uses_model():
    # seed-pinned behaviors stay
    assert estimate_choice(Problem((64,))).backend == "dft"
    assert estimate_choice(Problem((1 << 20,))).backend == "xla"
    # mid-size pow2: a single-HBM-touch fused kernel wins the model
    assert estimate_choice(Problem((4096,))).backend in (
        "fourstep_pallas", "stockham_pallas")
    # beyond every fused kernel's reach the vendor path wins again
    assert estimate_choice(Problem((1 << 18,))).backend == "xla"


def test_estimate_pins_nonpow2_classes():
    """Acceptance pins: radix357 and oddshape extents plan onto fused
    Pallas paths, never the xla / jnp-bluestein fallbacks."""
    fused = ("stockham_pallas", "fourstep_pallas", "chirpz_pallas")
    for kind in ("Outplace_Complex", "Outplace_Real"):
        # radix357 (e.g. 3072 = 3*2^10): one-touch mixed-radix territory
        assert estimate_choice(Problem((3072,), kind)).backend in fused
        assert estimate_choice(Problem((18432,), kind)).backend in fused
        # oddshape (e.g. 6859 = 19^3): the fused chirp-Z
        assert estimate_choice(
            Problem((6859,), kind)).backend == "chirpz_pallas"
    # past the fourstep kernel's 16384 cap only the mixed-radix kernel
    # offers a single touch, so the pick is specific
    assert estimate_choice(
        Problem((18432,), "Outplace_Complex")).backend == "stockham_pallas"


def test_patient_sweeps_chirpz_knobs():
    cands = candidates(Problem((6859,), "Outplace_Complex"), patient=True)
    keys = {c.key() for c in cands}
    assert "chirpz_pallas(engine=stockham_pallas)" in keys
    assert "chirpz_pallas(engine=sixstep)" in keys
    assert "chirpz_pallas(tile_b=16)" in keys
    assert len(keys) == len(cands)  # no duplicate candidates


def test_patient_chirpz_engine_knob_honors_every_axis():
    """A forced chirp engine applies to every axis of a separable ND plan,
    so a knob is only emitted when ALL axes' padded lengths fit it — a
    (2^21, 100) problem pads axis 0 to 2^22 > the stockham_pallas cap,
    which must exclude that engine (and keep sixstep, which covers 2^22)."""
    cands = candidates(Problem((1 << 21, 100), "Outplace_Complex"),
                       patient=True)
    keys = {c.key() for c in cands}
    assert "chirpz_pallas(engine=stockham_pallas)" not in keys
    assert "chirpz_pallas(engine=sixstep)" in keys
    # every emitted chirp engine knob must actually build (no raise)
    from repro.fft.bluestein import resolve_engine
    for c in cands:
        if c.backend == "chirpz_pallas" and "engine" in c.opts():
            for ax_n in (1 << 21, 100):
                eng, m = resolve_engine(ax_n, c.opts()["engine"])
                if eng == "stockham_pallas":
                    assert m <= 1 << 20, (c.key(), ax_n, m)


# --------------------------------------------------------------------------
# PATIENT sweep -> wisdom -> warm reuse
# --------------------------------------------------------------------------
def test_patient_measures_candidates_and_roundtrips_wisdom(tmp_path):
    """Acceptance: a PATIENT plan for a large extent records per-candidate
    measured_ms for >= 6 candidates and round-trips through wisdom."""
    problem = Problem((1 << 16,), "Outplace_Complex", "float")
    wpath = str(tmp_path / "wisdom.json")
    w = Wisdom(wpath, device_kind="testdev")
    plan = make_plan(problem, PlanRigor.PATIENT,
                     build=lambda c: build_forward(problem, c), wisdom=w)
    assert len(plan.measured_ms) >= 6
    finite = [v for v in plan.measured_ms.values() if v == v]
    assert len(finite) >= 6
    assert plan.candidate.key() in plan.measured_ms
    assert plan.plan_time_ms > 0

    # the winning candidate (knobs included) persists through the JSON store
    w.save()
    stored = json.load(open(wpath))
    assert len(stored) == 1
    w2 = Wisdom(wpath, device_kind="testdev")
    assert w2.lookup(problem) == plan.candidate

    # warm planner: wisdom short-circuits the sweep (no timings, ~instant)
    plan2 = make_plan(problem, PlanRigor.PATIENT,
                      build=lambda c: build_forward(problem, c), wisdom=w2)
    assert plan2.candidate == plan.candidate
    assert plan2.measured_ms == {}
    assert plan2.plan_time_ms < plan.plan_time_ms


def test_buildless_measure_never_records_wisdom(tmp_path):
    """make_plan under MEASURE/PATIENT without a build falls back to the
    untimed ESTIMATE pick; recording that would let the wisdom-first
    short-circuit lock in an unmeasured choice forever."""
    problem = Problem((1024,), "Outplace_Complex", "float")
    w = Wisdom(str(tmp_path / "w.json"), device_kind="testdev")
    plan = make_plan(problem, PlanRigor.MEASURE, wisdom=w)  # build=None
    assert plan.measured_ms == {}
    assert w.lookup(problem) is None       # nothing persisted
    # a real sweep afterwards still runs and records
    plan2 = make_plan(problem, PlanRigor.MEASURE,
                      build=lambda c: build_forward(problem, c), wisdom=w)
    assert plan2.measured_ms and w.lookup(problem) == plan2.candidate


def test_warm_session_reuses_patient_wisdom(tmp_path, monkeypatch):
    """Suite-level: PATIENT run 1 sweeps and persists wisdom; a second
    Session pointed at the same wisdom file never sweeps."""
    import repro.core.plan as plan_mod

    calls = []
    real_measure = plan_mod.measure_plan

    def counting_measure(*a, **kw):
        calls.append(1)
        return real_measure(*a, **kw)

    monkeypatch.setattr(plan_mod, "measure_plan", counting_measure)
    wpath = str(tmp_path / "wisdom.json")
    spec = SuiteSpec(clients=("Planned",), extents=("512",),
                     kinds=("Outplace_Complex",), precisions=("float",),
                     rigor="patient", warmups=0, repetitions=1,
                     wisdom=wpath, output=None)
    rs1 = Session().run(spec)
    assert not rs1.failures(), [r.error for r in rs1.failures()]
    assert len(calls) >= 1          # cold: the sweep ran
    import os
    assert os.path.exists(wpath)    # Session persisted the tuned selection

    calls.clear()
    rs2 = Session().run(spec)       # fresh Session, same wisdom file
    assert not rs2.failures(), [r.error for r in rs2.failures()]
    assert calls == []              # warm: sweep skipped entirely

    s = rs2.summary()
    assert s["failures"] == 0
    assert s["plan_time_ms"] > 0    # init ops still carry compile time


def test_pinned_client_persists_scoped_wisdom(tmp_path, monkeypatch):
    """Backend-pinned clients sweep only their own knobs; the winner
    persists under a backend-scoped wisdom key (so it can't clobber the
    open planner's entry) and a warm Session skips the pinned sweep too."""
    import repro.core.plan as plan_mod

    calls = []
    real_measure = plan_mod.measure_plan

    def counting_measure(*a, **kw):
        calls.append(1)
        return real_measure(*a, **kw)

    monkeypatch.setattr(plan_mod, "measure_plan", counting_measure)
    wpath = str(tmp_path / "wisdom.json")
    spec = SuiteSpec(clients=("StockhamPallas",), extents=("256",),
                     kinds=("Outplace_Complex",), precisions=("float",),
                     rigor="patient", warmups=0, repetitions=1,
                     wisdom=wpath, output=None)
    rs1 = Session().run(spec)
    assert not rs1.failures(), [r.error for r in rs1.failures()]
    assert len(calls) >= 1

    stored = json.load(open(wpath))
    assert all(k.endswith("|stockham_pallas") for k in stored)  # scoped
    assert all(v["backend"] == "stockham_pallas" for v in stored.values())

    calls.clear()
    rs2 = Session().run(spec)       # fresh Session, same wisdom file
    assert not rs2.failures(), [r.error for r in rs2.failures()]
    assert calls == []              # pinned sweep skipped

    # scoped entries are invisible to the open planner's unscoped lookup
    w = Wisdom(wpath, device_kind=Session().device_kind)
    assert w.lookup(Problem((256,), "Outplace_Complex", "float")) is None
    assert w.lookup(Problem((256,), "Outplace_Complex", "float"),
                    scope="stockham_pallas") is not None

    # WISDOM_ONLY honors the persisted scoped knobs...
    spec_wo = SuiteSpec(clients=("StockhamPallas",), extents=("256",),
                        kinds=("Outplace_Complex",), precisions=("float",),
                        rigor="wisdom_only", warmups=0, repetitions=1,
                        wisdom=wpath, output=None)
    rs3 = Session().run(spec_wo)
    assert not rs3.failures(), [r.error for r in rs3.failures()]
    assert calls == []
    # ...and a wisdom miss is an fftw NULL plan (recorded failure), not a
    # silent fall-back to untuned defaults
    spec_miss = SuiteSpec(clients=("StockhamPallas",), extents=("128",),
                          kinds=("Outplace_Complex",), precisions=("float",),
                          rigor="wisdom_only", warmups=0, repetitions=1,
                          wisdom=wpath, output=None)
    rs4 = Session().run(spec_miss)
    fails = rs4.failures()
    assert fails and "NULL plan" in fails[0].error
