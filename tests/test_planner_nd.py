"""ND-native planner regression tests: golden candidate lists per problem
class, cost-model sanity (bytes-moved monotone in n, infeasible => inf, ND
transpose passes counted, r2c half-spectrum accounting), per-axis mixed
candidates, and wisdom round-trips of per-axis assignments — so model edits
can't silently flip ESTIMATE picks."""

import json
import math

import pytest

from repro.core.client import KINDS, PRECISIONS, Problem
from repro.core.plan import (BACKENDS, Candidate, FFT2_PALLAS_MAX_ELEMS,
                             FFT2_PALLAS_VMEM_ELEMS, axis_engine_n,
                             backend_supports, candidates,
                             estimate_bytes_moved, estimate_choice,
                             hbm_passes)
from repro.core.wisdom import Wisdom

INF = float("inf")


def homogeneous_backends(problem, patient=False):
    return [c.backend for c in candidates(problem, patient=patient)
            if not c.axes and not c.options]


# --------------------------------------------------------------------------
# golden candidate lists per problem class
# --------------------------------------------------------------------------
def test_golden_candidates_rank1_pow2():
    assert homogeneous_backends(Problem((64,), "Outplace_Complex")) == [
        "xla", "stockham", "fourstep", "dft", "fourstep_pallas",
        "stockham_pallas", "sixstep", "chirpz_pallas", "bluestein"]


def test_golden_candidates_rank1_smooth():
    # 100 = 2^2 * 5^2: 7-smooth (mixed-radix fused kernel applies) and
    # 10x10-factorable, but not pow2
    assert homogeneous_backends(Problem((100,), "Outplace_Complex")) == [
        "xla", "fourstep", "dft", "fourstep_pallas", "stockham_pallas",
        "chirpz_pallas", "bluestein"]


def test_golden_candidates_rank1_prime():
    # 97: prime; dft, the single-pass fft4step (97 x 1) and the chirp
    # paths still apply
    assert homogeneous_backends(Problem((97,), "Outplace_Complex")) == [
        "xla", "dft", "fourstep_pallas", "chirpz_pallas", "bluestein"]


def test_golden_candidates_rank2_pow2_offers_fft2():
    got = homogeneous_backends(Problem((8, 16), "Outplace_Complex"))
    assert got == ["xla", "stockham", "fourstep", "dft", "fourstep_pallas",
                   "stockham_pallas", "sixstep", "fft2_pallas",
                   "chirpz_pallas", "bluestein"]
    # the fused rank-2 kernel is rank-2 only and VMEM-capped
    assert "fft2_pallas" not in homogeneous_backends(
        Problem((16,), "Outplace_Complex"))
    assert "fft2_pallas" not in homogeneous_backends(
        Problem((8, 8, 8), "Outplace_Complex"))
    assert "fft2_pallas" not in homogeneous_backends(
        Problem((1024, 1024), "Outplace_Complex"))


def test_golden_estimate_picks():
    """The ESTIMATE picks the paper tables depend on, pinned per class."""
    assert estimate_choice(Problem((64,))).backend == "dft"
    assert estimate_choice(Problem((4096,))).backend in (
        "fourstep_pallas", "stockham_pallas")
    assert estimate_choice(Problem((1 << 20,))).backend == "xla"
    assert estimate_choice(Problem((64, 64, 64))).backend == "xla"
    for kind in KINDS:
        for precision in PRECISIONS:
            for ext in [(8, 8), (64, 64), (128, 512), (256, 256)]:
                c = estimate_choice(Problem(ext, kind, precision))
                assert c.backend == "fft2_pallas", (ext, kind, precision, c)
    # past the fused tile's VMEM budget the vendor path wins again
    assert estimate_choice(Problem((512, 512))).backend == "xla"


# --------------------------------------------------------------------------
# per-axis (mixed) candidates
# --------------------------------------------------------------------------
def test_mixed_candidates_enumerated_and_unique():
    cands = candidates(Problem((4, 4096), "Outplace_Complex"), patient=True)
    keys = [c.key() for c in cands]
    assert len(keys) == len(set(keys))
    mixed = [c for c in cands if c.axes]
    assert mixed, "rank-2 space must hold per-axis assignments"
    for c in mixed:
        assert c.backend == "nd" and len(c.axes) == 2
        assert estimate_bytes_moved(Problem((4, 4096), "Outplace_Complex"),
                                    c) < INF     # pruned by the model
    # rank-1 never gets mixed assignments
    assert not [c for c in candidates(Problem((4096,)), patient=True)
                if c.axes]


def test_mixed_candidate_cost_is_per_axis_sum():
    p = Problem((4, 4096), "Outplace_Complex")
    mixed = Candidate("nd", axes=(Candidate("dft"),
                                  Candidate("stockham_pallas")))
    elems = p.n_elems
    outer = (hbm_passes("dft", 4) + 2.0) * 2.0 * elems * 8   # + swap pair
    inner = hbm_passes("stockham_pallas", 4096) * 2.0 * elems * 8
    assert estimate_bytes_moved(p, mixed) == outer + inner


def test_per_axis_knobs_survive_in_plan():
    mixed = Candidate("nd", axes=(Candidate("dft"),
                                  Candidate("stockham_pallas",
                                            (("radix", 4),))))
    assert mixed.per_axis(2)[1].opts() == {"radix": 4}
    assert mixed.key() == "nd[dft;stockham_pallas(radix=4)]"
    with pytest.raises(ValueError):
        mixed.per_axis(3)


# --------------------------------------------------------------------------
# cost-model sanity
# --------------------------------------------------------------------------
def test_bytes_moved_monotone_in_n():
    for backend in ("xla", "stockham", "stockham_pallas", "chirpz_pallas",
                    "bluestein"):
        costs = [estimate_bytes_moved(Problem((1 << e,), "Outplace_Complex"),
                                      Candidate(backend))
                 for e in range(2, 15)]
        assert all(a <= b for a, b in zip(costs, costs[1:])), backend


def test_infeasible_is_inf():
    assert estimate_bytes_moved(Problem((100,), "Outplace_Complex"),
                                Candidate("stockham")) == INF
    assert estimate_bytes_moved(Problem((1024, 1024), "Outplace_Complex"),
                                Candidate("fft2_pallas")) == INF
    # offered (within the hard cap) but past the VMEM budget: modeled inf
    p512 = Problem((512, 512), "Outplace_Complex")
    assert 512 * 512 <= FFT2_PALLAS_MAX_ELEMS
    assert 512 * 512 > FFT2_PALLAS_VMEM_ELEMS
    assert backend_supports("fft2_pallas", p512)
    assert estimate_bytes_moved(p512, Candidate("fft2_pallas")) == INF
    # ...but the VMEM budget binds the PACKED tile for real kinds: a
    # 512x256 real problem really holds a 512x128 = 2^16 tile, so the
    # fused kernel stays modeled-feasible (and wins ESTIMATE) there
    pr = Problem((512, 256), "Outplace_Real")
    assert estimate_bytes_moved(pr, Candidate("fft2_pallas")) < INF
    assert estimate_choice(pr).backend == "fft2_pallas"
    assert estimate_bytes_moved(Problem((512, 256), "Outplace_Complex"),
                                Candidate("fft2_pallas")) == INF


def test_nd_transpose_passes_counted():
    """nd._apply_last pays one swapaxes in + one out per NON-innermost axis
    and none for the innermost: the model must charge exactly that."""
    p1 = Problem((4096,), "Outplace_Complex")
    p2 = Problem((4096, 4096), "Outplace_Complex")
    one = estimate_bytes_moved(p1, Candidate("stockham_pallas"))
    both = estimate_bytes_moved(p2, Candidate("stockham_pallas"))
    # rank-2: inner axis = 1 engine pass, outer = 1 engine + 2 swap passes;
    # rank-2 signal holds 4096x more elements than the rank-1 probe
    assert both == (1 + 3) * 4096 * one
    # the fused whole-transform backends pay no transpose traffic
    assert estimate_bytes_moved(p2, Candidate("xla")) == 2 * 4096 * one


def test_r2c_half_spectrum_accounting():
    pc = Problem((4096,), "Outplace_Complex")
    pr = Problem((4096,), "Outplace_Real")
    assert estimate_bytes_moved(pr, Candidate("stockham_pallas")) == \
        estimate_bytes_moved(pc, Candidate("stockham_pallas")) / 2
    # outer axes of a real transform run on n//2+1 half-spectrum bins
    pr2 = Problem((8, 4096), "Outplace_Real")
    inner = hbm_passes("stockham_pallas", 2048) * 2.0 * (8 * 2048) * 8
    outer = (hbm_passes("stockham_pallas", 8) + 2.0) * 2.0 * (8 * 2049) * 8
    assert estimate_bytes_moved(pr2, Candidate("stockham_pallas")) == \
        inner + outer
    # odd real lengths fall back to the full-length complex engine
    assert axis_engine_n(Problem((15,), "Outplace_Real"), 0) == 15
    assert axis_engine_n(Problem((16,), "Outplace_Real"), 0) == 8
    assert axis_engine_n(Problem((16,), "Outplace_Complex"), 0) == 16


def test_backend_supports_respects_packed_length():
    """Real-kind feasibility looks at the engine length (n//2), not the
    nominal extent — a backend that can't run the packed half is out."""
    # stockham needs pow2 at the ENGINE length; real 2*odd fails even
    # though... (6 is not pow2 either way; 2*pow2 always halves to pow2)
    assert backend_supports("stockham", Problem((8,), "Outplace_Real"))
    assert not backend_supports("stockham", Problem((6,), "Outplace_Real"))
    # sixstep's packed half can drop below its own composition minimum;
    # the engine falls back to the fused kernel there, so support holds
    assert backend_supports("sixstep", Problem((4,), "Outplace_Real"))
    assert not backend_supports("sixstep", Problem((2,), "Outplace_Real"))


def test_odd_length_real_kinds_route_to_full_complex_chirp():
    """The packed r2c trick only exists for even n: an odd-length real kind
    plans at the FULL extent, on the full-complex chirp path — feasibility,
    caps, and the cost model all see n, never a meaningless n//2."""
    p = Problem((6859,), "Outplace_Real")
    assert axis_engine_n(p, 0) == 6859              # full length, not 3429
    backs = [c.backend for c in candidates(p) if not c.axes]
    assert "chirpz_pallas" in backs and "bluestein" in backs
    # the chirp candidates enter through backend_supports like everyone
    # else (no unconditional append), so the cap binds at the full length:
    # an odd n past CHIRPZ_PALLAS_MAX_N keeps only the jnp chirp
    from repro.core.plan import CHIRPZ_PALLAS_MAX_N
    p_big = Problem(((CHIRPZ_PALLAS_MAX_N + 1),), "Outplace_Real")
    assert not backend_supports("chirpz_pallas", p_big)
    assert backend_supports("bluestein", p_big)
    assert "chirpz_pallas" not in [c.backend for c in candidates(p_big)]
    assert "bluestein" in [c.backend for c in candidates(p_big)]
    # the model charges full-length traffic for the odd real extent (the
    # even neighbor runs packed at half the elements)
    odd = estimate_bytes_moved(p, Candidate("bluestein"))
    even = estimate_bytes_moved(Problem((6860,), "Outplace_Real"),
                                Candidate("bluestein"))
    assert odd > even
    # and the ESTIMATE pick lands on the fused chirp, not xla/jnp-bluestein
    assert estimate_choice(p).backend == "chirpz_pallas"


# --------------------------------------------------------------------------
# wisdom round-trips per-axis assignments
# --------------------------------------------------------------------------
def test_wisdom_roundtrips_axes_candidates(tmp_path):
    p = Problem((4, 4096), "Outplace_Complex")
    cand = Candidate("nd", axes=(Candidate("dft"),
                                 Candidate("stockham_pallas",
                                           (("radix", 4), ("tile_b", 16)))))
    path = str(tmp_path / "w.json")
    w = Wisdom(path, device_kind="testdev")
    w.record(p, cand)
    w.save()
    stored = json.load(open(path))
    assert len(stored) == 1
    w2 = Wisdom(path, device_kind="testdev")
    assert w2.lookup(p) == cand
    # legacy flat records (no 'axes') still load
    key = next(iter(stored))
    stored[key] = {"backend": "xla", "options": []}
    json.dump(stored, open(path, "w"))
    assert Wisdom(path, device_kind="testdev").lookup(p) == Candidate("xla")


def test_backends_registry_is_complete():
    """Every backend the candidate space can emit appears in BACKENDS (the
    conformance matrix sweeps exactly this tuple)."""
    seen = set()
    for ext in [(64,), (100,), (97,), (8, 16), (4, 4, 8), (1 << 16,)]:
        for c in candidates(Problem(ext, "Outplace_Complex"), patient=True):
            for ax in (c.per_axis(len(ext)) if c.axes else (c,)):
                seen.add(ax.backend)
    assert seen <= set(BACKENDS) | {"nd"}
    assert set(BACKENDS) <= seen | {"nd"}
