"""gearshifft core framework tests: tree, selection, planner, runner, CSV."""

import numpy as np
import pytest

from repro.core.benchmark import Benchmark, BenchmarkConfig, make_input, roundtrip_error
from repro.core.client import Context, Problem
from repro.core.extents import classify, parse_extents, format_extents
from repro.core.plan import Candidate, PlanRigor, candidates, estimate_choice, make_plan
from repro.core.tree import build_tree, select
from repro.core.wisdom import Wisdom
from repro.core.clients import jax_fft as jf


# --------------------------------------------------------------------------
# extents
# --------------------------------------------------------------------------
def test_parse_extents():
    assert parse_extents("128x128x128") == (128, 128, 128)
    assert parse_extents("1024") == (1024,)
    assert format_extents((32, 64)) == "32x64"
    with pytest.raises(ValueError):
        parse_extents("12x-1")
    with pytest.raises(ValueError):
        parse_extents("1x2x3x4")


def test_classify():
    assert classify((1024,)) == "powerof2"
    assert classify((128, 128, 128)) == "powerof2"
    assert classify((120,)) == "radix357"      # 2^3*3*5
    assert classify((19 * 19,)) == "oddshape"  # paper's power-of-19


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------
def test_candidates_feasibility():
    backs = {c.backend for c in candidates(Problem((1024,)))}
    assert {"xla", "stockham", "fourstep", "fourstep_pallas", "bluestein"} <= backs
    assert "dft" not in backs  # 1024 > 128
    backs_odd = {c.backend for c in candidates(Problem((19 * 19,)))}
    assert "stockham" not in backs_odd and "bluestein" in backs_odd
    backs_tiny = {c.backend for c in candidates(Problem((64,)))}
    assert "dft" in backs_tiny


def test_estimate_heuristic():
    assert estimate_choice(Problem((64,))).backend == "dft"
    assert estimate_choice(Problem((1 << 20,))).backend == "xla"


def test_measure_plan_picks_feasible():
    problem = Problem((256,), "Outplace_Complex", "float")
    plan = make_plan(problem, PlanRigor.MEASURE,
                     build=lambda c: jf.build_forward(problem, c))
    # MEASURE picks by wall time: any feasible backend at n=256 may win
    assert plan.candidate.backend in {"xla", "stockham", "fourstep",
                                      "fourstep_pallas", "stockham_pallas",
                                      "sixstep", "chirpz_pallas", "dft",
                                      "bluestein"}
    assert plan.plan_time_ms > 0
    assert any(v == v for v in plan.measured_ms.values())  # some finite timing


def test_wisdom_roundtrip(tmp_path):
    w = Wisdom(str(tmp_path / "wisdom.json"), device_kind="cpu")
    problem = Problem((128,))
    assert w.lookup(problem) is None
    # WISDOM_ONLY with empty store -> NULL plan (fftw semantics)
    assert make_plan(problem, PlanRigor.WISDOM_ONLY, wisdom=w) is None
    w.record(problem, Candidate("fourstep", (("tile_b", 8),)))
    w.save()
    w2 = Wisdom(str(tmp_path / "wisdom.json"), device_kind="cpu")
    cand = w2.lookup(problem)
    assert cand.backend == "fourstep" and cand.opts() == {"tile_b": 8}
    plan = make_plan(problem, PlanRigor.WISDOM_ONLY, wisdom=w2)
    assert plan is not None and plan.candidate.backend == "fourstep"


# --------------------------------------------------------------------------
# tree + selection
# --------------------------------------------------------------------------
def test_tree_and_wildcards():
    nodes = build_tree([jf.XlaFFTClient, jf.StockhamClient], [(128,), (32, 32)],
                       kinds=("Inplace_Real", "Outplace_Complex"),
                       precisions=("float", "double"))
    assert len(nodes) == 2 * 2 * 2 * 2
    sel = select(nodes, "*/float/*/Inplace_Real")
    assert len(sel) == 4 and all("float/"
                                 in n.path and n.path.endswith("Inplace_Real") for n in sel)
    sel2 = select(nodes, "Stockham")
    assert len(sel2) == 8
    assert select(nodes, "NoSuch/*") == []


# --------------------------------------------------------------------------
# runner end-to-end
# --------------------------------------------------------------------------
def test_make_input_seesaw():
    x = make_input(Problem((1024,)), 0)
    assert x.dtype == np.float32 and x.min() >= 0 and x.max() < 1


def test_roundtrip_error_metric():
    x = np.ones((100,), np.float32)
    assert roundtrip_error(x, x) == 0.0
    assert roundtrip_error(x, x + 1e-3) < 1e-6  # constant offset: std ~ 0
    noisy = x + np.random.default_rng(0).normal(0, 1e-3, 100).astype(np.float32)
    assert roundtrip_error(x, noisy) > 1e-4


@pytest.mark.parametrize("client", [jf.XlaFFTClient, jf.StockhamClient,
                                    jf.FourStepClient])
def test_benchmark_runs_and_validates(client, tmp_path):
    nodes = build_tree([client], [(64,), (16, 16)],
                       kinds=("Outplace_Real", "Inplace_Complex"),
                       precisions=("float",))
    cfg = BenchmarkConfig(warmups=1, repetitions=2,
                          output=str(tmp_path / "result.csv"))
    writer = Benchmark(Context(), cfg).run_nodes(nodes)
    path = writer.save()
    rows = [r for r in writer.rows if r.op == "validate"]
    assert len(rows) == len(nodes)
    assert all(r.success for r in rows), [r.error for r in rows if not r.success]
    # every op recorded for every counted run
    ef = [r for r in writer.rows if r.op == "execute_forward"]
    assert len(ef) == len(nodes) * cfg.repetitions
    assert all(r.time_ms >= 0 for r in ef)
    with open(path) as f:
        header = f.readline().strip().split(",")
    assert header[0] == "library" and "time_ms" in header


def test_benchmark_failure_continues(tmp_path):
    # Stockham on non-pow2 extents must fail validation/planning but not abort
    nodes = build_tree([jf.StockhamClient], [(100,), (64,)],
                       kinds=("Outplace_Complex",), precisions=("float",))
    cfg = BenchmarkConfig(warmups=0, repetitions=1,
                          output=str(tmp_path / "r.csv"))
    writer = Benchmark(Context(), cfg).run_nodes(nodes)
    vals = {r.extents: r.success for r in writer.rows if r.op == "validate"}
    assert vals["100"] is False and vals["64"] is True


def test_cli_end_to_end(tmp_path):
    from repro.core.cli import main
    out = str(tmp_path / "cli.csv")
    rc = main(["-e", "64", "16x16", "--client", "XlaFFT", "--kinds",
               "Outplace_Real", "--precisions", "float", "--reps", "2",
               "--warmups", "0", "-o", out])
    assert rc == 0
    data = open(out).read()
    assert "XlaFFT" in data and "execute_forward" in data


def test_cli_wildcard_and_inplace(tmp_path):
    from repro.core.cli import main
    out = str(tmp_path / "cli2.csv")
    rc = main(["-e", "32x32", "--client", "FourStep", "-r",
               "*/float/*/Inplace_Real", "--reps", "1", "--warmups", "0",
               "-o", out])
    assert rc == 0
    data = open(out).read()
    assert "Inplace_Real" in data and "Outplace" not in data
