"""Wisdom schema v3: provenance fields, legacy-file loading, the
nearest-neighbor ``lookup_near`` read path with its feasibility-class
boundary, ``wisdom_near``-tagged plans, and concurrent union-merge saves."""

import json

import pytest

from repro.core.client import Problem
from repro.core.plan import Candidate, PlanRigor, make_plan
from repro.core.wisdom import (WISDOM_SCHEMA_VERSION, Wisdom,
                               _feasibility_class, _strip_shape_knobs)


def _wisdom(tmp_path, name="wisdom.json", device_kind="cpu") -> Wisdom:
    return Wisdom(str(tmp_path / name), device_kind=device_kind)


# ---------------------------------------------------------------------------
# v1/v2 fixtures load unchanged
# ---------------------------------------------------------------------------
def test_v1_and_v2_fixtures_load_unchanged(tmp_path):
    path = tmp_path / "wisdom.json"
    path.write_text(json.dumps({
        # v1: the pre-versioning layout — no "v", no axes/mesh
        "cpu|256/float/Outplace_Complex/b1": {
            "backend": "stockham_pallas", "options": [["radix", 4]]},
        # v2: versioned, per-axis assignment
        "cpu|64x64/float/Outplace_Complex/b1": {
            "v": 2, "backend": "nd", "options": [],
            "axes": [{"v": 2, "backend": "stockham", "options": []},
                     {"v": 2, "backend": "fourstep", "options": []}]},
        # demotions table (any vintage)
        "__demoted__": {"cpu|powerof2|r1": ["sixstep"]},
    }))
    w = Wisdom(str(path), device_kind="cpu")
    assert len(w) == 2
    c1 = w.lookup(Problem((256,), "Outplace_Complex", "float"))
    assert c1 == Candidate("stockham_pallas", (("radix", 4),))
    c2 = w.lookup(Problem((64, 64), "Outplace_Complex", "float"))
    assert c2.backend == "nd" and [a.backend for a in c2.axes] \
        == ["stockham", "fourstep"]
    assert w.demoted(Problem((1024,), "Outplace_Complex", "float")) \
        == frozenset({"sixstep"})


def test_future_schema_and_malformed_records_are_skipped(tmp_path):
    path = tmp_path / "wisdom.json"
    path.write_text(json.dumps({
        "cpu|256/float/Outplace_Complex/b1": {
            "v": WISDOM_SCHEMA_VERSION + 1, "backend": "xla", "options": []},
        "cpu|512/float/Outplace_Complex/b1": {
            "v": 3, "backend": "xla", "options": [],
            "measured_ms": "fast"},                       # malformed field
        "cpu|1024/float/Outplace_Complex/b1": {
            "v": 3, "backend": "xla", "options": []},     # fine
    }))
    with pytest.warns(UserWarning):
        w = Wisdom(str(path), device_kind="cpu")
    assert len(w) == 1
    assert w.lookup(Problem((1024,), "Outplace_Complex", "float")) is not None


# ---------------------------------------------------------------------------
# v3 provenance round-trip + measurements()
# ---------------------------------------------------------------------------
def test_v3_provenance_round_trips(tmp_path):
    w = _wisdom(tmp_path)
    p = Problem((256,), "Outplace_Complex", "float")
    w.record(p, Candidate("stockham_pallas"), measured_ms=1.25,
             rigor="measure")
    w.save()
    doc = json.loads((tmp_path / "wisdom.json").read_text())
    rec = doc["cpu|256/float/Outplace_Complex/b1"]
    assert rec["v"] == WISDOM_SCHEMA_VERSION
    assert rec["measured_ms"] == 1.25 and rec["rigor"] == "measure"
    w2 = _wisdom(tmp_path)
    rows = w2.measurements()
    assert rows == [(p, Candidate("stockham_pallas"), 1.25)]


def test_record_omits_unset_and_nan_provenance(tmp_path):
    w = _wisdom(tmp_path)
    p = Problem((256,), "Outplace_Complex", "float")
    w.record(p, Candidate("xla"))                              # legacy call
    w.record(Problem((512,), "Outplace_Complex", "float"),
             Candidate("xla"), measured_ms=float("nan"))       # untimed
    w.save()
    doc = json.loads((tmp_path / "wisdom.json").read_text())
    for rec in doc.values():
        assert "measured_ms" not in rec and "rigor" not in rec
    assert w.measurements() == []


def test_measurements_includes_scoped_entries(tmp_path):
    w = _wisdom(tmp_path)
    p = Problem((256,), "Outplace_Complex", "float")
    w.record(p, Candidate("stockham_pallas"), scope="stockham_pallas",
             measured_ms=0.5)
    assert w.measurements() == [(p, Candidate("stockham_pallas"), 0.5)]


# ---------------------------------------------------------------------------
# lookup_near: nearest same-class neighbor, never across feasibility
# ---------------------------------------------------------------------------
def test_lookup_near_picks_log2_closest_shape(tmp_path):
    w = _wisdom(tmp_path)
    for n, backend in ((256, "stockham_pallas"), (4096, "fourstep_pallas")):
        w.record(Problem((n,), "Outplace_Complex", "float"),
                 Candidate(backend))
    hit = w.lookup_near(Problem((512,), "Outplace_Complex", "float"))
    assert hit is not None
    cand, neighbor_key = hit
    # 512 is 1 octave from 256, 3 from 4096
    assert cand.backend == "stockham_pallas"
    assert neighbor_key == "cpu|256/float/Outplace_Complex/b1"


def test_lookup_near_skips_the_exact_key_and_empty_store(tmp_path):
    w = _wisdom(tmp_path)
    p = Problem((256,), "Outplace_Complex", "float")
    assert w.lookup_near(p) is None          # empty store
    w.record(p, Candidate("xla"))
    # only the exact shape is stored: a *near* lookup must not return it
    # (the caller already tried lookup())
    assert w.lookup_near(p) is None


def test_lookup_near_respects_class_rank_and_kind(tmp_path):
    w = _wisdom(tmp_path)
    w.record(Problem((256,), "Outplace_Complex", "float"), Candidate("xla"))
    # different extent class (radix357 vs powerof2)
    assert w.lookup_near(
        Problem((384,), "Outplace_Complex", "float")) is None
    # different rank
    assert w.lookup_near(
        Problem((512, 512), "Outplace_Complex", "float")) is None
    # different kind
    assert w.lookup_near(
        Problem((512,), "Outplace_Real", "float")) is None


def test_lookup_near_never_crosses_feasibility_boundary(tmp_path):
    # 16384 and 65536 are both powerof2 rank-1 — but the stockham_pallas
    # VMEM cap sits between them, so their backend-support sets differ and
    # neither may warm-start the other
    a = Problem((16384,), "Outplace_Complex", "float")
    b = Problem((65536,), "Outplace_Complex", "float")
    assert _feasibility_class(a) != _feasibility_class(b)
    w = _wisdom(tmp_path)
    w.record(a, Candidate("stockham_pallas"))
    assert w.lookup_near(b) is None
    # same-side neighbor: feasibility class matches, the hit transfers
    c = Problem((8192,), "Outplace_Complex", "float")
    assert _feasibility_class(a) == _feasibility_class(c)
    assert w.lookup_near(c) is not None


def test_lookup_near_strips_shape_knobs_across_extents(tmp_path):
    w = _wisdom(tmp_path)
    tuned = Candidate("sixstep", (("split_n1", 64), ("tile_b", 8)))
    w.record(Problem((4096,), "Outplace_Complex", "float"), tuned)
    hit = w.lookup_near(Problem((2048,), "Outplace_Complex", "float"))
    assert hit is not None
    cand, _ = hit
    # the n1*n2 factorization of 4096 is meaningless at 2048; the batch
    # tile transfers
    assert cand == Candidate("sixstep", (("tile_b", 8),))
    # same extents, different batch: the knobs are shape-valid and kept
    hit = w.lookup_near(Problem((4096,), "Outplace_Complex", "float",
                                batch=4))
    assert hit is not None and hit[0] == tuned


def test_strip_shape_knobs_recurses_into_axes():
    nd = Candidate("nd", (), (Candidate("sixstep", (("split_n1", 32),)),
                              Candidate("stockham", (("engine", "pow2"),))))
    stripped = _strip_shape_knobs(nd)
    assert stripped.axes[0].options == ()
    assert stripped.axes[1].options == ()


def test_lookup_near_never_transfers_mesh_candidates(tmp_path):
    w = _wisdom(tmp_path)
    w.record(Problem((4096,), "Outplace_Complex", "float"),
             Candidate("slab", (), (), (4,)))
    assert w.lookup_near(
        Problem((2048,), "Outplace_Complex", "float")) is None


def test_lookup_near_scoped_namespaces_are_separate(tmp_path):
    w = _wisdom(tmp_path)
    w.record(Problem((256,), "Outplace_Complex", "float"),
             Candidate("stockham_pallas"), scope="stockham_pallas")
    q = Problem((512,), "Outplace_Complex", "float")
    assert w.lookup_near(q) is None                        # unscoped view
    assert w.lookup_near(q, scope="stockham_pallas") is not None


# ---------------------------------------------------------------------------
# make_plan integration: wisdom_near plan source + the near=False opt-out
# ---------------------------------------------------------------------------
def test_make_plan_tags_interpolated_pick_wisdom_near(tmp_path):
    w = _wisdom(tmp_path)
    w.record(Problem((256,), "Outplace_Complex", "float"),
             Candidate("stockham_pallas"), measured_ms=0.8, rigor="measure")
    q = Problem((512,), "Outplace_Complex", "float")
    plan = make_plan(q, PlanRigor.MEASURE, wisdom=w)
    assert plan.source == "wisdom_near"
    assert plan.candidate.backend == "stockham_pallas"
    # exact hit stays plain 'wisdom'
    exact = make_plan(Problem((256,), "Outplace_Complex", "float"),
                      PlanRigor.MEASURE, wisdom=w)
    assert exact.source == "wisdom"
    # WISDOM_ONLY: near hit instead of the fftw NULL plan
    wo = make_plan(q, PlanRigor.WISDOM_ONLY, wisdom=w)
    assert wo is not None and wo.source == "wisdom_near"


def test_make_plan_near_false_disables_interpolation(tmp_path):
    w = _wisdom(tmp_path)
    w.record(Problem((256,), "Outplace_Complex", "float"),
             Candidate("stockham_pallas"))
    q = Problem((512,), "Outplace_Complex", "float")
    assert make_plan(q, PlanRigor.WISDOM_ONLY, wisdom=w, near=False) is None
    plan = make_plan(q, PlanRigor.MEASURE, wisdom=w, near=False)
    # build-less MEASURE falls through to the estimate pick — and must NOT
    # have been recorded as if it were measured
    assert plan.source == "estimate"
    assert w.lookup(q) is None


def test_near_pick_skips_demoted_backends(tmp_path):
    w = _wisdom(tmp_path)
    w.record(Problem((256,), "Outplace_Complex", "float"),
             Candidate("stockham_pallas"))
    q = Problem((512,), "Outplace_Complex", "float")
    w.record_demotion(q, "stockham_pallas")
    plan = make_plan(q, PlanRigor.MEASURE, wisdom=w)
    assert plan.source == "estimate"      # near hit rejected, estimate path
    assert plan.candidate.backend != "stockham_pallas"


# ---------------------------------------------------------------------------
# concurrent saves union-merge v3 fields
# ---------------------------------------------------------------------------
def test_concurrent_saves_union_merge_provenance(tmp_path):
    p = Problem((256,), "Outplace_Complex", "float")
    a = _wisdom(tmp_path)
    b = _wisdom(tmp_path)          # loaded before A saves
    a.record(p, Candidate("stockham_pallas"), measured_ms=0.9,
             rigor="measure")
    a.save()
    # B persists the same selection without provenance: A's fields survive
    b.record(p, Candidate("stockham_pallas"))
    b.save()
    doc = json.loads((tmp_path / "wisdom.json").read_text())
    rec = doc["cpu|256/float/Outplace_Complex/b1"]
    assert rec["measured_ms"] == 0.9 and rec["rigor"] == "measure"
    # ...and the merged store is what B now serves
    assert b.measurements() == [(p, Candidate("stockham_pallas"), 0.9)]


def test_concurrent_save_conflicting_selection_keeps_ours(tmp_path):
    p = Problem((256,), "Outplace_Complex", "float")
    a = _wisdom(tmp_path)
    b = _wisdom(tmp_path)
    a.record(p, Candidate("stockham_pallas"), measured_ms=0.9)
    a.save()
    b.record(p, Candidate("xla"), measured_ms=2.0, rigor="patient")
    b.save()
    doc = json.loads((tmp_path / "wisdom.json").read_text())
    rec = doc["cpu|256/float/Outplace_Complex/b1"]
    # different selection: B's record wins whole, no field bleed-through
    assert rec["backend"] == "xla" and rec["measured_ms"] == 2.0


def test_concurrent_demotions_union(tmp_path):
    p = Problem((256,), "Outplace_Complex", "float")
    a = _wisdom(tmp_path)
    b = _wisdom(tmp_path)
    a.record_demotion(p, "sixstep")
    a.save()
    b.record_demotion(p, "fourstep_pallas")
    b.save()
    fresh = _wisdom(tmp_path)
    assert fresh.demoted(p) == frozenset({"sixstep", "fourstep_pallas"})
