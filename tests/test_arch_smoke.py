"""Per-architecture smoke tests: REDUCED configs (same family/block
structure, tiny widths) run one forward + loss + grad and a prefill/decode
round on CPU, asserting output shapes and finiteness (no NaNs)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_configs, input_specs, SHAPES
from repro.models.model import Model

ARCHS = ["granite-moe-1b-a400m", "deepseek-v2-lite-16b", "gemma3-27b",
         "starcoder2-7b", "qwen3-1.7b", "internlm2-20b",
         "llama-3.2-vision-90b", "xlstm-350m", "hymba-1.5b",
         "musicgen-medium"]

B, S = 2, 16


def _batch(cfg, rng):
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, tok_shape), jnp.int32)}
    if cfg.block_kind == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_image_tokens, cfg.d_model)),
            jnp.float32).astype(cfg.dtype)
    return batch


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_registry_full_config(arch):
    cfg = get_config(arch)
    assert cfg.n_layers > 0 and cfg.d_model > 0 and cfg.vocab_size > 0
    # every shape has well-defined input specs
    for shape in SHAPES:
        specs = input_specs(cfg, shape)
        assert "tokens" in specs


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg, mesh=None, remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)

    logits, aux, _ = jax.jit(lambda p, b: model.forward(
        p, b["tokens"], image_embeds=b.get("image_embeds")))(params, batch)
    exp = (B, S + 0, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks \
        else (B, S, cfg.vocab_size)
    assert logits.shape == exp, (logits.shape, exp)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN in logits"

    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        model.loss_fn, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), "NaN loss"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, "bad grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch, rng):
    cfg = get_config(arch).reduced()
    model = Model(cfg, mesh=None, remat=False)
    params = model.init_params(jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)
    img = batch.get("image_embeds")
    max_len = S + 4

    cache = model.init_cache(B, max_len)
    last, cache = jax.jit(lambda p, t, c: model.prefill(
        p, t, c, image_embeds=img))(params, batch["tokens"][:, :S], cache)
    assert np.isfinite(np.asarray(last, np.float32)).all()

    tok_next = batch["tokens"][:, :1]
    step = jax.jit(lambda p, t, c, pos: model.decode_step(
        p, t, c, pos, image_embeds=img))
    logits, cache = step(params, tok_next, cache, jnp.asarray(S, jnp.int32))
    vshape = (B, 1, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks \
        else (B, 1, cfg.vocab_size)
    assert logits.shape == vshape
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    logits2, _ = step(params, tok_next, cache, jnp.asarray(S + 1, jnp.int32))
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_decode_consistency_gqa(rng):
    """decode_step(t) after prefill(0..t-1) == column t of the full forward."""
    cfg = get_config("qwen3-1.7b").reduced()
    model = Model(cfg, mesh=None, remat=False)
    params = model.init_params(jax.random.PRNGKey(2))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 9)), jnp.int32)
    full, _, _ = model.forward(params, tokens)
    cache = model.init_cache(1, 16)
    _, cache = model.prefill(params, tokens[:, :8], cache)
    dec, _ = model.decode_step(params, tokens[:, 8:9], cache,
                               jnp.asarray(8, jnp.int32))
    a = np.asarray(dec[0, 0], np.float32)
    b = np.asarray(full[0, 8], np.float32)
    # bf16 params/cache + different (blocked vs dense) softmax accumulation
    # order: compare up to bf16-scale noise + demand near-perfect correlation
    assert np.abs(a - b).max() < 0.5, np.abs(a - b).max()
    assert np.corrcoef(a, b)[0, 1] > 0.999


def test_decode_consistency_xlstm(rng):
    cfg = get_config("xlstm-350m").reduced()
    model = Model(cfg, mesh=None, remat=False)
    params = model.init_params(jax.random.PRNGKey(3))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 9)), jnp.int32)
    full, _, _ = model.forward(params, tokens)
    cache = model.init_cache(1, 16)
    _, cache = model.prefill(params, tokens[:, :8], cache)
    dec, _ = model.decode_step(params, tokens[:, 8:9], cache,
                               jnp.asarray(8, jnp.int32))
    a = np.asarray(dec[0, 0], np.float32)
    b = np.asarray(full[0, 8], np.float32)
    assert np.abs(a - b).max() < 0.5, np.abs(a - b).max()
    assert np.corrcoef(a, b)[0, 1] > 0.999


def test_all_archs_registered():
    assert set(ARCHS) <= set(list_configs())
