"""fft2_pallas kernel: interpret-mode numerics vs the pure-jnp oracle and
numpy, knob sweeps, batching/padding, and the VMEM feasibility cap.  (The
backend x kind x precision x rank sweep lives in test_conformance.py; this
module isolates the fused-kernel lowering itself.)"""

import numpy as np
import pytest
import jax.numpy as jnp

from helpers.accuracy import rel_l2
from repro.kernels.fft2_pallas import ops as f2_ops
from repro.kernels.fft2_pallas.ref import fft2_ref

RNG = np.random.default_rng(43)


def rc(shape, dtype=np.complex64):
    return (RNG.standard_normal(shape) +
            1j * RNG.standard_normal(shape)).astype(dtype)


# --------------------------------------------------------------------------
# kernel vs oracle vs numpy
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n1,n2", [(2, 2), (4, 16), (16, 4), (32, 64),
                                   (1, 16), (16, 1)])
@pytest.mark.parametrize("inverse", [False, True])
def test_kernel_matches_ref_and_numpy(n1, n2, inverse):
    x = rc((3, n1, n2))
    want_np = (np.fft.ifft2(x, axes=(-2, -1)) if inverse
               else np.fft.fft2(x, axes=(-2, -1)))
    ref = fft2_ref(jnp.asarray(x), inverse=inverse)
    got = f2_ops.fft2(jnp.asarray(x), inverse=inverse, interpret=True)
    assert rel_l2(ref, want_np) < 1e-3
    assert rel_l2(got, want_np) < 1e-3
    assert rel_l2(got, ref) < 1e-3


@pytest.mark.parametrize("radix", [2, 4, 8])
def test_radix_knob(radix):
    x = rc((2, 16, 32))
    got = f2_ops.fft2(jnp.asarray(x), radix=radix, interpret=True)
    assert rel_l2(got, np.fft.fft2(x, axes=(-2, -1))) < 1e-3


@pytest.mark.parametrize("batch,tile_b", [((1,), None), ((5,), 2),
                                          ((2, 3), 4), ((7,), 8)])
def test_batching_and_padding(batch, tile_b):
    """Batch tiles that don't divide the batch are padded by ops.fft2."""
    x = rc((*batch, 8, 16))
    got = f2_ops.fft2(jnp.asarray(x), tile_b=tile_b, interpret=True)
    assert got.shape == x.shape
    assert rel_l2(got, np.fft.fft2(x, axes=(-2, -1))) < 1e-3


def test_double_precision():
    x = rc((2, 16, 16), dtype=np.complex128)
    got = f2_ops.fft2(jnp.asarray(x), interpret=True)
    assert got.dtype == jnp.complex128
    assert rel_l2(got, np.fft.fft2(x, axes=(-2, -1))) < 1e-12


def test_roundtrip():
    x = rc((4, 32, 32))
    y = f2_ops.fft2(jnp.asarray(x), interpret=True)
    back = f2_ops.fft2(y, inverse=True, interpret=True)
    assert rel_l2(back, x) < 1e-3


# --------------------------------------------------------------------------
# feasibility contract
# --------------------------------------------------------------------------
def test_rejects_non_pow2_and_oversize():
    with pytest.raises(ValueError):
        f2_ops.fft2(jnp.zeros((3, 12, 16), jnp.complex64), interpret=True)
    with pytest.raises(ValueError):
        f2_ops.fft2(jnp.zeros((1, 1024, 1024), jnp.complex64), interpret=True)
    with pytest.raises(ValueError):
        f2_ops.fft2(jnp.zeros((16,), jnp.complex64), interpret=True)


def test_cap_matches_planner_constant():
    from repro.core.plan import FFT2_PALLAS_MAX_ELEMS
    assert f2_ops.MAX_ELEMS == FFT2_PALLAS_MAX_ELEMS


def test_engine_rejects_wrong_rank_loudly():
    """A pinned Fft2Pallas client forced onto a rank-1/3 problem must fail
    at build time — fft2 over the last two axes of a (batch, n) array would
    transform the batch axis and return correct-shaped wrong math."""
    from repro.core.client import Problem
    from repro.core.plan import Candidate
    from repro.core.clients.jax_fft import build_forward, build_inverse
    for ext in [(1024,), (8, 8, 8)]:
        with pytest.raises(ValueError, match="rank-2 only"):
            build_forward(Problem(ext, "Outplace_Complex"),
                          Candidate("fft2_pallas"))
        with pytest.raises(ValueError, match="rank-2 only"):
            build_inverse(Problem(ext, "Outplace_Complex"),
                          Candidate("fft2_pallas"))
