"""Layer-level correctness: blocked attention vs naive oracle, MLA,
decode-vs-sequence consistency for the recurrent mixers, MoE routing."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers, moe, ssm

KEY = jax.random.PRNGKey(0)


def naive_attention(q, k, v, causal=True, window=0, kv_len=None):
    b, sq, h, d = q.shape
    kh = k.shape[2]
    rep = h // kh
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * d ** -0.5
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= (qp - kp) < window
    if kv_len is not None:
        mask &= kp < kv_len
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(p.dtype)).astype(q.dtype)


@pytest.mark.parametrize("sq,skv,h,kh,causal,window", [
    (64, 64, 4, 4, True, 0),
    (64, 64, 8, 2, True, 0),     # GQA
    (33, 33, 4, 2, True, 0),     # ragged vs block size
    (64, 64, 4, 4, True, 16),    # sliding window
    (17, 64, 4, 4, False, 0),    # cross-attn shape
])
def test_blocked_attention_matches_naive(sq, skv, h, kh, causal, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, h, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, skv, kh, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, skv, kh, 16), jnp.float32)
    got = attn.blocked_attention(q, k, v, causal=causal, window=window,
                                 block_q=16, block_k=16)
    want = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_blocked_attention_is_global_flag():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 8))
    k = jax.random.normal(ks[1], (1, 32, 2, 8))
    v = jax.random.normal(ks[2], (1, 32, 2, 8))
    local = attn.blocked_attention(q, k, v, window=8, is_global=jnp.asarray(False),
                                   block_q=8, block_k=8)
    glob = attn.blocked_attention(q, k, v, window=8, is_global=jnp.asarray(True),
                                  block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(local),
                               np.asarray(naive_attention(q, k, v, window=8)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(glob),
                               np.asarray(naive_attention(q, k, v)),
                               rtol=2e-4, atol=2e-4)


def test_attention_decode_matches_prefill():
    d, h, kh, hd, smax = 32, 4, 2, 8, 24
    p = attn.init_attention(KEY, d, h, kh, hd, qk_norm=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    full, _ = attn.attention(p, x, n_heads=h, n_kv=kh, head_dim=hd,
                             positions=jnp.arange(8), qk_norm=True,
                             block_q=8, block_k=8)
    # prefill 7 tokens, then decode token 8
    cache = {"k": jnp.zeros((2, smax, kh, hd)), "v": jnp.zeros((2, smax, kh, hd))}
    _, cache = attn.attention(p, x[:, :7], n_heads=h, n_kv=kh, head_dim=hd,
                              positions=jnp.arange(7), qk_norm=True,
                              cache=cache, kv_len=jnp.asarray(0),
                              block_q=8, block_k=8)
    y1, _ = attn.attention(p, x[:, 7:8], n_heads=h, n_kv=kh, head_dim=hd,
                           positions=jnp.arange(7, 8), qk_norm=True,
                           cache=cache, kv_len=jnp.asarray(7),
                           block_q=8, block_k=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(full[:, 7:8]),
                               rtol=2e-3, atol=2e-3)


def test_mla_decode_matches_prefill():
    d, h = 32, 4
    dims = dict(kv_lora=16, nope_dim=8, rope_dim=4, v_dim=8)
    p = attn.init_mla(KEY, d, h, kv_lora=16, nope_dim=8, rope_dim=4, v_dim=8)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 9, d))
    full, _ = attn.mla_attention(p, x, n_heads=h, positions=jnp.arange(9),
                                 block_q=8, block_k=8, **dims)
    cache = {"c_kv": jnp.zeros((2, 16, 16)), "k_rope": jnp.zeros((2, 16, 4))}
    _, cache = attn.mla_attention(p, x[:, :8], n_heads=h, positions=jnp.arange(8),
                                  cache=cache, kv_len=jnp.asarray(0),
                                  block_q=8, block_k=8, **dims)
    y, _ = attn.mla_attention(p, x[:, 8:9], n_heads=h, positions=jnp.arange(8, 9),
                              cache=cache, kv_len=jnp.asarray(8),
                              block_q=8, block_k=8, **dims)
    np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, 8:9]),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# recurrent mixers
# --------------------------------------------------------------------------
def test_conv1d_causal_and_decode():
    p = ssm.init_conv1d(KEY, 6, 4)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 10, 6))
    y_full, _ = ssm.conv1d(p, x)
    # step-by-step with state
    state = jnp.zeros((2, 3, 6))
    outs = []
    for t in range(10):
        y, state = ssm.conv1d(p, x[:, t:t + 1], state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-5)


def test_mlstm_chunked_matches_decode():
    d, h = 16, 2
    p = ssm.init_mlstm(KEY, d, h, proj_factor=2.0, conv_k=4)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 12, d)) * 0.5
    y_seq = ssm.mlstm_sequence(p, x, h, chunk=4)  # chunked path
    cache = ssm.mlstm_decode_init(2, h, 2 * d, 4)
    outs = []
    for t in range(12):
        y, cache = ssm.mlstm_decode(p, x[:, t:t + 1], cache, h)
        outs.append(y)
    y_dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq),
                               rtol=5e-3, atol=5e-3)


def test_mlstm_chunk_invariance():
    d, h = 16, 2
    p = ssm.init_mlstm(KEY, d, h)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, d)) * 0.5
    y4 = ssm.mlstm_sequence(p, x, h, chunk=4)
    y16 = ssm.mlstm_sequence(p, x, h, chunk=16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), rtol=3e-3, atol=3e-3)


def test_slstm_runs_and_streams():
    d, h = 16, 4
    p = ssm.init_slstm(KEY, d, h)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 10, d)) * 0.5
    y_full, st_full = ssm.slstm_sequence(p, x, h)
    assert y_full.shape == (2, 10, d)
    # streaming over two halves == full
    y1, st = ssm.slstm_sequence(p, x[:, :5], h)
    y2, _ = ssm.slstm_sequence(p, x[:, 5:], h, state=st)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-3)


def test_mamba_chunked_matches_decode():
    d, di = 12, 24
    p = ssm.init_mamba(KEY, d, di, state=8, conv_k=4)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 9, d)) * 0.5
    y_full, _ = ssm.mamba_mix(p, x, chunk=4)
    conv_state = jnp.zeros((2, 3, di))
    ssm_state = jnp.zeros((2, di, 8))
    outs = []
    for t in range(9):
        y, (conv_state, ssm_state) = ssm.mamba_mix(p, x[:, t:t + 1],
                                                   conv_state, ssm_state)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), rtol=5e-3, atol=5e-3)


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------
def test_moe_routes_and_balances():
    d, dff, e, k = 16, 32, 8, 2
    p = moe.init_moe(KEY, d, dff, e, n_shared=1, d_ff_shared=32)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 12, d))
    y, aux = moe.moe_ffn(p, x, top_k=k, capacity_factor=2.0)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0.5  # aux ~ 1 for near-uniform routing


def test_moe_capacity_drops_dont_nan():
    d, dff, e, k = 8, 16, 4, 2
    p = moe.init_moe(KEY, d, dff, e)
    x = jax.random.normal(jax.random.PRNGKey(9), (1, 64, d))
    y, _ = moe.moe_ffn(p, x, top_k=k, capacity_factor=0.25)  # heavy drops
    assert np.isfinite(np.asarray(y)).all()


def test_moe_expert_slices_sum_to_full():
    """Simulate 2-way EP by hand: sum of partial outputs (each over half the
    experts) equals the single-device result."""
    d, dff, e, k = 8, 16, 4, 2
    p = moe.init_moe(KEY, d, dff, e)
    x = jax.random.normal(jax.random.PRNGKey(10), (1, 10, d))
    full, _ = moe.moe_ffn(p, x, top_k=k, capacity_factor=4.0)
    parts = []
    for lo in (0, 2):
        pp = dict(p)
        pp = {**p,
              "up": p["up"][lo:lo + 2], "gate": p["gate"][lo:lo + 2],
              "down": p["down"][lo:lo + 2]}
        pp.pop("shared", None)
        y, _ = moe.moe_ffn(pp, x, top_k=k, capacity_factor=4.0,
                           expert_offset=lo, n_experts_total=e)
        parts.append(y)
    np.testing.assert_allclose(np.asarray(parts[0] + parts[1]),
                               np.asarray(full), rtol=2e-4, atol=2e-4)
