"""Unit + property tests for the FFT math substrate (repro.fft.*)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.fft import stockham, fourstep, bluestein, rfft as rfft_mod, nd

jax.config.update("jax_enable_x64", True)

RNG = np.random.default_rng(42)


def rand_complex(shape, dtype=np.complex64):
    return (RNG.standard_normal(shape) + 1j * RNG.standard_normal(shape)).astype(dtype)


def rand_real(shape, dtype=np.float32):
    return RNG.standard_normal(shape).astype(dtype)


# --------------------------------------------------------------------------
# complex engines vs numpy
# --------------------------------------------------------------------------
ENGINES = {"stockham": stockham.fft, "fourstep": fourstep.fft, "bluestein": bluestein.fft}


@pytest.mark.parametrize("engine", ["stockham", "fourstep", "bluestein"])
@pytest.mark.parametrize("n", [2, 4, 8, 64, 128, 256, 1024, 4096])
@pytest.mark.parametrize("batch", [(), (3,), (2, 5)])
def test_cfft_pow2_matches_numpy(engine, n, batch):
    x = rand_complex((*batch, n))
    got = np.asarray(ENGINES[engine](jnp.asarray(x)))
    want = np.fft.fft(x, axis=-1)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * np.sqrt(n))


@pytest.mark.parametrize("engine", ["stockham", "fourstep", "bluestein"])
@pytest.mark.parametrize("n", [8, 256, 2048])
def test_cfft_roundtrip(engine, n):
    x = rand_complex((4, n))
    f = ENGINES[engine]
    got = np.asarray(f(f(jnp.asarray(x)), inverse=True))
    np.testing.assert_allclose(got, x, rtol=1e-4, atol=1e-4 * np.sqrt(n))


@pytest.mark.parametrize("n", [3, 5, 6, 12, 96, 120, 360, 1000])
def test_fourstep_smooth_sizes(n):
    x = rand_complex((2, n))
    got = np.asarray(fourstep.fft(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.fft.fft(x, axis=-1), rtol=2e-4, atol=2e-4 * np.sqrt(n))


@pytest.mark.parametrize("n", [3, 7, 17, 19, 97, 361, 1009])  # incl. 19^2 (paper oddshape)
def test_bluestein_arbitrary_sizes(n):
    x = rand_complex((2, n))
    got = np.asarray(bluestein.fft(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.fft.fft(x, axis=-1), rtol=3e-4, atol=3e-4 * np.sqrt(n))


def test_float64_precision():
    x = rand_complex((2, 512), dtype=np.complex128)
    got = np.asarray(stockham.fft(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.fft.fft(x, axis=-1), rtol=1e-12, atol=1e-10)


# --------------------------------------------------------------------------
# real transforms
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [2, 8, 64, 750, 1024])
def test_rfft_matches_numpy(n):
    x = rand_real((3, n))
    cfft = fourstep.fft if n % 2 == 0 or n == 750 else bluestein.fft
    got = np.asarray(rfft_mod.rfft(jnp.asarray(x), cfft))
    want = np.fft.rfft(x, axis=-1)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4 * np.sqrt(n))


@pytest.mark.parametrize("n", [8, 64, 1024, 27])
def test_irfft_roundtrip(n):
    x = rand_real((3, n))
    cfft = fourstep.fft if n % 2 == 0 else bluestein.fft
    spec = rfft_mod.rfft(jnp.asarray(x), cfft)
    back = np.asarray(rfft_mod.irfft(spec, n, cfft))
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# N-d transforms (the paper's 3D R2C headline case)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(8, 8), (4, 8, 16), (16, 16, 16)])
def test_fftn_matches_numpy(shape):
    x = rand_complex(shape)
    got = np.asarray(nd.fftn(jnp.asarray(x), stockham.fft))
    np.testing.assert_allclose(got, np.fft.fftn(x), rtol=1e-3, atol=1e-3 * np.sqrt(np.prod(shape)))


@pytest.mark.parametrize("shape", [(8, 16), (16, 16, 16), (8, 12, 20)])
def test_rfftn_matches_numpy(shape):
    x = rand_real(shape)
    got = np.asarray(nd.rfftn(jnp.asarray(x), fourstep.fft))
    np.testing.assert_allclose(got, np.fft.rfftn(x), rtol=1e-3, atol=1e-3 * np.sqrt(np.prod(shape)))


@pytest.mark.parametrize("shape", [(16, 16, 16), (8, 12, 20)])
def test_irfftn_roundtrip(shape):
    x = rand_real(shape)
    spec = nd.rfftn(jnp.asarray(x), fourstep.fft)
    back = np.asarray(nd.irfftn(spec, shape, fourstep.fft))
    np.testing.assert_allclose(back, x, rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------------
# property tests: DFT invariants
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(logn=st.integers(1, 9), seed=st.integers(0, 2**31 - 1))
def test_property_linearity(logn, seed):
    n = 2 ** logn
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((n,)) + 1j * rng.standard_normal((n,))).astype(np.complex64)
    y = (rng.standard_normal((n,)) + 1j * rng.standard_normal((n,))).astype(np.complex64)
    a, b = 0.7, -1.3
    lhs = np.asarray(stockham.fft(jnp.asarray(a * x + b * y)))
    rhs = a * np.asarray(stockham.fft(jnp.asarray(x))) + b * np.asarray(stockham.fft(jnp.asarray(y)))
    np.testing.assert_allclose(lhs, rhs, rtol=2e-3, atol=2e-3 * np.sqrt(n))


@settings(max_examples=25, deadline=None)
@given(logn=st.integers(1, 9), seed=st.integers(0, 2**31 - 1))
def test_property_parseval(logn, seed):
    n = 2 ** logn
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((n,)) + 1j * rng.standard_normal((n,))).astype(np.complex64)
    X = np.asarray(fourstep.fft(jnp.asarray(x)))
    np.testing.assert_allclose(np.sum(np.abs(X) ** 2) / n, np.sum(np.abs(x) ** 2),
                               rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 257), seed=st.integers(0, 2**31 - 1))
def test_property_bluestein_roundtrip_any_n(n, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((n,)) + 1j * rng.standard_normal((n,))).astype(np.complex64)
    back = np.asarray(bluestein.fft(bluestein.fft(jnp.asarray(x)), inverse=True))
    np.testing.assert_allclose(back, x, rtol=2e-3, atol=2e-3)
