"""Training substrate: optimizer, data determinism, checkpoint/restart,
preemption, straggler watchdog, gradient compression."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.model import Model
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, schedule
from repro.train.trainer import TrainConfig, Trainer, build_train_step
from repro.train import compression


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0,
                    clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, m = adamw_update(cfg, params, grads, state)
    assert float(loss(params)) < 1e-2


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) < 0.2
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 0.1
    assert float(schedule(cfg, jnp.asarray(100))) <= 0.11


def test_grad_clipping():
    cfg = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    huge = {"w": jnp.full(4, 1e6)}
    new_params, _, m = adamw_update(cfg, params, huge, state)
    assert float(m["grad_norm"]) > 1e5
    assert np.isfinite(np.asarray(new_params["w"])).all()


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------
def test_data_deterministic_and_step_indexed():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=7)
    a = SyntheticTokens(cfg)
    b = SyntheticTokens(cfg)
    np.testing.assert_array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
    assert not np.array_equal(a.batch(5)["tokens"], a.batch(6)["tokens"])
    assert int(a.batch(0)["tokens"].max()) < 1000


def test_data_multicodebook():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=2, n_codebooks=4)
    t = SyntheticTokens(cfg).batch(0)["tokens"]
    assert t.shape == (2, 16, 4)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    opt = init_opt_state(params)
    for step in (10, 20, 30):
        mgr.save(step, params, opt, extra={"data_step": step})
    assert mgr.all_steps() == [20, 30]  # rotated
    template = jax.tree.map(jnp.zeros_like, params)
    otemp = init_opt_state(params)
    p2, o2, manifest = mgr.restore(template, otemp)
    assert manifest["step"] == 30 and manifest["data_step"] == 30
    np.testing.assert_array_equal(p2["a"], params["a"])
    assert o2["step"].dtype == np.int32


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, {"w": jnp.ones(3)})
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(5, {"w": jnp.ones(3)})
    mgr.wait()
    assert mgr.latest_step() == 5


# --------------------------------------------------------------------------
# compression
# --------------------------------------------------------------------------
def test_compression_error_feedback_converges():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, 64), jnp.float32)}
    residual = compression.init_residual(g)
    total_true = np.zeros(64)
    total_comp = np.zeros(64)
    for _ in range(50):
        (q, s), residual = compression.compress_tree(g, residual)
        deq = compression.decompress_tree(q, s)
        total_true += np.asarray(g["w"])
        total_comp += np.asarray(deq["w"])
    # error feedback keeps the cumulative sum unbiased
    np.testing.assert_allclose(total_comp, total_true, rtol=0, atol=0.2)
    assert q["w"].dtype == jnp.int8


# --------------------------------------------------------------------------
# trainer end-to-end (tiny model)
# --------------------------------------------------------------------------
def _tiny_setup(tmp_path, steps=6, **tkw):
    cfg = get_config("qwen3-1.7b").reduced(n_layers=2)
    model = Model(cfg, remat=False)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=4))
    tcfg = TrainConfig(steps=steps, checkpoint_every=3,
                       checkpoint_dir=str(tmp_path), log_every=100,
                       opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=steps),
                       **tkw)
    return model, data, tcfg


def test_trainer_loss_decreases(tmp_path):
    model, data, tcfg = _tiny_setup(tmp_path, steps=30)
    tcfg.checkpoint_every = 1000
    out = Trainer(model, data, tcfg).run(verbose=False)
    # compare against step-0 loss
    params0 = model.init_params(jax.random.PRNGKey(0))
    l0 = float(model.loss_fn(params0, data.batch(0))[0])
    assert out["step"] == 30
    assert out["loss"] < l0, (out["loss"], l0)


def test_trainer_checkpoint_restart_resumes(tmp_path):
    model, data, tcfg = _tiny_setup(tmp_path, steps=3)
    out1 = Trainer(model, data, tcfg).run(verbose=False)
    assert out1["step"] == 3
    # second run continues to step 6 from the step-3 checkpoint
    tcfg.steps = 6
    out2 = Trainer(model, data, tcfg).run(verbose=False)
    assert out2["step"] == 6
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() == 6


def test_trainer_preemption_checkpoints_and_resumes(tmp_path):
    model, data, tcfg = _tiny_setup(tmp_path, steps=50)

    class PreemptingData:
        def __init__(self, inner, trainer_box, at):
            self.inner, self.box, self.at = inner, trainer_box, at
        def batch(self, step):
            if step >= self.at:
                self.box[0]._stop = True  # simulate SIGTERM mid-run
            return self.inner.batch(step)

    box = [None]
    pdata = PreemptingData(data, box, at=4)
    tr = Trainer(model, pdata, tcfg)
    box[0] = tr
    out = tr.run(verbose=False)
    assert out["preempted"] and out["step"] == 5
    assert CheckpointManager(str(tmp_path)).latest_step() == 5
    # clean restart picks up exactly where preemption checkpointed
    tcfg.steps = 7
    out2 = Trainer(model, data, tcfg).run(verbose=False)
    assert out2["step"] == 7 and not out2["preempted"]


def test_trainer_grad_compression_runs(tmp_path):
    model, data, tcfg = _tiny_setup(tmp_path, steps=4, grad_compression=True)
    out = Trainer(model, data, tcfg).run(verbose=False)
    assert out["step"] == 4 and np.isfinite(out["loss"])


def test_trainer_microbatch_equivalence(tmp_path):
    """2 microbatches == 1 full batch (same grads up to fp noise)."""
    cfg = get_config("qwen3-1.7b").reduced(n_layers=1)
    model = Model(cfg, remat=False)
    data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                      global_batch=4))
    params = model.init_params(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    batch = data.batch(0)
    s1 = build_train_step(model, OptConfig(lr=1e-3), microbatches=1)
    s2 = build_train_step(model, OptConfig(lr=1e-3), microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, init_opt_state(params), batch)
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-2, d  # bf16 params; loss means differ by microbatch averaging


def test_straggler_watchdog():
    from repro.train.trainer import Trainer as T
    t = T.__new__(T)
    t.cfg = TrainConfig(straggler_factor=2.0)
    t._step_times, t.stragglers = [], []
    for step, dt in enumerate([1, 1, 1, 1, 1, 5, 1]):
        t._watchdog(step, dt)
    assert t.stragglers == [5]
