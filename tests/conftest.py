import jax

# Full-precision twiddles and f64 oracle paths throughout the suite.
# (The dry-run sets its own XLA_FLAGS in a separate process; tests always
# see the default single host device.)
jax.config.update("jax_enable_x64", True)
