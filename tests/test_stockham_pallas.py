"""stockham_pallas kernel + six-step path: interpret-mode numerics vs the
pure-jnp oracle and numpy, both precisions, batched and rank-2.

(The hypothesis property tests live in test_stockham_pallas_props.py so
this module still runs where hypothesis is not installed.)
"""

import numpy as np
import pytest
import jax.numpy as jnp

from helpers.accuracy import rel_l2
from repro.fft import nd, sixstep
from repro.kernels.stockham_pallas import ops as sp_ops
from repro.kernels.stockham_pallas.ref import stockham_ref
from repro.kernels.stockham_pallas.stockham_pallas import radix_schedule

RNG = np.random.default_rng(31)


def rc(shape, dtype=np.complex64):
    return (RNG.standard_normal(shape) +
            1j * RNG.standard_normal(shape)).astype(dtype)


# --------------------------------------------------------------------------
# radix schedule
# --------------------------------------------------------------------------
def test_radix_schedule():
    assert radix_schedule(1024, 8) == (8, 8, 8, 2)      # radix-2 cleanup
    assert radix_schedule(256, 8) == (8, 8, 4)          # radix-4 cleanup
    assert radix_schedule(4096, 8) == (8, 8, 8, 8)
    assert radix_schedule(64, 4) == (4, 4, 4)
    assert radix_schedule(32, 2) == (2,) * 5
    assert radix_schedule(2, 8) == (2,)
    for n, radix in ((1 << 20, 8), (1 << 13, 4)):
        sched = radix_schedule(n, radix)
        prod = 1
        for r in sched:
            prod *= r
        assert prod == n
    with pytest.raises(ValueError):
        radix_schedule(97, 8)       # not 7-smooth
    with pytest.raises(ValueError):
        radix_schedule(88, 8)       # 8 * 11: 11 is not a stage radix
    with pytest.raises(ValueError):
        radix_schedule(64, 16)


def test_radix_schedule_mixed():
    """Odd prime factors become their own radix-7/5/3 work stages ahead of
    the pow2 chain; the stage product is always exactly n."""
    assert radix_schedule(3072, 8) == (3, 8, 8, 8, 2)   # 3 * 2^10
    assert radix_schedule(12, 8) == (3, 4)
    assert radix_schedule(100, 8) == (5, 5, 4)
    assert radix_schedule(945, 8) == (7, 5, 3, 3, 3)    # odd-only length
    assert radix_schedule(3, 8) == (3,)
    for n in (6, 60, 360, 1050, 18432):
        sched = radix_schedule(n)
        prod = 1
        for r in sched:
            prod *= r
        assert prod == n
        assert all(r in (2, 3, 4, 5, 7, 8) for r in sched)


# --------------------------------------------------------------------------
# kernel vs oracle vs numpy
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [2, 8, 64, 512, 4096])
@pytest.mark.parametrize("radix", [2, 4, 8])
@pytest.mark.parametrize("inverse", [False, True])
def test_kernel_matches_ref_and_numpy(n, radix, inverse):
    x = rc((3, n))
    want_np = np.fft.ifft(x, axis=-1) if inverse else np.fft.fft(x, axis=-1)
    ref = stockham_ref(jnp.asarray(x), radix=radix, inverse=inverse)
    got = sp_ops.fft(jnp.asarray(x), inverse=inverse, radix=radix,
                     interpret=True)
    assert rel_l2(ref, want_np) < 1e-3
    assert rel_l2(got, want_np) < 1e-3
    assert rel_l2(got, ref) < 1e-3


@pytest.mark.parametrize("batch,tile_b", [((1,), None), ((5,), 4),
                                          ((2, 3), 8), ((7,), 16)])
def test_ops_batching_and_padding(batch, tile_b):
    """Batch tiles that do not divide the flattened batch are padded."""
    x = rc((*batch, 256))
    got = sp_ops.fft(jnp.asarray(x), tile_b=tile_b, interpret=True)
    assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-3


@pytest.mark.parametrize("n", [16, 1024, 1 << 16, 1 << 20])
def test_ops_accuracy_c64(n):
    x = rc((1, n))
    got = sp_ops.fft(jnp.asarray(x), interpret=True)
    assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-3


# --------------------------------------------------------------------------
# mixed radix (the paper's radix357 class): one HBM touch for 7-smooth n
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [3, 12, 45, 100, 360, 3072])
@pytest.mark.parametrize("inverse", [False, True])
def test_mixed_radix_matches_ref_and_numpy(n, inverse):
    x = rc((3, n))
    want_np = np.fft.ifft(x, axis=-1) if inverse else np.fft.fft(x, axis=-1)
    ref = stockham_ref(jnp.asarray(x), inverse=inverse)
    got = sp_ops.fft(jnp.asarray(x), inverse=inverse, interpret=True)
    assert rel_l2(ref, want_np) < 1e-3
    assert rel_l2(got, want_np) < 1e-3


def test_mixed_radix_c128_and_radix_knob():
    x = rc((2, 972), np.complex128)          # 2^2 * 3^5
    for radix in (2, 4, 8):
        got = sp_ops.fft(jnp.asarray(x), radix=radix, interpret=True)
        assert np.asarray(got).dtype == np.complex128
        assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-8


def test_mixed_radix_roundtrip_and_batching():
    x = rc((5, 1050))                        # 2 * 3 * 5^2 * 7, padded tile
    y = sp_ops.fft(jnp.asarray(x), tile_b=4, interpret=True)
    back = sp_ops.fft(y, inverse=True, tile_b=4, interpret=True)
    assert rel_l2(back, x) < 1e-3


@pytest.mark.parametrize("n", [16, 2048, 1 << 15])
def test_ops_accuracy_c128(n):
    x = rc((2, n), np.complex128)
    got = sp_ops.fft(jnp.asarray(x), interpret=True)
    assert np.asarray(got).dtype == np.complex128
    assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-8


@pytest.mark.parametrize("n", [8, 512, 4096])
@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_ops_roundtrip(n, dtype):
    x = rc((3, n), dtype)
    y = sp_ops.fft(jnp.asarray(x), interpret=True)
    back = sp_ops.fft(y, inverse=True, interpret=True)
    tol = 1e-3 if dtype == np.complex64 else 1e-10
    assert rel_l2(back, x) < tol


def test_ops_rank2_via_nd():
    x = rc((16, 64))
    eng = lambda v, inverse=False: sp_ops.fft(v, inverse=inverse, interpret=True)
    got = nd.fftn(jnp.asarray(x), eng)
    assert rel_l2(got, np.fft.fft2(x)) < 1e-3


def test_ops_rejects_bad_lengths():
    with pytest.raises(ValueError, match="7-smooth"):
        sp_ops.fft(jnp.asarray(rc((2, 97))), interpret=True)
    with pytest.raises(ValueError, match="7-smooth"):
        sp_ops.fft(jnp.asarray(rc((2, 19 * 19))), interpret=True)
    with pytest.raises(ValueError, match="sixstep"):
        sp_ops.fft(jnp.asarray(rc((1, 1 << 21))), interpret=True)


# --------------------------------------------------------------------------
# six-step large-N path
# --------------------------------------------------------------------------
def test_sixstep_split():
    assert sixstep.choose_split(1 << 20) == (64, 16384)
    assert sixstep.choose_split(1 << 16) == (4, 16384)
    assert sixstep.choose_split(4) == (2, 2)
    # planner knob wins when valid, falls back when not
    assert sixstep.choose_split(1 << 16, n1=256) == (256, 256)
    assert sixstep.choose_split(1 << 16, n1=3) == (4, 16384)
    assert sixstep.choose_split(1 << 16, n1=1 << 15) == (4, 16384)  # n2 too small
    with pytest.raises(ValueError):
        sixstep.choose_split(100)


@pytest.mark.parametrize("n", [4, 256, 4096, 1 << 16, 1 << 20])
def test_sixstep_matches_numpy_c64(n):
    x = rc((1 if n >= 1 << 16 else 3, n))
    got = sixstep.fft(jnp.asarray(x), interpret=True)
    assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-3


@pytest.mark.parametrize("n", [256, 1 << 16])
def test_sixstep_matches_numpy_c128(n):
    x = rc((2, n), np.complex128)
    got = sixstep.fft(jnp.asarray(x), interpret=True)
    assert np.asarray(got).dtype == np.complex128
    assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-8


@pytest.mark.parametrize("n,n1", [(4096, 64), (1 << 16, 1024)])
def test_sixstep_split_knob(n, n1):
    x = rc((2, n))
    got = sixstep.fft(jnp.asarray(x), n1=n1, interpret=True)
    assert rel_l2(got, np.fft.fft(x, axis=-1)) < 1e-3


@pytest.mark.parametrize("dtype", [np.complex64, np.complex128])
def test_sixstep_roundtrip(dtype):
    x = rc((2, 1 << 14), dtype)
    y = sixstep.fft(jnp.asarray(x), interpret=True)
    back = sixstep.fft(y, inverse=True, interpret=True)
    tol = 1e-3 if dtype == np.complex64 else 1e-10
    assert rel_l2(back, x) < tol


def test_sixstep_rank2_via_nd():
    x = rc((8, 256))
    eng = lambda v, inverse=False: sixstep.fft(v, inverse=inverse, interpret=True)
    got = nd.fftn(jnp.asarray(x), eng)
    assert rel_l2(got, np.fft.fft2(x)) < 1e-3
