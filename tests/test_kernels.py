"""Pallas kernel tests: interpret=True vs pure-jnp oracles, shape/dtype sweeps."""

import numpy as np
import pytest
import jax.numpy as jnp

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.fft.reference import dft_matrix
from repro.kernels.dft_matmul.ref import dft_ref
from repro.kernels.dft_matmul import ops as dft_ops
from repro.kernels.fft4step.ref import fft4step_ref
from repro.kernels.fft4step import ops as fs_ops
from repro.kernels.fft4step.fft4step import fft4step
from repro.kernels.fftconv.ref import fftconv_ref
from repro.kernels.fftconv import ops as conv_ops

RNG = np.random.default_rng(7)


def rc(shape, dtype=np.complex64):
    return (RNG.standard_normal(shape) + 1j * RNG.standard_normal(shape)).astype(dtype)


# --------------------------------------------------------------------------
# dft_matmul
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [8, 32, 64, 128])
@pytest.mark.parametrize("b", [1, 5, 64, 300])
@pytest.mark.parametrize("inverse", [False, True])
def test_dft_matmul_kernel_vs_ref(n, b, inverse):
    x = rc((b, n))
    w = dft_matrix(n, inverse=inverse, dtype=jnp.complex128)
    wr = np.real(np.asarray(w)).astype(np.float32)
    wi = np.imag(np.asarray(w)).astype(np.float32)
    xr, xi = np.real(x).copy(), np.imag(x).copy()
    want_r, want_i = dft_ref(jnp.asarray(xr), jnp.asarray(xi), inverse=inverse)
    pad = (-b) % min(8, b) if b < 8 else (-b) % 8
    from repro.kernels.dft_matmul.dft_matmul import dft_matmul
    tile = 8 if b >= 8 else b
    bb = b + ((-b) % tile)
    xr_p = np.pad(xr, ((0, bb - b), (0, 0)))
    xi_p = np.pad(xi, ((0, bb - b), (0, 0)))
    got_r, got_i = dft_matmul(jnp.asarray(xr_p), jnp.asarray(xi_p),
                              jnp.asarray(wr), jnp.asarray(wi),
                              tile_b=tile, interpret=True)
    np.testing.assert_allclose(np.asarray(got_r)[:b], np.asarray(want_r),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(got_i)[:b], np.asarray(want_i),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("n", [16, 128])
def test_dft_ops_matches_numpy(n):
    x = rc((3, 7, n))
    got = np.asarray(dft_ops.dft(jnp.asarray(x), interpret=True))
    np.testing.assert_allclose(got, np.fft.fft(x, axis=-1), rtol=1e-3, atol=1e-3)
    got_i = np.asarray(dft_ops.dft(jnp.asarray(x), inverse=True, interpret=True))
    np.testing.assert_allclose(got_i, np.fft.ifft(x, axis=-1), rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------------
# fft4step
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n1,n2", [(4, 4), (8, 16), (32, 32), (128, 128), (64, 128)])
@pytest.mark.parametrize("inverse", [False, True])
def test_fft4step_kernel_vs_ref(n1, n2, inverse):
    b = 8
    xr = RNG.standard_normal((b, n1, n2)).astype(np.float32)
    xi = RNG.standard_normal((b, n1, n2)).astype(np.float32)
    want_r, want_i = fft4step_ref(jnp.asarray(xr), jnp.asarray(xi), n1, n2, inverse)

    from repro.fft.reference import twiddles
    f32 = lambda z: (np.real(np.asarray(z)).astype(np.float32),
                     np.imag(np.asarray(z)).astype(np.float32))
    w1r, w1i = f32(dft_matrix(n1, inverse=inverse, dtype=jnp.complex128))
    w2r, w2i = f32(dft_matrix(n2, inverse=inverse, dtype=jnp.complex128))
    tr, ti = f32(twiddles(n1, n2, inverse=inverse, dtype=jnp.complex128))
    got_r, got_i = fft4step(jnp.asarray(xr), jnp.asarray(xi),
                            jnp.asarray(w1r), jnp.asarray(w1i),
                            jnp.asarray(w2r), jnp.asarray(w2i),
                            jnp.asarray(tr), jnp.asarray(ti),
                            n1=n1, n2=n2, tile_b=4, interpret=True)
    tol = 1e-3 * np.sqrt(n1 * n2)
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(want_r), rtol=1e-3, atol=tol)
    np.testing.assert_allclose(np.asarray(got_i), np.asarray(want_i), rtol=1e-3, atol=tol)


@pytest.mark.parametrize("n", [64, 256, 1024, 4096, 16384])
def test_fft4step_ops_matches_numpy(n):
    x = rc((4, n))
    got = np.asarray(fs_ops.fft(jnp.asarray(x), interpret=True))
    want = np.fft.fft(x, axis=-1)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3 * np.sqrt(n))


@pytest.mark.parametrize("n", [256, 16384])
def test_fft4step_ops_roundtrip(n):
    x = rc((2, n))
    y = fs_ops.fft(jnp.asarray(x), interpret=True)
    back = np.asarray(fs_ops.fft(y, inverse=True, interpret=True))
    np.testing.assert_allclose(back, x, rtol=2e-3, atol=2e-3)


def test_fft4step_factor_choice():
    assert fs_ops.choose_factors(16384) == (128, 128)
    assert fs_ops.choose_factors(4096) == (64, 64)
    n1, n2 = fs_ops.choose_factors(8192)
    assert n1 * n2 == 8192 and n1 <= 128 and n2 <= 128
    with pytest.raises(ValueError):
        fs_ops.choose_factors(2 ** 20)


# --------------------------------------------------------------------------
# fused fftconv
# --------------------------------------------------------------------------
@pytest.mark.parametrize("c,b,L,K", [(2, 4, 100, 5), (1, 1, 512, 64),
                                     (3, 2, 1000, 24), (2, 8, 8000, 128)])
def test_fftconv_kernel_vs_ref(c, b, L, K):
    x = RNG.standard_normal((c, b, L)).astype(np.float32)
    h = RNG.standard_normal((c, K)).astype(np.float32) / np.sqrt(K)
    n = conv_ops._next_square_pow2(L + K - 1)
    want = np.asarray(fftconv_ref(jnp.asarray(x), jnp.asarray(h), n))
    got = np.asarray(conv_ops.fftconv(jnp.asarray(x), jnp.asarray(h), interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3 * np.sqrt(L))


def test_fftconv_is_causal_linear_conv():
    c, b, L, K = 1, 1, 64, 8
    x = RNG.standard_normal((c, b, L)).astype(np.float32)
    h = RNG.standard_normal((c, K)).astype(np.float32)
    got = np.asarray(conv_ops.fftconv(jnp.asarray(x), jnp.asarray(h), interpret=True))
    want = np.zeros((L,), np.float32)
    for t in range(L):
        for s in range(K):
            if t - s >= 0:
                want[t] += h[0, s] * x[0, 0, t - s]
    np.testing.assert_allclose(got[0, 0], want, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(logn=st.sampled_from([6, 8, 10]), seed=st.integers(0, 2**31 - 1),
       inverse=st.booleans())
def test_property_fft4step_matches_numpy(logn, seed, inverse):
    n = 2 ** logn
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((2, n)) + 1j * rng.standard_normal((2, n))).astype(np.complex64)
    got = np.asarray(fs_ops.fft(jnp.asarray(x), inverse=inverse, interpret=True))
    want = np.fft.ifft(x, axis=-1) if inverse else np.fft.fft(x, axis=-1)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3 * np.sqrt(n))
