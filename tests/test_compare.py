"""Shared comparison core (repro.core.compare) + the bench_diff gate.

Covers the three layers the perf-trajectory loop depends on: document
schema round-trip and legacy (schema-1) normalization, cross-run row
alignment, and the noise-aware verdicts — plus the golden markdown report
over the two checked-in fixtures and the bench_diff CLI exit codes.
"""

from __future__ import annotations

import importlib.util
import json
import math
import os

import pytest

from repro.core import compare, results
from repro.core.compare import (
    SMOKE_THRESHOLDS, AggStats, BenchDoc, BenchFormatError, Thresholds,
    aggregate_result_rows, align_rows, compare_pair, diff_docs, fig7_report,
    load_bench, make_meta, markdown_report, normalize_row, pooled_stderr,
    row_key,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures")


def _load_bench_diff():
    spec = importlib.util.spec_from_file_location(
        "bench_diff", os.path.join(ROOT, "tools", "bench_diff.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


bench_diff = _load_bench_diff()


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def _grid_row(**over):
    row = {"backend": "xla", "extent": "1024", "rank": 1,
           "class": "powerof2", "kind": "Outplace_Complex",
           "precision": "float", "time_ms": 1.0, "ok": True}
    row.update(over)
    return row


# ---------------------------------------------------------------------------
# documents: schema round-trip + legacy normalization
# ---------------------------------------------------------------------------
def test_make_meta_round_trip(tmp_path):
    meta = make_meta(device_kind="cpu", platform="cpu", jax="0.0", reps=2)
    assert meta["schema"] == compare.SCHEMA_VERSION
    # in this repo there is always a HEAD to stamp
    assert meta["git_sha"] and len(meta["git_sha"]) == 40
    path = _write(tmp_path, "BENCH_x.json",
                  {"meta": meta, "results": [_grid_row()]})
    doc = load_bench(path)
    assert doc.schema == compare.SCHEMA_VERSION
    assert doc.git_sha == meta["git_sha"]
    assert doc.meta["reps"] == 2
    assert doc.label == "BENCH_x.json"
    assert len(doc.ok_rows()) == 1


def test_load_legacy_committed_bench():
    """The committed schema-1 trajectory docs load and normalize."""
    doc = load_bench(os.path.join(ROOT, "BENCH_PR5.json"))
    assert doc.schema == 1
    assert doc.rows
    for r in doc.rows:
        assert r["mode"] == "grid"
        assert r["kind"] == "Outplace_Complex"
        assert r["precision"] == "float"
        assert r["devices"] == 1
        assert r["rank"] == len(str(r["extent"]).split("x"))


def test_normalize_serve_row():
    row = normalize_row({"mode": "serve_replay", "p50_ms": 1.0, "ok": True})
    assert row["backend"] == "serve_replay"
    assert row["extent"] == ""
    assert row["rank"] == 0
    assert row["devices"] == 1


@pytest.mark.parametrize("doc, msg", [
    ("[]", "top level"),
    ('{"results": []}', "meta"),
    ('{"meta": {"device_kind": "cpu", "platform": "cpu"}}', "results"),
    ('{"meta": {"platform": "cpu"}, "results": []}', "device_kind"),
    ('{"meta": {"device_kind": "cpu", "platform": "cpu", "schema": 99}, '
     '"results": []}', "newer than supported"),
    ('{"meta": {"device_kind": "cpu", "platform": "cpu"}, '
     '"results": [{"extent": "8"}]}', "no backend"),
    ("not json", "not valid JSON"),
])
def test_load_bench_rejects_malformed(tmp_path, doc, msg):
    p = tmp_path / "bad.json"
    p.write_text(doc)
    with pytest.raises(BenchFormatError, match=msg):
        load_bench(str(p))


# ---------------------------------------------------------------------------
# alignment
# ---------------------------------------------------------------------------
def test_align_rows_pairs_and_orphans():
    a = [normalize_row(_grid_row()),
         normalize_row(_grid_row(extent="4096"))]
    b = [normalize_row(_grid_row(time_ms=2.0)),
         normalize_row(_grid_row(backend="stockham"))]
    pairs = {k: (ra, rb) for k, ra, rb in align_rows(a, b)}
    assert len(pairs) == 3
    ra, rb = pairs[row_key(a[0])]
    assert ra["time_ms"] == 1.0 and rb["time_ms"] == 2.0
    assert pairs[row_key(a[1])][1] is None          # removed
    assert pairs[row_key(b[1])][0] is None          # added


def test_align_rows_duplicate_first_wins():
    a = [normalize_row(_grid_row(time_ms=1.0)),
         normalize_row(_grid_row(time_ms=9.0))]
    aligned = align_rows(a, [normalize_row(_grid_row(time_ms=2.0))])
    assert len(aligned) == 1
    assert aligned[0][1]["time_ms"] == 1.0


def test_serve_rows_never_collide_with_grid():
    grid = normalize_row(_grid_row())
    serve = normalize_row({"mode": "serve_replay", "p50_ms": 1.0})
    assert row_key(grid) != row_key(serve)


# ---------------------------------------------------------------------------
# noise-aware verdicts
# ---------------------------------------------------------------------------
def _pair(va, vb, th=Thresholds(), a_over=None, b_over=None):
    ra = normalize_row(_grid_row(time_ms=va, **(a_over or {})))
    rb = normalize_row(_grid_row(time_ms=vb, **(b_over or {})))
    return compare_pair(row_key(ra), ra, rb, th)


def test_feasibility_loss_gates_unconditionally():
    # even the loosest thresholds never excuse a lost grid point
    r = _pair(1.0, None, th=SMOKE_THRESHOLDS,
              b_over={"ok": False, "error": "boom"})
    assert r.verdict == "regression"
    assert "boom" in r.detail


def test_now_feasible_is_improvement():
    r = _pair(None, 1.0, a_over={"ok": False})
    assert r.verdict == "improvement"
    r = _pair(None, None, a_over={"ok": False}, b_over={"ok": False})
    assert r.verdict == "unchanged"


def test_one_rep_rows_gate_on_floors_only():
    th = Thresholds(sigma=3.0, min_rel=0.10, min_abs_ms=0.05)
    # n=1, no sd: pooled stderr is 0, the floors are the only gate
    assert _pair(1.0, 1.05, th).verdict == "unchanged"     # under min_rel
    assert _pair(1.0, 1.2, th).verdict == "regression"
    assert _pair(1.0, 0.8, th).verdict == "improvement"
    # micro-row: 50% slower but under the absolute floor
    assert _pair(0.01, 0.015, th).verdict == "unchanged"


def test_sigma_gate_uses_pooled_stderr():
    spread = {"sd_ms": 1.0, "n": 4}
    # +0.9 ms on 2.0 clears both floors but not 3 x sqrt(2*1/4) ~ 2.12
    r = _pair(2.0, 2.9, a_over=spread, b_over=spread)
    assert r.verdict == "unchanged"
    r = _pair(2.0, 6.0, a_over=spread, b_over=spread)
    assert r.verdict == "regression"
    assert r.stderr == pytest.approx(math.sqrt(0.5))


def test_pooled_stderr_defaults_to_zero():
    assert pooled_stderr(_grid_row(), _grid_row()) == 0.0
    assert pooled_stderr({"sd_ms": 2.0, "n": 4},
                         {"sd_ms": 0.0, "n": 1}) == pytest.approx(1.0)


def test_smoke_preset_ignores_small_slowdowns():
    assert _pair(1.0, 3.0, th=SMOKE_THRESHOLDS).verdict == "unchanged"
    assert _pair(1.0, 6.0, th=SMOKE_THRESHOLDS).verdict == "regression"


def test_zero_baseline_never_nan():
    r = _pair(0.0, 0.0)
    assert r.delta_rel == 0.0
    r = _pair(0.0, 5.0)
    assert r.delta_rel == math.inf and r.verdict == "regression"


def test_higher_is_better_metrics():
    ra = normalize_row({"mode": "serve_burst", "speedup": 4.0, "ok": True})
    rb = normalize_row({"mode": "serve_burst", "speedup": 1.5, "ok": True})
    r = compare_pair(row_key(ra), ra, rb, Thresholds())
    assert r.verdict == "regression"
    r = compare_pair(row_key(ra), rb, ra, Thresholds())
    assert r.verdict == "improvement"


def test_missing_metric_is_unchanged():
    ra = normalize_row(_grid_row())
    del ra["time_ms"]
    r = compare_pair(row_key(ra), ra, normalize_row(_grid_row()),
                     Thresholds())
    assert r.verdict == "unchanged" and "missing" in r.detail


# ---------------------------------------------------------------------------
# diff_docs + reports
# ---------------------------------------------------------------------------
def _doc(rows, label="x.json", **meta_over):
    meta = {"schema": 2, "device_kind": "cpu", "platform": "cpu"}
    meta.update(meta_over)
    return BenchDoc(path=label, meta=meta,
                    rows=[normalize_row(r) for r in rows])


def test_diff_docs_warns_on_device_mismatch_and_dups():
    a = _doc([_grid_row(), _grid_row()], label="a.json")
    b = _doc([_grid_row()], label="b.json", device_kind="tpu v5e")
    res = diff_docs(a, b)
    assert any("duplicate row key" in w for w in res.warnings)
    assert any("device kinds differ" in w for w in res.warnings)
    report = markdown_report(res)
    assert "**warning:**" in report


def test_golden_markdown_report():
    """The checked-in fixtures produce exactly the checked-in report."""
    res = diff_docs(load_bench(os.path.join(FIXTURES, "BENCH_a.json")),
                    load_bench(os.path.join(FIXTURES, "BENCH_b.json")),
                    Thresholds())
    with open(os.path.join(FIXTURES, "bench_diff_golden.md")) as f:
        golden = f.read()
    assert markdown_report(res) == golden
    assert res.has_regression
    assert res.count("improvement") == 1
    assert res.count("added") == 1
    assert res.count("removed") == 1


def test_fig7_report_cells():
    rows = [
        _grid_row(roofline_frac=0.25),
        _grid_row(extent="960", **{"class": "radix357"},
                  roofline_frac=0.5),
        _grid_row(backend="fourstep", ok=False, error="nope"),
        _grid_row(backend="stockham"),                 # ok, no roofline data
    ]
    doc = _doc(rows)
    report = fig7_report(doc)
    lines = report.splitlines()
    header = next(ln for ln in lines if ln.startswith("| backend"))
    # powerof2 column sorts before radix357
    assert header.index("powerof2/1d") < header.index("radix357/1d")
    xla = next(ln for ln in lines if ln.startswith("| xla"))
    assert "25.0%" in xla and "50.0%" in xla
    four = next(ln for ln in lines if ln.startswith("| fourstep"))
    assert "·" in four
    stock = next(ln for ln in lines if ln.startswith("| stockham"))
    assert "?" in stock
    assert "3/4 grid points feasible" in report


# ---------------------------------------------------------------------------
# bench_diff CLI
# ---------------------------------------------------------------------------
def test_bench_diff_exit_codes(tmp_path, capsys):
    a = os.path.join(FIXTURES, "BENCH_a.json")
    b = os.path.join(FIXTURES, "BENCH_b.json")
    md = str(tmp_path / "out.md")
    assert bench_diff.main([a, b, "--md", md]) == 1    # injected regression
    capsys.readouterr()
    with open(md) as f:
        assert "VERDICT: FAIL" in f.read()
    assert bench_diff.main([a, b, "--no-fail"]) == 0
    assert bench_diff.main([a, a]) == 0                # self-diff passes
    out = capsys.readouterr().out
    assert "VERDICT: PASS" in out
    assert bench_diff.main([a, str(tmp_path / "missing.json")]) == 2


def test_bench_diff_fail_on_missing(tmp_path, capsys):
    a = os.path.join(FIXTURES, "BENCH_a.json")
    with open(a) as f:
        doc = json.load(f)
    doc["results"] = doc["results"][:-2]       # drop bluestein + fourstep
    trimmed = _write(tmp_path, "trimmed.json", doc)
    # identical timings, two rows gone: clean pass unless missing rows gate
    assert bench_diff.main([a, trimmed]) == 0
    assert bench_diff.main([a, trimmed, "--fail-on-missing"]) == 1
    capsys.readouterr()


def test_bench_diff_threshold_overrides(capsys):
    a = os.path.join(FIXTURES, "BENCH_a.json")
    b = os.path.join(FIXTURES, "BENCH_b.json")
    # a 400% slowdown passes once the min-effect floor is above it
    assert bench_diff.main([a, b, "--min-rel", "5.0"]) == 0
    out = capsys.readouterr().out
    assert "`custom`" in out


def test_legacy_cross_schema_diff():
    """Schema-1 vs schema-2 docs align (the PR5-vs-PR7 acceptance path)."""
    doc5 = load_bench(os.path.join(ROOT, "BENCH_PR5.json"))
    doc7 = load_bench(os.path.join(ROOT, "BENCH_PR7.json"))
    res = diff_docs(doc5, doc7, SMOKE_THRESHOLDS)
    assert res.rows
    report = markdown_report(res)
    assert "VERDICT:" in report


# ---------------------------------------------------------------------------
# suite-result aggregation through the shared core
# ---------------------------------------------------------------------------
def _rows():
    out = []
    for lib, t in (("a", [1.0, 2.0, 3.0]), ("b", [5.0])):
        for i, ms in enumerate(t):
            out.append(results.Row(
                library=lib, device="cpu", extents="8", rank=1,
                extent_class="powerof2", precision="float",
                kind="Outplace_Real", rigor="estimate", run=i,
                op="execute_forward", time_ms=ms))
    out.append(results.Row(
        library="a", device="cpu", extents="8", rank=1,
        extent_class="powerof2", precision="float", kind="Outplace_Real",
        rigor="estimate", run=9, op="execute_forward", time_ms=99.0,
        success=False, error="x"))
    return out


def test_aggregate_named_matches_legacy_tuples():
    rows = _rows()
    named = aggregate_result_rows(rows, op="execute_forward")
    legacy = results.aggregate_rows(rows, op="execute_forward")
    assert [a.as_tuple() for a in named] == legacy
    a = next(r for r in named if r.library == "a")
    assert a.mean == pytest.approx(2.0)
    assert a.n == 3                                 # failed row excluded
    assert a.stats.best == 1.0


def test_aggregate_percentile_layout():
    rows = _rows()
    named = aggregate_result_rows(rows, op="execute_forward",
                                  percentiles=True)
    a = next(r for r in named if r.library == "a")
    assert a.p50 == pytest.approx(2.0)
    assert a.as_tuple() == (*a.as_tuple()[:6], a.mean, a.sd,
                            a.p50, a.p95, a.p99, a.n)
    legacy = results.aggregate_rows(rows, op="execute_forward",
                                    percentiles=True)
    assert [r.as_tuple() for r in named] == legacy


def test_aggstats_single_sample():
    s = AggStats.of([4.0])
    assert s.mean == 4.0 and s.sd == 0.0 and s.n == 1 and s.best == 4.0
