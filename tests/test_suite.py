"""SuiteSpec/Session API tests: TOML/JSON round-trips, --dump-config golden
output, CLI-vs-spec node-tree equivalence, ResultSet helpers, and the
Session-shared plan cache."""

import csv
import json

import pytest

from repro.core.client import KINDS, Problem
from repro.core.results import COLUMNS, Row, columns_for
from repro.core.suite import (ResultSet, Session, SuiteSpec, SweepSpec,
                              run_suite)
from repro.core.tree import build_tree, select
from repro.core.clients import jax_fft as jf


# --------------------------------------------------------------------------
# spec construction + validation
# --------------------------------------------------------------------------
def test_spec_normalizes_extents_forms():
    s = SuiteSpec(extents=("128x64", 1024, (32, 32)))
    assert s.extents == ((128, 64), (1024,), (32, 32))


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="unknown kind"):
        SuiteSpec(kinds=("Sideways_Real",))
    with pytest.raises(ValueError, match="unknown precision"):
        SuiteSpec(precisions=("half",))
    with pytest.raises(ValueError, match="unknown rigor"):
        SuiteSpec(rigor="vibes")
    with pytest.raises(ValueError, match="batch"):
        SuiteSpec(batch=0)
    with pytest.raises(ValueError, match="unknown format"):
        SuiteSpec(format="xml")
    with pytest.raises(ValueError, match="unknown sweep class"):
        SweepSpec("fibonacci")
    with pytest.raises(ValueError, match="requires"):
        SweepSpec("powerof2", min_exp=3)   # max_exp missing: eager failure


def test_spec_resolved_extents_explicit_plus_sweeps():
    s = SuiteSpec(extents=("100",),
                  sweeps=(SweepSpec("powerof2", rank=1, min_exp=3, max_exp=4),
                          SweepSpec("oddshape", rank=1, count=1)))
    assert s.resolved_extents() == ((100,), (8,), (16,), (19,))


def test_spec_build_nodes_requires_extents():
    with pytest.raises(ValueError, match="resolves no extents"):
        SuiteSpec(extents=()).build_nodes()


# --------------------------------------------------------------------------
# serialization round-trips
# --------------------------------------------------------------------------
FULL_SPEC = SuiteSpec(
    clients=("XlaFFT", "Stockham"), load=(),
    extents=("64", "32x32"),
    sweeps=(SweepSpec("powerof2", rank=3, min_exp=3, max_exp=5),
            SweepSpec("radix357", rank=1, count=4, start=96)),
    kinds=("Outplace_Real", "Inplace_Complex"), precisions=("float",),
    batch=2, select="*/float/*/Outplace_Real", rigor="measure",
    warmups=2, repetitions=4, error_bound=1e-4, seed=7,
    plan_cache=False, wisdom="w.json", output="out.jsonl", format="jsonl",
    verbose=True)


def test_toml_roundtrip_equality():
    assert SuiteSpec.from_toml(FULL_SPEC.to_toml()) == FULL_SPEC
    # defaults round-trip too (None fields omitted from the file)
    d = SuiteSpec(extents=("16",))
    assert SuiteSpec.from_toml(d.to_toml()) == d
    assert "select" not in d.to_toml() and "wisdom" not in d.to_toml()


def test_json_roundtrip_equality():
    assert SuiteSpec.from_json(FULL_SPEC.to_json()) == FULL_SPEC
    # json and toml describe the identical dict
    assert json.loads(FULL_SPEC.to_json()) == FULL_SPEC.to_dict()


def test_file_roundtrip_by_extension(tmp_path):
    t = str(tmp_path / "s.toml")
    j = str(tmp_path / "s.json")
    FULL_SPEC.save(t)
    FULL_SPEC.save(j)
    assert SuiteSpec.from_file(t) == SuiteSpec.from_file(j) == FULL_SPEC
    assert open(j).read().lstrip().startswith("{")


def test_from_dict_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown SuiteSpec key"):
        SuiteSpec.from_dict({"extents": ["64"], "repititions": 3})
    with pytest.raises(ValueError, match="unknown sweep key"):
        SuiteSpec.from_dict({"sweep": [{"class": "oddshape", "depth": 2}]})
    with pytest.raises(ValueError, match="missing 'class'"):
        SuiteSpec.from_dict({"sweep": [{"rank": 1}]})


# --------------------------------------------------------------------------
# CLI adapter: argv -> spec -> identical node tree
# --------------------------------------------------------------------------
def test_cli_and_spec_produce_identical_node_trees():
    from repro.core.cli import build_parser, spec_from_args
    argv = ["-e", "64", "16x16", "--client", "XlaFFT", "Stockham",
            "--kinds", "Outplace_Real", "Inplace_Complex",
            "--precisions", "float", "-r", "*/float/*/Outplace_Real",
            "-b", "2"]
    spec = spec_from_args(build_parser().parse_args(argv))
    expected = select(
        build_tree([jf.XlaFFTClient, jf.StockhamClient], [(64,), (16, 16)],
                   kinds=("Outplace_Real", "Inplace_Complex"),
                   precisions=("float",), batch=2),
        "*/float/*/Outplace_Real")
    assert spec.build_nodes() == expected


def test_cli_defaults_map_to_spec_defaults():
    from repro.core.cli import build_parser, spec_from_args
    spec = spec_from_args(build_parser().parse_args([]))
    assert spec.clients == ("XlaFFT",)
    assert spec.extents == ((32, 32, 32),)
    assert spec.kinds == KINDS and spec.precisions == ("float",)
    assert spec.plan_cache is True and spec.select is None


def test_dump_config_golden(capsys):
    from repro.core.cli import main
    rc = main(["-e", "64", "--kinds", "Outplace_Real", "--precisions",
               "float", "--reps", "2", "--warmups", "0", "--dump-config"])
    assert rc == 0
    golden = """\
clients = ["XlaFFT"]
extents = ["64"]
kinds = ["Outplace_Real"]
precisions = ["float"]
batch = 1
rigor = "estimate"
warmups = 0
repetitions = 2
error_bound = 1e-05
seed = 2017
plan_cache = true
verbose = false
output = "result.csv"
"""
    assert capsys.readouterr().out == golden


def test_dump_config_config_roundtrip_runs_identically(tmp_path):
    """--dump-config → --config replays the CLI invocation: same spec, same
    node tree, same CSV schema."""
    from repro.core.cli import build_parser, main, spec_from_args
    argv = ["-e", "16", "--client", "XlaFFT", "--kinds", "Outplace_Real",
            "--precisions", "float", "--reps", "1", "--warmups", "0"]
    spath = str(tmp_path / "spec.toml")
    assert main(argv + ["--dump-config", spath]) == 0

    replayed = SuiteSpec.from_file(spath)
    direct = spec_from_args(build_parser().parse_args(argv))
    assert replayed == direct
    assert replayed.build_nodes() == direct.build_nodes()

    out_a = str(tmp_path / "a.csv")
    out_b = str(tmp_path / "b.csv")
    assert main(argv + ["-o", out_a]) == 0
    assert main(["--config", spath, "-o", out_b]) == 0
    with open(out_a) as fa, open(out_b) as fb:
        assert fa.readline() == fb.readline()    # identical CSV schema


def test_config_with_explicit_flag_override(tmp_path, capsys):
    from repro.core.cli import main
    spath = str(tmp_path / "spec.toml")
    SuiteSpec(extents=("64",), repetitions=5, warmups=3,
              kinds=("Outplace_Real",)).save(spath)
    rc = main(["--config", spath, "--reps", "1", "--dump-config", "-"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "repetitions = 1" in out      # explicit flag wins
    assert "warmups = 3" in out          # file value kept
    assert 'extents = ["64"]' in out


def test_cli_config_end_to_end(tmp_path):
    from repro.core.cli import main
    spath = str(tmp_path / "spec.toml")
    out = str(tmp_path / "r.csv")
    SuiteSpec(clients=("XlaFFT",), extents=("16",), kinds=("Outplace_Real",),
              precisions=("float",), warmups=0, repetitions=1,
              output=out).save(spath)
    assert main(["--config", spath]) == 0
    rows = list(csv.DictReader(open(out)))
    assert any(r["op"] == "execute_forward" for r in rows)
    assert all(r["success"] == "True" for r in rows if r["op"] == "validate")


# --------------------------------------------------------------------------
# ResultSet
# --------------------------------------------------------------------------
def _rows():
    return [Row("lib", "cpu", "64", 1, "powerof2", "float", "Outplace_Real",
                "estimate", i, "execute_forward", 2.0, 64, True, "")
            for i in range(3)] + \
           [Row("lib", "cpu", "64", 1, "powerof2", "float", "Outplace_Real",
                "estimate", 0, "validate", 0.0, 0, False, "boom")]


def test_result_set_query_and_counts():
    rs = ResultSet(_rows(), COLUMNS)
    assert len(rs) == rs.n_rows == 4 and rs.n_failures == 1
    assert len(rs.query(op="execute_forward")) == 3
    assert rs.query(op="execute_forward", run=2)[0].run == 2
    assert rs.failures()[0].error == "boom"


def test_result_set_aggregate_matches_result_writer():
    from repro.core.results import ResultWriter
    w = ResultWriter("unused.csv")
    rs = ResultSet(_rows(), COLUMNS)
    for r in _rows():
        w.add(r)
    assert rs.aggregate(op="execute_forward") == \
        w.aggregate(op="execute_forward")
    (lib, ext, prec, kind, rg, op, mean, sd, n) = rs.aggregate()[0]
    assert (lib, op, mean, n) == ("lib", "execute_forward", 2.0, 3)


def test_result_set_summary_surfaces_plan_cost():
    from repro.core.plan import PlanCacheStats
    rows = _rows() + [
        Row("lib", "cpu", "64", 1, "powerof2", "float", "Outplace_Real",
            "measure", -1, "init_forward", 40.0, 0, True, "",
            plan_cache="miss"),
        Row("lib", "cpu", "64", 1, "powerof2", "float", "Outplace_Real",
            "measure", 0, "init_forward", 1.5, 0, True, "", plan_cache="hit"),
        Row("lib", "cpu", "64", 1, "powerof2", "float", "Outplace_Real",
            "measure", 0, "init_inverse", 2.5, 0, True, "", plan_cache="hit"),
    ]
    stats = PlanCacheStats(hits=2, misses=1, cold_ms=40.0)
    s = ResultSet(rows, columns_for(True), plan_stats=stats).summary()
    assert s["rows"] == 7 and s["failures"] == 1
    assert s["plan_time_ms"] == pytest.approx(44.0)
    assert s["plan_time_cold_ms"] == pytest.approx(40.0)
    assert (s["plan_cache_hits"], s["plan_cache_misses"]) == (2, 1)
    assert s["plan_cache"] == {"hits": 2, "misses": 1, "cold_ms": 40.0}
    # without a plan cache the session-level block is absent, rest works
    s2 = ResultSet(_rows(), COLUMNS).summary()
    assert "plan_cache" not in s2 and s2["plan_time_ms"] == 0.0
    # cache off: no hit/miss markers — every init op re-plans, so the
    # whole planning time is cold, not zero
    s3 = ResultSet(_rows() + [
        Row("lib", "cpu", "64", 1, "powerof2", "float", "Outplace_Real",
            "measure", 0, "init_forward", 30.0, 0, True, "")], COLUMNS).summary()
    assert s3["plan_time_ms"] == s3["plan_time_cold_ms"] == pytest.approx(30.0)


def test_result_set_concat_and_save(tmp_path):
    a = ResultSet(_rows(), COLUMNS)
    b = ResultSet(_rows(), COLUMNS)
    both = ResultSet.concat([a, b])
    assert both.n_rows == 8 and both.n_failures == 2
    path = both.save(str(tmp_path / "all.csv"))
    data = list(csv.DictReader(open(path)))
    assert len(data) == 8 and data[0]["library"] == "lib"
    with pytest.raises(ValueError, match="different columns"):
        ResultSet.concat([a, ResultSet(_rows(), columns_for(True))])


# --------------------------------------------------------------------------
# Session
# --------------------------------------------------------------------------
TINY = SuiteSpec(clients=("XlaFFT",), extents=("16",),
                 kinds=("Outplace_Real",), precisions=("float",),
                 warmups=0, repetitions=1, output=None)


def test_session_run_in_memory_only():
    rs = run_suite(TINY)
    assert rs.path is None and rs.n_rows > 0
    assert rs.query(op="validate")[0].success
    assert rs.columns == columns_for(True)       # plan cache on by default
    assert rs.plan_stats is not None and rs.plan_stats.misses == 2


def test_session_shares_plan_cache_across_runs():
    session = Session()
    r1 = session.run(TINY)
    assert r1.plan_stats.misses == 2            # forward + inverse compiled
    r2 = session.run(TINY)
    assert r2.plan_stats.misses == 2            # nothing new compiled
    assert r2.query(op="init_forward")[0].plan_cache == "hit"


def test_session_no_plan_cache_restores_seed_schema(tmp_path):
    out = str(tmp_path / "s.csv")
    from dataclasses import replace
    rs = run_suite(replace(TINY, plan_cache=False, output=out))
    assert rs.columns == list(COLUMNS)
    with open(out) as f:
        assert f.readline().strip() == ",".join(COLUMNS)
    assert rs.path == out


def test_session_streams_to_file_and_memory(tmp_path):
    out = str(tmp_path / "s.jsonl")
    from dataclasses import replace
    rs = run_suite(replace(TINY, output=out))
    lines = [json.loads(line) for line in open(out)]
    assert len(lines) == rs.n_rows               # same rows in both places
    assert lines[-1]["op"] == rs.rows[-1].op


def test_session_runs_sweep_spec():
    spec = SuiteSpec(clients=("XlaFFT",),
                     sweeps=(SweepSpec("powerof2", rank=1,
                                       min_exp=3, max_exp=4),),
                     kinds=("Outplace_Real",), precisions=("float",),
                     warmups=0, repetitions=1, output=None)
    rs = run_suite(spec)
    assert {r.extents for r in rs.query(op="validate")} == {"8", "16"}
    assert all(r.success for r in rs.query(op="validate"))
